"""Serving engine: prefill/decode steps + continuous batching driver."""

import numpy as np
import pytest
import jax

from repro import configs
from repro.dist.sharding import Runtime
from repro.models import model as M
from repro.serve.engine import ServeConfig, ServingEngine, make_decode_step

RT = Runtime(mesh=None)


def test_engine_generates():
    cfg = configs.get_smoke("yi-9b")
    params = M.init_params(cfg, RT, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, RT, params, ServeConfig(batch=4, max_len=64))
    outs = eng.run([np.array([1, 2, 3]), np.array([9, 8])], max_new=6)
    assert len(outs) == 2
    assert all(len(o) == 7 for o in outs)   # prefill token + 6 decoded
    assert all(0 <= t < cfg.vocab for o in outs for t in o)


def test_greedy_is_deterministic():
    cfg = configs.get_smoke("olmoe-1b-7b")
    params = M.init_params(cfg, RT, jax.random.PRNGKey(1))
    sc = ServeConfig(batch=2, max_len=32)
    e1 = ServingEngine(cfg, RT, params, sc)
    e2 = ServingEngine(cfg, RT, params, sc)
    p = [np.array([5, 6, 7])]
    assert e1.run(p, max_new=5) == e2.run(p, max_new=5)


def test_encoder_only_has_no_decode():
    cfg = configs.get_smoke("hubert-xlarge")
    with pytest.raises(AssertionError, match="encoder-only"):
        make_decode_step(cfg, RT, ServeConfig(batch=1, max_len=8))


def test_ssm_decode_constant_state():
    """rwkv decode: cache holds fixed-size state regardless of history."""
    cfg = configs.get_smoke("rwkv6-7b")
    params = M.init_params(cfg, RT, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, RT, params, ServeConfig(batch=2, max_len=16))
    outs = eng.run([np.array([1, 2])], max_new=4)
    assert len(outs[0]) == 5
