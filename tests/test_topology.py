"""Topology construction invariants (paper §2.2, Appendix A)."""

import numpy as np
import pytest

from repro.core import topology as T


ALL = [
    ("slim_fly", lambda: T.slim_fly(5)),
    ("dragonfly", lambda: T.dragonfly(3)),
    ("jellyfish", lambda: T.jellyfish(60, 8, 4, seed=1)),
    ("xpander", lambda: T.xpander(8)),
    ("hyperx2", lambda: T.hyperx(2, 6)),
    ("hyperx3", lambda: T.hyperx(3, 4)),
    ("fat_tree", lambda: T.fat_tree(8)),
    ("clique", lambda: T.clique(12)),
    ("star", lambda: T.star(24)),
]


@pytest.mark.parametrize("name,make", ALL)
def test_valid_and_symmetric(name, make):
    topo = make()
    topo.validate()
    adj = np.asarray(topo.adj)
    assert (adj == adj.T).all(), "links are full-duplex/undirected"
    assert not adj.diagonal().any(), "no self-links"
    assert topo.n_endpoints == int(np.sum(topo.concentration))


def test_slim_fly_structure():
    """MMS graph for prime q: N_r = 2q^2, k' = (3q - delta)/2."""
    for q in (5, 7, 11):
        sf = T.slim_fly(q)
        assert sf.n_routers == 2 * q * q
        deg = np.asarray(sf.adj).sum(axis=1)
        assert deg.min() == deg.max(), "SF is regular"
        from repro.core.paths import diameter
        assert diameter(np.asarray(sf.adj)) == 2


def test_dragonfly_balanced():
    """Balanced DF: a = 2p = 2h, g = ah + 1 groups, one global link/pair."""
    p = 4
    df = T.dragonfly(p)
    a, h = 2 * p, p
    g = a * h + 1
    assert df.n_routers == a * g
    deg = np.asarray(df.adj).sum(axis=1)
    assert deg.max() == (a - 1) + h
    from repro.core.paths import diameter
    assert diameter(np.asarray(df.adj)) == 3


def test_xpander_regular():
    xp = T.xpander(8)
    deg = np.asarray(xp.adj).sum(axis=1)
    assert deg.min() == deg.max() == 8


def test_hyperx_structure():
    hx = T.hyperx(2, 5)
    assert hx.n_routers == 25
    deg = np.asarray(hx.adj).sum(axis=1)
    assert deg.min() == deg.max() == 2 * 4
    from repro.core.paths import diameter
    assert diameter(np.asarray(hx.adj)) == 2


def test_fat_tree_structure():
    """3-stage FT from radix-k routers: 5k^2/4 routers, k^3/4 endpoints."""
    k = 8
    ft = T.fat_tree(k)
    assert ft.n_routers == 5 * k * k // 4
    assert ft.n_endpoints == k ** 3 // 4
    from repro.core.paths import diameter
    assert diameter(np.asarray(ft.adj)) == 4


def test_equivalent_jellyfish_same_hardware():
    sf = T.slim_fly(5)
    jf = T.equivalent_jellyfish(sf, seed=0)
    assert jf.n_routers == sf.n_routers
    assert np.asarray(jf.adj).sum() <= np.asarray(sf.adj).sum()
    assert jf.n_endpoints == sf.n_endpoints


def test_edge_density_constant(sf5):
    """Paper Fig 10: cables/endpoints is O(1); SF ~ 1.7 for p = ceil(k'/2)."""
    d = sf5.edge_density
    assert 1.0 < d < 3.0


def test_by_name_dispatch():
    topo = T.by_name("sf:5")
    assert topo.n_routers == 50
    with pytest.raises((KeyError, ValueError)):
        T.by_name("nope:1")
