"""Deterministic sharded data pipeline."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, SyntheticDataset
from repro.dist.sharding import Runtime
from repro.models.config import ModelConfig


RT = Runtime(mesh=None)


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=32, n_heads=2,
                n_kv_heads=2, d_head=16, d_ff=64, vocab=256, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_determinism_across_instances():
    ds1 = SyntheticDataset(_cfg(), DataConfig(8, 64, seed=5), RT)
    ds2 = SyntheticDataset(_cfg(), DataConfig(8, 64, seed=5), RT)
    b1, b2 = ds1.batch(13), ds2.batch(13)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds1.batch(14)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_tokens_in_vocab():
    ds = SyntheticDataset(_cfg(vocab=100), DataConfig(4, 128, seed=1), RT)
    tok = np.asarray(ds.batch(0)["tokens"])
    assert tok.min() >= 0 and tok.max() < 100


def test_frontend_embeds():
    cfg = _cfg(family="audio", causal=False, frontend="audio", frontend_dim=24)
    ds = SyntheticDataset(cfg, DataConfig(4, 32, seed=0), RT)
    b = ds.batch(0)
    assert "embeds" in b and "tokens" not in b
    assert b["embeds"].shape == (4, 32, 24)


def test_bigram_structure_learnable():
    """The lm generator induces bigram structure: followers (31t+17)%V must
    be over-represented."""
    ds = SyntheticDataset(_cfg(vocab=64), DataConfig(8, 512, seed=2), RT)
    tok = np.asarray(ds.batch(0)["tokens"])
    follow = (tok[:, :-1] * 31 + 17) % 64
    rate = (tok[:, 1:] == follow).mean()
    assert rate > 0.2, rate
