"""Maximum achievable throughput via the layered MCF LP (paper §6.4)."""

import numpy as np
import pytest

from repro.core import layers as L
from repro.core import throughput as TH
from repro.core import traffic as TR
from repro.core.topology import clique, slim_fly


def test_clique_minimal_vs_layered():
    """D=1 clique: minimal routing has exactly ONE path per pair, so
    colliding permutation flows bound T at 1/max_collisions; sparse layers
    add 2-hop detours and lift T (paper §4.1: D=1 demands high diversity —
    the VLB effect)."""
    topo = clique(8)
    wl = TR.make_workload(topo, "permutation", seed=0)
    minimal = TH.mat_lp(L.build_layers(topo, 2, 1.0, seed=0), wl)
    layered = TH.mat_lp(L.build_layers(topo, 9, 0.7, seed=0), wl)
    assert minimal.throughput <= 1.0
    assert layered.throughput > minimal.throughput, "layers lift D=1 MAT"
    assert layered.throughput >= 0.45, layered


def test_layered_geq_single_layer(sf5):
    lr = L.build_layers(sf5, n_layers=5, rho=0.6, seed=0)
    wl = TR.make_workload(sf5, "adversarial", seed=1)
    multi = TH.mat_lp(lr, wl)
    single = TH.mat_single_layer(lr, wl)
    assert multi.throughput >= single.throughput - 1e-6, \
        "more layers can only help the MCF"


def test_worst_case_lower_than_permutation(sf5):
    lr = L.build_layers(sf5, n_layers=5, rho=0.6, seed=0)
    wl_p = TR.make_workload(sf5, "permutation", seed=0)
    wl_w = TR.make_workload(sf5, "worstcase", seed=0)
    tp = TH.mat_lp(lr, wl_p).throughput
    tw = TH.mat_lp(lr, wl_w).throughput
    assert tw <= tp + 1e-6, "worst-case pattern must not beat permutation"
