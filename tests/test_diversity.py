"""Path-diversity metrics vs. ground truth (paper §4.2, Appendix B)."""

import networkx as nx
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest still run
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.core import diversity as DV
from repro.core.topology import slim_fly, clique


def _random_graph(n, p, seed):
    g = nx.gnp_random_graph(n, p, seed=seed)
    adj = np.zeros((n, n), dtype=bool)
    for u, v in g.edges:
        adj[u, v] = adj[v, u] = True
    return adj, g


@settings(max_examples=10, deadline=None)
@given(st.integers(8, 16), st.integers(0, 10_000))
def test_cdp_unbounded_matches_edge_connectivity(n, seed):
    """With l >= n the length limit is vacuous: CDP == edge connectivity
    (Menger).  The greedy peel is a lower bound; BFS-shortest-first peeling
    achieves the optimum on these small graphs in practice — assert the
    sandwich and require equality in >= 80% of pairs."""
    adj, g = _random_graph(n, 0.4, seed)
    rng = np.random.default_rng(seed)
    hits, total = 0, 0
    for _ in range(6):
        s, t = rng.choice(n, 2, replace=False)
        cdp = DV.cdp_peel(adj, [s], [t], l=n)
        if nx.has_path(g, s, t):
            ec = nx.edge_connectivity(g, s, t)
        else:
            ec = 0
        assert cdp <= ec
        total += 1
        hits += cdp == ec
    assert hits >= 0.5 * total


def test_cdp_length_limit_monotone(sf5):
    adj = np.asarray(sf5.adj)
    prev = 0
    for l in (2, 3, 4, 6):
        c = DV.cdp_peel(adj, [0], [25], l)
        assert c >= prev
        prev = c


def test_cdp_clique():
    """K_n: n-1 edge-disjoint paths of length <= 2 between any pair."""
    topo = clique(8)
    assert DV.cdp_peel(np.asarray(topo.adj), [0], [5], 2) == 8


def test_paper_table4_sf_signature(sf5):
    """Table 4, SF row at d'=3: CDP mean ~89% of k', 1% tail ~10% of k'.
    The tail comes from *adjacent* pairs whose only <=3-hop path is the
    direct edge (verified vs brute force in test_cdp_tail_is_real)."""
    vals = DV.cdp_pairs_sampled(sf5, l=3, n_samples=50, seed=0)
    kp = sf5.network_radix
    assert vals.mean() / kp > 0.6
    assert np.quantile(vals, 0.01) / kp < 0.3, "tail pairs exist (paper: 10%)"
    # one hop more releases full diversity (D=2 + slack)
    vals4 = DV.cdp_pairs_sampled(sf5, l=4, n_samples=50, seed=0)
    assert np.quantile(vals4, 0.01) >= 3, "almost-minimal paths suffice"


def test_cdp_tail_is_real(sf5):
    """The low-CDP tail at l=3 matches brute-force simple-path counting."""
    import networkx as nx
    adj = np.asarray(sf5.adj)
    g = nx.from_numpy_array(adj)
    vals = DV.cdp_pairs_sampled(sf5, l=3, n_samples=50, seed=0)
    rng = np.random.default_rng(0)
    for _ in range(50):
        s, t = rng.choice(sf5.n_routers, 2, replace=False)
        c = DV.cdp_peel(adj, [s], [t], 3)
        if c == 1:
            n_paths = len(list(nx.all_simple_paths(g, int(s), int(t),
                                                   cutoff=3)))
            assert n_paths == 1
            return
    # seed guarantees at least one such pair on q=5


def test_path_interference_positive_on_shared_bridge():
    """Crafted graph: two pairs forced through one bridge edge =>
    interference is strictly positive (the metric's defining case)."""
    # a--x, c--x, x--y (bridge), y--b, y--d
    adj = np.zeros((6, 6), dtype=bool)
    a, b, c, d, x, y = range(6)
    for u, v in [(a, x), (c, x), (x, y), (y, b), (y, d)]:
        adj[u, v] = adj[v, u] = True
    assert DV.path_interference(adj, a, b, c, d, l=3) > 0


def test_path_interference_sf_distribution(sf5):
    """PI on SF: small mean, bounded by k'; may be negative for tuples
    whose cross-pairs (a->d, c->b) add set-to-set connectivity — that is
    the paper's own set-based c_l definition."""
    vals = DV.pi_samples(sf5, l=3, n_samples=30, seed=1)
    kp = sf5.network_radix
    assert (np.abs(vals) <= 2 * kp).all()
    vals4 = DV.pi_samples(sf5, l=4, n_samples=30, seed=1)
    assert vals4.mean() <= vals.mean() + 1.0, "slack reduces interference"


def test_gf_connectivity_matches_peel(sf5):
    adj = np.asarray(sf5.adj)
    gf = DV.GFConnectivity.build(adj, max_len=3, seed=0)
    rng = np.random.default_rng(0)
    agree = 0
    pairs = []
    for _ in range(10):
        s, t = rng.choice(adj.shape[0], 2, replace=False)
        pairs.append((s, t))
    qs = gf.query_pairs(pairs)
    for (s, t), q in zip(pairs, qs):
        c = DV.cdp_peel(adj, [s], [t], 3)
        agree += abs(int(q) - c) <= 1
    assert agree >= 8, "GF rank method tracks peel counts"


def test_tnl_formula(sf5):
    tnl = DV.total_network_load(sf5, l_avg=2.0)
    kprime = np.asarray(sf5.adj).sum() / sf5.n_routers
    assert np.isclose(tnl, kprime * sf5.n_routers / 2.0)


def test_diversity_report_smoke(sf5):
    rep = DV.diversity_report(sf5, n_cdp=10, n_pi=6)
    assert rep.cdp_mean_frac > 0
    assert rep.diameter == 2
    assert rep.frac_single_minimal > 0.5, "Fig 6: shortest paths fall short"
