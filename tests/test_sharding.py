"""Runtime layout contract: single-device no-ops + tp_disabled folding.

The mesh=None half runs in-process on the real single CPU device; the
tp_disabled half needs an 8-device mesh and follows the subprocess
pattern of test_collectives.py.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp

from repro.dist.sharding import P, Runtime

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")


def test_mesh_none_helpers_are_noops():
    rt = Runtime(mesh=None)
    assert rt.tp_size == 1 and rt.fsdp_size == 1
    assert not rt.tp
    assert rt.fsdp is None
    # spec builders resolve every logical entry to replicated
    assert rt.spec("fsdp", None) == P(None, None)
    assert rt.spec_div(("fsdp", "tp", None), (4, 6, 8)) == P(None, None, None)
    # placement helpers are identity (no constraint inserted, same object)
    x = jnp.ones((4, 6))
    assert rt.shard(x, "fsdp", "tp") is x
    assert rt.shard_spec(x, P(None, None)) is x
    assert rt.tree_sharding({"w": P(None)}) is None
    fn = lambda v: v  # noqa: E731
    assert rt.shard_map(fn, in_specs=P(), out_specs=P()) is fn


def test_astype_uses_collective_dtype():
    rt = Runtime(mesh=None, collective_dtype="bfloat16")
    assert rt.astype(jnp.ones((2,), jnp.float32)).dtype == jnp.bfloat16
    rt32 = Runtime(mesh=None, collective_dtype="float32")
    assert rt32.astype(jnp.ones((2,), jnp.bfloat16)).dtype == jnp.float32


_PROG = textwrap.dedent("""
    import jax
    from repro.dist.sharding import P, Runtime

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    rt = Runtime(mesh=mesh, data_axes=("data",))
    assert rt.tp == "model" and rt.tp_size == 4 and rt.fsdp_size == 2
    assert rt.spec_div(("fsdp", "tp"), (16, 8)) == P("data", "model")
    # divide-or-replicate: 6 % 4 != 0 drops the tp entry
    assert rt.spec_div(("fsdp", "tp"), (16, 6)) == P("data", None)

    # tp_disabled folds the model axis into the data axes whether or not
    # the caller lists it explicitly
    for axes in (("data",), ("data", "model")):
        fs = Runtime(mesh=mesh, data_axes=axes, tp_disabled=True)
        assert fs.tp == False, fs.tp
        assert fs.tp_size == 1
        assert fs.fsdp_size == 8, fs.fsdp_size
        assert fs.fsdp_axes == ("data", "model")
        assert fs.spec_div(("fsdp", "tp"), (16, 8)) == \\
            P(("data", "model"), None)
    print("SHARDING_OK")
""")


def test_tp_disabled_folds_model_axis_into_fsdp():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=300,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": _SRC, "PATH": "/usr/bin:/bin"})
    assert "SHARDING_OK" in r.stdout, r.stderr[-2000:]
