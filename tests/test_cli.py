"""Experiments CLI: error paths (unknown names, out-of-whitelist
parameters), ``list`` output, single-cell ``run``, artifact ``diff``."""

import json

import pytest

from repro.experiments.__main__ import main

QUICK = ["--evaluator", "transport(steps=30)"]


# ---- error paths ------------------------------------------------------------
def test_unknown_topology_exits_2(capsys):
    rc = main(["sweep", "--topos", "notatopo", "--schemes", "ecmp",
               "--patterns", "uniform", "--quick"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "unknown topology" in err and "notatopo" in err
    assert "sf" in err                      # lists the valid options


def test_unknown_scheme_exits_2(capsys):
    rc = main(["run", "--topo", "clique(k=6)", "--scheme", "ospf",
               "--pattern", "uniform", *QUICK])
    assert rc == 2
    assert "unknown routing scheme" in capsys.readouterr().err


def test_out_of_whitelist_parameter_exits_2(capsys):
    rc = main(["run", "--topo", "clique(k=6)",
               "--scheme", "fatpaths(layers=9)",     # 'n_layers', not 'layers'
               "--pattern", "uniform", *QUICK])
    assert rc == 2
    err = capsys.readouterr().err
    assert "no parameter" in err and "n_layers" in err


def test_malformed_spec_exits_2(capsys):
    rc = main(["run", "--topo", "sf(q=5", "--scheme", "ecmp",
               "--pattern", "uniform", *QUICK])
    assert rc == 2
    assert "error:" in capsys.readouterr().err


# ---- list -------------------------------------------------------------------
def test_list_covers_registered_axes(capsys):
    assert main(["list"]) == 0
    out = capsys.readouterr().out
    for section in ("topologies:", "routing schemes:", "traffic patterns:",
                    "evaluators:"):
        assert section in out
    for name in ("sf(", "fatpaths(", "adversarial(", "transport("):
        assert name in out
    assert "n_layers=9" in out              # defaults are shown


# ---- run: one cell ----------------------------------------------------------
def test_run_single_cell_emits_runresult_json(capsys, tmp_path):
    out_json = str(tmp_path / "cell.json")
    rc = main(["run", "--topo", "clique(k=6)", "--scheme", "ecmp(n=2)",
               "--pattern", "uniform", "--evaluator", "transport(steps=30)",
               "--seed", "3", "--json", out_json])
    assert rc == 0
    d = json.loads(capsys.readouterr().out)
    assert d["topo"] == "clique(k=6)" and d["seed"] == 3
    assert d["metrics"]["finished"] > 0
    [on_disk] = json.load(open(out_json))
    assert on_disk == d


def test_run_quick_caps_unpinned_steps(capsys):
    rc = main(["run", "--topo", "clique(k=6)", "--scheme", "ecmp(n=2)",
               "--pattern", "uniform", "--evaluator", "transport(steps=25)",
               "--quick"])
    assert rc == 0
    d = json.loads(capsys.readouterr().out)
    assert d["evaluator"] == "transport(steps=25)"   # pinned steps survive


# ---- sweep --filter ----------------------------------------------------------
def test_sweep_filter_runs_matching_subset(capsys):
    rc = main(["sweep", "--topos", "clique(k=6)",
               "--schemes", "ecmp(n=2),fatpaths(n_layers=3)",
               "--patterns", "uniform",
               "--evaluators", "transport(steps=30)",
               "--filter", "fatpaths"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "1 of 2 cell(s)" in out
    assert "fatpaths" in out
    assert "# 1 cells" in out               # only the matching cell ran


def test_sweep_filter_no_match_exits_2_with_cell_list(capsys):
    rc = main(["sweep", "--topos", "clique(k=6)",
               "--schemes", "ecmp(n=2),fatpaths(n_layers=3)",
               "--patterns", "uniform",
               "--evaluators", "transport(steps=30)",
               "--filter", "nosuchcell"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "matches none of the 2 grid cell(s)" in err
    # the full cell list is printed so the user can fix the filter
    assert "clique(k=6)/ecmp(n=2)/uniform/transport(steps=30)@s0" in err
    assert "clique(k=6)/fatpaths(n_layers=3)/uniform/transport(steps=30)@s0" \
        in err


# ---- diff -------------------------------------------------------------------
@pytest.fixture()
def artifact(tmp_path):
    path = str(tmp_path / "sweep.json")
    rc = main(["sweep", "--topos", "clique(k=6)", "--schemes", "ecmp(n=2)",
               "--patterns", "uniform", "--evaluators", "transport(steps=30)",
               "--json", path])
    assert rc == 0
    return path


def test_diff_identical_and_differing(artifact, capsys, tmp_path):
    assert main(["diff", artifact, artifact]) == 0
    assert "identical" in capsys.readouterr().out

    mutated = json.load(open(artifact))
    mutated[0]["metrics"]["fct_p50_us"] += 1.0
    other = str(tmp_path / "other.json")
    json.dump(mutated, open(other, "w"))
    assert main(["diff", artifact, other]) == 1
    cap = capsys.readouterr()
    assert "fct_p50_us" in cap.out and "difference" in cap.err
