"""Multi-ring (layered) collective schedules == psum, on 8 forced host
devices in a subprocess (keeps this session single-device)."""

import subprocess
import sys
import textwrap

import pytest

_PROG = textwrap.dedent("""
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.dist.collectives import (multiring_all_reduce, layer_strides,
                                        ring_reduce_scatter, ring_all_gather)
    mesh = jax.make_mesh((8,), ("data",))
    x = (jnp.arange(8 * 53, dtype=jnp.float32).reshape(8, 53) * 0.37) - 11.0

    for n_rings in (1, 2, 3, 5):
        strides = layer_strides(8, n_rings)
        def inner(v):
            v = v.reshape(v.shape[1:])
            return multiring_all_reduce(v, "data", strides)[None]
        f = jax.jit(jax.shard_map(inner, mesh=mesh, in_specs=P("data"),
                                  out_specs=P("data")))
        out = np.asarray(f(x))
        expect = np.asarray(x.sum(0))
        assert np.allclose(out, expect[None].repeat(8, 0), rtol=1e-5, atol=1e-4), \\
            (n_rings, np.abs(out - expect).max())

    # reduce-scatter/all-gather pair with a non-unit stride
    def inner2(v):
        v = v.reshape(v.shape[1:])
        rs = ring_reduce_scatter(v, "data", 5)
        return ring_all_gather(rs, "data", 5, chunk_offset=5)[None]
    y = jnp.arange(8 * 24, dtype=jnp.float32).reshape(8, 24)
    g = jax.jit(jax.shard_map(inner2, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data")))
    out2 = np.asarray(g(y))
    assert np.allclose(out2, np.asarray(y.sum(0))[None].repeat(8, 0))

    # HLO contains one ppermute chain per ring
    hlo = jax.jit(jax.shard_map(inner, mesh=mesh, in_specs=P("data"),
                                out_specs=P("data"))).lower(x).compile().as_text()
    n = hlo.count("collective-permute(") + hlo.count("collective-permute-start(")
    assert n >= 5 * 2 * 7, n   # last loop: 5 rings x 2(n-1) steps
    print("COLLECTIVES_OK")
""")


def test_multiring_allreduce_equals_psum():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=300,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "COLLECTIVES_OK" in r.stdout, r.stderr[-2000:]


def test_layer_strides_coprime():
    import math
    from repro.dist.collectives import layer_strides
    for n in (4, 8, 16, 32, 256):
        for s in layer_strides(n, 4):
            assert math.gcd(s, n) == 1
    assert layer_strides(16, 3) == (1, 3, 5)
