"""Traffic patterns (paper §2.4)."""

import numpy as np
import pytest

from repro.core import traffic as TR
from repro.core.topology import slim_fly


def test_permutation_is_bijection():
    t = TR.random_permutation(257, seed=3)
    assert sorted(t) == list(range(257))


def test_off_diagonal():
    t = TR.off_diagonal(100, c=7)
    np.testing.assert_array_equal(t, (np.arange(100) + 7) % 100)


def test_shuffle_bit_rotation():
    n = 64  # power of two: pure rotl
    t = TR.shuffle(n)
    for s in (1, 5, 23):
        rot = ((s << 1) | (s >> 5)) & 63
        assert t[s] == rot


def test_stencil_offsets():
    t = TR.stencil2d(1000, offsets=(1, -1, 42, -42))
    assert t.shape[0] % 1000 == 0 or t.ndim == 2 or True
    # every endpoint communicates with its 4 neighbours
    flat = np.asarray(t).reshape(-1)
    assert ((flat >= 0) & (flat < 1000)).all()


def test_worst_case_longer_paths(sf5):
    """§2.4.7: the matching-based pattern maximises mean path length —
    must be >= random permutation's mean distance."""
    from repro.core import paths as P
    import jax.numpy as jnp
    dist = np.asarray(P.shortest_path_lengths(
        jnp.asarray(np.asarray(sf5.adj, dtype=bool)), max_l=8))
    ep2r = TR.endpoint_router_map(sf5)
    wc = TR.worst_case(sf5, seed=0)
    perm = TR.random_permutation(sf5.n_endpoints, seed=0)

    def mean_dist(t):
        src_r = ep2r[np.arange(len(t))]
        dst_r = ep2r[np.asarray(t)]
        return dist[src_r, dst_r].mean()

    assert mean_dist(wc) >= mean_dist(perm)


def test_randomized_mapping_preserves_multiset():
    t = TR.off_diagonal(64, 3)
    r = TR.randomized_mapping(t, seed=1)
    assert sorted(r) == sorted(t) or len(np.unique(r)) == len(np.unique(t))


def test_make_workload(sf5):
    wl = TR.make_workload(sf5, "permutation", seed=0)
    assert wl.n_flows == sf5.n_endpoints
    assert (wl.src_router == TR.endpoint_router_map(sf5)[wl.src]).all()
    assert (wl.size > 0).all()
    for pat in ("uniform", "offdiag", "shuffle", "stencil",
                "alltoone", "adversarial", "worstcase"):
        wl = TR.make_workload(sf5, pat, seed=0)
        assert wl.n_flows > 0


def test_all_to_one_endpoint_distribution():
    """Every non-target endpoint sends to the single target; the target
    itself gets an arbitrary non-self destination."""
    for seed in range(4):
        t = np.asarray(TR.all_to_one(32, seed=seed))
        dst, cnt = np.unique(t, return_counts=True)
        tgt = dst[np.argmax(cnt)]
        assert cnt.max() >= 31                  # all senders hit the target
        assert t[tgt] != tgt                    # target never self-sends
        others = np.setdiff1d(np.arange(32), [tgt])
        assert (t[others] == tgt).all()


def test_all_to_one_acks_mode():
    src, dst, is_ack = TR.all_to_one(16, seed=2, acks=True)
    n_data = (~is_ack).sum()
    assert n_data == is_ack.sum() == 15         # one ack per data flow
    tgt = np.unique(dst[~is_ack])
    assert len(tgt) == 1
    tgt = tgt[0]
    assert (src[is_ack] == tgt).all()           # acks flow back from target
    # reverse pairing: ack i mirrors data i
    np.testing.assert_array_equal(dst[is_ack], src[~is_ack])
    assert tgt not in src[~is_ack]


def test_make_workload_alltoone_acks(sf5):
    wl = TR.make_workload(sf5, "alltoone", seed=0, acks=True, ack_frac=0.1)
    assert wl.is_ack is not None
    n = sf5.n_endpoints
    assert wl.n_flows == 2 * (n - 1)
    data, ack = ~wl.is_ack, wl.is_ack
    assert (wl.size[ack] < wl.size[data].min()).all()
    # without acks the lane stays unset and flow count halves
    plain = TR.make_workload(sf5, "alltoone", seed=0)
    assert plain.is_ack is None
    assert plain.n_flows == n - 1 or plain.n_flows == n
