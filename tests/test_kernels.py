"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per the task spec: shape/dtype sweeps with assert_allclose against ref.py.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gfmm import gf_matmul
from repro.kernels.pathcount import pathcount_matmul


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 384),
                                   (128, 256, 128), (384, 384, 256)])
def test_pathcount_shapes(m, k, n):
    rng = np.random.default_rng(m + k + n)
    a = jnp.asarray(rng.random((m, k), dtype=np.float32))
    b = jnp.asarray(rng.random((k, n), dtype=np.float32))
    out = pathcount_matmul(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.pathcount_ref(a, b)),
                               rtol=1e-5)


def test_pathcount_saturates():
    a = jnp.full((128, 128), 1e30, jnp.float32)
    out = pathcount_matmul(a, a, interpret=True)
    assert np.isfinite(np.asarray(out)).all(), "saturating matmul must not inf"


@pytest.mark.parametrize("m,k,n,p", [(128, 128, 128, 1009),
                                     (256, 128, 128, 1009),
                                     (128, 384, 256, 127)])
def test_gfmm_shapes(m, k, n, p):
    rng = np.random.default_rng(m * k + n)
    a = jnp.asarray(rng.integers(0, p, (m, k)), dtype=jnp.int32)
    b = jnp.asarray(rng.integers(0, p, (k, n)), dtype=jnp.int32)
    out = gf_matmul(a, b, p=p, interpret=True)
    expect = ref.gf_matmul_ref(a, b, p)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,hkv,s,d", [(1, 4, 4, 128, 64),
                                         (2, 4, 2, 256, 64),
                                         (1, 8, 1, 128, 128)])
def test_flash_attention_gqa(b, h, hkv, s, d, causal):
    rng = np.random.default_rng(h * s + d)
    q = jnp.asarray(rng.standard_normal((b, h, s, d), dtype=np.float32))
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d), dtype=np.float32))
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d), dtype=np.float32))
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_window(window):
    rng = np.random.default_rng(window)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 64), dtype=np.float32))
    out = flash_attention(q, q, q, causal=True, window=window, interpret=True)
    expect = ref.attention_ref(q, q, q, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_softcap():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64), dtype=np.float32))
    out = flash_attention(q, q, q, causal=True, softcap=30.0, interpret=True)
    expect = ref.attention_ref(q, q, q, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype=jnp.bfloat16)
    out = flash_attention(q, q, q, causal=True, interpret=True)
    expect = ref.attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(expect, dtype=np.float32),
                               rtol=5e-2, atol=5e-2)


def test_ops_wrappers():
    """ops.py jit wrappers dispatch to interpret kernels on CPU."""
    from repro.kernels import ops
    adj = jnp.asarray(np.eye(128, k=1, dtype=np.float32))
    out = ops.path_counts_power(adj, 3)
    expect = np.linalg.matrix_power(np.asarray(adj), 3)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6)
