"""repro.experiments: spec grammar, registries, session caching, results."""

import dataclasses
import json

import numpy as np
import pytest

from repro.experiments import (EVALUATORS, ROUTINGS, TOPOLOGIES, TRAFFIC,
                               ExperimentSpec, RunResult, Session, Spec,
                               SpecError, results_from_json, results_to_json,
                               split_spec_list, topo_spec)

QUICK_EV = "transport(steps=30)"


@pytest.fixture(scope="module")
def session():
    return Session()


# ---- spec grammar -----------------------------------------------------------
def test_spec_parse_format_roundtrip():
    for text in ("sf(q=19)", "fatpaths(n_layers=9,rho=0.6)", "ecmp(n=8)",
                 "adversarial", "transport(steps=400,transport=tcp)",
                 "jfeq(of=sf(q=5),seed=1)", "hx(l=2,s=6)"):
        spec = Spec.parse(text)
        assert Spec.parse(spec.format()) == spec
        assert Spec.parse(spec.format()).format() == spec.format()


def test_spec_canonical_order_and_types():
    a = Spec.parse("fatpaths(rho=0.6,n_layers=9)")
    b = Spec.parse("fatpaths(n_layers=9,rho=0.6)")
    assert a == b and hash(a) == hash(b)
    assert a.format() == "fatpaths(n_layers=9,rho=0.6)"
    kw = Spec.parse("x(a=3,b=0.5,c=true,d=false,e=none,f=tcp)").kw
    assert kw == {"a": 3, "b": 0.5, "c": True, "d": False, "e": None,
                  "f": "tcp"}
    assert isinstance(kw["a"], int) and not isinstance(kw["a"], bool)
    # nested spec values survive the round trip as strings
    nested = Spec.parse("jfeq(of=sf(q=5))")
    assert nested.kw["of"] == "sf(q=5)"


@pytest.mark.parametrize("bad", ["sf(q=19", "sf q=19)", "sf(q)", "sf(=3)",
                                 "sf(q=3,q=4)", "", "sf(q=)", "3sf"])
def test_spec_parse_rejects_malformed(bad):
    with pytest.raises(SpecError):
        Spec.parse(bad)


def test_split_spec_list_respects_parens():
    assert split_spec_list("ecmp(n=4),fatpaths(n_layers=9,rho=0.6),sf") == \
        ["ecmp(n=4)", "fatpaths(n_layers=9,rho=0.6)", "sf"]


def test_compact_topo_specs():
    assert topo_spec("sf:11") == Spec.parse("sf(q=11)")
    assert topo_spec("hx:2x6") == Spec.parse("hx(l=2,s=6)")
    with pytest.raises(SpecError):
        topo_spec("nope:3")


# ---- registry rejection -----------------------------------------------------
def test_registries_reject_unknown_names(session):
    with pytest.raises(SpecError, match="unknown topology"):
        session.topology("notatopo")
    with pytest.raises(SpecError, match="unknown routing scheme"):
        session.routing("clique(k=4)", "ospf")
    with pytest.raises(SpecError, match="unknown traffic pattern"):
        session.workload("clique(k=4)", "elephants")
    with pytest.raises(SpecError, match="unknown evaluator"):
        session.run("clique(k=4)", "ecmp(n=2)", "uniform", "htsim")


def test_registries_reject_unknown_params(session):
    with pytest.raises(SpecError, match="no parameter"):
        session.topology("sf(qq=5)")
    with pytest.raises(SpecError, match="no parameter"):
        session.routing("clique(k=4)", "fatpaths(layers=9)")


def test_registry_listings_cover_the_matrix():
    assert {"sf", "df", "jf", "xp", "hx", "ft"} <= set(TOPOLOGIES.names())
    assert {"ecmp", "letflow", "fatpaths", "minimal"} <= set(ROUTINGS.names())
    assert {"adversarial", "shuffle", "permutation"} <= set(TRAFFIC.names())
    assert {"transport", "mat", "fabric"} <= set(EVALUATORS.names())


# ---- session caching --------------------------------------------------------
def test_session_never_rebuilds_layer_stacks():
    s = Session()
    grid = s.sweep(topos=["clique(k=6)"],
                   routings=["fatpaths(n_layers=3)", "ecmp(n=2)",
                             "letflow(n=2)"],
                   patterns=["uniform", "adversarial"],
                   evaluators=[QUICK_EV], seeds=[0])
    assert len(grid) == 6
    # one fatpaths layer stack + ONE table stack shared by ecmp & letflow
    assert s.stats["stack_build"] == 2
    before = s.stats["stack_build"]
    s.sweep(topos=["clique(k=6)"],
            routings=["fatpaths(n_layers=3)", "letflow(n=2)"],
            patterns=["uniform"], evaluators=[QUICK_EV], seeds=[0])
    assert s.stats["stack_build"] == before          # all cache hits
    # a different seed is a different stack
    s.run("clique(k=6)", "fatpaths(n_layers=3)", "uniform", QUICK_EV, seed=1)
    assert s.stats["stack_build"] == before + 1


def test_fabric_shares_session_layer_stack():
    s = Session()
    bundle = s.routing("clique(k=6)", "fatpaths(n_layers=9,rho=0.6)")
    fb = s.fabric("clique(k=6)", n_layers=9, rho=0.6)
    assert fb.layers is bundle.routing          # same object, not a rebuild
    assert s.stats["stack_build"] == 2          # layers + fabric's tables


def test_default_and_explicit_specs_share_cache():
    s = Session()
    assert s.topology("clique") is s.topology("clique(k=12)")
    s.workload("clique", "uniform")
    s.workload("clique(k=12)", "uniform(rounds=1)")
    assert s.stats["workload_build"] == 1
    s.routing("clique", "ecmp", seed=0)
    s.routing("clique(k=12)", "ecmp(n=8)", seed=0)
    assert s.stats["stack_build"] == 1


def test_fabric_evaluator_uses_the_cells_own_stack():
    s = Session()
    rr = s.run("clique(k=6)", "minimal(n_layers=3)", "uniform", "fabric")
    # only the cell's minimal stack was built — no shadow FatPaths stack,
    # no unused ECMP table stack
    assert s.stats["stack_build"] == 1
    fb = s.bundle_fabric("clique(k=6)", "minimal(n_layers=3)")
    assert fb.layers is s.routing("clique(k=6)", "minimal(n_layers=3)").routing
    assert rr.meta["fabric_scheme"] == "fatpaths"   # flowlet balancing
    # ablation is real: the minimal fabric exposes fewer candidate links
    # than the non-minimal default on an adversarial-ish pattern
    assert fb.layers.n_layers == 3


def test_run_rejects_spec_plus_extra_args():
    s = Session()
    spec = ExperimentSpec.make("clique(k=6)", "ecmp(n=2)", "uniform",
                               QUICK_EV)
    with pytest.raises(ValueError, match="no other arguments"):
        s.run(spec, seed=3)
    with pytest.raises(ValueError, match="no other arguments"):
        s.run(spec, evaluator="mat")
    assert s.run(spec).metrics["finished"] >= 0     # bare spec still fine


def test_workloads_and_topologies_cached(session):
    w1 = session.workload("clique(k=6)", "uniform", seed=0)
    w2 = session.workload("clique(k=6)", "uniform", seed=0)
    assert w1 is w2
    assert session.topology("clique(k=6)") is session.topology("clique(k=6)")


# ---- results ----------------------------------------------------------------
def test_run_result_json_roundtrip(session):
    rr = session.run("clique(k=6)", "ecmp(n=2)", "uniform", QUICK_EV)
    assert rr.metrics["finished"] > 0           # sanity: flows completed
    back = RunResult.from_json(rr.to_json())
    assert back == rr
    assert json.loads(rr.to_json())["metrics"] == rr.metrics
    many = results_from_json(results_to_json([rr, rr]))
    assert many == [rr, rr]


def test_run_result_records_cell_and_tables(session):
    rr = session.run("clique(k=6)", "fatpaths(n_layers=3)", "adversarial",
                     QUICK_EV, seed=2)
    assert rr.topo == "clique(k=6)"
    assert rr.routing == "fatpaths(n_layers=3)"
    assert rr.seed == 2
    assert rr.meta["table_exact"] > 0
    assert rr.meta["table_prefix"] <= rr.meta["table_exact"]
    assert rr.wall_s > 0
    assert "clique(k=6)/fatpaths(n_layers=3)/adversarial" in rr.cell_id


def test_mat_and_fabric_evaluators(session):
    mat = session.run("clique(k=6)", "fatpaths(n_layers=3)",
                      "permutation(frac=0.8)", "mat")
    assert mat.metrics["mat_T"] > 0
    assert mat.metrics["mat_T_single"] <= mat.metrics["mat_T"] + 1e-9
    fab = session.run("clique(k=6)", "fatpaths(n_layers=3)", "alltoone",
                      "fabric")
    assert fab.metrics["bottleneck_mb"] > 0
    assert fab.meta["fabric_scheme"] == "fatpaths"


# ---- vmap seed sweep --------------------------------------------------------
def test_simulate_seeds_matches_sequential(session):
    from repro.core import transport as TP

    topo = session.topology("clique(k=6)")
    bundle = session.routing("clique(k=6)", "letflow(n=2)")
    wl = session.workload("clique(k=6)", "uniform")
    cfg = TP.SimConfig(balancing=bundle.balancing, n_steps=40)
    batch = TP.simulate_seeds(topo, bundle.routing, wl, cfg, [0, 7])
    for res, seed in zip(batch, [0, 7]):
        single = TP.simulate(topo, bundle.routing, wl,
                             dataclasses.replace(cfg, seed=seed))
        np.testing.assert_allclose(res.fct, single.fct, rtol=1e-6)
        assert (res.finished == single.finished).all()
        assert res.config.seed == seed
    assert TP.simulate_seeds(topo, bundle.routing, wl, cfg, []) == []


# ---- the grid acceptance shape ---------------------------------------------
def test_sweep_grid_shape_and_ids():
    s = Session()
    rs = s.sweep(topos=["clique(k=6)", "star(n=8)"],
                 routings=["ecmp(n=2)", "fatpaths(n_layers=3)"],
                 patterns=["uniform"], evaluators=[QUICK_EV], seeds=[0, 1])
    assert len(rs) == 8
    assert len({r.cell_id for r in rs}) == 8
    for r in rs:
        assert set(r.metrics) >= {"fct_p50_us", "fct_p99_us", "finished"}


def test_experiment_spec_make():
    e = ExperimentSpec.make("sf(q=5)", "ecmp", "uniform", seed=4)
    assert e.topo == Spec.parse("sf(q=5)") and e.seed == 4
    assert "sf(q=5)/ecmp/uniform/transport@s4" == e.cell_id


# ---- build-time accounting (batched semiring builds) ------------------------
def test_run_result_reports_build_split():
    """RunResult.meta exposes the build-vs-simulate split and the cache
    hit/miss counters; Session.stats accumulates the wall-time totals."""
    s = Session()
    rr = s.run("sf", "fatpaths(n_layers=3)", "uniform", QUICK_EV)
    assert rr.meta["cache_builds"] >= 1
    assert rr.meta["cache_hits"] == 0
    assert rr.meta["build_s"] > 0
    assert rr.meta["build_device_s"] >= 0
    # second identical cell: everything cached, no new build time
    rr2 = s.run("sf", "fatpaths(n_layers=3)", "uniform", QUICK_EV)
    assert rr2.meta["cache_builds"] == 0
    assert rr2.meta["cache_hits"] >= 1
    assert rr2.meta["build_s"] == 0.0
    assert s.stats["build_wall_s"] > 0
    assert s.stats["build_device_s"] > 0
    # the split round-trips through the canonical JSON record
    back = RunResult.from_json(rr.to_json())
    assert back.meta["build_s"] == rr.meta["build_s"]
    assert back.meta["cache_builds"] == rr.meta["cache_builds"]
