"""Fused water-filling transport step: Pallas kernel (interpret=True) vs
jnp oracle, feasibility invariants, and the adaptive scan horizon's
early-exit == full-horizon guarantee."""

import dataclasses
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest still run
    from _hypothesis_stub import given, settings, st  # noqa: F401

import jax
import jax.numpy as jnp

from repro.kernels import kernel_backend, ref
from repro.kernels.waterfill import waterfill_step

# Ragged (F, S, E) instances: tile multiples AND odd remainders in both
# the flow and link grid dimensions (kernel tiles are bf=128, be=512).
SHAPES = [(7, 3, 19), (128, 7, 512), (200, 7, 751), (1, 5, 33),
          (130, 9, 513), (256, 4, 1024)]


def _instance(f, s, e, seed, idle_frac=0.25):
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, e - 1, (f, s)).astype(np.int32)
    edges[rng.random((f, s)) < 0.3] = e - 1          # trash-padded slots
    w = (rng.random(f) >= idle_frac).astype(np.float32)
    edges[w == 0] = e - 1                            # inert flows: all trash
    desired = rng.random(f).astype(np.float32) * w
    cap = np.ones(e, np.float32)
    return (jnp.asarray(edges), jnp.asarray(w), jnp.asarray(desired),
            jnp.asarray(cap))


@pytest.mark.parametrize("f,s,e", SHAPES)
@pytest.mark.parametrize("fair_iters", [0, 1, 2])
def test_kernel_matches_oracle(f, s, e, fair_iters):
    edges, w, desired, cap = _instance(f, s, e, seed=f * s + e)
    sent, share = waterfill_step(edges, w, desired, cap,
                                 fair_iters=fair_iters, backend="pallas",
                                 interpret=True)
    sent_r, share_r = ref.waterfill_ref(edges, w, desired, cap,
                                        fair_iters=fair_iters)
    np.testing.assert_allclose(np.asarray(sent), np.asarray(sent_r),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(share), np.asarray(share_r),
                               rtol=1e-5)


@pytest.mark.parametrize("f,s,e", [(7, 3, 19), (130, 9, 513)])
def test_active_lane_matches_oracle_and_masking(f, s, e):
    """The dynamic-traffic active lane: kernel == oracle under a mixed
    active mask, inactive rows send nothing and see an uncongested
    network (+inf share), and active=all-True == active=None bitwise.
    Raw -1 walk padding in the edge tensor must be tolerated."""
    edges, w, desired, cap = _instance(f, s, e, seed=e, idle_frac=0.0)
    edges = np.array(edges)               # writable copy
    rng = np.random.default_rng(5)
    edges[rng.random((f, s)) < 0.2] = -1          # raw walk padding
    edges = jnp.asarray(edges)
    active = jnp.asarray(rng.random(f) < 0.6)
    sent_k, share_k = waterfill_step(edges, w, desired, cap,
                                     active=active, backend="pallas",
                                     interpret=True)
    sent_r, share_r = ref.waterfill_ref(edges, w, desired, cap,
                                        active=active)
    np.testing.assert_allclose(np.asarray(sent_k), np.asarray(sent_r),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(share_k), np.asarray(share_r),
                               rtol=1e-5)
    inact = ~np.asarray(active)
    assert (np.asarray(sent_r)[inact] == 0).all()
    assert np.isposinf(np.asarray(share_r)[inact]).all()
    # all-active lane is bitwise the no-lane path (closed-loop reduction)
    e2 = jnp.where(edges >= 0, edges, e - 1)
    s_all, sh_all = ref.waterfill_ref(e2, w, desired, cap,
                                      active=jnp.ones(f, bool))
    s_none, sh_none = ref.waterfill_ref(e2, w, desired, cap)
    np.testing.assert_array_equal(np.asarray(s_all), np.asarray(s_none))
    np.testing.assert_array_equal(np.asarray(sh_all), np.asarray(sh_none))


def _link_load(edges, sent, e):
    load = np.zeros(e)
    np.add.at(load, np.asarray(edges).reshape(-1),
              np.repeat(np.asarray(sent), edges.shape[1]))
    load[e - 1] = 0.0                    # trash slot is write-only
    return load


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_never_oversubscribes(backend):
    """After the refinement iterations no link carries more than its
    capacity — the simulator's feasibility-by-construction invariant."""
    for seed in range(5):
        edges, w, desired, cap = _instance(160, 6, 301, seed=seed,
                                           idle_frac=0.1)
        sent, _ = waterfill_step(edges, w, desired, cap, fair_iters=2,
                                 backend=backend, interpret=True)
        load = _link_load(edges, sent, 301)
        assert (load <= np.asarray(cap) + 1e-4).all(), load.max()
        # and sends never exceed what was asked for
        assert (np.asarray(sent) <= np.asarray(desired) + 1e-6).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 200), st.integers(2, 10), st.integers(3, 400),
       st.integers(0, 10_000))
def test_oversubscription_property(f, s, e, seed):
    edges, w, desired, cap = _instance(f, s, e, seed=seed)
    sent, share = ref.waterfill_ref(edges, w, desired, cap, fair_iters=2)
    load = _link_load(edges, sent, e)
    assert (load <= np.asarray(cap) + 1e-4).all()
    # the fair-share signal is positive wherever a flow actually sends
    sent = np.asarray(sent)
    assert (np.asarray(share)[sent > 0] > 0).all()


def _tiny_cell(balancing="fatpaths", topo_spec="clique(k=6)"):
    from repro.core import transport as TP
    from repro.experiments import Session

    s = Session()
    topo = s.topology(topo_spec)
    scheme = {"fatpaths": "fatpaths(n_layers=3)", "ecmp": "ecmp(n=2)",
              "letflow": "letflow(n=2)"}[balancing]
    bundle = s.routing(topo_spec, scheme)
    wl = s.workload(topo_spec, "uniform")
    return TP, topo, bundle, wl


@pytest.mark.parametrize("transport", ["ndp", "tcp", "dctcp"])
def test_sim_kernel_backend_parity(transport):
    """The full simulator agrees between the fused Pallas step
    (interpret=True on CPU) and the jnp oracle, for every transport."""
    TP, topo, bundle, wl = _tiny_cell()
    mk = lambda be: TP.SimConfig(  # noqa: E731
        transport=transport, balancing=bundle.balancing, n_steps=30,
        kernel_backend=be)
    res_ref = TP.simulate(topo, bundle.routing, wl, mk("ref"))
    res_pl = TP.simulate(topo, bundle.routing, wl, mk("pallas"))
    np.testing.assert_allclose(res_pl.fct, res_ref.fct, rtol=1e-5)
    np.testing.assert_allclose(res_pl.delivered, res_ref.delivered,
                               rtol=1e-4)
    np.testing.assert_array_equal(res_pl.finished, res_ref.finished)


# ---- adaptive horizon -------------------------------------------------------
@pytest.mark.parametrize("balancing", ["fatpaths", "ecmp"])
def test_early_exit_equals_full_horizon(balancing):
    """A cell whose flows all finish early must return results
    bit-identical to the full-horizon run — and must actually exit early
    (fewer than all scan chunks executed)."""
    TP, topo, bundle, wl = _tiny_cell(balancing)
    mk = lambda ad: TP.SimConfig(  # noqa: E731
        balancing=bundle.balancing, n_steps=400, horizon_chunk=32,
        adaptive_horizon=ad)
    jarrs, static = TP.prepare(topo, bundle.routing, wl, mk(True))
    key = jax.random.PRNGKey(3)
    fin_ad = jax.device_get(TP._run_scan(jarrs, key, mk(True), static))
    fin_fl = jax.device_get(TP._run_scan(jarrs, key, mk(False), static))
    assert int(fin_ad["horizon_chunks"]) < int(fin_fl["horizon_chunks"])
    for k in ("remaining", "hops", "sent_acc", "w_acc", "depart_step"):
        np.testing.assert_array_equal(fin_ad[k], fin_fl[k], err_msg=k)
    ra = TP._to_result(np.asarray(jarrs["size"]), fin_ad, mk(True))
    rf = TP._to_result(np.asarray(jarrs["size"]), fin_fl, mk(False))
    np.testing.assert_array_equal(ra.fct, rf.fct)
    assert ra.link_util_mean == rf.link_util_mean
    assert ra.finished.all()


def test_early_exit_on_provably_stuck_flows():
    """Unroutable (weight-0 forever) flows must not pin the horizon: a
    cell whose remaining flows can never route exits early with state
    identical to the full run."""
    TP, topo, bundle, wl = _tiny_cell("fatpaths")
    cfg = TP.SimConfig(balancing="fatpaths", n_steps=320, horizon_chunk=32)
    jarrs, static = TP.prepare(topo, bundle.routing, wl, cfg)
    # Make half the flows unroutable in EVERY layer (routed=False and
    # usable=False => they can only ever pick non-routing layers).
    f = jarrs["size"].shape[0]
    sick = jnp.arange(f) % 2 == 0
    jarrs = dict(jarrs,
                 routed=jarrs["routed"] & ~sick[None, :],
                 usable=jarrs["usable"] & ~sick[:, None])
    key = jax.random.PRNGKey(0)
    cfg_f = dataclasses.replace(cfg, adaptive_horizon=False)
    fin_ad = jax.device_get(TP._run_scan(jarrs, key, cfg, static))
    fin_fl = jax.device_get(TP._run_scan(jarrs, key, cfg_f, static))
    assert int(fin_ad["horizon_chunks"]) < int(fin_fl["horizon_chunks"])
    for k in ("remaining", "hops", "sent_acc", "w_acc", "depart_step"):
        np.testing.assert_array_equal(fin_ad[k], fin_fl[k], err_msg=k)
    # stuck flows really never went anywhere
    assert (fin_ad["remaining"][np.asarray(sick)] ==
            np.asarray(jarrs["size"])[np.asarray(sick)]).all()


def test_active_flows_pin_the_horizon():
    """Slow-but-routable flows (incast) keep the scan running: adaptive
    and full horizons execute the same chunk count."""
    from repro.core import traffic as TR
    TP, topo, bundle, _ = _tiny_cell("fatpaths")
    wl = TR.make_workload(topo, "alltoone", seed=1,
                          flow_size=float(1 << 30))   # never finishes
    cfg = TP.SimConfig(balancing="fatpaths", n_steps=128, horizon_chunk=32)
    jarrs, static = TP.prepare(topo, bundle.routing, wl, cfg)
    fin = jax.device_get(TP._run_scan(jarrs, jax.random.PRNGKey(0), cfg,
                                      static))
    assert int(fin["horizon_chunks"]) == 128 // 32


# ---- backend selection ------------------------------------------------------
def test_kernel_backend_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
    assert kernel_backend() == "pallas"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    assert kernel_backend() == "ref"
    monkeypatch.delenv("REPRO_KERNEL_BACKEND")
    monkeypatch.delenv("REPRO_SEMIRING_BACKEND", raising=False)
    assert kernel_backend() in ("pallas", "ref")     # auto


def test_semiring_backend_env_is_deprecated_alias(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    monkeypatch.setenv("REPRO_SEMIRING_BACKEND", "pallas")
    with pytest.warns(DeprecationWarning, match="REPRO_KERNEL_BACKEND"):
        assert kernel_backend() == "pallas"
    # the explicit new var wins over the alias
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "ref")
    assert kernel_backend() == "ref"
    # semiring's public default_backend rides the same helper
    from repro.kernels.semiring import default_backend
    assert default_backend() == "ref"


def test_unknown_backend_values_fall_through(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "cuda")
    monkeypatch.delenv("REPRO_SEMIRING_BACKEND", raising=False)
    assert kernel_backend() in ("pallas", "ref")


def test_explicit_unknown_backend_rejected():
    edges, w, desired, cap = _instance(8, 3, 17, seed=0)
    with pytest.raises(ValueError, match="unknown backend"):
        waterfill_step(edges, w, desired, cap, backend="jnp")
