"""Semiring engine: Pallas kernel (interpret=True) vs jnp oracle, for all
three semirings, including saturation and padded-tile edges."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.semiring import SEMIRINGS, semiring_matmul

# Shapes chosen to hit exact tile multiples AND ragged padding in every
# grid dimension.
SHAPES = [(128, 128, 128), (256, 128, 384), (100, 130, 70), (1, 257, 129),
          (130, 1, 200)]


def _operands(m, k, n, semiring, seed):
    rng = np.random.default_rng(seed)
    a = rng.random((m, k), dtype=np.float32)
    b = rng.random((k, n), dtype=np.float32)
    if semiring == "bool":
        return jnp.asarray(a > 0.6), jnp.asarray(b > 0.6)
    if semiring == "minplus":
        # sprinkle +inf (non-edges) to exercise the additive identity
        a[rng.random((m, k)) < 0.3] = np.inf
        b[rng.random((k, n)) < 0.3] = np.inf
    return jnp.asarray(a), jnp.asarray(b)


@pytest.mark.parametrize("semiring", SEMIRINGS)
@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kernel_matches_oracle(semiring, m, k, n):
    a, b = _operands(m, k, n, semiring, seed=m * k + n)
    out = semiring_matmul(a, b, semiring, backend="pallas", interpret=True)
    expect = ref.semiring_matmul_ref(a, b, semiring)
    assert out.shape == (m, n)
    assert out.dtype == expect.dtype
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(expect, dtype=np.float32),
                               rtol=1e-5)


@pytest.mark.parametrize("semiring", SEMIRINGS)
def test_kernel_matches_oracle_batched(semiring):
    a0, b0 = _operands(100, 130, 70, semiring, seed=0)
    a1, b1 = _operands(100, 130, 70, semiring, seed=1)
    a = jnp.stack([a0, a1])
    b = jnp.stack([b0, b1])
    out = semiring_matmul(a, b, semiring, backend="pallas", interpret=True)
    expect = ref.semiring_matmul_ref(a, b, semiring)
    assert out.shape == (2, 100, 70)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(expect, dtype=np.float32),
                               rtol=1e-5)


def test_count_saturates():
    big = jnp.full((150, 150), 1e30, jnp.float32)
    for backend in ("pallas", "ref"):
        out = semiring_matmul(big, big, "count", backend=backend,
                              interpret=True)
        assert np.isfinite(np.asarray(out)).all(), backend


def test_bool_is_reachability():
    rng = np.random.default_rng(3)
    a = rng.random((60, 60)) < 0.1
    out = np.asarray(semiring_matmul(jnp.asarray(a), jnp.asarray(a), "bool",
                                     backend="pallas", interpret=True))
    expect = (a.astype(np.int64) @ a.astype(np.int64)) > 0
    np.testing.assert_array_equal(out, expect)


def test_minplus_is_tropical_product():
    rng = np.random.default_rng(4)
    w = np.where(rng.random((40, 40)) < 0.2,
                 rng.random((40, 40)).astype(np.float32), np.inf)
    np.fill_diagonal(w, 0.0)
    expect = (w[:, :, None] + w[None, :, :]).min(axis=1)
    for backend in ("pallas", "ref"):
        out = np.asarray(semiring_matmul(jnp.asarray(w), jnp.asarray(w),
                                         "minplus", backend=backend,
                                         interpret=True))
        np.testing.assert_allclose(out, expect, rtol=1e-6)


def test_unknown_semiring_rejected():
    a = jnp.zeros((8, 8))
    with pytest.raises(ValueError):
        semiring_matmul(a, a, "maxtimes")


def test_pathcount_is_count_instance():
    """The historical pathcount kernel is the count semiring."""
    from repro.kernels.pathcount import pathcount_matmul

    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.random((96, 96), dtype=np.float32))
    np.testing.assert_allclose(
        np.asarray(pathcount_matmul(a, a, interpret=True)),
        np.asarray(ref.pathcount_ref(a, a)), rtol=1e-5)
