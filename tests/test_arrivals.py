"""Arrival-process subsystem: determinism/prefix-stability contract,
process statistics, incast schedules, and the bisection normalizer."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest still run
    from _hypothesis_stub import given, settings, st  # noqa: F401

import jax

from repro.core import arrivals, topology


# ---- determinism / prefix stability -----------------------------------------
@pytest.mark.parametrize("process", ["poisson", "pareto"])
def test_activation_steps_prefix_stable(process):
    """Same (key, flow) => same activation step regardless of how many
    flows follow — the padding-class invariance the batched engines
    (which bucket cells by padded flow count) rest on."""
    key = jax.random.PRNGKey(7)
    a = arrivals.activation_steps(key, 100, rate=0.5, process=process)
    b = arrivals.activation_steps(key, 128, rate=0.5, process=process)
    c = arrivals.activation_steps(key, 101, rate=0.5, process=process)
    np.testing.assert_array_equal(a, b[:100])
    np.testing.assert_array_equal(a, c[:100])
    assert a.dtype == np.int32


@pytest.mark.parametrize("process", ["poisson", "pareto"])
def test_activation_steps_shape_and_order(process):
    key = jax.random.PRNGKey(0)
    s = arrivals.activation_steps(key, 200, rate=2.0, process=process)
    assert s[0] == 0                      # flow 0 arrives immediately
    assert (s >= 0).all()
    assert (np.diff(s) >= 0).all()        # cumsum => non-decreasing
    # different keys give different streams
    s2 = arrivals.activation_steps(jax.random.PRNGKey(1), 200, rate=2.0,
                                   process=process)
    assert not np.array_equal(s, s2)


def test_activation_steps_validation():
    key = jax.random.PRNGKey(0)
    assert arrivals.activation_steps(key, 0, rate=1.0).shape == (0,)
    with pytest.raises(ValueError, match="rate"):
        arrivals.activation_steps(key, 4, rate=0.0)
    with pytest.raises(ValueError, match="process"):
        arrivals.interarrival_gaps(key, 4, 1.0, process="uniform")
    with pytest.raises(ValueError, match="Pareto"):
        arrivals.interarrival_gaps(key, 4, 1.0, process="pareto", bound=0.5)


# ---- process statistics -----------------------------------------------------
def test_gap_means_match_configured_rate():
    key = jax.random.PRNGKey(3)
    for process in ("poisson", "pareto"):
        gaps = arrivals.interarrival_gaps(key, 4000, 5.0, process=process)
        assert gaps.min() > 0
        assert abs(gaps.mean() / 5.0 - 1.0) < 0.15, (process, gaps.mean())


def test_pareto_is_burstier_than_poisson():
    """Bounded-Pareto interarrivals are heavy-tailed: at equal mean their
    coefficient of variation exceeds the exponential's."""
    key = jax.random.PRNGKey(11)
    po = arrivals.interarrival_gaps(key, 4000, 1.0, process="poisson")
    pa = arrivals.interarrival_gaps(key, 4000, 1.0, process="pareto",
                                    shape=1.2, bound=512.0)
    cv = lambda g: g.std() / g.mean()  # noqa: E731
    assert cv(pa) > cv(po)


# ---- incast schedule --------------------------------------------------------
def test_incast_schedule_waves():
    s = arrivals.incast_schedule(10, fan_in=4, wave_period=32)
    np.testing.assert_array_equal(
        s, [0, 0, 0, 0, 32, 32, 32, 32, 64, 64])
    assert s.dtype == np.int32
    with pytest.raises(ValueError):
        arrivals.incast_schedule(4, fan_in=0, wave_period=8)


# ---- offered load / bisection -----------------------------------------------
def test_offered_load_accounting():
    sizes = np.full(100, 1e6)
    steps = np.arange(100)                # one flow per step, 100 steps
    dt = 1e-5
    cap = 1e6 / dt                        # 1 flow per step saturates cap
    assert arrivals.offered_load(sizes, steps, dt, cap) == pytest.approx(1.0)
    assert arrivals.offered_gbs(sizes, steps, dt) == pytest.approx(
        1e6 / dt / 1e9)
    assert arrivals.offered_load(np.zeros(0), np.zeros(0), dt, cap) == 0.0


def test_bisection_exact_on_clique():
    """clique(k=6) is a 7-router clique: every balanced 3/4 bipartition
    cuts 3*4 pairs in both directions => 24 directed links, and the
    sampled estimate is exact because all balanced cuts are minimal."""
    t = topology.clique(6)
    assert arrivals.bisection_bandwidth(t, line_rate=1.0) == 24.0
    assert arrivals.bisection_bandwidth(t) == 24.0 * 12.5e9
    # deterministic in the sampling seed
    assert (arrivals.bisection_bandwidth(t, seed=5)
            == arrivals.bisection_bandwidth(t, seed=5))


def test_bisection_sampling_keyed_per_index():
    """Bipartition i is drawn from default_rng((seed, i)) — the estimate
    is a running minimum over per-index streams, so it is monotone
    non-increasing in the sample count (prefix stability) and distinct
    seeds can explore distinct cuts."""
    t = topology.dragonfly(3)              # grouped: cuts genuinely differ
    ests = [arrivals.bisection_bandwidth(t, line_rate=1.0, samples=k,
                                         seed=0)
            for k in (1, 4, 16, 64)]
    assert all(b <= a for a, b in zip(ests, ests[1:]))
    # per-index keying: the same call repeated is bit-identical
    assert (arrivals.bisection_bandwidth(t, samples=16, seed=3)
            == arrivals.bisection_bandwidth(t, samples=16, seed=3))
    # the seed actually keys the draws: different seeds sample different
    # single bipartitions
    singles = {arrivals.bisection_bandwidth(t, line_rate=1.0, samples=1,
                                            seed=s) for s in range(8)}
    assert len(singles) > 1


def test_activation_starts_match_scan_clock():
    """Start seconds are computed through the same float32 product the
    scan uses for its step clock, so start <= i*dt flips exactly at the
    activation step."""
    steps = np.array([0, 1, 17, 1000], np.int32)
    dt = 10e-6
    starts = arrivals.activation_starts(steps, dt)
    t_at = steps.astype(np.float32) * np.float32(dt)
    assert (starts <= t_at).all()
    t_before = (steps - 1).astype(np.float32) * np.float32(dt)
    assert (starts[steps > 0] > t_before[steps > 0]).all()


# ---- property: offered load converges to the configured level ---------------
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.1, 0.9),
       st.sampled_from(["poisson", "pareto"]))
def test_offered_load_converges_to_level(seed, level, process):
    """For any seed, a stream built at rate level*capacity*dt/size
    realizes an offered load near `level` once the stream is long."""
    capacity = 300e9
    dt, size = 10e-6, 256e3
    rate = level * capacity * dt / size
    n = max(64, int(rate * 512))
    steps = arrivals.activation_steps(jax.random.PRNGKey(seed), n,
                                      rate=rate, process=process)
    got = arrivals.offered_load(np.full(n, size), steps, dt, capacity)
    assert abs(got - level) / level < 0.35, (got, level)
