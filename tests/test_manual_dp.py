"""Manual-DP step with FatPaths multi-ring gradient sync == pjit step
(8 host devices, subprocess); int8+EF wire stays close and converges."""

import subprocess
import sys
import textwrap

_PROG = textwrap.dedent("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.dist.sharding import Runtime
    from repro.models.config import ModelConfig
    from repro.models import model as M
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.train_step import TrainConfig, make_train_step
    from repro.train.manual_dp import ManualDPConfig, make_manual_dp_step

    mesh = jax.make_mesh((8,), ("data",))
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
                      vocab=256, dtype="float32", remat="none")
    rt = Runtime(mesh=mesh, data_axes=("data",), tp_disabled=True)
    params = M.init_params(cfg, rt, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    tok = jnp.asarray(np.arange(16 * 32).reshape(16, 32) % 256, jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=50)

    with mesh:
        # reference: pjit-managed DP
        ref_step = jax.jit(make_train_step(cfg, rt, TrainConfig(opt=oc)))
        rp, ro, rm = ref_step(params, opt, batch, jax.random.PRNGKey(1))

        # manual DP, f32 wire: must match the pjit step numerically
        man = jax.jit(make_manual_dp_step(
            cfg, rt, ManualDPConfig(opt=oc, wire="float32", n_rings=3)))
        mp, mo, mef, mm = man(params, opt, ef, batch)
    assert abs(float(rm["loss"]) - float(mm["loss"])) < 1e-4
    dmax = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree.leaves(rp), jax.tree.leaves(mp)))
    assert dmax < 5e-4, dmax

    # int8 + error feedback: converges on a fixed batch
    with mesh:
        man8 = jax.jit(make_manual_dp_step(
            cfg, rt, ManualDPConfig(opt=oc, wire="int8_ef", n_rings=3)))
        p, o, e = params, opt, ef
        losses = []
        for i in range(10):
            p, o, e, m = man8(p, o, e, batch)
            losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] - 0.2, losses
    print("MANUAL_DP_OK", dmax, losses[0], losses[-1])
""")


def test_manual_dp_matches_pjit_and_int8_converges():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=900,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "MANUAL_DP_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2500:])
