"""flash_chunked custom VJP: values AND gradients vs dense attention."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.attention import dense_attention, flash_chunked


@pytest.mark.parametrize("causal,window,softcap,g", [
    (True, 0, 0.0, 1),
    (True, 0, 0.0, 4),       # GQA
    (False, 0, 0.0, 2),
    (True, 48, 0.0, 1),      # sliding window
    (True, 0, 30.0, 2),      # softcap (gemma2)
])
def test_flash_vjp_matches_dense(causal, window, softcap, g):
    rng = np.random.default_rng(hash((causal, window, g)) % 2 ** 31)
    b, h, s, d = 2, 4, 160, 32          # s > chunk(64) => scan path
    hkv = h // g
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    scale = d ** -0.5

    def f_flash(q, k, v):
        return jnp.sum(jnp.square(flash_chunked(
            q, k, v, causal, window, softcap, scale, 64, 0)))

    def f_dense(q, k, v):
        return jnp.sum(jnp.square(dense_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale)))

    vf, gf = jax.value_and_grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    vd, gd = jax.value_and_grad(f_dense, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(float(vf), float(vd), rtol=1e-4)
    for a, b_ in zip(gf, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3)


def test_flash_vjp_mla_vdim():
    """v dim != qk dim (DeepSeek MLA)."""
    rng = np.random.default_rng(0)
    b, h, s, d, dv = 1, 2, 96, 24, 16
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, h, s, dv)), jnp.float32)

    def f(q, k, v):
        return jnp.sum(flash_chunked(q, k, v, True, 0, 0.0, d ** -0.5, 32, 0))

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    assert grads[2].shape == v.shape
    out = flash_chunked(q, k, v, True, 0, 0.0, d ** -0.5, 32, 0)
    expect = dense_attention(q, k, v, causal=True, window=0, softcap=0.0,
                             scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)
