"""PR 8 loss-recovery lanes and robustness satellites: recovery=off is
bitwise the pre-PR-8 program, kernel/oracle ECN-util parity, the RTO
state machine's backoff/reset algebra, blackhole-escape acceptance
(FatPaths recovers from a mid-run fault, a layer-pinned scheme never
does), recovery cells through both sweep engines, sweep watchdog,
checkpoint schema versioning, and dist_sweep bucket quarantine."""

import dataclasses
import json
import re
import subprocess
import sys
import textwrap

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest still run
    from _hypothesis_stub import given, settings, st  # noqa: F401

import jax.numpy as jnp

from repro.ckpt.sweep import SCHEMA, SchemaMismatch, SweepCheckpoint
from repro.core import transport as TP
from repro.experiments import Session, compare_results
from repro.experiments import dist_sweep as ds
from repro.experiments.__main__ import main
from repro.kernels import ref
from repro.kernels.waterfill import waterfill_step


# ---- recovery=off reproduces PR 7 bit-for-bit -------------------------------
# Golden metrics captured at the PR 7 tree tip (all clique(k=6) /
# uniform / seed 0).  Every recovery lane is trace-time gated, so
# recovery="off" (the default) must keep compiling the exact pre-PR-8
# program — equality below is ==, not allclose.
_FAIL = "failures(of=fatpaths(n_layers=3),rate=0.2,down_step=10)"
GOLDEN = {
    ("fatpaths(n_layers=3)", "transport(steps=40,transport=ndp)"): {
        "fct_mean_us": 219.76190185546875, "fct_p50_us": 181.0,
        "fct_p99_us": 381.5899963378906, "finished": 1.0,
        "link_util": 0.4108703954733628, "tput_gbs": 5.28138542175293},
    ("fatpaths(n_layers=3)", "transport(steps=40,transport=tcp)"): {
        "fct_mean_us": 265.9473571777344, "fct_p50_us": 240.99998474121094,
        "fct_p99_us": 347.29998779296875, "finished": 0.9047619047619048,
        "link_util": 0.31902273446717444, "tput_gbs": 4.174899101257324},
    ("fatpaths(n_layers=3)", "transport(steps=40,transport=dctcp)"): {
        "fct_mean_us": 244.07896423339844, "fct_p50_us": 221.0,
        "fct_p99_us": 310.9999694824219, "finished": 0.9047619047619048,
        "link_util": 0.34552265079709815, "tput_gbs": 4.507015705108643},
    (_FAIL, "transport(steps=60,transport=ndp)"): {
        "fct_mean_us": 238.8125, "fct_p50_us": 181.0,
        "fct_p99_us": 546.5, "finished": 0.7619047619047619,
        "link_util": 0.2382743884245196, "tput_gbs": 5.239025592803955},
    (_FAIL, "transport(steps=60,transport=tcp)"): {
        "fct_mean_us": 287.8620910644531, "fct_p50_us": 240.99998474121094,
        "fct_p99_us": 481.7200012207031, "finished": 0.6904761904761905,
        "link_util": 0.1862034094311057, "tput_gbs": 4.002628326416016},
    (_FAIL, "transport(steps=60,transport=dctcp)"): {
        "fct_mean_us": 264.3792724609375, "fct_p50_us": 221.0,
        "fct_p99_us": 441.719970703125, "finished": 0.6904761904761905,
        "link_util": 0.19540705318891569, "tput_gbs": 4.322918891906738},
}


@pytest.mark.parametrize("routing,evaluator", sorted(GOLDEN))
def test_recovery_off_reproduces_pr7_bitwise(routing, evaluator):
    rr = Session().run("clique(k=6)", routing, "uniform", evaluator, seed=0)
    want = GOLDEN[(routing, evaluator)]
    assert set(rr.metrics) == set(want)         # no retrans_mb when off
    for k, v in want.items():
        assert rr.metrics[k] == v, (k, rr.metrics[k], v)


# ---- ECN util lane: kernel == oracle ----------------------------------------
def _instance(f, s, e, seed, idle_frac=0.25):
    rng = np.random.default_rng(seed)
    edges = rng.integers(0, e - 1, (f, s)).astype(np.int32)
    edges[rng.random((f, s)) < 0.3] = e - 1          # trash-padded slots
    w = (rng.random(f) >= idle_frac).astype(np.float32)
    edges[w == 0] = e - 1                            # inert flows: all trash
    desired = rng.random(f).astype(np.float32) * w
    cap = np.ones(e, np.float32)
    return (jnp.asarray(edges), jnp.asarray(w), jnp.asarray(desired),
            jnp.asarray(cap))


@pytest.mark.parametrize("f,s,e",
                         [(7, 3, 19), (130, 9, 513), (1, 5, 33),
                          (256, 4, 1024)])
@pytest.mark.parametrize("fair_iters", [0, 1, 2])
def test_want_util_kernel_matches_oracle(f, s, e, fair_iters):
    """The want_util lane agrees between backends over ragged shapes
    (multi-tile flow and link grids) and does not perturb (sent, share):
    the flag only ADDS an output."""
    edges, w, desired, cap = _instance(f, s, e, seed=f + s + e)
    sent, share, util = waterfill_step(
        edges, w, desired, cap, fair_iters=fair_iters, backend="pallas",
        interpret=True, want_util=True)
    sent_r, share_r, util_r = ref.waterfill_ref(
        edges, w, desired, cap, fair_iters=fair_iters, want_util=True)
    np.testing.assert_allclose(np.asarray(sent), np.asarray(sent_r),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(share), np.asarray(share_r),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(util), np.asarray(util_r),
                               rtol=1e-5, atol=1e-7)
    # util is a demand utilization: finite, >= 0, 0 for all-trash rows
    u = np.asarray(util_r)
    assert np.isfinite(u).all() and (u >= 0).all()
    assert (u[np.asarray(w) == 0] == 0).all()
    # the lane must not change the base outputs (bitwise, per backend)
    s0, sh0 = waterfill_step(edges, w, desired, cap,
                             fair_iters=fair_iters, backend="pallas",
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(sent), np.asarray(s0))
    np.testing.assert_array_equal(np.asarray(share), np.asarray(sh0))
    s1, sh1 = ref.waterfill_ref(edges, w, desired, cap,
                                fair_iters=fair_iters)
    np.testing.assert_array_equal(np.asarray(sent_r), np.asarray(s1))
    np.testing.assert_array_equal(np.asarray(share_r), np.asarray(sh1))


def test_want_util_with_active_mask():
    """ECN util composes with the dynamic-traffic active lane: inactive
    rows report util 0 and the backends agree (shares go +inf for
    inactive rows, so compare them under a finite mask)."""
    f, s, e = 130, 5, 40
    edges, w, desired, cap = _instance(f, s, e, seed=3, idle_frac=0.0)
    rng = np.random.default_rng(9)
    active = jnp.asarray(rng.random(f) < 0.6)
    sent, share, util = waterfill_step(
        edges, w, desired, cap, active=active, backend="pallas",
        interpret=True, want_util=True)
    sent_r, share_r, util_r = ref.waterfill_ref(
        edges, w, desired, cap, active=active, want_util=True)
    np.testing.assert_allclose(np.asarray(sent), np.asarray(sent_r),
                               rtol=1e-5, atol=1e-7)
    fin = np.isfinite(np.asarray(share_r))
    np.testing.assert_array_equal(fin, np.isfinite(np.asarray(share)))
    np.testing.assert_allclose(np.asarray(share)[fin],
                               np.asarray(share_r)[fin], rtol=1e-5)
    np.testing.assert_allclose(np.asarray(util), np.asarray(util_r),
                               rtol=1e-5, atol=1e-7)
    assert (np.asarray(util_r)[~np.asarray(active)] == 0).all()


# ---- RTO state machine algebra ----------------------------------------------
@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.integers(1, 512), st.integers(0, 2 ** 31 - 1))
def test_rto_backoff_monotone_capped_and_reset(rto_base, cap_extra, seed):
    """_rto_next over random event sequences: backoff is monotone
    non-decreasing until a delivery, never exceeds rto_cap, delivery
    resets to rto_base and WINS over a same-step backoff, and no event
    leaves the timeout untouched."""
    rto_cap = rto_base + cap_extra
    rng = np.random.default_rng(seed)
    n = 16
    rto = jnp.full((n,), rto_base, jnp.int32)
    for _ in range(12):
        delivered = jnp.asarray(rng.random(n) < 0.3)
        backoff = jnp.asarray(rng.random(n) < 0.5)
        nxt = np.asarray(TP._rto_next(rto, delivered, backoff,
                                      rto_base, rto_cap))
        cur, d, b = np.asarray(rto), np.asarray(delivered), np.asarray(backoff)
        assert (nxt[d] == rto_base).all()                     # delivery wins
        assert (nxt[~d & b] >= cur[~d & b]).all()             # monotone
        assert (nxt[~d & b] == np.minimum(cur[~d & b] * 2, rto_cap)).all()
        assert (nxt[~d & ~b] == cur[~d & ~b]).all()           # inert
        assert (nxt <= rto_cap).all() and (nxt >= rto_base).all()
        rto = jnp.asarray(nxt)
    # sustained backoff saturates at the cap
    for _ in range(12):
        rto = TP._rto_next(rto, jnp.zeros(n, bool), jnp.ones(n, bool),
                           rto_base, rto_cap)
    assert (np.asarray(rto) == rto_cap).all()


def test_escape_layers_is_deterministic_and_cyclic():
    """Blackhole escape picks the NEXT usable surviving layer cyclically
    after the current one, no PRNG; flows with no escape keep their
    layer and report valid=False."""
    esc_ok = jnp.asarray([[True, False, True, False],
                          [False, False, False, False],
                          [False, True, True, True]])
    layer = jnp.asarray([0, 1, 2], jnp.int32)
    esc, valid = TP._escape_layers(layer, esc_ok)
    np.testing.assert_array_equal(np.asarray(esc), [2, 1, 3])
    np.testing.assert_array_equal(np.asarray(valid), [True, False, True])
    # cyclic wrap: from the last usable layer back to the first
    esc2, _ = TP._escape_layers(jnp.asarray([2, 0, 3], jnp.int32), esc_ok)
    np.testing.assert_array_equal(np.asarray(esc2), [0, 0, 1])


# ---- time-to-recover acceptance ---------------------------------------------
_BLACKHOLE = "failures(of={},rate=0.1,down_step=100)"
_BIGPERM = "permutation(flow_size=1000000000.0)"
_RECOV = "recovery(steps=400,eps=0.02)"


def test_recovery_fatpaths_recovers_pinned_ecmp_does_not():
    """The PR's headline: a mid-run blackhole under never-finishing
    permutation traffic.  FatPaths' RTO escape re-routes stalled flows
    onto surviving layers — goodput re-enters the pre-fault band at a
    finite time-to-recover and the stalled-flow count drains.  ECMP pins
    every flow to its hash layer: blackholed flows stay dark and the
    cell never re-enters the band (recovered=0, TTR=NaN)."""
    s = Session()
    fp = s.run("clique(k=6)", _BLACKHOLE.format("fatpaths(n_layers=9)"),
               _BIGPERM, _RECOV, seed=0)
    assert fp.metrics["recovered"] == 1.0
    assert np.isfinite(fp.metrics["ttr_steps"])
    assert 0 < fp.metrics["ttr_steps"] < 300
    assert fp.metrics["dip_frac"] > 0           # the fault actually bit
    assert fp.metrics["plateau_goodput"] > 0
    assert fp.metrics["retrans_mb"] > 0         # blackholed bytes resent
    assert fp.metrics["stalled_peak"] > 0
    # trajectory meta: downsampled curves, identical length, drained tail
    assert (len(fp.meta["curve_steps"]) == len(fp.meta["goodput_curve"])
            == len(fp.meta["stalled_curve"]))
    assert fp.meta["stalled_curve"][-1] == 0.0
    assert fp.meta["rto_base"] == 16 and fp.meta["rto_cap"] == 256

    ec = s.run("clique(k=6)", _BLACKHOLE.format("ecmp(n=4)"),
               _BIGPERM, _RECOV, seed=0)
    assert ec.metrics["recovered"] == 0.0
    assert np.isnan(ec.metrics["ttr_steps"])
    assert ec.meta["stalled_curve"][-1] > 0     # flows stay dark


def test_recovery_without_fault_is_trivially_recovered():
    rr = Session().run("clique(k=6)", "fatpaths(n_layers=3)", _BIGPERM,
                       "recovery(steps=120)", seed=0)
    assert rr.metrics["recovered"] == 1.0
    assert rr.metrics["ttr_steps"] == 0.0
    assert rr.metrics["dip_frac"] == 0.0


# ---- both sweep engines, failures x recovery grid ---------------------------
# steps=80 > horizon_chunk and recovery=on cells bucket separately from
# legacy cells (the SimConfig is part of the signature); every cell must
# come back identical to the sequential engine, diff-exact.
_PROG = textwrap.dedent("""
    from repro.experiments import Session, compare_results
    from repro.experiments.dist_sweep import dist_sweep
    import jax
    assert jax.device_count() == 8, jax.device_count()
    grid = dict(topos=["clique(k=6)"],
                routings=["fatpaths(n_layers=3)",
                          "failures(of=fatpaths(n_layers=3),rate=0.2,down_step=20)"],
                patterns=["uniform"],
                evaluators=["transport(steps=80,recovery=on)",
                            "transport(steps=80,recovery=on,transport=dctcp)",
                            "transport(steps=80)"],
                seeds=[0, 1])
    seq = Session().sweep(**grid)
    s8 = Session()
    d8 = dist_sweep(s8, s8.grid(**grid), devices=8)
    diffs = compare_results(seq, d8)
    assert diffs == [], diffs[:5]
    rec = [r for r in d8 if "recovery=on" in r.evaluator]
    assert len(rec) == 8
    assert all("retrans_mb" in r.metrics for r in rec)
    off = [r for r in d8 if "recovery" not in r.evaluator]
    assert all("retrans_mb" not in r.metrics for r in off)
    print("RECOV8_OK")
""")


def test_recovery_grid_8_devices_identical():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=600,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "RECOV8_OK" in r.stdout, r.stderr[-2000:]


# ---- satellite: checkpoint schema versioning --------------------------------
def test_sweep_checkpoint_rejects_stale_schema(tmp_path):
    ck = SweepCheckpoint(str(tmp_path))
    ck.put("cell_a", {"topo": "t"})
    stale = tmp_path / "cell_0000000000000000beef.json"
    stale.write_text(json.dumps(
        {"cell_id": "cell_b", "schema": SCHEMA - 1, "result": {}}))
    with pytest.raises(SchemaMismatch,
                       match=re.escape(str(tmp_path))):
        SweepCheckpoint(str(tmp_path)).load()
    # torn/foreign files are still just skipped, not fatal
    stale.write_text('{"cell_id": "cell_b"')
    assert SweepCheckpoint(str(tmp_path)).load() == {"cell_a": {"topo": "t"}}


# ---- satellite: dist_sweep graceful degradation -----------------------------
_GRID = dict(topos=["clique(k=6)"], routings=["ecmp(n=2)"],
             patterns=["uniform"], evaluators=["transport(steps=40)"],
             seeds=[0, 1])


def test_dist_sweep_quarantines_a_twice_failed_bucket(monkeypatch):
    calls = []

    def boom(works, finals, desc):
        calls.append([w.cfg.kernel_backend for w in works])
        raise RuntimeError("synthetic bucket failure")

    monkeypatch.setattr(ds, "_finalize_bucket", boom)
    s = Session()
    out = ds.dist_sweep(s, s.grid(**_GRID), devices=1)
    assert len(out) == 2 and len(calls) == 2    # original + one ref retry
    assert all(be == "ref" for be in calls[1])  # retry forced the oracle
    for rr in out:
        assert rr.metrics == {}
        err = rr.meta["error"]
        assert err["type"] == "bucket_failure"
        assert err["retried_ref"] is True
        assert err["exception"] == "RuntimeError"
        assert "synthetic" in err["message"]


def test_dist_sweep_ref_retry_recovers_identically(monkeypatch):
    real = ds._finalize_bucket
    state = {"n": 0}

    def flaky(works, finals, desc):
        state["n"] += 1
        if state["n"] == 1:
            raise RuntimeError("synthetic transient failure")
        assert all(w.cfg.kernel_backend == "ref" for w in works)
        return real(works, finals, desc)

    monkeypatch.setattr(ds, "_finalize_bucket", flaky)
    s = Session()
    out = ds.dist_sweep(s, s.grid(**_GRID), devices=1)
    assert state["n"] == 2
    assert all("error" not in rr.meta for rr in out)
    diffs = compare_results(Session().sweep(**_GRID), out)
    assert diffs == [], diffs[:5]


def test_dist_sweep_quarantines_nonfinite_cells(monkeypatch):
    real = ds._finalize_bucket

    def poison(works, finals, desc):
        sims, chunks = real(works, finals, desc)
        sims[0] = [dataclasses.replace(
            r, delivered=np.where(np.arange(len(r.delivered)) == 0,
                                  np.nan, r.delivered))
            for r in sims[0]]
        return sims, chunks

    monkeypatch.setattr(ds, "_finalize_bucket", poison)
    s = Session()
    out = ds.dist_sweep(s, s.grid(**_GRID), devices=1)
    assert len(out) == 2
    bad = [rr for rr in out if "error" in rr.meta]
    assert len(bad) == 1
    assert bad[0].meta["error"] == {"type": "nonfinite", "seeds_bad": 1}
    assert bad[0].metrics == {}
    good = [rr for rr in out if "error" not in rr.meta]
    assert good and good[0].metrics["finished"] > 0


def test_dist_sweep_error_cells_are_not_checkpointed(tmp_path, monkeypatch):
    monkeypatch.setattr(
        ds, "_finalize_bucket",
        lambda *a: (_ for _ in ()).throw(RuntimeError("synthetic")))
    s = Session()
    cells = s.grid(**_GRID)
    out = ds.dist_sweep(s, cells, devices=1, checkpoint_dir=str(tmp_path))
    assert all("error" in rr.meta for rr in out)
    assert len(SweepCheckpoint(str(tmp_path))) == 0
    # a resume with the fault gone re-attempts and completes every cell
    monkeypatch.undo()
    out2 = ds.dist_sweep(Session(), cells, devices=1,
                         checkpoint_dir=str(tmp_path))
    assert all("error" not in rr.meta for rr in out2)
    assert len(SweepCheckpoint(str(tmp_path))) == 2


# ---- satellite: --cell-timeout-s watchdog -----------------------------------
_CLI = ["sweep", "--topos", "clique(k=4)", "--schemes", "ecmp(n=2)",
        "--patterns", "uniform"]


def test_cell_timeout_marks_cell_and_exits_1(capsys, tmp_path):
    out_json = str(tmp_path / "wd.json")
    rc = main([*_CLI, "--evaluators", "transport(steps=2000,seeds=4)",
               "--cell-timeout-s", "0.01", "--json", out_json])
    assert rc == 1                              # nothing succeeded
    assert "failed-with-timeout" in capsys.readouterr().out
    rows = json.load(open(out_json))
    assert len(rows) == 1 and rows[0]["metrics"] == {}
    assert rows[0]["meta"]["error"] == {"type": "timeout",
                                        "timeout_s": 0.01}


def test_cell_timeout_passing_cells_exit_0(capsys, tmp_path):
    out_json = str(tmp_path / "wd.json")
    rc = main([*_CLI, "--evaluators", "transport(steps=40)",
               "--cell-timeout-s", "600", "--json", out_json])
    assert rc == 0
    assert "1 succeeded, 0 timed out" in capsys.readouterr().out
    rows = json.load(open(out_json))
    assert rows[0]["metrics"]["finished"] > 0


def test_cell_timeout_rejects_devices(capsys):
    rc = main([*_CLI, "--evaluators", "transport(steps=40)",
               "--cell-timeout-s", "5", "--devices", "2"])
    assert rc == 2
    assert "drop --devices" in capsys.readouterr().err


def test_cell_timeout_resume_reattempts_timed_out_cells(capsys, tmp_path):
    ck = str(tmp_path / "ck")
    ev = ["--evaluators", "transport(steps=2000,seeds=4)"]
    assert main([*_CLI, *ev, "--cell-timeout-s", "0.01",
                 "--checkpoint", ck]) == 1
    assert len(SweepCheckpoint(ck)) == 0        # timeouts never committed
    assert main([*_CLI, *ev, "--cell-timeout-s", "600",
                 "--checkpoint", ck]) == 0
    assert len(SweepCheckpoint(ck)) == 1
    out = capsys.readouterr().out
    assert "1 succeeded, 0 timed out" in out
