"""Cluster fabric model: collectives -> link loads under ECMP/FatPaths."""

import numpy as np
import pytest

from repro.core.topology import slim_fly
from repro.dist.fabric import ClusterFabric, collective_flows


@pytest.fixture(scope="module")
def fb():
    return ClusterFabric(slim_fly(5), n_layers=9, rho=0.6, seed=0)


def test_collective_flow_volumes():
    n, b = 8, 1e6
    fl = collective_flows("all-reduce", n, b)
    assert len(fl) == n
    total = sum(f[2] for f in fl)
    np.testing.assert_allclose(total, 2 * b * (n - 1) / n * n / 1, rtol=1e-6)
    a2a = collective_flows("all-to-all", n, b)
    assert len(a2a) == n * (n - 1)


def test_evaluate_scales_linearly(fb):
    r1 = fb.collective_time("all-to-all", 64, 1e8)
    r2 = fb.collective_time("all-to-all", 64, 2e8)
    np.testing.assert_allclose(r2.bottleneck_bytes,
                               2 * r1.bottleneck_bytes, rtol=0.05)


def test_fatpaths_not_worse_than_ecmp_much(fb):
    """Adaptive flowlet split must track or beat minimal ECMP on every
    collective pattern (paper: 'FatPaths ensures the highest performance
    in such cases as well')."""
    for kind in ("all-reduce", "all-gather", "all-to-all", "all-to-one"):
        e = fb.collective_time(kind, 64, 1e9, "ecmp")
        f = fb.collective_time(kind, 64, 1e9, "fatpaths")
        assert f.time_s <= e.time_s * 1.15, (kind, e.time_s, f.time_s)


def test_fatpaths_beats_ecmp_on_skewed_multiring(fb):
    """Large-stride rings collide on minimal paths; layers spread them."""
    e = fb.collective_time("all-reduce", 200, 1e9, "ecmp",
                           strides=(1, 37, 53, 91))
    f = fb.collective_time("all-reduce", 200, 1e9, "fatpaths",
                           strides=(1, 37, 53, 91))
    assert f.bottleneck_bytes <= e.bottleneck_bytes


def test_report_fields(fb):
    r = fb.collective_time("all-reduce", 32, 1e6)
    d = r.as_dict()
    assert set(d) >= {"scheme", "bottleneck_bytes", "time_s", "util_gini"}
    assert r.n_links_used > 0
