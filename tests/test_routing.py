"""Forwarding functions + deployment accounting (paper §5.1, §5.4, §5.5)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest still run
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.core import layers as L
from repro.core import routing as R
from repro.core.topology import slim_fly


@pytest.fixture(scope="module")
def lr():
    return L.build_layers(slim_fly(5), n_layers=5, rho=0.6, seed=0)


def test_forwarding_function_routes(lr):
    ff = R.ForwardingFunction(lr, layer=0)
    path = ff.route(0, 37)
    assert path[0] == 0 and path[-1] == 37
    assert len(path) <= 3, "SF D=2: minimal layer routes in <=2 hops"
    port, nxt = ff(0, 37)
    assert 0 <= port < lr.topo.network_radix
    assert nxt == path[1]


def test_forwarding_unroutable_raises(lr):
    for i in range(1, lr.n_layers):
        s, t = np.argwhere(~lr.reach[i])[0]
        if s != t:
            ff = R.ForwardingFunction(lr, layer=int(i))
            with pytest.raises(LookupError):
                ff.route(int(s), int(t))
            return


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 49), st.integers(0, 49), st.integers(0, 4))
def test_port_next_hop_consistency(s, t, layer):
    lrr = test_port_next_hop_consistency._lr
    if s == t or not lrr.reach[layer, s, t]:
        return
    ff = R.ForwardingFunction(lrr, layer=layer)
    port, nxt = ff(s, t)
    # the port must point at an actual neighbour, and nh must be a neighbour
    assert lrr.topo.adj[s, nxt]
    nbrs = np.nonzero(lrr.topo.adj[s])[0]
    assert nbrs[port] == nxt


test_port_next_hop_consistency._lr = L.build_layers(
    slim_fly(5), n_layers=5, rho=0.6, seed=0)


def test_table_size_compression(lr):
    """§5.5.2: prefix tables are O(N_r) per router vs O(N) exact — for SF
    with p=4 endpoints/router the saving is p^2 x at the network level."""
    exact = R.table_entries_exact(lr)
    prefix = R.table_entries_prefix(lr)
    n, n_r = lr.topo.n_endpoints, lr.topo.n_routers
    assert exact == n_r * lr.n_layers * n
    assert prefix == n_r * lr.n_layers * n_r
    assert prefix * (n // n_r) == exact


def test_vlan_budget(lr):
    """FatPaths needs O(1) VLANs (one per layer) — far below the 4094
    hardware limit the paper discusses; SPAIN-style tree layering needs
    O(k') or more."""
    assert R.vlan_layers_required(lr) == 5 < 4094