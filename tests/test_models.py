"""Per-architecture smoke tests (reduced configs) + decode consistency.

Task spec: 'a SMOKE test that instantiates a REDUCED config of the same
family and runs one forward/train step on CPU asserting output shapes +
no NaNs.'
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.dist.sharding import Runtime
from repro.models import model as M


RT = Runtime(mesh=None)


def _batch(cfg, b=2, s=16):
    if cfg.frontend is None:
        tok = jnp.asarray(np.arange(b * s).reshape(b, s) % cfg.vocab,
                          dtype=jnp.int32)
        return {"tokens": tok, "labels": tok}
    rng = np.random.default_rng(0)
    return {"embeds": jnp.asarray(
                rng.standard_normal((b, s, cfg.frontend_dim)), jnp.float32),
            "labels": jnp.ones((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    params = M.init_params(cfg, RT, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = M.forward(params, cfg, RT, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    from repro.train.train_step import TrainConfig, make_train_step
    from repro.train.optimizer import AdamWConfig, adamw_init
    step = make_train_step(cfg, RT, TrainConfig(
        opt=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)))
    opt = adamw_init(params)
    p2, o2, metrics = step(params, opt, batch, jax.random.PRNGKey(1))
    assert np.isfinite(float(metrics["loss"])), arch
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda acc, t: acc + float(jnp.abs(t[0] - t[1]).sum()),
        jax.tree.map(lambda a, b: (a, b), p2, params), 0.0)
    assert moved > 0.0


@pytest.mark.parametrize("arch", [a for a in configs.ARCHS
                                  if configs.get_smoke(a).causal])
def test_decode_matches_prefill(arch):
    """KV-cache/state decode must reproduce teacher-forced logits."""
    cfg = configs.get_smoke(arch)
    params = M.init_params(cfg, RT, jax.random.PRNGKey(0))
    b, s = 2, 12
    batch = _batch(cfg, b, s)
    full_logits, _ = M.forward(params, cfg, RT, batch)

    cache = M.init_cache(cfg, RT, b, 32, dtype=jnp.float32)
    if cfg.frontend is None:
        prefill_batch = {"tokens": batch["tokens"][:, :s - 1]}
    else:
        prefill_batch = {"embeds": batch["embeds"][:, :s - 1]}
    _, cache, _ = M.forward(params, cfg, RT, prefill_batch, cache=cache)
    if cfg.frontend is None:
        step_batch = {"tokens": batch["tokens"][:, s - 1:s]}
    else:
        step_batch = {"embeds": batch["embeds"][:, s - 1:s]}
    step_logits, cache, _ = M.forward(params, cfg, RT, step_batch,
                                      cache=cache)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full_logits[:, s - 1], np.float32),
        rtol=2e-2, atol=2e-2)


def test_param_specs_match_structure(rt0):
    for arch in configs.ARCHS:
        cfg = configs.get_smoke(arch)
        params = M.init_params(cfg, rt0, jax.random.PRNGKey(0))
        specs = M.param_specs(cfg, rt0)
        jax.tree.map(lambda p, s: None, params, specs,
                     is_leaf=lambda x: hasattr(x, "shape") or
                     type(x).__name__ == "PartitionSpec")


def test_full_configs_param_counts():
    """Exact configs match the assigned sizes (±15%)."""
    targets = {"glm4-9b": 9.4e9, "qwen2.5-32b": 32.5e9, "gemma2-27b": 27e9,
               "yi-9b": 8.8e9, "zamba2-1.2b": 1.2e9, "hubert-xlarge": 1e9,
               "qwen2-vl-7b": 7.6e9, "rwkv6-7b": 7.6e9,
               "deepseek-v2-236b": 236e9, "olmoe-1b-7b": 6.9e9}
    for arch, target in targets.items():
        n = configs.get_config(arch).param_count()
        assert abs(n - target) / target < 0.3, (arch, n, target)
    # MoE active counts
    assert configs.get_config("deepseek-v2-236b").active_param_count() < 25e9
    assert configs.get_config("olmoe-1b-7b").active_param_count() < 1.6e9


def test_applicability_matrix():
    cells = configs.cell_matrix(configs.ARCHS)
    assert cells[("hubert-xlarge", "decode_32k")][0] is False
    assert cells[("hubert-xlarge", "prefill_32k")][0] is True
    assert cells[("glm4-9b", "long_500k")][0] is False
    assert cells[("zamba2-1.2b", "long_500k")][0] is True
    assert cells[("rwkv6-7b", "long_500k")][0] is True
    runnable = sum(ok for ok, _ in cells.values())
    assert runnable == 31, runnable


def test_input_specs_shapes():
    cfg = configs.get_config("glm4-9b")
    sp = configs.input_specs(cfg, "train_4k")
    assert sp["tokens"].shape == (256, 4096)
    sp = configs.input_specs(cfg, "decode_32k")
    assert sp["tokens"].shape == (128, 1)
    vl = configs.get_config("qwen2-vl-7b")
    sp = configs.input_specs(vl, "prefill_32k")
    assert sp["embeds"].shape == (32, 32768, vl.frontend_dim)
