"""Checkpoint format: atomicity, checksums, elastic restore."""

import os
import tempfile

import numpy as np
import pytest
import jax.numpy as jnp

from repro.ckpt.checkpoint import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)


def _state(x=1.0):
    return {"a": {"w": jnp.full((4, 3), x), "b": jnp.arange(5)},
            "step": jnp.asarray(7)}


def test_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, _state(2.5), {"next_step": 3})
        got, extra = restore_checkpoint(d, _state(0.0))
        np.testing.assert_allclose(np.asarray(got["a"]["w"]), 2.5)
        assert extra["next_step"] == 3


def test_uncommitted_checkpoint_ignored():
    with tempfile.TemporaryDirectory() as d:
        p = save_checkpoint(d, 5, _state())
        os.remove(os.path.join(p, "COMMIT"))
        assert latest_step(d) is None
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(d, _state())


def test_checksum_detects_corruption():
    with tempfile.TemporaryDirectory() as d:
        p = save_checkpoint(d, 1, _state())
        shard = os.path.join(p, "shard_00000.npz")
        # corrupt one leaf
        data = dict(np.load(shard))
        data["a/w"] = data["a/w"] + 1
        np.savez(shard, **data)
        with pytest.raises(IOError, match="checksum"):
            restore_checkpoint(d, _state())


def test_latest_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (10, 20, 30):
            mgr.save(s, _state(float(s)))
            mgr.wait()
        assert latest_step(d) == 30
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [20, 30], "gc keeps the last 2"


def test_restore_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _state())
        bad = {"a": {"w": jnp.zeros((2, 2)), "b": jnp.arange(5)},
               "step": jnp.asarray(0)}
        with pytest.raises(AssertionError):
            restore_checkpoint(d, bad)
