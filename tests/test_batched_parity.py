"""Parity: batched on-device path engine vs the historical per-layer
numpy path (which lives on here as the reference implementation).

Covers batched APSP, forwarding-table construction (validity +
tie-break distribution + fixed-key determinism), the counting-semiring
edge-usage fixpoint, (min, +) weighted distances, min_path_stats, the
batched table walk, and build_layers invariants across all six schemes.
"""

import networkx as nx
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core import paths as P
from repro.core import transport as TP
from repro.core.topology import slim_fly

SCHEMES = ["rand", "undir", "pi_min", "spain", "past", "ksp"]


def _random_stack(n, n_layers, p, seed, oriented=True):
    rng = np.random.default_rng(seed)
    base = np.triu(rng.random((n, n)) < p, 1)
    base = base | base.T
    stack = [base]
    for _ in range(n_layers - 1):
        keep = np.triu(base, 1) & (rng.random((n, n)) < 0.7)
        la = np.zeros((n, n), dtype=bool)
        if oriented:
            pi = rng.permutation(n)
            iu, ju = np.nonzero(keep)
            fwd = pi[iu] < pi[ju]
            la[np.where(fwd, iu, ju), np.where(fwd, ju, iu)] = True
        else:
            la = keep | keep.T
        stack.append(la)
    return np.stack(stack)


# ---------------------------------------------------------------------------
# Reference implementations: the pre-batching host-side numpy path.
# ---------------------------------------------------------------------------
def _ref_edge_usage(nh, reach, max_hops):
    n = nh.shape[0]
    s_idx, t_idx = np.nonzero(reach & ~np.eye(n, dtype=bool))
    usage = np.zeros((n, n), dtype=np.int64)
    cur = s_idx.astype(np.int64).copy()
    tgt = t_idx.astype(np.int64)
    for _ in range(max_hops):
        active = cur != tgt
        if not active.any():
            break
        nxt = nh[cur[active], tgt[active]].astype(np.int64)
        good = nxt >= 0
        np.add.at(usage, (cur[active][good], nxt[good]), 1)
        new_cur = cur.copy()
        upd = np.where(good, nxt, tgt[active])
        new_cur[np.nonzero(active)[0]] = upd
        cur = new_cur
    return usage


def _ref_minplus_apsp(w, max_len):
    dist = w.copy()
    for _ in range(max_len):
        new = dist.copy()
        for s0 in range(0, w.shape[0], 128):
            s1 = min(w.shape[0], s0 + 128)
            new[s0:s1] = np.minimum(
                new[s0:s1], (dist[s0:s1, :, None] + w[None, :, :]).min(axis=1))
        if np.allclose(new, dist):
            break
        dist = new
    return dist


# ---------------------------------------------------------------------------
# APSP.
# ---------------------------------------------------------------------------
def test_apsp_batched_matches_per_layer():
    stack = _random_stack(24, 5, 0.2, seed=0)
    batched = np.asarray(P.apsp_batched(jnp.asarray(stack), max_l=24))
    for i, la in enumerate(stack):
        single = np.asarray(P.shortest_path_lengths(jnp.asarray(la), max_l=24))
        np.testing.assert_array_equal(batched[i], single)


def test_apsp_batched_matches_networkx():
    stack = _random_stack(18, 1, 0.25, seed=1)
    dist = np.asarray(P.apsp_batched(jnp.asarray(stack), max_l=18))[0]
    g = nx.from_numpy_array(stack[0])
    nxd = dict(nx.all_pairs_shortest_path_length(g))
    for s in range(18):
        for t in range(18):
            expect = nxd.get(s, {}).get(t)
            if expect is None:
                assert dist[s, t] > 18
            else:
                assert dist[s, t] == expect


# ---------------------------------------------------------------------------
# Forwarding tables: validity, determinism, tie-break distribution.
# ---------------------------------------------------------------------------
def test_forwarding_batched_entries_valid():
    stack = _random_stack(24, 4, 0.25, seed=2)
    dist = P.apsp_batched(jnp.asarray(stack), max_l=24)
    nh = np.asarray(P.forwarding_batched(stack, dist, jax.random.PRNGKey(0)))
    dist = np.asarray(dist)
    for i in range(stack.shape[0]):
        for s in range(24):
            for t in range(24):
                v = nh[i, s, t]
                if s == t:
                    assert v == s
                elif dist[i, s, t] <= 24:
                    assert v >= 0 and stack[i, s, v]
                    assert dist[i, v, t] == dist[i, s, t] - 1
                else:
                    # no candidate one hop closer -> -1
                    cands = stack[i, s] & (dist[i, :, t] == dist[i, s, t] - 1)
                    if not cands.any():
                        assert v == -1


def test_forwarding_batched_deterministic_per_key():
    stack = _random_stack(20, 3, 0.3, seed=3)
    dist = P.apsp_batched(jnp.asarray(stack), max_l=20)
    a = np.asarray(P.forwarding_batched(stack, dist, jax.random.PRNGKey(7)))
    b = np.asarray(P.forwarding_batched(stack, dist, jax.random.PRNGKey(7)))
    c = np.asarray(P.forwarding_batched(stack, dist, jax.random.PRNGKey(8)))
    np.testing.assert_array_equal(a, b)
    assert (a != c).any(), "different keys must re-roll some tie-break"


def test_forwarding_tie_break_uniform():
    """On C4 (the 4-cycle) each opposite-corner pair has exactly two
    equal-cost next hops; across keys both must appear with ~equal
    frequency (the batched builder picks uniformly among candidates,
    distribution-identical to the historical rng scoring)."""
    adj = np.zeros((4, 4), dtype=bool)
    for u, v in [(0, 1), (1, 2), (2, 3), (3, 0)]:
        adj[u, v] = adj[v, u] = True
    dist = P.apsp_batched(jnp.asarray(adj[None]), max_l=4)
    picks = []
    for k in range(200):
        nh = np.asarray(P.forwarding_batched(adj[None], dist,
                                             jax.random.PRNGKey(k)))
        picks.append(nh[0, 0, 2])          # 0 -> 2 via 1 or via 3
    picks = np.array(picks)
    assert set(picks.tolist()) == {1, 3}
    frac = (picks == 1).mean()
    assert 0.35 < frac < 0.65, frac


# ---------------------------------------------------------------------------
# Edge usage (pi_min's bias signal): counting fixpoint == table walk.
# ---------------------------------------------------------------------------
def test_edge_usage_matches_walk_reference():
    stack = _random_stack(22, 3, 0.25, seed=4)
    max_l = 10
    dist = P.apsp_batched(jnp.asarray(stack), max_l=max_l)
    nh = P.forwarding_batched(stack, dist, jax.random.PRNGKey(1))
    reach = np.asarray(dist) <= max_l
    usage = np.asarray(P.edge_usage_batched(nh, jnp.asarray(reach), max_l))
    nh = np.asarray(nh)
    for i in range(stack.shape[0]):
        expect = _ref_edge_usage(nh[i], reach[i], max_l)
        np.testing.assert_array_equal(usage[i].astype(np.int64), expect)


# ---------------------------------------------------------------------------
# (min, +) weighted distances (ksp's substrate).
# ---------------------------------------------------------------------------
def test_minplus_apsp_matches_bellman_ford():
    rng = np.random.default_rng(5)
    stack = _random_stack(26, 1, 0.2, seed=5)
    ws = []
    for _ in range(3):
        w = np.where(stack[0], 1.0 + 0.25 * rng.random((26, 26)), np.inf)
        w = np.minimum(w, w.T)
        np.fill_diagonal(w, 0.0)
        ws.append(w)
    ws = np.stack(ws)
    out = np.asarray(P.minplus_apsp_batched(jnp.asarray(ws), max_l=12))
    for i in range(3):
        np.testing.assert_allclose(out[i], _ref_minplus_apsp(ws[i], 12),
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# min_path_stats: device-side masked select.
# ---------------------------------------------------------------------------
def test_min_path_stats_matches_matrix_power():
    stack = _random_stack(16, 1, 0.3, seed=6)
    adj = stack[0]
    dist, counts = P.min_path_stats(adj, max_l=8)
    a = adj.astype(np.float64)
    cur = a.copy()
    for l in range(1, 9):
        mask = dist == l
        np.testing.assert_allclose(counts[mask], cur[mask])
        cur = cur @ a
    assert (counts[dist > 8] == 0).all()
    assert (counts[np.eye(16, dtype=bool)] == 0).all()


# ---------------------------------------------------------------------------
# Batched walks.
# ---------------------------------------------------------------------------
def test_walk_paths_layers_matches_single_walks():
    stack = _random_stack(20, 3, 0.3, seed=7)
    max_l = 10
    dist = P.apsp_batched(jnp.asarray(stack), max_l=max_l)
    nh = np.asarray(P.forwarding_batched(stack, dist, jax.random.PRNGKey(2)))
    rng = np.random.default_rng(0)
    li = rng.integers(3, size=40).astype(np.int32)
    s = rng.integers(20, size=40).astype(np.int32)
    t = (s + 1 + rng.integers(19, size=40)).astype(np.int32) % 20
    batched = P.walk_paths_layers(nh, li, s, t, max_hops=12)
    for j in range(40):
        single = P.walk_paths(nh[li[j]], s[j:j + 1], t[j:j + 1], max_hops=12)
        np.testing.assert_array_equal(batched[j], single[0])


# ---------------------------------------------------------------------------
# build_layers invariants, every scheme.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sf5():
    return slim_fly(5)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_build_layers_tables_consistent(sf5, scheme):
    """For every scheme: pathlen/reach agree with a per-layer APSP
    recomputation, and every reachable table entry steps one hop closer
    (ksp excepted: its tables follow weighted, near-minimal paths and are
    covered by the loop-free walk instead)."""
    lr = L.build_layers(sf5, n_layers=3, rho=0.6, scheme=scheme, seed=2)
    max_len = max(6, sf5.diameter_nominal + 4)
    for i in range(lr.n_layers):
        if scheme == "ksp":
            base = np.asarray(sf5.adj, dtype=bool)
            dist = np.asarray(P.shortest_path_lengths(jnp.asarray(base),
                                                      max_l=max_len))
        else:
            dist = np.asarray(P.shortest_path_lengths(
                jnp.asarray(lr.layer_adj[i]), max_l=max_len))
        reach = dist <= max_len
        np.testing.assert_array_equal(lr.reach[i], reach)
        np.testing.assert_array_equal(
            lr.pathlen[i], np.where(reach, dist, 10_000).astype(np.int16))
        if scheme != "ksp":
            s, t = np.nonzero(reach & (dist > 0))
            v = lr.nh[i, s, t]
            assert (v >= 0).all()
            assert lr.layer_adj[i][s, v].all()
            np.testing.assert_array_equal(dist[v, t], dist[s, t] - 1)
    lr.validate_loop_free(n_samples=150, seed=3)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_build_layers_deterministic(sf5, scheme):
    a = L.build_layers(sf5, n_layers=3, rho=0.6, scheme=scheme, seed=4)
    b = L.build_layers(sf5, n_layers=3, rho=0.6, scheme=scheme, seed=4)
    np.testing.assert_array_equal(a.nh, b.nh)
    np.testing.assert_array_equal(a.layer_adj, b.layer_adj)


def test_build_layers_reports_build_stats(sf5):
    lr = L.build_layers(sf5, n_layers=3, rho=0.6, seed=0)
    assert lr.build_stats is not None
    assert lr.build_stats["total_s"] > 0
    assert lr.build_stats["device_s"] > 0


def test_ecmp_routing_batched_tables_valid():
    # fat tree: lots of equal-cost minimal paths, so differently
    # tie-broken tables must actually differ (SF would not do: its pairs
    # have a UNIQUE minimal path — the paper's Fig 6 point).
    from repro.core.topology import fat_tree

    topo = fat_tree(4)
    ecmp = TP.ecmp_routing(topo, n_tables=4, seed=0)
    adj = np.asarray(topo.adj, dtype=bool)
    max_len = max(6, topo.diameter_nominal + 2)
    dist = np.asarray(P.shortest_path_lengths(jnp.asarray(adj),
                                              max_l=max_len))
    for i in range(4):
        s, t = np.nonzero((dist > 0) & (dist <= max_len))
        v = ecmp.nh[i, s, t]
        assert (v >= 0).all()
        assert adj[s, v].all()
        np.testing.assert_array_equal(dist[v, t], dist[s, t] - 1)
    # differently tie-broken tables must actually differ
    assert any((ecmp.nh[0] != ecmp.nh[i]).any() for i in range(1, 4))
