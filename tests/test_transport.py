"""Flow-level transport simulator behaviour (paper §3.3, §7)."""

import numpy as np
import pytest

from repro.core import layers as L
from repro.core import traffic as TR
from repro.core import transport as TP
from repro.core.topology import slim_fly, star


@pytest.fixture(scope="module")
def setup():
    topo = slim_fly(5)
    lr = L.build_layers(topo, n_layers=5, rho=0.6, seed=0)
    ecmp = TP.ecmp_routing(topo, n_tables=4, seed=0)
    return topo, lr, ecmp


def test_conservation_and_completion(setup):
    topo, lr, _ = setup
    wl = TR.make_workload(topo, "permutation", seed=1)
    res = TP.simulate(topo, lr, wl, TP.SimConfig(n_steps=800))
    assert (res.delivered <= res.size + 1e-3).all(), "no over-delivery"
    assert res.fct_stats()["finished"] > 0.95
    ok = res.finished
    assert np.isfinite(res.fct[ok]).all()
    # FCT at least the latency floor (sw latency + >=0 hops)
    assert (res.fct[ok] >= res.config.sw_latency - 1e-9).all()


def test_ndp_beats_tcp_slow_start(setup):
    """Purified transport starts at line rate: short flows finish faster
    than TCP's slow-start ramp (paper §3.3 / §7.3)."""
    topo, lr, _ = setup
    wl = TR.make_workload(topo, "permutation", seed=2, flow_size=256 * 1024)
    ndp = TP.simulate(topo, lr, wl, TP.SimConfig(transport="ndp", n_steps=600))
    tcp = TP.simulate(topo, lr, wl, TP.SimConfig(transport="tcp", n_steps=600))
    assert ndp.fct_stats()["p50"] < tcp.fct_stats()["p50"]


def test_fatpaths_resolves_collisions_ecmp_cannot(setup):
    """Paper Fig 5 + §4.1 in one microcase: all endpoints of router A send
    to router B (distance 2).  SF has exactly ONE minimal A->B path, so
    ECMP *and* LetFlow stack every flow onto it; FatPaths spreads flowlets
    over non-minimal layers => ~2x faster completion.  This is the paper's
    thesis in miniature."""
    import jax.numpy as jnp
    from repro.core import paths as P
    topo, _, ecmp = setup
    lr9 = L.build_layers(topo, n_layers=9, rho=0.6, seed=0)
    ep2r = TR.endpoint_router_map(topo)
    dist = np.asarray(P.shortest_path_lengths(
        jnp.asarray(np.asarray(topo.adj, bool)), max_l=8))
    A, B = next((a, b) for a in range(topo.n_routers)
                for b in range(topo.n_routers) if dist[a, b] == 2)
    src = np.concatenate([np.where(ep2r == A)[0]] * 4)
    dst = np.tile(np.where(ep2r == B)[0], 4)
    wl = TR.FlowWorkload(
        src=src.astype(np.int32), dst=dst.astype(np.int32),
        size=np.full(len(src), 4 * 2 ** 20), start=np.zeros(len(src)),
        src_router=ep2r[src].astype(np.int32),
        dst_router=ep2r[dst].astype(np.int32))
    stats = {}
    for name, routing, bal in [("fp", lr9, "fatpaths"),
                               ("ecmp", ecmp, "ecmp"),
                               ("letflow", ecmp, "letflow")]:
        res = TP.simulate(topo, routing, wl,
                          TP.SimConfig(balancing=bal, n_steps=4000))
        stats[name] = res.fct_stats()
    assert stats["fp"]["finished"] == 1.0
    # minimal-path multipathing is useless here (one minimal path):
    np.testing.assert_allclose(stats["letflow"]["p50"],
                               stats["ecmp"]["p50"], rtol=0.05)
    # non-minimal layers give ~2x:
    assert stats["fp"]["p50"] < 0.65 * stats["ecmp"]["p50"], stats


def test_fatpaths_noninferior_on_randomized(setup):
    """§3.4-randomised traffic is the easy case; FatPaths must not lose to
    minimal ECMP there (paper: 'highest performance in such cases as
    well')."""
    topo, lr, ecmp = setup
    wl = TR.make_workload(topo, "adversarial", seed=3)
    # p99 of a single sim seed is noisy; compare the seed-mean tail
    # (simulate_seeds batches the sweep through one vmapped scan).
    fp = TP.simulate_seeds(topo, lr, wl,
                           TP.SimConfig(balancing="fatpaths", n_steps=1200),
                           range(4))
    ec = TP.simulate_seeds(topo, ecmp, wl,
                           TP.SimConfig(balancing="ecmp", n_steps=1200),
                           range(4))
    f_fp = [r.fct_stats() for r in fp]
    f_ec = [r.fct_stats() for r in ec]
    assert (np.mean([f["finished"] for f in f_fp])
            >= np.mean([f["finished"] for f in f_ec]) - 1e-9)
    assert (np.mean([f["p99"] for f in f_fp])
            <= np.mean([f["p99"] for f in f_ec]) * 1.25), (f_fp, f_ec)


def test_star_is_topology_free_baseline():
    """§7.1.6: the crossbar star shows pure endpoint contention."""
    topo = star(24)
    lr = TP.ecmp_routing(topo, n_tables=1)
    wl = TR.make_workload(topo, "permutation", seed=0)
    res = TP.simulate(topo, lr, wl, TP.SimConfig(n_steps=600,
                                                 balancing="ecmp"))
    st = res.fct_stats()
    assert st["finished"] == 1.0
    # permutation on a crossbar: no sharing -> tight FCT distribution
    assert st["p99"] <= st["p50"] * 3


def test_empty_workload_simulates(setup):
    """Regression: _virtual_links used to crash on ``wl.dst.max()`` for a
    zero-flow workload; an empty cell must shape-probe and simulate to an
    all-empty result instead."""
    topo, lr, _ = setup
    z = np.zeros(0)
    wl = TR.FlowWorkload(src=z.astype(np.int32), dst=z.astype(np.int32),
                         size=z, start=z,
                         src_router=z.astype(np.int32),
                         dst_router=z.astype(np.int32))
    n_flows, e_tot, n_layers = TP.shape_signature(topo, lr, wl)
    assert n_flows == 0 and e_tot > 0 and n_layers == lr.nh.shape[0]
    res = TP.simulate(topo, lr, wl, TP.SimConfig(n_steps=40))
    assert len(res.fct) == 0
    assert res.fct_stats()["finished"] == 0.0
    assert res.link_util_mean == 0.0


def test_flowlet_rerolls_under_congestion(setup):
    """All-to-one incast: fatpaths' flowlet elasticity must keep finishing
    flows (re-rolling layers), even if slowly."""
    topo, lr, _ = setup
    wl = TR.make_workload(topo, "alltoone", seed=1, flow_size=64 * 1024)
    res = TP.simulate(topo, lr, wl, TP.SimConfig(n_steps=1500))
    assert res.fct_stats()["finished"] > 0.5
