"""PR 10 link-churn engine: schedule invariants (sorted, disjoint,
nested in rate, padding-independent), the rate-0 / schedule-free
bit-for-bit contract against the PR 9 golden cells, `_churn_state`
capacity-vs-pickability semantics, re-convergence gating, engine
identity for churn cells across 8 devices, and the availability-SLO
acceptance pairing (FatPaths beats a layer-pinned scheme on the same
flapping fabric)."""

import subprocess
import sys
import textwrap

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest still run
    from _hypothesis_stub import given, settings, st  # noqa: F401

import jax.numpy as jnp

import repro.core.topology as topo_mod
from repro.core import failures as F
from repro.core import transport as TP
from repro.experiments.session import Session

from test_recovery import GOLDEN

IMAX = np.iinfo(np.int32).max


@pytest.fixture(scope="module")
def session():
    return Session()


@pytest.fixture(scope="module")
def sf5(session):
    return session.topology("sf(q=5)")


def _real_events(sched):
    """(link, k, (down, up)) triples for real (non-sentinel) events on
    the upper triangle."""
    s = np.asarray(sched)
    tri = np.triu(np.ones(s.shape[:2], dtype=bool), 1)
    out = {}
    for i, j in zip(*np.nonzero(tri)):
        ev = s[i, j][s[i, j, :, 0] < IMAX]
        if len(ev):
            out[(int(i), int(j))] = ev
    return out


# ---- schedule invariants ----------------------------------------------------
@pytest.mark.parametrize("pattern", F.CHURN_PATTERNS)
def test_churn_schedule_sorted_disjoint_symmetric(sf5, pattern):
    adj = np.asarray(sf5.adj, bool)
    key = F.scenario_key(0)
    a = F.churn_schedule(key, adj, 0.4, pattern=pattern, mtbf=30.0,
                        mttr=10.0, events=4)
    b = F.churn_schedule(key, adj, 0.4, pattern=pattern, mtbf=30.0,
                        mttr=10.0, events=4)
    assert (a == b).all()                           # deterministic
    assert (a == np.swapaxes(a, 0, 1)).all()        # symmetric
    assert not (np.any(a[..., 0] < IMAX, axis=-1) & ~adj).any()
    evs = _real_events(a)
    assert evs                                      # something churns
    for ev in evs.values():
        flat = ev.reshape(-1).astype(np.int64)
        assert ev[0, 0] >= 1                        # never gates step 0
        assert (np.diff(flat) > 0).all()            # 1<=d0<u0<d1<u1<...


def test_churn_flap_set_matches_bernoulli_and_is_nested(sf5):
    """flap/repair select churning links with the SAME uniforms as the
    bernoulli failure mask: the churned set at a lower rate is a subset
    of any higher rate, and a link's event stream is identical at every
    rate that includes it."""
    adj = np.asarray(sf5.adj, bool)
    key = F.scenario_key(3)
    for pattern in ("flap", "repair"):
        prev = {}
        for rate in (0.0, 0.05, 0.2, 0.5, 1.0):
            sched = F.churn_schedule(key, adj, rate, pattern=pattern,
                                     mtbf=40.0, mttr=15.0, events=3)
            evs = _real_events(sched)
            churned = np.any(sched[..., 0] < IMAX, axis=-1)
            dead = np.asarray(F.failure_mask(key, adj, rate, "bernoulli"))
            assert (churned == dead).all(), (pattern, rate)
            assert set(prev) <= set(evs), (pattern, rate)
            for lk, ev in prev.items():             # streams rate-invariant
                np.testing.assert_array_equal(evs[lk], ev)
            prev = evs


def test_churn_schedule_is_per_link_independent(sf5):
    """Masking every OTHER link out of the adjacency leaves a link's
    event stream untouched — draws are keyed by canonical link id, so
    schedules are invariant under padding and the presence of other
    links."""
    adj = np.asarray(sf5.adj, bool)
    key = F.scenario_key(0)
    full = _real_events(F.churn_schedule(key, adj, 0.6, mtbf=25.0,
                                         mttr=10.0, events=3))
    (i, j), want = sorted(full.items())[0]
    only = np.zeros_like(adj)
    only[i, j] = only[j, i] = True
    alone = _real_events(F.churn_schedule(key, only, 0.6, mtbf=25.0,
                                          mttr=10.0, events=3))
    np.testing.assert_array_equal(alone[(i, j)], want)


def test_churn_rate_zero_and_empty_adj():
    adj = np.asarray(topo_mod.clique(4).adj, bool)
    key = F.scenario_key(0)
    z = F.churn_schedule(key, adj, 0.0)
    assert (z == IMAX).all()
    assert F.churn_summary(z) == {"churn_links": 0, "churn_events": 0,
                                  "churn_first_down": -1}
    e = F.churn_schedule(key, np.zeros((4, 4), bool), 0.9)
    assert (e == IMAX).all()


def test_churn_rolling_covers_every_group_once(sf5):
    """Rolling maintenance: windows are sequential and disjoint in time,
    and every link carries the windows of its (<= 2) endpoint groups."""
    adj = np.asarray(sf5.adj, bool)
    n = adj.shape[0]
    sched = F.churn_schedule(F.scenario_key(0), adj, 0.25,
                             pattern="rolling", mtbf=20.0, mttr=8.0)
    gsize = max(1, int(round(0.25 * n)))
    group = np.arange(n) // gsize
    for (i, j), ev in _real_events(sched).items():
        want = sorted({int(group[i]), int(group[j])})
        downs = [20 + g * 28 for g in want]         # gap + g*(w+gap)
        np.testing.assert_array_equal(ev[:, 0], downs)
        np.testing.assert_array_equal(ev[:, 1], [d + 8 for d in downs])


def test_churn_summary_counts(sf5):
    adj = np.asarray(sf5.adj, bool)
    sched = F.churn_schedule(F.scenario_key(0), adj, 0.4, mtbf=30.0,
                             mttr=10.0, events=4)
    evs = _real_events(sched)
    summ = F.churn_summary(sched)
    assert summ["churn_links"] == len(evs)
    assert summ["churn_events"] == sum(len(e) for e in evs.values())
    assert summ["churn_first_down"] == min(
        int(e[0, 0]) for e in evs.values())


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 31),
       st.floats(0.05, 1.0), st.floats(0.05, 1.0),
       st.sampled_from(["exp", "pareto"]))
def test_churn_schedule_properties(seed, fseed, r_lo, r_hi, proc):
    """Random scenario keys / rates: events sorted and non-overlapping,
    down >= 1, symmetric, lower-rate event set nested in higher-rate
    with identical per-link streams."""
    adj = np.asarray(topo_mod.clique(7).adj, bool)
    key = F.scenario_key(seed, fseed)
    lo, hi = sorted((r_lo, r_hi))
    kw = dict(pattern="flap", mtbf=33.0, mttr=9.0, events=3, proc=proc)
    s_lo = F.churn_schedule(key, adj, lo, **kw)
    s_hi = F.churn_schedule(key, adj, hi, **kw)
    for s in (s_lo, s_hi):
        assert (s == np.swapaxes(s, 0, 1)).all()
        for ev in _real_events(s).values():
            flat = ev.reshape(-1).astype(np.int64)
            assert ev[0, 0] >= 1
            assert (np.diff(flat) > 0).all()
    lo_ev, hi_ev = _real_events(s_lo), _real_events(s_hi)
    assert set(lo_ev) <= set(hi_ev)
    for lk, ev in lo_ev.items():
        np.testing.assert_array_equal(hi_ev[lk], ev)


# ---- capacity vs pickability: the conv window -------------------------------
def test_churn_state_capacity_at_up_pickable_at_up_plus_conv():
    """An outage (down=5, up=10) with conv=3: capacity is zero on
    [5, 10), the link is unpickable on [5, 13) — flowlets may re-pick it
    only after the re-convergence delay."""
    sched = jnp.asarray([[[5, 10]], [[IMAX, IMAX]]], jnp.int32)
    pick = jnp.asarray([[13], [IMAX]], jnp.int32)
    want = {4: (False, False), 5: (True, True), 9: (True, True),
            10: (False, True), 12: (False, True), 13: (False, False)}
    for i, (dead, unpick) in want.items():
        d, u = TP._churn_state(jnp.int32(i), sched, pick)
        assert bool(d[0]) == dead and bool(u[0]) == unpick, i
        assert not bool(d[1]) and not bool(u[1])    # sentinel never fires


def test_churn_state_multi_event_and_zero_conv():
    sched = jnp.asarray([[[5, 10], [20, 25]]], jnp.int32)
    pick = sched[..., 1]                            # conv=0: pick == up
    for i, dead in [(5, True), (10, False), (19, False), (20, True),
                    (24, True), (25, False)]:
        d, u = TP._churn_state(jnp.int32(i), sched, pick)
        assert bool(d[0]) == dead and bool(u[0]) == dead, i


# ---- churn off reproduces the PR 9 golden cells bit-for-bit -----------------
@pytest.mark.parametrize("routing,evaluator", sorted(GOLDEN))
def test_churn_rate_zero_reproduces_golden_bitwise(session, routing,
                                                   evaluator):
    """`churn(rate=0)` realizes an empty schedule and must return the
    inner bundle itself — metrics equal the golden cells with ==, per
    transport mode, and no recovery-lane keys appear."""
    rr = session.run("clique(k=6)", f"churn(of={routing},rate=0)",
                     "uniform", evaluator, seed=0)
    want = GOLDEN[(routing, evaluator)]
    assert set(rr.metrics) == set(want)
    for k, v in want.items():
        assert rr.metrics[k] == v, (k, rr.metrics[k], v)


def test_churn_axis_rejects_nesting(session):
    with pytest.raises(Exception, match="nest"):
        session.run("clique(k=4)", "churn(of=churn(of=ecmp),rate=0.1)",
                    "uniform", "transport(steps=4)", seed=0)


def test_churn_cell_runs_and_reports_meta(session):
    rr = session.run(
        "clique(k=6)",
        "churn(of=fatpaths(n_layers=3),rate=0.4,mtbf=30,mttr=10,conv=4)",
        "uniform", "transport(steps=60,recovery=on)", seed=0)
    fm = rr.meta
    assert fm["churn_pattern"] == "flap" and fm["churn_rate"] == 0.4
    assert fm["churn_conv"] == 4 and fm["churn_links"] > 0
    assert fm["churn_events"] > 0 and fm["churn_first_down"] >= 1
    assert rr.metrics["retrans_mb"] >= 0
    # the outages actually bite vs the pristine cell
    base = session.run("clique(k=6)", "fatpaths(n_layers=3)", "uniform",
                       "transport(steps=60,recovery=on)", seed=0)
    assert rr.metrics["tput_gbs"] < base.metrics["tput_gbs"]


# ---- availability-SLO acceptance --------------------------------------------
_CHURN = ("churn(of={},rate=0.4,mtbf=100,mttr=80,conv=8)")
_HALFPERM = "permutation(flow_size=1000000000.0,frac=0.5)"
_AVAIL = "availability(steps=400,slo=0.8)"


def test_availability_fatpaths_beats_pinned_ecmp(session):
    """The PR's headline: under a flapping fabric at half-load, FatPaths
    with the recovery lanes armed re-routes around each outage and
    sustains strictly higher availability(slo=0.8) than the layer-pinned
    ecmp control, whose flows stay dark for every outage + nothing else
    runs in their place."""
    fp = session.run("clique(k=6)", _CHURN.format("fatpaths(n_layers=9)"),
                     _HALFPERM, _AVAIL, seed=0)
    ec = session.run("clique(k=6)", _CHURN.format("ecmp(n=4)"),
                     _HALFPERM, _AVAIL, seed=0)
    assert 0 < fp.metrics["availability"] < 1
    assert fp.metrics["availability"] > ec.metrics["availability"]
    for rr in (fp, ec):
        assert rr.metrics["plateau_goodput"] > 0
        assert rr.metrics["violations"] >= 1
        assert rr.metrics["max_outage_steps"] > 0
        assert rr.meta["availability_slo"] == 0.8
        assert (len(rr.meta["curve_steps"]) == len(rr.meta["goodput_curve"])
                == len(rr.meta["pristine_curve"]))
    assert fp.meta["pristine_routing"] == "fatpaths(n_layers=9)"
    assert ec.meta["pristine_routing"] == "ecmp(n=4)"


def test_availability_without_churn_is_trivial(session):
    rr = session.run("clique(k=6)", "fatpaths(n_layers=3)", _HALFPERM,
                     "availability(steps=120)", seed=0)
    assert rr.metrics["availability"] == 1.0
    assert rr.metrics["violations"] == 0.0
    assert rr.meta["pristine_routing"] == "fatpaths(n_layers=3)"


def test_recovery_reads_first_churn_down(session):
    """recovery(...) without a one-shot link_down_step falls back to the
    first churn down-event as the fault time."""
    rr = session.run(
        "clique(k=6)",
        "churn(of=fatpaths(n_layers=9),rate=0.4,mtbf=60,mttr=20,conv=4)",
        _HALFPERM, "recovery(steps=200)", seed=0)
    assert rr.metrics["dip_frac"] > 0               # the outages bit
    assert rr.metrics["plateau_goodput"] > 0


# ---- engine identity: churn grid, sequential vs 8 devices -------------------
_PROG = textwrap.dedent("""
    from repro.experiments import Session, compare_results
    from repro.experiments.dist_sweep import dist_sweep
    import jax
    assert jax.device_count() == 8, jax.device_count()
    grid = dict(
        topos=["clique(k=6)"],
        routings=[
            "churn(of=fatpaths(n_layers=3),rate=0.4,mtbf=30,mttr=10,conv=4)",
            "churn(of=ecmp(n=4),pattern=rolling,rate=0.34,mtbf=20,mttr=8,conv=4)",
            "fatpaths(n_layers=3)"],
        patterns=["uniform"],
        evaluators=["transport(steps=80,recovery=on)",
                    "transport(steps=80)"],
        seeds=[0, 1])
    seq = Session().sweep(**grid)
    s8 = Session()
    d8 = dist_sweep(s8, s8.grid(**grid), devices=8)
    diffs = compare_results(seq, d8)
    assert diffs == [], diffs[:5]
    ch = [r for r in d8 if r.routing.startswith("churn")]
    assert len(ch) == 8
    assert all(r.meta["churn_events"] > 0 for r in ch)
    print("CHURN8_OK")
""")


def test_churn_grid_8_devices_identical():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=600,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "CHURN8_OK" in r.stdout, r.stderr[-2000:]
