"""TP vs pure-FSDP logical layouts on one physical mesh (8 host devices,
subprocess): same model, same data => same loss, different collectives."""

import subprocess
import sys
import textwrap

_PROG = textwrap.dedent("""
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.dist.sharding import Runtime
    from repro.models.config import ModelConfig
    from repro.models import model as M
    from repro.train.train_step import TrainConfig, make_train_step, \\
        make_train_state

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
                      vocab=256, dtype="float32", remat="none")
    tok = jnp.asarray(np.arange(8 * 32).reshape(8, 32) % 256, jnp.int32)
    batch = {"tokens": tok, "labels": tok}

    losses = {}
    hlos = {}
    for name, rt in [
        ("tp", Runtime(mesh=mesh, data_axes=("data",))),
        ("fsdp", Runtime(mesh=mesh, data_axes=("data", "model"),
                         tp_disabled=True)),
    ]:
        params, opt, pspecs, ospecs = make_train_state(
            cfg, rt, jax.random.PRNGKey(0))
        step = make_train_step(cfg, rt, TrainConfig())
        with mesh:
            jitted = jax.jit(step)
            p2, o2, m2 = jitted(params, opt, batch, jax.random.PRNGKey(1))
            losses[name] = float(m2["loss"])
            hlos[name] = jitted.lower(params, opt, batch,
                                      jax.random.PRNGKey(1)) \\
                .compile().as_text()
    assert abs(losses["tp"] - losses["fsdp"]) < 1e-3, losses
    # TP layout must emit model-axis activation reductions; FSDP must not
    assert "all-reduce" in hlos["tp"] or "reduce-scatter" in hlos["tp"]
    print("LAYOUTS_OK", losses)
""")


def test_tp_and_fsdp_layouts_agree():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=900,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "LAYOUTS_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])
