"""int8 error-feedback gradient compression: training still converges."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist.sharding import Runtime
from repro.models.config import ModelConfig
from repro.models import model as M
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import TrainConfig, make_train_step

RT = Runtime(mesh=None)


def _cfg():
    return ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
                       vocab=128, dtype="float32", remat="none")


def test_int8_ef_trains():
    cfg = _cfg()
    tc = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20,
                                     compress="int8_ef"), grad_accum=2)
    params = M.init_params(cfg, RT, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, RT, tc))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, 128, (8, 32)), jnp.int32)  # fixed batch
    losses = []
    for i in range(12):
        params, opt, m = step(params, opt, {"tokens": tok, "labels": tok},
                              jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0] - 0.2, losses


def test_int8_quantizer_roundtrip():
    from repro.train.train_step import _quantize_int8
    x = jnp.asarray(np.random.default_rng(1).standard_normal(1000) * 0.01,
                    jnp.float32)
    q, s = _quantize_int8(x)
    err = np.abs(np.asarray(q, np.float32) * float(s) - np.asarray(x)).max()
    assert err <= float(s) * 0.51 + 1e-9
