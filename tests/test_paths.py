"""Shortest paths / path counting vs. networkx ground truth (Appendix B.1)."""

import networkx as nx
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest still run
    from _hypothesis_stub import given, settings, st  # noqa: F401

import jax.numpy as jnp

from repro.core import paths as P


def _random_graph(n, p, seed):
    g = nx.gnp_random_graph(n, p, seed=seed)
    adj = np.zeros((n, n), dtype=bool)
    for u, v in g.edges:
        adj[u, v] = adj[v, u] = True
    return adj, g


@settings(max_examples=12, deadline=None)
@given(st.integers(8, 24), st.integers(0, 10_000))
def test_shortest_path_lengths_match_networkx(n, seed):
    adj, g = _random_graph(n, 0.25, seed)
    dist = np.asarray(P.shortest_path_lengths(jnp.asarray(adj), max_l=n))
    nxd = dict(nx.all_pairs_shortest_path_length(g))
    for s in range(n):
        for t in range(n):
            expect = nxd.get(s, {}).get(t)
            if s == t:
                assert dist[s, t] == 0
            elif expect is None:
                assert dist[s, t] > n, "unreachable must exceed max_l"
            else:
                assert dist[s, t] == expect


def test_path_counts_exact_length():
    """A^l entries == number of length-l walks (Theorem 1)."""
    adj = np.array([[0, 1, 1, 0],
                    [1, 0, 1, 0],
                    [1, 1, 0, 1],
                    [0, 0, 1, 0]], dtype=bool)
    a = adj.astype(np.float64)
    for l in (1, 2, 3, 4):
        counts = np.asarray(P.path_counts_exact_length(jnp.asarray(adj), l))
        np.testing.assert_allclose(counts, np.linalg.matrix_power(a, l))


@settings(max_examples=8, deadline=None)
@given(st.integers(8, 20), st.integers(0, 10_000))
def test_forwarding_reaches_destination(n, seed):
    adj, g = _random_graph(n, 0.3, seed)
    nh = P.build_forwarding(adj, seed=seed)
    dist = np.asarray(P.shortest_path_lengths(jnp.asarray(adj), max_l=n))
    ss, tt = np.nonzero((dist > 0) & (dist <= n))
    if len(ss) == 0:
        return
    walked = P.walk_paths(nh, ss, tt, max_hops=n + 1)
    assert (walked[:, -1] == tt).all(), "every reachable pair is routed"
    # hop count equals shortest distance (minimal-path forwarding)
    hops = (walked[:, :-1] != walked[:, 1:]).sum(axis=1)
    np.testing.assert_array_equal(hops, dist[ss, tt])


def test_min_path_stats_sf(sf5):
    """Paper Fig 6: in SF most pairs have exactly one minimal path."""
    dist, counts = P.min_path_stats(np.asarray(sf5.adj))
    d2 = counts[dist == 2]
    assert (d2 == 1).mean() > 0.5
    assert (counts[dist == 1] == 1).all()
