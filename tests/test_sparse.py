"""Block-sparse path engine: kernel oracle parity, blocked-vs-dense
bit-identity across every layer scheme, and the compressed forwarding
representation (PR 9)."""

import numpy as np
import pytest

from repro.core import failures as F
from repro.core import layers as L
from repro.core import paths as P
from repro.core import topology as T
from repro.kernels import sparse_semiring_matmul, tile_occupancy
from repro.kernels.ref import semiring_matmul_ref

SCHEMES = ["rand", "undir", "pi_min", "spain", "past", "ksp"]


def _rand_operands(rng, n, semiring, density=0.25):
    a = (rng.random((n, n)) < density).astype(np.float32)
    b = (rng.random((n, n)) < density).astype(np.float32)
    if semiring == "minplus":
        a = np.where(a > 0, rng.integers(1, 9, (n, n)), np.inf)
        b = np.where(b > 0, rng.integers(1, 9, (n, n)), np.inf)
    return a.astype(np.float32), b.astype(np.float32)


# -----------------------------------------------------------------------------
# Kernel vs oracle.
# -----------------------------------------------------------------------------
@pytest.mark.parametrize("semiring", ["count", "bool", "minplus"])
def test_sparse_kernel_matches_oracle(semiring):
    rng = np.random.default_rng(3)
    a, b = _rand_operands(rng, 96, semiring)
    got = np.asarray(sparse_semiring_matmul(
        a, b, semiring, bm=32, bn=32, bk=32, interpret=True))
    want = np.asarray(semiring_matmul_ref(a, b, semiring))
    if semiring == "bool":
        want = want > 0.5
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("semiring", ["count", "minplus"])
def test_sparse_ref_backend_matches_dense(semiring):
    rng = np.random.default_rng(4)
    a, b = _rand_operands(rng, 64, semiring)
    got = np.asarray(sparse_semiring_matmul(a, b, semiring, backend="ref"))
    np.testing.assert_array_equal(got,
                                  np.asarray(semiring_matmul_ref(a, b,
                                                                 semiring)))


def test_tile_occupancy_flags_identity_tiles():
    a = np.zeros((64, 64), np.float32)
    a[40, 10] = 2.0                         # only tile (1, 0) is live
    occ = np.asarray(tile_occupancy(a, 32, 32, "count"))
    np.testing.assert_array_equal(occ, [[0, 0], [1, 0]])
    m = np.full((64, 64), np.inf, np.float32)
    m[5, 50] = 1.0                          # minplus identity is +inf
    occ = np.asarray(tile_occupancy(m, 32, 32, "minplus"))
    np.testing.assert_array_equal(occ, [[0, 1], [0, 0]])


# -----------------------------------------------------------------------------
# Blocked engine == dense engine, bit for bit.
# -----------------------------------------------------------------------------
def test_engine_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_PATH_ENGINE", raising=False)
    assert P.path_engine(50) == "dense"
    assert P.path_engine(P._BLOCKED_MIN_N) == "blocked"
    assert P.representation_for(50) == "dense"
    monkeypatch.setenv("REPRO_PATH_ENGINE", "blocked")
    assert P.path_engine(50) == "blocked"
    assert P.representation_for(50) == "compressed"
    monkeypatch.setenv("REPRO_PATH_ENGINE", "bogus")
    with pytest.raises(ValueError):
        P.path_engine(50)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_stack_parity_all_schemes(sf5, scheme):
    lr_d = L.build_layers(sf5, 4, 0.6, scheme=scheme, seed=2,
                          engine="dense", representation="dense")
    lr_b = L.build_layers(sf5, 4, 0.6, scheme=scheme, seed=2,
                          engine="blocked", representation="dense")
    np.testing.assert_array_equal(lr_d.nh, lr_b.nh)
    np.testing.assert_array_equal(lr_d.reach, lr_b.reach)
    np.testing.assert_array_equal(lr_d.pathlen, lr_b.pathlen)
    np.testing.assert_array_equal(lr_d.layer_adj, lr_b.layer_adj)


def test_apsp_parity_asymmetric_stack(sf5):
    # Oriented (DAG) layers make the stack adjacency asymmetric — the
    # frontier engine must relax over IN-neighbors, not out-neighbors.
    lr = L.build_layers(sf5, 5, 0.6, scheme="rand", seed=0)
    adj = np.asarray(lr.layer_adj, bool)
    assert not np.array_equal(adj[1], adj[1].T)
    import jax.numpy as jnp
    d_dense = np.asarray(P.apsp_batched(jnp.asarray(adj), max_l=16,
                                        engine="dense"))
    d_block = np.asarray(P.apsp_batched(jnp.asarray(adj), max_l=16,
                                        engine="blocked"))
    np.testing.assert_array_equal(d_dense, d_block)


def test_edge_usage_parity(sf5):
    import jax.numpy as jnp
    lr_d = L.build_layers(sf5, 3, 0.6, scheme="rand", seed=5, engine="dense")
    lr_b = L.build_layers(sf5, 3, 0.6, scheme="rand", seed=5,
                          engine="blocked")
    u_d = np.asarray(P.edge_usage_batched(jnp.asarray(lr_d.nh),
                                          jnp.asarray(lr_d.reach), 16))
    u_b = np.asarray(P.edge_usage_batched(jnp.asarray(lr_b.nh),
                                          jnp.asarray(lr_b.reach), 16))
    np.testing.assert_array_equal(u_d, u_b)


def test_min_path_stats_parity(sf5):
    adj = np.asarray(sf5.adj, bool)
    d0, c0 = P.min_path_stats(adj, max_l=6, engine="dense")
    d1, c1 = P.min_path_stats(adj, max_l=6, engine="blocked")
    np.testing.assert_array_equal(d0, d1)
    np.testing.assert_array_equal(c0, c1)


def test_ecmp_parity(sf5, monkeypatch):
    from repro.core.transport import ecmp_routing
    monkeypatch.delenv("REPRO_PATH_ENGINE", raising=False)
    ec_d = ecmp_routing(sf5, n_tables=4, seed=1)
    monkeypatch.setenv("REPRO_PATH_ENGINE", "blocked")
    ec_b = ecmp_routing(sf5, n_tables=4, seed=1)
    np.testing.assert_array_equal(ec_d.nh, ec_b.nh)
    assert ec_b.compressed is not None
    np.testing.assert_array_equal(ec_b.compressed.dense(), ec_b.nh)


def test_loop_check_reports_identical_after_failures(sf5, monkeypatch):
    """The loop-freedom repair re-resolves next hops against a
    failure-masked (asymmetric) adjacency; both engines must produce the
    same repaired tables and therefore identical LoopCheckReports."""
    base = L.build_layers(sf5, 4, 0.6, scheme="rand", seed=3)
    key = F.scenario_key(3, 0)
    dead = F.failure_mask(key, sf5.adj, 0.1, "bernoulli")
    monkeypatch.delenv("REPRO_PATH_ENGINE", raising=False)
    lr_d, rep_d = F.apply_failures(base, dead, mode="repair", seed=3)
    monkeypatch.setenv("REPRO_PATH_ENGINE", "blocked")
    lr_b, rep_b = F.apply_failures(base, dead, mode="repair", seed=3)
    np.testing.assert_array_equal(lr_d.nh, lr_b.nh)
    assert rep_d == rep_b
    chk_d = lr_d.validate_loop_free(n_samples=10 ** 9, raise_on_fail=False)
    chk_b = lr_b.validate_loop_free(n_samples=10 ** 9, raise_on_fail=False)
    assert chk_d == chk_b
    assert chk_d.exhaustive


# -----------------------------------------------------------------------------
# Compressed forwarding representation.
# -----------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_compressed_lookup_matches_dense_gather(seed):
    topo = T.jellyfish(40 + 8 * seed, 5, 2, seed=seed)
    lr = L.build_layers(topo, 3, 0.7, scheme="rand", seed=seed,
                        representation="compressed")
    assert lr.compressed is not None
    ct = lr.compressed
    np.testing.assert_array_equal(ct.dense(), lr.nh)
    rng = np.random.default_rng(seed)
    m = 500
    li = rng.integers(lr.n_layers, size=m)
    s = rng.integers(topo.n_routers, size=m)
    t = rng.integers(topo.n_routers, size=m)
    np.testing.assert_array_equal(ct.lookup(li, s, t), lr.nh[li, s, t])
    assert ct.nbytes < lr.nh.nbytes


def test_compressed_auto_block_high_radix():
    # An FT2 spine reaches every leaf via a distinct next hop, so a
    # 512-destination block would need >255 set entries — from_dense
    # must auto-halve the block until the uint8 selector fits.
    topo = T.two_layer_fat_tree(300, 4, 2)
    from repro.core.transport import ecmp_routing
    ec = ecmp_routing(topo, n_tables=2, seed=0)
    ct = P.CompressedTables.from_dense(ec.nh)
    assert ct.block < 512
    np.testing.assert_array_equal(ct.dense(), ec.nh)
    with pytest.raises(ValueError):
        P.CompressedTables.from_dense(ec.nh, block=512)


def test_walk_paths_compressed_parity(sf5):
    lr = L.build_layers(sf5, 4, 0.6, scheme="rand", seed=7,
                        representation="compressed")
    rng = np.random.default_rng(7)
    m = 200
    li = rng.integers(lr.n_layers, size=m)
    s = rng.integers(sf5.n_routers, size=m)
    t = rng.integers(sf5.n_routers, size=m)
    w_dense = P.walk_paths_layers(lr.nh, li, s, t, 16)
    w_comp = P.walk_paths_layers(lr.compressed, li, s, t, 16)
    np.testing.assert_array_equal(w_dense, w_comp)


def test_transport_prepare_compressed_parity(sf5):
    import jax

    from repro.core import traffic, transport
    lr_d = L.build_layers(sf5, 4, 0.6, scheme="rand", seed=1,
                          representation="dense")
    lr_c = L.build_layers(sf5, 4, 0.6, scheme="rand", seed=1,
                          representation="compressed")
    wl = traffic.make_workload(sf5, "permutation", seed=3)
    cfg = transport.SimConfig()
    arrs_d, stat_d = transport.prepare(sf5, lr_d, wl, cfg)
    arrs_c, stat_c = transport.prepare(sf5, lr_c, wl, cfg)
    assert stat_d == stat_c
    leaves_d = jax.tree_util.tree_leaves(arrs_d)
    leaves_c = jax.tree_util.tree_leaves(arrs_c)
    assert len(leaves_d) == len(leaves_c)
    for x, y in zip(leaves_d, leaves_c):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -----------------------------------------------------------------------------
# Cost-equalised two-layer fat tree.
# -----------------------------------------------------------------------------
def test_ft2_structure():
    ft2 = T.two_layer_fat_tree(8, 4, 4)
    ft2.validate()
    assert ft2.n_routers == 12 and ft2.n_endpoints == 32
    assert P.diameter(np.asarray(ft2.adj, bool)) == 2
    assert ft2.edge_density == pytest.approx(1 + 4 / 4)


def test_ft2_cost_match():
    sf = T.slim_fly(11)
    ft2 = T.cost_matched_ft2(sf)
    ft2.validate()
    assert abs(ft2.edge_density - sf.edge_density) / sf.edge_density < 0.05
    assert abs(ft2.n_endpoints - sf.n_endpoints) / sf.n_endpoints < 0.05


def test_ft2_catalog_registration():
    from repro.experiments.catalog import TOPOLOGIES, topo_spec
    t = TOPOLOGIES.build(topo_spec("ft2:8x4x4"))
    assert t.family == "ft2" and t.n_routers == 12
    teq = TOPOLOGIES.build(topo_spec("ft2eq(of=sf(q=5))"))
    assert teq.family == "ft2"
