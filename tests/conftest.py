"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit tests run on the real
single CPU device; anything needing a multi-device mesh spawns a subprocess
(see test_collectives.py) so the dry-run's 512-device forcing never leaks
into this session."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def sf5():
    from repro.core.topology import slim_fly
    return slim_fly(5)


@pytest.fixture(scope="session")
def df4():
    from repro.core.topology import dragonfly
    return dragonfly(4)


@pytest.fixture(scope="session")
def rt0():
    from repro.dist.sharding import Runtime
    return Runtime(mesh=None)
