"""Open-loop dynamic traffic through the transport scan: closed-loop
reduction, activation gating, early-exit safety with pending arrivals,
padding exactness of the activation lane, and the dynamic catalog axes
(load / incast+outcast / anycast)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import topology, transport as TP
from repro.core.traffic import make_workload
from repro.experiments import ExperimentSpec, Session, compare_results
from repro.experiments.dist_sweep import dist_sweep


def _cell(n_steps=200, chunk=64, transport="tcp", adaptive=True, seed=3):
    s = Session()
    topo = s.topology("clique(k=6)")
    bundle = s.routing("clique(k=6)", "fatpaths(n_layers=3)")
    cfg = TP.SimConfig(transport=transport, balancing=bundle.balancing,
                       n_steps=n_steps, horizon_chunk=chunk,
                       adaptive_horizon=adaptive, seed=seed)
    return topo, bundle, cfg


# ---- closed-loop reduction --------------------------------------------------
@pytest.mark.parametrize("transport", ["ndp", "tcp", "dctcp"])
def test_all_zero_activation_is_bitwise_closed_loop(transport):
    """active_step == zeros must reproduce the static-batch result bit
    for bit: the activation predicate reduces to the old start-time
    check, and the kernel's active lane to the old masking."""
    topo, bundle, cfg = _cell(transport=transport)
    wl = make_workload(topo, "uniform", seed=1)
    base = TP.simulate(topo, bundle.routing, wl, cfg)
    wl0 = dataclasses.replace(wl,
                              active_step=np.zeros(wl.n_flows, np.int32))
    dyn = TP.simulate(topo, bundle.routing, wl0, cfg)
    np.testing.assert_array_equal(base.fct, dyn.fct)
    np.testing.assert_array_equal(base.delivered, dyn.delivered)
    np.testing.assert_array_equal(base.finished, dyn.finished)
    np.testing.assert_array_equal(base.depart_step, dyn.depart_step)
    assert base.link_util_mean == dyn.link_util_mean


def test_activation_delays_departures():
    """A flow cannot send, finish, or depart before its activation step;
    a uniformly delayed copy of a workload finishes uniformly later."""
    from repro.core.arrivals import activation_starts

    topo, bundle, cfg = _cell()
    wl = make_workload(topo, "uniform", seed=1)
    base = TP.simulate(topo, bundle.routing, wl, cfg)
    delay = 17
    steps = np.full(wl.n_flows, delay, np.int32)
    wl_d = dataclasses.replace(
        wl, active_step=steps,
        start=activation_starts(steps, cfg.dt))
    dyn = TP.simulate(topo, bundle.routing, wl_d, cfg)
    assert (dyn.depart_step[dyn.finished] >= delay).all()
    # draws depend on (flow, step) — a delayed flow sees DIFFERENT draws,
    # so completion is not a pure shift; but nothing finishes earlier
    both = base.finished & dyn.finished
    assert both.any()
    assert (dyn.depart_step[both] > base.depart_step[both]).all()


# ---- early exit with pending arrivals ---------------------------------------
def test_early_exit_waits_for_late_arrivals():
    """Arrivals extending past the first horizon chunk must not be
    dropped by the early-exit predicate: adaptive == full horizon on
    every result-bearing channel, including depart_step."""
    topo, bundle, cfg = _cell(n_steps=320, chunk=32)
    wl = make_workload(topo, "uniform", seed=1)
    # all flows arrive AFTER the first chunk; staggered over chunks 2-5
    from repro.core.arrivals import activation_starts
    steps = (40 + 25 * (np.arange(wl.n_flows) % 4)).astype(np.int32)
    wl = dataclasses.replace(wl, active_step=steps,
                             start=activation_starts(steps, cfg.dt))
    jarrs, static = TP.prepare(topo, bundle.routing, wl, cfg)
    key = jax.random.PRNGKey(cfg.seed)
    cfg_f = dataclasses.replace(cfg, adaptive_horizon=False)
    fin_ad = jax.device_get(TP._run_scan(jarrs, key, cfg, static))
    fin_fl = jax.device_get(TP._run_scan(jarrs, key, cfg_f, static))
    for k in ("remaining", "hops", "sent_acc", "w_acc", "depart_step"):
        np.testing.assert_array_equal(fin_ad[k], fin_fl[k], err_msg=k)
    # and nothing departed before it arrived
    dep = fin_ad["depart_step"]
    assert (dep[dep >= 0] >= steps[dep >= 0]).all()


def test_padding_preserves_dynamic_results():
    """pad_prepared on a dynamic workload (extra flow rows, links, hop
    slots) is bitwise exact — padded rows never activate."""
    topo, bundle, cfg = _cell(n_steps=100, chunk=32)
    wl = make_workload(topo, "uniform", seed=2)
    from repro.core.arrivals import activation_starts
    steps = (np.arange(wl.n_flows) % 50).astype(np.int32)
    wl = dataclasses.replace(wl, active_step=steps,
                             start=activation_starts(steps, cfg.dt))
    base = TP.simulate(topo, bundle.routing, wl, cfg)
    arrs, static = TP.prepare(topo, bundle.routing, wl, cfg)
    F = arrs["size"].shape[0]
    padded, pstatic = TP.pad_prepared(
        arrs, static, n_flows=F + 11, n_edges=static[0] + 5,
        hop_slots=arrs["path_edges"].shape[2] + 1)
    fin = jax.device_get(TP._run_scan(padded, jax.random.PRNGKey(cfg.seed),
                                      cfg, pstatic))
    got = TP.batch_result(np.asarray(arrs["size"]),
                          {k: np.asarray(v) for k, v in fin.items()},
                          cfg, n_flows=F, start=np.asarray(arrs["start"]))
    np.testing.assert_array_equal(got.fct, base.fct)
    np.testing.assert_array_equal(got.delivered, base.delivered)
    np.testing.assert_array_equal(got.depart_step, base.depart_step)
    assert got.link_util_mean == base.link_util_mean


# ---- engine identity on dynamic cells ---------------------------------------
def test_dist_engine_matches_sequential_on_dynamic_cells():
    grid = dict(topos=["clique(k=6)"],
                routings=["fatpaths(n_layers=3)", "ecmp(n=2)"],
                patterns=["load(level=0.4,window=96)",
                          "incast(fan_in=4,waves=3,wave_period=32)"],
                evaluators=["transport(steps=150)"], seeds=[0])
    seq = Session().sweep(**grid)
    s = Session()
    dist = dist_sweep(s, s.grid(**grid), devices=1)
    assert compare_results(seq, dist) == []
    assert all("offered_gbs" in r.meta for r in dist)


# ---- catalog axes -----------------------------------------------------------
def test_load_cell_reports_offered_rate():
    r = Session().run(ExperimentSpec.make(
        "clique(k=6)", "fatpaths(n_layers=3)", "load(level=0.4,window=96)",
        "transport(steps=150)"))
    assert r.meta["offered_gbs"] > 0
    assert np.isfinite(r.metrics["fct_p50_us"])


def test_load_level_scales_flow_count():
    s = Session()
    topo = s.topology("clique(k=6)")
    lo = s.workload("clique(k=6)", "load(level=0.2,window=96)")
    hi = s.workload("clique(k=6)", "load(level=0.8,window=96)")
    assert hi.n_flows > 2.5 * lo.n_flows
    for wl in (lo, hi):
        assert wl.active_step is not None
        assert (np.diff(wl.active_step) >= 0).all()
        assert wl.n_flows <= topo.n_endpoints * 100


def test_incast_outcast_cell_reports_fairness():
    r = Session().run(ExperimentSpec.make(
        "clique(k=6)", "fatpaths(n_layers=3)",
        "incast(fan_in=4,waves=3,wave_period=32)", "outcast(steps=300)"))
    m = r.metrics
    assert m["victim_flows"] == 12.0          # 3 waves x 4 senders
    assert 0.0 < m["jain_goodput"] <= 1.0 + 1e-9
    assert m["fct_p99_over_p50"] >= 1.0
    assert np.isfinite(m["fct_p50_us"])


def test_incast_workload_structure():
    s = Session()
    wl = s.workload("clique(k=6)", "incast(fan_in=4,waves=3,wave_period=32)")
    assert wl.n_flows == 24                   # 12 data + 12 ack
    data, ack = ~wl.is_ack, wl.is_ack
    assert data.sum() == ack.sum() == 12
    victim = np.unique(wl.dst[data])
    assert len(victim) == 1                   # single victim
    assert (wl.src[ack] == victim[0]).all()   # acks flow back from it
    assert (wl.size[ack] < wl.size[data]).all()
    np.testing.assert_array_equal(np.unique(wl.active_step), [0, 32, 64])


def test_anycast_policy_orders_path_length():
    """closest resolves each client to a nearer replica than farthest
    does (strictly nearer somewhere on a non-degenerate topology)."""
    import jax.numpy as jnp

    from repro.core import paths as paths_mod

    s = Session()
    topo = s.topology("hx(l=2,s=3)")
    near = s.workload("hx(l=2,s=3)", "anycast(replicas=3,policy=closest)")
    far = s.workload("hx(l=2,s=3)", "anycast(replicas=3,policy=farthest)")
    np.testing.assert_array_equal(near.src, far.src)  # same clients
    dist = np.asarray(paths_mod.shortest_path_lengths(
        jnp.asarray(np.asarray(topo.adj, bool)), max_l=16))
    d_near = dist[near.src_router, near.dst_router]
    d_far = dist[far.src_router, far.dst_router]
    assert (d_near <= d_far).all()
    assert d_near.mean() < d_far.mean()
    assert near.active_step is not None


def test_anycast_rejects_unknown_policy():
    from repro.experiments.specs import SpecError

    with pytest.raises(SpecError, match="policy"):
        Session().workload("clique(k=6)", "anycast(policy=nearest)")
