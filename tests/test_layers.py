"""Layered routing properties (paper §5.2-§5.4, Listing 1)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; the rest still run
    from _hypothesis_stub import given, settings, st  # noqa: F401

from repro.core import layers as L
from repro.core.topology import slim_fly, dragonfly, jellyfish


SCHEMES = ["rand", "undir", "pi_min", "spain", "past", "ksp"]


@pytest.fixture(scope="module")
def lr(sf5_mod=None):
    from repro.core.topology import slim_fly
    return L.build_layers(slim_fly(5), n_layers=5, rho=0.6, seed=0)


def test_layer0_is_full_graph(lr):
    np.testing.assert_array_equal(lr.layer_adj[0],
                                  np.asarray(lr.topo.adj, dtype=bool))
    assert lr.reach[0].all(), "layer 0 routes every pair (D=2 connected)"


def test_oriented_layers_are_dags(lr):
    """Listing 1: pi(u) < pi(v) orientation => acyclic layers."""
    for i in range(1, lr.n_layers):
        a = lr.layer_adj[i].astype(float)
        n = a.shape[0]
        # a DAG has a nilpotent adjacency matrix
        power = a.copy()
        for _ in range(n.bit_length() + 1):
            power = np.clip(power @ power, 0, 1)
        assert power.trace() == 0, f"layer {i} has a cycle"


def test_layer_sparsity(lr):
    full = lr.layer_adj[0].sum()  # directed count = 2x undirected
    for i in range(1, lr.n_layers):
        frac = lr.layer_adj[i].sum() / (full / 2)   # oriented: one dir each
        assert 0.3 < frac < 0.9, "rho=0.6 sampled edges out of range"


def test_loop_free_all_schemes():
    topo = slim_fly(5)
    for scheme in SCHEMES:
        lr = L.build_layers(topo, n_layers=4, rho=0.6, scheme=scheme, seed=1)
        report = lr.validate_loop_free(n_samples=80, seed=2)
        assert report and report.n_checked > 0 and not report.exhaustive


def test_loop_check_report_describe():
    ok = L.LoopCheckReport(ok=True, n_checked=42, exhaustive=True)
    assert bool(ok) and "exhaustive" in ok.describe()
    sampled = L.LoopCheckReport(ok=True, n_checked=42, exhaustive=False)
    assert "sampled" in sampled.describe()
    bad = L.LoopCheckReport(ok=False, n_checked=42, exhaustive=True,
                            witnesses=((1, 2, 3),), kinds=("loop",))
    assert not bad
    assert "loop@(l=1,s=2,t=3)" in bad.describe()
    assert "1 bad forwarding entry" in bad.describe()


def test_reach_walk_consistency(lr):
    """reach[i, s, t] == True must imply the walk reaches t."""
    from repro.core import paths as P
    rng = np.random.default_rng(3)
    n = lr.nh.shape[1]
    for _ in range(60):
        i = rng.integers(lr.n_layers)
        s, t = rng.choice(n, 2, replace=False)
        seq = P.walk_paths(lr.nh[i], np.array([s]), np.array([t]),
                           max_hops=20)[0]
        if lr.reach[i, s, t]:
            assert seq[-1] == t
        else:
            assert seq[-1] != t


def test_nonminimal_layers_give_longer_paths(lr):
    """Sparse-layer paths are non-minimal in the full topology (the point
    of FatPaths): intra-layer path length >= global shortest distance, with
    strict inequality for a decent fraction."""
    from repro.core import paths as P
    import jax.numpy as jnp
    dist = np.asarray(P.shortest_path_lengths(
        jnp.asarray(np.asarray(lr.topo.adj, dtype=bool)), max_l=8))
    longer = total = 0
    for i in range(1, lr.n_layers):
        m = lr.reach[i] & (dist > 0)
        total += m.sum()
        longer += (lr.pathlen[i][m] > dist[m]).sum()
    assert longer > 0.2 * total


def test_pi_min_reduces_overlap():
    """§5.3.2 heuristic should not *increase* average inter-layer overlap."""
    topo = slim_fly(5)
    r1 = L.build_layers(topo, 5, 0.6, scheme="rand", seed=5)
    r2 = L.build_layers(topo, 5, 0.6, scheme="pi_min", seed=5)

    def overlap(lr):
        tot = 0.0
        for i in range(1, lr.n_layers):
            for j in range(1, i):
                inter = (lr.layer_adj[i] & lr.layer_adj[j]).sum()
                union = (lr.layer_adj[i] | lr.layer_adj[j]).sum()
                tot += inter / max(union, 1)
        return tot

    assert overlap(r2) <= overlap(r1) * 1.15


def test_disjoint_paths_grow_with_layers():
    """Paper Fig 12: more layers -> more realised disjoint paths.  The
    'nine layers => three disjoint paths' regime needs paper-scale k'
    (N~10k, k'~30) — checked by benchmarks/bench_layers.py; here (q=7,
    k'=11) we assert monotone growth and a sane floor."""
    topo = slim_fly(7)
    lr3 = L.build_layers(topo, 3, 0.6, seed=0)
    lr9 = L.build_layers(topo, 9, 0.6, seed=0)
    rng = np.random.default_rng(0)

    def mean_disjoint(lr):
        vals = []
        rng2 = np.random.default_rng(1)
        for _ in range(30):
            s, t = rng2.choice(topo.n_routers, 2, replace=False)
            vals.append(L.layer_disjoint_paths(lr, s, t))
        return np.mean(vals)

    m3, m9 = mean_disjoint(lr3), mean_disjoint(lr9)
    assert m9 >= m3, (m3, m9)
    assert m9 >= 1.5


def test_spain_layers_are_trees():
    topo = slim_fly(5)
    lr = L.build_layers(topo, 4, 0.6, scheme="spain", seed=0)
    n = topo.n_routers
    for i in range(1, lr.n_layers):
        und = lr.layer_adj[i] | lr.layer_adj[i].T
        assert und.sum() // 2 <= n - 1, "SPAIN layer is a spanning tree"
