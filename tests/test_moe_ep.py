"""EP (all_to_all expert-parallel) MoE must match TP MoE numerically.

Runs on 8 forced host devices in a subprocess (mesh (2, 4): data x model).
With generous capacity no tokens drop, so the two dispatch strategies give
the same function.
"""

import subprocess
import sys
import textwrap

_PROG = textwrap.dedent("""
    import numpy as np, dataclasses
    import jax, jax.numpy as jnp
    from repro.dist.sharding import Runtime
    from repro.models.config import ModelConfig, MoEConfig
    from repro.models import moe as moe_mod

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_head=8, d_ff=64, vocab=64,
                      dtype="float32",
                      moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16,
                                    capacity_factor=8.0))
    rt_tp = Runtime(mesh=mesh, moe_mode="tp")
    rt_ep = Runtime(mesh=mesh, moe_mode="ep")
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

    with mesh:
        y_tp, aux_tp = jax.jit(
            lambda p, v: moe_mod.moe_apply(p, cfg, rt_tp, v))(params, x)
        y_ep, aux_ep = jax.jit(
            lambda p, v: moe_mod.moe_apply(p, cfg, rt_ep, v))(params, x)
    err = float(jnp.abs(y_tp - y_ep).max())
    rel = err / float(jnp.abs(y_tp).max())
    assert rel < 2e-4, (err, rel)
    # aux: EP averages per-shard switch estimators (local token counts),
    # TP computes one global estimator — same regularizer, slightly
    # different estimate.
    assert abs(float(aux_tp) - float(aux_ep)) < 0.25 * float(aux_tp)
    # gradients flow through the all_to_all dispatch
    g = jax.grad(lambda p: jnp.sum(
        moe_mod.moe_apply(p, cfg, rt_ep, x)[0] ** 2))(params)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("MOE_EP_OK", rel)
""")


def test_ep_matches_tp():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=600,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "MOE_EP_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])
