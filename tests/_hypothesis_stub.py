"""Minimal stand-in for hypothesis when it is not installed.

Property tests decorated with the stub ``given`` skip with a clear
reason; everything else in the importing module still runs.  Strategy
constructors accept anything and return inert placeholders (they are
only ever passed to ``given``).
"""

import pytest


class _Strategy:
    def __getattr__(self, name):
        return self

    def __call__(self, *args, **kwargs):
        return self


st = _Strategy()


def given(*_args, **_kwargs):
    def deco(fn):
        # zero-arg wrapper: the hypothesis-provided params must not look
        # like pytest fixtures
        def wrapper():
            pytest.skip("hypothesis not installed")
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco
