"""Elastic restore: checkpoint written under one mesh layout restores onto
a different mesh (8 host devices, subprocess) — the restart-on-different-
pod-count story."""

import subprocess
import sys
import textwrap

_PROG = textwrap.dedent("""
    import tempfile
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt.checkpoint import save_checkpoint, restore_checkpoint

    mesh_a = jax.make_mesh((8, 1), ("data", "model"))
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))

    x = jnp.arange(16 * 12, dtype=jnp.float32).reshape(16, 12)
    state_a = {"w": jax.device_put(
        x, NamedSharding(mesh_a, P("data", None)))}

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, state_a, {"next_step": 5})
        # target: different mesh AND different partitioning
        like_b = {"w": jax.device_put(
            jnp.zeros_like(x), NamedSharding(mesh_b, P("model", "data")))}
        restored, extra = restore_checkpoint(d, like_b)
        assert extra["next_step"] == 5
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(x))
        s = restored["w"].sharding
        assert s.spec == P("model", "data"), s
    print("ELASTIC_OK")
""")


def test_elastic_cross_mesh_restore():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=300,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "ELASTIC_OK" in r.stdout, (r.stdout[-300:], r.stderr[-1500:])
