"""Fault-injection engine: mask determinism/nestedness, repair-vs-drop
degradation semantics, the rate-0 bit-for-bit contract for every
transport mode, mid-run link death + flowlet rerouting, the degradation
evaluator's monotone curves, and engine identity for failure cells."""

import dataclasses

import numpy as np
import pytest

import repro.core.topology as topo_mod
from repro.core import failures as F
from repro.core import transport as TP
from repro.experiments.dist_sweep import dist_sweep
from repro.experiments.results import compare_results
from repro.experiments.session import Session


@pytest.fixture(scope="module")
def session():
    return Session()


@pytest.fixture(scope="module")
def sf5(session):
    return session.topology("sf(q=5)")


# ---- failure masks ----------------------------------------------------------
def test_masks_deterministic_symmetric_and_adj_subset(sf5):
    adj = np.asarray(sf5.adj, bool)
    key = F.scenario_key(0)
    for pat in F.PATTERNS:
        a = F.failure_mask(key, adj, 0.2, pat)
        b = F.failure_mask(key, adj, 0.2, pat)
        assert (a == b).all()
        assert (a == a.T).all()
        assert not (a & ~adj).any()          # only real links die
        assert a.any()


def test_masks_nested_in_rate(sf5):
    """Coupled draws: the dead set at a lower rate is a SUBSET of the
    dead set at any higher rate — degradation curves are monotone in the
    failure set by construction."""
    adj = np.asarray(sf5.adj, bool)
    key = F.scenario_key(1)
    for pat in F.PATTERNS:
        prev = np.zeros_like(adj)
        for rate in (0.0, 0.02, 0.05, 0.1, 0.3, 0.7):
            dead = F.failure_mask(key, adj, rate, pat)
            assert (prev <= dead).all(), (pat, rate)
            prev = dead


def test_mask_rate_zero_and_one_extremes(sf5):
    adj = np.asarray(sf5.adj, bool)
    key = F.scenario_key(0)
    assert not F.failure_mask(key, adj, 0.0, "bernoulli").any()
    dead = F.failure_mask(key, adj, 1.0, "blast")
    assert (dead == adj).all()               # blast at rate 1 kills all


def test_switch_kill_is_per_router(sf5):
    """A failed router loses ALL incident links — dead links of the
    switch pattern decompose into full router stars."""
    adj = np.asarray(sf5.adj, bool)
    key = F.scenario_key(2)
    n = adj.shape[0]
    ur = F.link_uniforms(key, n * n + np.arange(n))
    down = ur < 0.2
    dead = F.failure_mask(key, adj, 0.2, "switch")
    expect = adj & (down[:, None] | down[None, :])
    assert (dead == expect).all()


def test_mask_draws_are_per_link_independent(sf5):
    """A link's uniform depends only on (key, link id): querying ids one
    at a time reproduces the batch draw (padding/shape independence)."""
    adj = np.asarray(sf5.adj, bool)
    key = F.scenario_key(0)
    iu, ju = np.nonzero(np.triu(adj, 1))
    ids = iu.astype(np.int64) * adj.shape[0] + ju
    batch = F.link_uniforms(key, ids)
    singles = np.array([F.link_uniforms(key, [i])[0] for i in ids[:16]])
    assert (batch[:16] == singles).all()


def test_scenario_key_varies_with_seed_and_fseed(sf5):
    adj = np.asarray(sf5.adj, bool)
    masks = {F.failure_mask(F.scenario_key(s, f), adj, 0.2,
                            "bernoulli").tobytes()
             for s in (0, 1) for f in (0, 1)}
    assert len(masks) == 4                   # all scenarios distinct


# ---- apply_failures: repair and drop ---------------------------------------
@pytest.fixture(scope="module")
def stack(session):
    return session.routing("sf(q=5)", "fatpaths(n_layers=5)").routing


def test_repair_reresolves_and_stays_loop_free(stack, sf5):
    dead = F.failure_mask(F.scenario_key(0), np.asarray(sf5.adj, bool),
                          0.15, "bernoulli")
    lr, rep = F.apply_failures(stack, dead, mode="repair", rate=0.15)
    assert lr is not stack
    assert not (lr.layer_adj & dead[None]).any()     # dead links removed
    # exhaustive walk over every (layer, s, t) entry
    report = lr.validate_loop_free(n_samples=10 ** 9)
    assert report.ok and report.exhaustive
    assert rep.mode == "repair" and rep.failed_links > 0
    # repaired next hops only use surviving layer edges
    L, N, _ = lr.nh.shape
    for layer in range(L):
        s, t = np.nonzero(lr.reach[layer] & ~np.eye(N, dtype=bool))
        nh = lr.nh[layer, s, t]
        assert lr.layer_adj[layer, s, nh].all()


def test_drop_invalidates_broken_entries(stack, sf5):
    dead = F.failure_mask(F.scenario_key(0), np.asarray(sf5.adj, bool),
                          0.15, "bernoulli")
    lr, rep = F.apply_failures(stack, dead, mode="drop", rate=0.15)
    # surviving entries are unchanged table entries (a sub-table)
    kept = lr.reach
    assert (lr.nh[kept] == stack.nh[kept]).all()
    assert (kept <= stack.reach).all()
    # no surviving entry's first hop crosses a dead link
    L, N, _ = lr.nh.shape
    off = ~np.eye(N, dtype=bool)
    s, t = np.nonzero((kept & off[None]).any(axis=0))
    assert rep.disconnected_pairs >= 0
    for layer in range(L):
        ss, tt = np.nonzero(kept[layer] & off)
        assert not dead[ss, lr.nh[layer, ss, tt]].any()
    assert lr.validate_loop_free(n_samples=10 ** 9).ok


def test_drop_counts_dead_layers():
    """Killing every link of a sparse layer leaves it reach-free and
    counted in dead_layers."""
    s = Session()
    lr = s.routing("sf(q=5)", "fatpaths(n_layers=4,rho=0.3)").routing
    # kill exactly layer 1's links (undirected closure of its DAG edges)
    la1 = lr.layer_adj[1]
    dead = la1 | la1.T
    assert dead.any()
    degraded, rep = F.apply_failures(lr, dead, mode="drop")
    off = ~np.eye(dead.shape[0], dtype=bool)
    assert not (degraded.reach[1] & off).any()
    assert rep.dead_layers >= 1


def test_disconnection_counts_monotone_in_rate(stack, sf5):
    adj = np.asarray(sf5.adj, bool)
    key = F.scenario_key(4)
    prev_disc, prev_deadl = -1, -1
    for rate in (0.05, 0.2, 0.5, 0.8):
        dead = F.failure_mask(key, adj, rate, "switch")
        _, rep = F.apply_failures(stack, dead, mode="drop", rate=rate)
        assert rep.disconnected_pairs >= prev_disc
        assert rep.dead_layers >= prev_deadl
        prev_disc, prev_deadl = rep.disconnected_pairs, rep.dead_layers


def test_empty_mask_returns_same_object(stack):
    n = stack.nh.shape[1]
    lr, rep = F.apply_failures(stack, np.zeros((n, n), bool))
    assert lr is stack                        # bit-for-bit by identity
    assert rep.failed_links == 0 and rep.disconnected_pairs == 0


# ---- rate=0 bit-for-bit through the experiment axis ------------------------
@pytest.mark.parametrize("transport", ["ndp", "tcp", "dctcp"])
def test_rate_zero_reproduces_pristine_cell_bitwise(transport):
    s = Session()
    ev = f"transport(steps=40,transport={transport})"
    base = s.run("clique(k=6)", "fatpaths(n_layers=3)", "uniform", ev)
    wrapped = s.run("clique(k=6)", "failures(of=fatpaths(n_layers=3),rate=0)",
                    "uniform", ev)
    assert base.metrics == wrapped.metrics    # exact float equality
    assert wrapped.meta["failed_links"] == 0
    assert wrapped.meta["dead_layers"] == 0


# ---- mid-run link death ----------------------------------------------------
def test_link_down_schedule_layout():
    dead = np.zeros((4, 4), bool)
    dead[0, 1] = True                         # one direction set ...
    lds = F.link_down_schedule(dead, 7)
    assert lds[0, 1] == 7 and lds[1, 0] == 7  # ... both directions die
    assert lds[2, 3] == np.iinfo(np.int32).max


def test_midrun_death_changes_results_only_after_step():
    """Same fabric, death scheduled beyond the horizon == pristine."""
    s = Session()
    topo = s.topology("clique(k=6)")
    b = s.routing("clique(k=6)", "fatpaths(n_layers=3)")
    wl = s.workload("clique(k=6)", "uniform")
    dead = F.failure_mask(F.scenario_key(0), np.asarray(topo.adj, bool),
                          0.3, "bernoulli")
    cfg = TP.SimConfig(transport="ndp", balancing="fatpaths", n_steps=80,
                       seed=0)
    base = TP.simulate(topo, b.routing, wl, cfg)
    late = dataclasses.replace(
        b.routing, link_down_step=F.link_down_schedule(dead, 10_000))
    mid = dataclasses.replace(
        b.routing, link_down_step=F.link_down_schedule(dead, 10))
    r_late = TP.simulate(topo, late, wl, cfg)
    r_mid = TP.simulate(topo, mid, wl, cfg)
    assert (r_late.fct[r_late.finished] == base.fct[base.finished]).all()
    assert float(r_mid.delivered.sum()) < float(base.delivered.sum())


def test_midrun_reroute_recovers_goodput_vs_no_reroute():
    """The acceptance scenario: links die mid-run; flowlet balancing
    re-picks surviving layers and delivers more than the pinned-layer
    (no-reroute) control on the SAME degraded fabric."""
    s = Session()
    topo = s.topology("clique(k=6)")
    lr = s.routing("clique(k=6)", "fatpaths(n_layers=5)").routing
    wl = s.workload("clique(k=6)", "uniform")
    dead = F.failure_mask(F.scenario_key(3), np.asarray(topo.adj, bool),
                          0.35, "bernoulli")
    assert dead.any()
    hurt = dataclasses.replace(lr,
                               link_down_step=F.link_down_schedule(dead, 30))
    out = {}
    for balancing in ("fatpaths", "ecmp"):    # ecmp = layer pinned forever
        cfg = TP.SimConfig(transport="ndp", balancing=balancing,
                           n_steps=400, seed=0)
        r = TP.simulate(topo, hurt, wl, cfg)
        out[balancing] = (float(r.delivered.sum()), float(r.finished.mean()))
    assert out["fatpaths"][0] > out["ecmp"][0]
    assert out["fatpaths"][1] > out["ecmp"][1]


# ---- experiment axis + engines ---------------------------------------------
FAIL_GRID = dict(
    topos=["sf(q=5)", "df(p=3)"],
    routings=["failures(of=fatpaths(n_layers=3),rate=0.05)",
              "failures(of=fatpaths(n_layers=3),rate=0.15)",
              "failures(of=fatpaths(n_layers=3),rate=0.3)",
              "failures(of=ecmp(n=2),rate=0.15,pattern=switch,mode=drop)",
              "failures(of=letflow(n=2),rate=0.15,pattern=blast)",
              "failures(of=fatpaths(n_layers=3),rate=0.15,down_step=20)"],
    patterns=["uniform"],
    evaluators=["transport(steps=40)"],
    seeds=[0],
)


def test_failure_grid_engine_identity_and_meta():
    """Sequential engine == distributed batch engine at rtol 0 for a
    failure-rate x pattern grid (static repair, static drop, mid-run);
    every failure cell's meta carries the damage counts."""
    s1, s2 = Session(), Session()
    seq = [s1.run(spec) for spec in s1.grid(**FAIL_GRID)]
    dist = dist_sweep(s2, s2.grid(**FAIL_GRID), devices=1)
    assert compare_results(seq, dist) == []
    for r in dist:
        assert "dead_layers" in r.meta and "disconnected_pairs" in r.meta
        assert "failed_links" in r.meta
    # nested masks: damage monotone over the rate ladder (dist results
    # come back in grid order: topo-major, routings in listed order,
    # and the first three routings are the fatpaths rate ladder)
    n_r = len(FAIL_GRID["routings"])
    for ti, topo in enumerate(FAIL_GRID["topos"]):
        ladder = dist[ti * n_r: ti * n_r + 3]
        assert [r.topo for r in ladder] == [ladder[0].topo] * 3
        fails = [r.meta["failed_links"] for r in ladder]
        discs = [r.meta["disconnected_pairs"] for r in ladder]
        assert fails == sorted(fails) and fails[-1] > 0
        assert discs == sorted(discs)


def test_degradation_evaluator_curves():
    s = Session()
    rr = s.run("sf(q=5)", "fatpaths(n_layers=3)", "shuffle",
               "degradation(steps=60,rates=0.1:0.4,patterns=switch)")
    m = rr.metrics
    assert m["monotone_disc_switch"] == 1.0
    assert m["disc_switch_r0.1"] <= m["disc_switch_r0.4"]
    assert m["finished_switch_r0.4"] <= m["finished_base"]
    assert rr.meta["scenarios"]["switch_r0.4"]["failure_pattern"] == "switch"
    # identical spec through a fresh session reproduces the curve exactly
    rr2 = Session().run("sf(q=5)", "fatpaths(n_layers=3)", "shuffle",
                        "degradation(steps=60,rates=0.1:0.4,patterns=switch)")
    assert rr.metrics == rr2.metrics


def test_failures_axis_rejects_nesting_and_bad_pattern():
    from repro.experiments.specs import SpecError
    s = Session()
    with pytest.raises(SpecError):
        s.routing("clique(k=6)", "failures(of=failures(of=ecmp))")
    with pytest.raises(ValueError):
        F.failure_mask(F.scenario_key(0), np.eye(4, dtype=bool), 0.5,
                       "meteor")


# ---- loop-freedom witnesses (satellite) ------------------------------------
def test_validate_loop_free_reports_witnesses():
    s = Session()
    lr = s.routing("clique(k=6)", "fatpaths(n_layers=3)").routing
    # layer 0 is the minimal layer: full off-diagonal reach on a clique,
    # so the corrupted entries are guaranteed to be checked
    assert lr.reach[0, 0, 2] and lr.reach[0, 1, 2]
    bad = dataclasses.replace(lr, nh=lr.nh.copy())
    # manufacture a 2-cycle: 0 -> 1 -> 0 towards destination 2
    bad.nh[0, 0, 2] = 1
    bad.nh[0, 1, 2] = 0
    report = bad.validate_loop_free(n_samples=10 ** 9, raise_on_fail=False)
    assert not report
    assert report.exhaustive
    assert (0, 0, 2) in report.witnesses and (0, 1, 2) in report.witnesses
    kinds = dict(zip(report.witnesses, report.kinds))
    assert kinds[(0, 0, 2)] in ("loop", "hole")
    with pytest.raises(AssertionError, match=r"l=0"):
        bad.validate_loop_free(n_samples=10 ** 9)


def test_validate_loop_free_exhaustive_beats_sampling():
    """The old sampler could silently pass when n_samples exceeded the
    pair count but the draws missed the bad entry; exhaustive mode
    checks EVERY entry."""
    s = Session()
    lr = s.routing("clique(k=3)", "ecmp(n=1)").routing
    L, N, _ = lr.nh.shape
    assert 10 ** 9 >= L * N * (N - 1)
    report = lr.validate_loop_free(n_samples=10 ** 9)
    assert report.exhaustive
    assert report.n_checked == int((lr.reach & ~np.eye(N, dtype=bool)).sum())
    sampled = lr.validate_loop_free(n_samples=5)
    assert not sampled.exhaustive


def test_validate_loop_free_ok_on_all_schemes_returns_report(sf5, session):
    for scheme in ("fatpaths(n_layers=3)", "ecmp(n=2)"):
        lr = session.routing("sf(q=5)", scheme).routing
        report = lr.validate_loop_free(n_samples=100, seed=1)
        assert report and report.n_checked > 0
