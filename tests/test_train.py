"""Training loop: convergence, restart determinism, fault tolerance."""

import os
import tempfile

import numpy as np
import pytest
import jax

from repro.data.pipeline import DataConfig
from repro.dist.sharding import Runtime
from repro.models.config import ModelConfig
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optimizer import AdamWConfig, schedule
from repro.train.train_step import TrainConfig
import jax.numpy as jnp


RT = Runtime(mesh=None)


def _tiny():
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
                       vocab=128, dtype="float32", remat="none")


def _loop(d, total, inject=None, ga=1):
    return TrainLoop(
        _tiny(), RT, DataConfig(global_batch=8, seq_len=32),
        TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                    total_steps=total), grad_accum=ga),
        LoopConfig(total_steps=total, ckpt_every=10, log_every=5,
                   ckpt_dir=d, inject_failure_at=inject))


def test_loss_decreases():
    with tempfile.TemporaryDirectory() as d:
        out = _loop(d, 30).run()
        losses = [h["loss"] for h in out["history"]]
        assert losses[-1] < losses[0] - 0.3


def test_failure_injection_and_restart_reproduces_trajectory():
    """Crash at step 17, restart, and the post-restart losses must equal a
    never-crashed run exactly (deterministic data + ckpt restore)."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        golden = _loop(d1, 25).run()

        crashed = _loop(d2, 25, inject=17)
        with pytest.raises(RuntimeError, match="injected failure"):
            crashed.run()
        resumed = _loop(d2, 25).run()   # restores step 10 checkpoint
        g = {h["step"]: h["loss"] for h in golden["history"]}
        r = {h["step"]: h["loss"] for h in resumed["history"]}
        for step in (20, 24):
            assert step in r
            np.testing.assert_allclose(r[step], g[step], rtol=1e-5)


def test_grad_accum_matches_full_batch():
    """grad_accum=2 must match the single-batch step (same global batch)."""
    import jax.numpy as jnp
    from repro.models import model as M
    from repro.train.optimizer import adamw_init
    from repro.train.train_step import make_train_step
    cfg = _tiny()
    params = M.init_params(cfg, RT, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tok = jnp.asarray(np.arange(8 * 32).reshape(8, 32) % cfg.vocab,
                      dtype=jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    s1 = make_train_step(cfg, RT, TrainConfig(grad_accum=1))
    s2 = make_train_step(cfg, RT, TrainConfig(grad_accum=2))
    p1, _, m1 = s1(params, opt, batch, jax.random.PRNGKey(1))
    p2, _, m2 = s2(params, opt, batch, jax.random.PRNGKey(1))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(diffs)) < 5e-4, diffs


def test_straggler_detection_with_fake_clock():
    times = iter([0.0, 1.0,          # step 0: 1s
                  1.0, 2.0,          # step 1
                  2.0, 3.0,          # ...
                  3.0, 4.0,
                  4.0, 5.0,
                  5.0, 30.0,         # step 5: 25s -> straggler
                  30.0, 31.0,
                  31.0, 32.0])
    clock = lambda: next(times)
    loop = TrainLoop(_tiny(), RT, DataConfig(global_batch=8, seq_len=32),
                     TrainConfig(opt=AdamWConfig(warmup_steps=1,
                                                 total_steps=8)),
                     LoopConfig(total_steps=8, ckpt_every=100, log_every=100),
                     clock=clock)
    out = loop.run()
    assert 5 in out["stragglers"]


def test_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert np.isclose(float(schedule(cfg, jnp.asarray(10))), 1e-3)
    assert float(schedule(cfg, jnp.asarray(100))) >= 0.99e-4
