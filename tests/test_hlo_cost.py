"""Loop-aware HLO cost model vs hand-counted ground truth."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import module_cost, _shape_info


def test_shape_info():
    assert _shape_info("bf16[16,4096]{1,0}")[0] == 16 * 4096 * 2
    b, n, dims = _shape_info("(s32[], f32[8,4])")
    assert b == 4 + 8 * 4 * 4
    assert n == 1 and dims == []


def test_single_matmul():
    a = jnp.zeros((512, 256), jnp.float32)
    b = jnp.zeros((256, 128), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    mc = module_cost(c.as_text())
    assert mc.flops == 2 * 512 * 256 * 128
    # ideal bytes: read A + B, write C
    expect = (512 * 256 + 256 * 128 + 512 * 128) * 4
    assert abs(mc.bytes_ideal - expect) / expect < 0.5


def test_scan_multiplies_trip_count():
    a = jnp.zeros((256, 256), jnp.float32)

    def g(a):
        def body(c, _):
            return c @ a, None
        out, _ = jax.lax.scan(body, a, None, length=11)
        return out

    mc = module_cost(jax.jit(g).lower(a).compile().as_text())
    expect = 11 * 2 * 256 ** 3
    assert abs(mc.flops - expect) / expect < 0.05, mc.flops


def test_nested_scan():
    a = jnp.zeros((128, 128), jnp.float32)

    def g(a):
        def outer(c, _):
            def inner(d, _):
                return d @ a, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        out, _ = jax.lax.scan(outer, a, None, length=5)
        return out

    mc = module_cost(jax.jit(g).lower(a).compile().as_text())
    expect = 5 * 3 * 2 * 128 ** 3
    assert abs(mc.flops - expect) / expect < 0.05, mc.flops


def test_transcendentals_counted():
    x = jnp.zeros((1000,), jnp.float32)
    mc = module_cost(jax.jit(jnp.tanh).lower(x).compile().as_text())
    assert mc.transcendentals >= 1000


def test_remat_increases_flops():
    a = jnp.zeros((256, 256), jnp.float32)

    def loss(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return jnp.sum(y)

    plain = jax.jit(jax.grad(loss))
    mc1 = module_cost(plain.lower(a, a).compile().as_text())

    def loss_r(w, x):
        @jax.checkpoint
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return jnp.sum(y)

    mc2 = module_cost(jax.jit(jax.grad(loss_r)).lower(a, a).compile().as_text())
    assert mc2.flops >= mc1.flops, "remat recompute must show up"
