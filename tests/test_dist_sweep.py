"""Distributed sweep engine: padding exactness, engine identity with the
sequential path, checkpoint resume, stable ordering; the 8-device case
runs in a subprocess (keeps this session single-device)."""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.experiments import (ExperimentSpec, RunResult, Session,
                               compare_results, order_results)
from repro.experiments.dist_sweep import bucket_signature, dist_sweep
from repro.experiments.results import EXECUTION_META_KEYS

GRID = dict(topos=["clique(k=6)", "star(n=8)"],
            routings=["ecmp(n=2)", "fatpaths(n_layers=3)"],
            patterns=["uniform"],
            evaluators=["transport(steps=40)"], seeds=[0, 1])


# ---- padding exactness ------------------------------------------------------
def test_pad_prepared_is_bitwise_exact():
    """A cell simulated standalone == the same cell padded (flows, links,
    hop slots) and run inside a vmapped batch — bit for bit, every
    SimResult field.  This is the invariant the whole engine rests on."""
    import jax
    import jax.numpy as jnp

    from repro.core import transport as TP

    s = Session()
    topo = s.topology("clique(k=6)")
    bundle = s.routing("clique(k=6)", "fatpaths(n_layers=3)")
    wl = s.workload("clique(k=6)", "uniform")
    cfg = TP.SimConfig(balancing=bundle.balancing, n_steps=50)
    base = TP.simulate(topo, bundle.routing, wl, cfg)

    arrs, static = TP.prepare(topo, bundle.routing, wl, cfg)
    F = arrs["size"].shape[0]
    padded, pstatic = TP.pad_prepared(
        arrs, static, n_flows=F + 13, n_edges=static[0] + 7,
        hop_slots=arrs["path_edges"].shape[2] + 2)
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    keys = keys.at[1].set(jax.random.PRNGKey(cfg.seed))   # element 1 = cell
    stacked = {k: jnp.stack([v] * 3) for k, v in padded.items()}
    finals = jax.jit(jax.vmap(
        lambda a, k: TP._run_scan_impl(a, k, cfg, pstatic)))(stacked, keys)
    got = TP.batch_result(np.asarray(arrs["size"]),
                          {k: np.asarray(v)[1] for k, v in finals.items()},
                          cfg, n_flows=F)
    np.testing.assert_array_equal(got.fct, base.fct)
    np.testing.assert_array_equal(got.delivered, base.delivered)
    np.testing.assert_array_equal(got.finished, base.finished)
    assert got.link_util_mean == base.link_util_mean


def test_pad_prepared_rejects_shrinking():
    from repro.core import transport as TP

    s = Session()
    cell = s.resolve(ExperimentSpec.make("clique(k=6)", "ecmp(n=2)",
                                         "uniform", "transport(steps=10)"))
    cfg = TP.SimConfig(balancing="ecmp", n_steps=10)
    arrs, static = TP.prepare(cell.topo, cell.bundle.routing, cell.workload,
                              cfg)
    with pytest.raises(ValueError, match="smaller than cell"):
        TP.pad_prepared(arrs, static, n_flows=1, n_edges=static[0],
                        hop_slots=arrs["path_edges"].shape[2])


def test_bucket_signature_keys_scheme_and_layers():
    from repro.core.transport import SimConfig

    a = SimConfig(balancing="fatpaths", n_steps=40, seed=3)
    b = SimConfig(balancing="fatpaths", n_steps=40, seed=9)
    c = SimConfig(balancing="ecmp", n_steps=40, seed=3)
    assert bucket_signature(a, (10, 5, 40)) == bucket_signature(b, (99, 5, 40))
    assert bucket_signature(a, (10, 5, 40)) != bucket_signature(c, (10, 5, 40))
    assert bucket_signature(a, (10, 5, 40)) != bucket_signature(b, (10, 6, 40))


# ---- engine identity --------------------------------------------------------
def test_dist_sweep_matches_sequential_cell_for_cell():
    seq = Session().sweep(**GRID)
    s = Session()
    cells = s.grid(**GRID)
    dist = dist_sweep(s, cells, devices=1)
    assert [r.cell_id for r in dist] == [c.cell_id for c in cells]
    assert compare_results(seq, dist) == []


def test_dist_sweep_seed_sweep_shares_operands():
    """transport(seeds=S) cells take the nested-vmap path (one operand
    copy per cell, inner vmap over sim-seed keys) — still identical to
    the sequential engine, cell for cell."""
    grid = dict(topos=["clique(k=6)", "star(n=8)"],
                routings=["fatpaths(n_layers=3)", "letflow(n=2)"],
                patterns=["uniform"],
                evaluators=["transport(steps=40,seeds=3)"], seeds=[0])
    seq = Session().sweep(**grid)
    s = Session()
    logs = []
    dist = dist_sweep(s, s.grid(**grid), devices=1, log=logs.append)
    assert compare_results(seq, dist) == []
    assert any("seednest" in m for m in logs)
    assert all(r.meta["n_seeds"] == 3 for r in dist)


def test_dist_sweep_mixed_evaluators_fall_back():
    """mat/fabric cells run sequentially inside the same sweep and keep
    canonical ordering interleaved with batched transport cells."""
    grid = dict(topos=["clique(k=6)"], routings=["fatpaths(n_layers=3)"],
                patterns=["uniform"],
                evaluators=["transport(steps=40)", "mat"], seeds=[0])
    seq = Session().sweep(**grid)
    s = Session()
    dist = dist_sweep(s, s.grid(**grid), devices=1)
    assert compare_results(seq, dist) == []
    assert [r.evaluator for r in dist] == ["transport(steps=40)", "mat"]


def test_sweep_devices_kwarg_routes_to_engine():
    got = Session().sweep(devices=1, **GRID)
    seq = Session().sweep(**GRID)
    assert compare_results(seq, got) == []


# ---- resumable sweeps -------------------------------------------------------
def test_checkpoint_resume_skips_completed_cells(tmp_path):
    ckdir = str(tmp_path / "ck")
    s1 = Session()
    cells = s1.grid(**GRID)
    part = dist_sweep(s1, cells[:3], devices=1, checkpoint_dir=ckdir)
    assert len(part) == 3
    assert len([f for f in os.listdir(ckdir) if f.endswith(".json")]) == 3

    s2 = Session()
    streamed = []
    full = dist_sweep(s2, cells, devices=1, checkpoint_dir=ckdir,
                      callback=lambda rr: streamed.append(rr.cell_id))
    assert len(full) == len(cells) == len(streamed)
    resumed = [r for r in full if r.meta.get("sweep_resumed")]
    assert len(resumed) == 3
    # resumed cells were NOT re-simulated: no artifact builds for them
    fresh = Session().sweep(**GRID)
    assert compare_results(fresh, full) == []
    # the full sweep's results come back in canonical grid order
    assert [r.cell_id for r in full] == [c.cell_id for c in cells]


FAIL_GRID = dict(
    topos=["clique(k=6)"],
    routings=["failures(of=fatpaths(n_layers=3),rate=0.1)",
              "failures(of=fatpaths(n_layers=3),rate=0.3,mode=drop)",
              "failures(of=fatpaths(n_layers=3),rate=0.2,down_step=15)",
              "fatpaths(n_layers=3)"],
    patterns=["uniform"], evaluators=["transport(steps=40)"], seeds=[0])


def _artifact_bytes(results):
    """The sweep artifact as CI would diff it: execution-dependent
    fields (walls, build accounting, batch bookkeeping) stripped."""
    dicts = []
    for r in results:
        d = r.to_dict()
        d.pop("wall_s")
        for k in EXECUTION_META_KEYS:
            d["meta"].pop(k, None)
        dicts.append(d)
    return json.dumps(dicts, indent=1, sort_keys=True).encode()


def test_checkpoint_resume_failure_grid_byte_identical(tmp_path):
    """Interrupting a degraded-fabric sweep mid-grid and resuming yields
    an artifact BYTE-identical to the uninterrupted sweep — failure
    scenarios (static repair, static drop, mid-run death) checkpoint and
    resume like any other cell."""
    ckdir = str(tmp_path / "ck")
    s1 = Session()
    cells = s1.grid(**FAIL_GRID)
    part = dist_sweep(s1, cells[:2], devices=1, checkpoint_dir=ckdir)
    assert len(part) == 2

    s2 = Session()
    full = dist_sweep(s2, cells, devices=1, checkpoint_dir=ckdir)
    assert len([r for r in full if r.meta.get("sweep_resumed")]) == 2

    s3 = Session()
    uninterrupted = dist_sweep(s3, s3.grid(**FAIL_GRID), devices=1)
    assert compare_results(uninterrupted, full) == []
    assert _artifact_bytes(full) == _artifact_bytes(uninterrupted)
    # damage accounting survives the checkpoint round-trip
    for r in full:
        if r.routing.startswith("failures"):
            assert "disconnected_pairs" in r.meta
            assert "dead_layers" in r.meta


def test_checkpoint_ignores_torn_files(tmp_path):
    from repro.ckpt import SweepCheckpoint

    ck = SweepCheckpoint(str(tmp_path))
    ck.put("a/b/c@s0", {"topo": "a"})
    with open(os.path.join(str(tmp_path), "cell_deadbeef.json"), "w") as f:
        f.write('{"cell_id": "x"')          # torn write, no rename
    assert ck.load() == {"a/b/c@s0": {"topo": "a"}}
    assert "a/b/c@s0" in ck and len(ck) == 1
    assert ck.get("missing") is None


# ---- results helpers --------------------------------------------------------
def _rr(cell="t/r/p/e@s0", **over):
    d = dict(topo="t", routing="r", pattern="p", evaluator="e", seed=0,
             metrics={"m": 1.0}, meta={"k": 2, "build_s": 0.5}, wall_s=1.0)
    d.update(over)
    return RunResult(**d)


def test_order_results_restores_canonical_order():
    a, b = _rr(routing="r1"), _rr(routing="r2")
    assert order_results([b, a], [a.cell_id, b.cell_id]) == [a, b]
    with pytest.raises(KeyError, match="no result"):
        order_results([a], [a.cell_id, b.cell_id])
    with pytest.raises(KeyError, match="unplanned"):
        order_results([a, b], [a.cell_id])


def test_compare_results_ignores_execution_meta():
    a = _rr()
    b = dataclasses.replace(a, wall_s=99.0,
                            meta={**a.meta, "build_s": 7.0,
                                  "sweep_bucket": 3, "sweep_resumed": True})
    assert compare_results([a], [b]) == []
    c = dataclasses.replace(a, metrics={"m": 1.0 + 1e-9})
    assert compare_results([a], [c]) != []          # exact by default
    assert compare_results([a], [c], rtol=1e-6) == []
    d = dataclasses.replace(a, meta={**a.meta, "k": 3})
    assert any("meta[k]" in x for x in compare_results([a], [d]))
    e = dataclasses.replace(a, routing="other")
    assert any("cell sets differ" in x for x in compare_results([a], [e]))


# ---- mesh helper ------------------------------------------------------------
def test_host_device_runtime_degrades_and_errors():
    from repro.dist import host_device_runtime

    rt = host_device_runtime(1)
    assert rt.mesh is None
    with pytest.raises(RuntimeError, match="XLA_FLAGS"):
        host_device_runtime(8)          # this session is single-device


# ---- the 8-device case (subprocess: forced host devices) --------------------
# steps=200 > horizon_chunk, so the batched while_loop (adaptive early
# exit) runs UNDER shard_map and must still match the sequential engine.
_PROG = textwrap.dedent("""
    from repro.experiments import Session, compare_results
    from repro.experiments.dist_sweep import dist_sweep
    import jax
    assert jax.device_count() == 8, jax.device_count()
    grid = dict(topos=["clique(k=6)", "star(n=8)"],
                routings=["ecmp(n=2)", "fatpaths(n_layers=3)",
                          "failures(of=fatpaths(n_layers=3),rate=0.2,down_step=60)"],
                patterns=["uniform", "load(level=0.4,window=96)"],
                evaluators=["transport(steps=200)"],
                seeds=[0])
    seq = Session().sweep(**grid)
    s8 = Session()
    d8 = dist_sweep(s8, s8.grid(**grid), devices=8)
    diffs = compare_results(seq, d8)
    assert diffs == [], diffs[:5]
    assert any("offered_gbs" in r.meta for r in d8)  # dynamic cells batched
    assert any("failed_links" in r.meta for r in d8)  # degraded cells batched
    chunks = [r.meta["sweep_chunks"] for r in d8
              if r.pattern.startswith("uniform")
              and not r.routing.startswith("failures")]
    assert all(c < 200 // 64 for c in chunks), chunks   # early exit fired
    print("DIST8_OK")
""")


def test_dist_sweep_8_devices_identical():
    r = subprocess.run(
        [sys.executable, "-c", _PROG],
        capture_output=True, text=True, timeout=600,
        env={"XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "DIST8_OK" in r.stdout, r.stderr[-2000:]
