"""The paper's full analysis pipeline on a chosen topology.

    PYTHONPATH=src python examples/fatpaths_analysis.py [--topo "sf(q=7)"]

topology -> diversity metrics (Table 4 row) -> layer construction sweep
(MAT LP) -> flow-simulated FCT under three routing schemes -> a summary
of whether FatPaths helps *this* network (and why).  Every cell is an
``repro.experiments`` spec; compact forms like ``sf:7`` work too.
"""

import argparse

from repro.core.diversity import diversity_report
from repro.experiments import Session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topo", default="sf(q=5)")
    args = ap.parse_args()

    session = Session()
    topo = session.topology(args.topo)
    print(f"== {topo.name}: N_r={topo.n_routers} N={topo.n_endpoints} "
          f"k'={topo.network_radix} ==")

    rep = diversity_report(topo, n_cdp=50, n_pi=15)
    print(f"diameter {rep.diameter}, avg path {rep.avg_path_len:.2f}")
    print(f"single-minimal-path pairs: {rep.frac_single_minimal:.0%} "
          f"(the paper's 'shortest paths fall short')")
    print(f"CDP@d'={rep.d_prime}: mean {rep.cdp_mean_frac:.0%}k', "
          f"1% tail {rep.cdp_tail_frac:.0%}k'; "
          f"PI mean {rep.pi_mean_frac:.0%}k'; TNL {rep.tnl:.0f}")

    print("\nlayer sweep (MAT via multicommodity LP):")
    for n, rho in ((2, 1.0), (5, 0.6), (9, 0.6)):
        rr = session.run(args.topo, f"fatpaths(n_layers={n},rho={rho})",
                         "permutation(frac=0.55)", "mat")
        print(f"  n={n} rho={rho}: T={rr.metrics['mat_T']:.3f} "
              f"({rr.metrics['n_paths']:.0f} candidate paths)")

    print("\nflow simulation, skewed adversarial traffic:")
    rows = []
    for name, scheme in (("FatPaths(9 layers)", "fatpaths(n_layers=9,rho=0.6)"),
                         ("LetFlow(minimal)", "letflow"),
                         ("ECMP(minimal)", "ecmp")):
        rr = session.run(args.topo, scheme, "adversarial",
                         "transport(steps=1500)", seed=3)
        rows.append((name, rr.metrics))
        print(f"  {name:20s} p50 {rr.metrics['fct_p50_us']:7.0f}us  "
              f"p99 {rr.metrics['fct_p99_us']:7.0f}us  "
              f"fin {rr.metrics['finished']:.0%}")

    fp, ec = rows[0][1], rows[2][1]
    verdict = "helps" if fp["fct_p99_us"] <= ec["fct_p99_us"] \
        else "is neutral on"
    ratio = fp["fct_p99_us"] / max(ec["fct_p99_us"], 1e-12)
    print(f"\n=> FatPaths {verdict} this network (p99 {ratio:.2f}x of ECMP)")


if __name__ == "__main__":
    main()
