"""The paper's full analysis pipeline on a chosen topology.

    PYTHONPATH=src python examples/fatpaths_analysis.py [--topo sf:7]

topology -> diversity metrics (Table 4 row) -> layer construction sweep ->
MAT (LP) -> flow-simulated FCT under three routing schemes -> a summary of
whether FatPaths helps *this* network (and why).
"""

import argparse

import numpy as np

from repro.core import layers as L
from repro.core import throughput as TH
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core import transport as TP
from repro.core.diversity import diversity_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topo", default="sf:5")
    args = ap.parse_args()

    topo = T.by_name(args.topo)
    print(f"== {topo.name}: N_r={topo.n_routers} N={topo.n_endpoints} "
          f"k'={topo.network_radix} ==")

    rep = diversity_report(topo, n_cdp=50, n_pi=15)
    print(f"diameter {rep.diameter}, avg path {rep.avg_path_len:.2f}")
    print(f"single-minimal-path pairs: {rep.frac_single_minimal:.0%} "
          f"(the paper's 'shortest paths fall short')")
    print(f"CDP@d'={rep.d_prime}: mean {rep.cdp_mean_frac:.0%}k', "
          f"1% tail {rep.cdp_tail_frac:.0%}k'; "
          f"PI mean {rep.pi_mean_frac:.0%}k'; TNL {rep.tnl:.0f}")

    wl = TR.make_workload(topo, "permutation", seed=0, frac_endpoints=0.55)
    print("\nlayer sweep (MAT via multicommodity LP):")
    for n, rho in ((2, 1.0), (5, 0.6), (9, 0.6)):
        lr = L.build_layers(topo, n, rho, seed=0)
        mat = TH.mat_lp(lr, wl)
        print(f"  n={n} rho={rho}: T={mat.throughput:.3f} "
              f"({mat.n_paths} candidate paths)")

    print("\nflow simulation, skewed adversarial traffic:")
    lr9 = L.build_layers(topo, 9, 0.6, seed=0)
    wl = TR.make_workload(topo, "adversarial", seed=3, randomize=False,
                          n_rounds=2)
    rows = []
    for name, routing, bal in (
            ("FatPaths(9 layers)", lr9, "fatpaths"),
            ("LetFlow(minimal)", TP.ecmp_routing(topo), "letflow"),
            ("ECMP(minimal)", TP.ecmp_routing(topo), "ecmp")):
        st = TP.simulate(topo, routing, wl,
                         TP.SimConfig(balancing=bal, n_steps=1500)).fct_stats()
        rows.append((name, st))
        print(f"  {name:20s} p50 {st['p50'] * 1e6:7.0f}us  "
              f"p99 {st['p99'] * 1e6:7.0f}us  fin {st['finished']:.0%}")

    fp, ec = rows[0][1], rows[2][1]
    verdict = "helps" if fp["p99"] <= ec["p99"] else "is neutral on"
    print(f"\n=> FatPaths {verdict} this network "
          f"(p99 {fp['p99'] / max(ec['p99'], 1e-12):.2f}x of ECMP)")


if __name__ == "__main__":
    main()
