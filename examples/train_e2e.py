"""End-to-end training driver: checkpointed run with restart-safe data.

Default (CPU-friendly): a ~20M-param llama-style model, 120 steps.
``--full`` selects the ~100M configuration / 300 steps for real hardware.

    PYTHONPATH=src python examples/train_e2e.py [--full] [--ckpt DIR]

Kill it mid-run and re-run the same command: it resumes from the last
committed checkpoint and reproduces the exact trajectory.
"""

import argparse
import tempfile

from repro.data.pipeline import DataConfig
from repro.dist.sharding import Runtime
from repro.models.config import ModelConfig
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainConfig


def model_config(full: bool) -> ModelConfig:
    if full:   # ~100M params
        return ModelConfig(name="e2e-100m", family="dense", n_layers=10,
                           d_model=640, n_heads=10, n_kv_heads=5, d_head=64,
                           d_ff=2560, vocab=16384, dtype="bfloat16")
    return ModelConfig(name="e2e-20m", family="dense", n_layers=4,
                       d_model=256, n_heads=4, n_kv_heads=2, d_head=64,
                       d_ff=1024, vocab=8192, dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = model_config(args.full)
    steps = args.steps or (300 if args.full else 120)
    ckpt = args.ckpt or tempfile.mkdtemp(prefix="repro_e2e_")
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params), "
          f"{steps} steps, ckpt -> {ckpt}")

    loop = TrainLoop(
        cfg, Runtime(mesh=None),
        DataConfig(global_batch=8, seq_len=128, seed=0),
        TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=20,
                                    total_steps=steps)),
        LoopConfig(total_steps=steps, ckpt_every=40, log_every=10,
                   ckpt_dir=ckpt))
    out = loop.run()
    first, last = out["history"][0], out["history"][-1]
    print(f"loss: {first['loss']:.3f} (step {first['step']}) -> "
          f"{last['loss']:.3f} (step {last['step']})")
    assert last["loss"] < first["loss"], "training must make progress"

    # What would this run's gradient all-reduce cost on a real cluster?
    # The experiments API models the DP collective on a Slim Fly fabric
    # under minimal-path ECMP vs FatPaths layered routing (paper §8).
    from repro.experiments import Session

    fb = Session().fabric("sf(q=5)")
    grad_bytes = cfg.param_count() * 2          # bf16 gradients
    times = {s: fb.collective_time("all-reduce", 64, grad_bytes, s).time_s
             for s in ("ecmp", "fatpaths")}
    print(f"modelled 64-rank gradient all-reduce on sf(q=5): "
          f"ecmp {times['ecmp'] * 1e3:.1f} ms vs "
          f"fatpaths {times['fatpaths'] * 1e3:.1f} ms per step")
    print("OK")


if __name__ == "__main__":
    main()
