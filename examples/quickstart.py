"""FatPaths quickstart: one declarative experiment cell per comparison.

    PYTHONPATH=src python examples/quickstart.py

Cells are mini-specs (topology / routing scheme / traffic pattern /
evaluator) run through a memoizing ``repro.experiments.Session`` — the
layer stack is built once and shared by everything below.
"""

from repro.core.diversity import diversity_report
from repro.experiments import Session


def main():
    session = Session()

    # 1. a Slim Fly (the paper's flagship D=2 topology)
    topo = session.topology("sf(q=5)")
    print(f"topology: {topo.name}  routers={topo.n_routers} "
          f"endpoints={topo.n_endpoints} k'={topo.network_radix}")

    # 2. how scarce are shortest paths? (paper Fig 6 / Table 4)
    rep = diversity_report(topo, n_cdp=40, n_pi=10)
    print(f"pairs with a single minimal path: {rep.frac_single_minimal:.0%}"
          f"  (CDP at d'={rep.d_prime}: {rep.cdp_mean_frac:.0%} of k')")

    # 3. FatPaths layered routing: 1 minimal + 8 sparse non-minimal layers
    bundle = session.routing("sf(q=5)", "fatpaths(n_layers=9,rho=0.6)",
                             seed=3)     # seed 3 == the cells below
    bundle.routing.validate_loop_free(n_samples=100)
    print(f"layers: {bundle.routing.n_layers} (rho={bundle.routing.rho}), "
          "loop-free OK")

    # 4. simulate an adversarial workload under FatPaths vs minimal ECMP
    for name, scheme in (("FatPaths", "fatpaths(n_layers=9,rho=0.6)"),
                         ("ECMP", "ecmp")):
        rr = session.run("sf(q=5)", scheme, "adversarial",
                         "transport(steps=1200)", seed=3)
        m = rr.metrics
        print(f"{name:9s} p50 FCT {m['fct_p50_us']:7.0f} us   "
              f"p99 {m['fct_p99_us']:7.0f} us   "
              f"finished {m['finished']:.0%}")


if __name__ == "__main__":
    main()
