"""FatPaths quickstart: topology -> layers -> flowlet routing -> FCT.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import layers, topology, traffic, transport
from repro.core.diversity import diversity_report


def main():
    # 1. a Slim Fly (the paper's flagship D=2 topology)
    topo = topology.slim_fly(q=5)
    print(f"topology: {topo.name}  routers={topo.n_routers} "
          f"endpoints={topo.n_endpoints} k'={topo.network_radix}")

    # 2. how scarce are shortest paths? (paper Fig 6 / Table 4)
    rep = diversity_report(topo, n_cdp=40, n_pi=10)
    print(f"pairs with a single minimal path: {rep.frac_single_minimal:.0%}"
          f"  (CDP at d'={rep.d_prime}: {rep.cdp_mean_frac:.0%} of k')")

    # 3. FatPaths layered routing: 1 minimal + 8 sparse non-minimal layers
    lr = layers.build_layers(topo, n_layers=9, rho=0.6, seed=0)
    lr.validate_loop_free(n_samples=100)
    print(f"layers: {lr.n_layers} (rho={lr.rho}), loop-free OK")

    # 4. simulate an adversarial workload under FatPaths vs minimal ECMP
    wl = traffic.make_workload(topo, "adversarial", seed=3, randomize=False,
                               n_rounds=2)
    for name, routing, bal in (
            ("FatPaths", lr, "fatpaths"),
            ("ECMP", transport.ecmp_routing(topo), "ecmp")):
        res = transport.simulate(topo, routing, wl,
                                 transport.SimConfig(balancing=bal,
                                                     n_steps=1200))
        st = res.fct_stats()
        print(f"{name:9s} p50 FCT {st['p50'] * 1e6:7.0f} us   "
              f"p99 {st['p99'] * 1e6:7.0f} us   "
              f"finished {st['finished']:.0%}")


if __name__ == "__main__":
    main()
