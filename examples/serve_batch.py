"""Batched serving with flowlet-style replica balancing.

Two replicas of a small MoE model serve a stream of request bursts.  The
dispatcher reuses FatPaths' flowlet idea: each burst ("flowlet") goes to a
randomly chosen replica among those below their load watermark — elastic
balancing with zero probing, exactly §3.2 applied to serving.

    PYTHONPATH=src python examples/serve_batch.py
"""

import numpy as np
import jax

from repro import configs
from repro.dist.sharding import Runtime
from repro.models import model as model_mod
from repro.serve.engine import ServeConfig, ServingEngine


class FlowletDispatcher:
    """Pick a replica per burst: random among un-congested (watermark),
    falling back to least-loaded — no probing, elastic by construction."""

    def __init__(self, engines, watermark: float = 0.75, seed: int = 0):
        self.engines = engines
        self.load = np.zeros(len(engines))
        self.watermark = watermark
        self.rng = np.random.default_rng(seed)

    def dispatch(self, prompts, max_new):
        ok = np.nonzero(self.load <= self.watermark * max(self.load.max(),
                                                          1e-9))[0]
        pick = int(self.rng.choice(ok)) if len(ok) else int(self.load.argmin())
        self.load[pick] += len(prompts)
        outs = self.engines[pick].run(prompts, max_new=max_new)
        self.load[pick] *= 0.5          # decay: completed work drains
        return pick, outs


def main():
    # Why flowlet dispatch?  Model the serving ingress itself: a burst of
    # requests converging on one frontend is the paper's incast
    # (all-to-one) — one declarative experiment cell shows the NIC, not
    # the fabric, is the bottleneck, so zero-probing elastic balancing
    # (not smarter routing) is the right lever at the replica layer.
    from repro.experiments import Session

    rr = Session().run("sf(q=5)", "fatpaths", "alltoone", "fabric")
    print(f"ingress incast on {rr.topo}: bottleneck "
          f"{rr.metrics['bottleneck_mb']:.0f} MB at the NIC "
          f"(fabric gini {rr.metrics['util_gini']:.2f}) -> "
          "balance at the replica layer, flowlet-style\n")

    cfg = configs.get_smoke("olmoe-1b-7b")
    rt = Runtime(mesh=None)
    params = model_mod.init_params(cfg, rt, jax.random.PRNGKey(0))
    sc = ServeConfig(batch=4, max_len=64)
    replicas = [ServingEngine(cfg, rt, params, sc) for _ in range(2)]
    disp = FlowletDispatcher(replicas)

    rng = np.random.default_rng(1)
    counts = np.zeros(2, dtype=int)
    for burst in range(6):
        prompts = [rng.integers(1, cfg.vocab, size=int(rng.integers(2, 7)))
                   for _ in range(int(rng.integers(1, 5)))]
        replica, outs = disp.dispatch(prompts, max_new=8)
        counts[replica] += len(outs)
        print(f"burst {burst}: {len(prompts)} reqs -> replica {replica}; "
              f"first output: {outs[0][:6]}")
    print(f"served per replica: {counts.tolist()} (balanced, no probing)")


if __name__ == "__main__":
    main()
