"""Paper Fig 12 / Fig 16: layer-count (n) and sparsity (rho) sweep.

Claims reproduced:
  * more layers -> more realised edge-disjoint paths per pair (Fig 12);
    nine layers resolve most collisions on SF;
  * when many layers are available, denser layers (higher rho) are better
    (more alternatives per layer + shorter paths);
  * FCT improves with (n, rho) up to saturation (flow simulator).
"""

from __future__ import annotations

import numpy as np

from repro.core import layers as L
from repro.core import traffic as TR
from repro.core import transport as TP
from repro.core.topology import slim_fly

from .common import emit, timeit


def mean_disjoint(lr, n_samples: int = 40, seed: int = 1) -> float:
    rng = np.random.default_rng(seed)
    vals = []
    for _ in range(n_samples):
        s, t = rng.choice(lr.topo.n_routers, 2, replace=False)
        vals.append(L.layer_disjoint_paths(lr, s, t))
    return float(np.mean(vals))


def main(quick: bool = False) -> None:
    topo = slim_fly(7 if quick else 11)   # k'=11 / 17
    for n in (3, 5, 9):
        for rho in (0.4, 0.6, 0.8):
            us = timeit(lambda: L.build_layers(topo, n, rho, seed=0), n=1)
            lr = L.build_layers(topo, n, rho, seed=0)
            emit(f"fig12/disjoint/sf{topo.n_routers}/n{n}/rho{rho}", us,
                 f"mean_disjoint={mean_disjoint(lr):.2f}")

    # FCT sweep on the small instance (flow simulator)
    topo5 = slim_fly(5)
    wl = TR.make_workload(topo5, "adversarial", seed=3, randomize=False,
                          n_rounds=2, flow_size=1 << 20)
    for n, rho in ((3, 0.4), (5, 0.6), (9, 0.6), (9, 0.8)):
        lr = L.build_layers(topo5, n, rho, seed=0)
        res = TP.simulate(topo5, lr, wl,
                          TP.SimConfig(n_steps=400 if quick else 1500))
        st = res.fct_stats()
        emit(f"fig12/fct/n{n}/rho{rho}", st["p50"] * 1e6,
             f"p99us={st['p99'] * 1e6:.0f} fin={st['finished']:.2f}")


if __name__ == "__main__":
    main()
