"""Paper Fig 12 / Fig 16: layer-count (n) and sparsity (rho) sweep.

Claims reproduced:
  * more layers -> more realised edge-disjoint paths per pair (Fig 12);
    nine layers resolve most collisions on SF;
  * when many layers are available, denser layers (higher rho) are better
    (more alternatives per layer + shorter paths);
  * FCT improves with (n, rho) up to saturation (flow simulator).
"""

from __future__ import annotations

import numpy as np

from repro.core import layers as L

from .common import emit, get_session, timeit


def mean_disjoint(lr, n_samples: int = 40, seed: int = 1) -> float:
    """All (sample, layer) table walks batched into one call."""
    rng = np.random.default_rng(seed)
    pairs = np.stack([rng.choice(lr.topo.n_routers, 2, replace=False)
                      for _ in range(n_samples)])
    return float(L.layer_disjoint_paths_batch(lr, pairs[:, 0],
                                              pairs[:, 1]).mean())


def main(quick: bool = False) -> None:
    from repro.experiments import Session

    session = get_session()
    tspec = f"sf(q={7 if quick else 11})"   # k'=11 / 17
    for n in (3, 5, 9):
        for rho in (0.4, 0.6, 0.8):
            rspec = f"fatpaths(n_layers={n},rho={rho})"
            # Cold build time: a fresh Session per call (the shared
            # session would make every call after the first a cache hit).
            # n=5: these are ms-scale device builds and the CI gate
            # compares min-over-samples — more samples tighten the min
            # against scheduler noise on small shared runners.
            us = timeit(lambda: Session().routing(tspec, rspec, seed=0),
                        n=5, warmup=0)
            lr = session.routing(tspec, rspec, seed=0).routing
            nr = lr.topo.n_routers
            emit(f"fig12/disjoint/sf{nr}/n{n}/rho{rho}", us,
                 f"mean_disjoint={mean_disjoint(lr):.2f}")

    # FCT sweep on the small instance (flow simulator)
    steps = 400 if quick else 1500
    for n, rho in ((3, 0.4), (5, 0.6), (9, 0.6), (9, 0.8)):
        rr = session.run("sf(q=5)", f"fatpaths(n_layers={n},rho={rho})",
                         "adversarial", f"transport(steps={steps})", seed=3)
        emit(f"fig12/fct/n{n}/rho{rho}", rr.metrics["fct_p50_us"],
             f"p99us={rr.metrics['fct_p99_us']:.0f} "
             f"fin={rr.metrics['finished']:.2f}")


if __name__ == "__main__":
    main()
