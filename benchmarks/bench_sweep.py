"""Distributed sweep engine vs the sequential grid runner.

Times the same topology x scheme x pattern grid through both execution
engines (artifact builds are excluded: both engines run against a
pre-warmed Session, so the rows measure pure simulate/dispatch cost):

  * ``sweep/seq/grid``   — the sequential per-cell loop (one scan
                           dispatch + jit-cache entry per cell);
  * ``sweep/dist/grid``  — the bucketed/padded/vmapped batch engine
                           (CI-GUARDED: one compiled program per shape
                           bucket).

``speedup`` in the derived column is seq/dist on this machine.  Both
rows run SINGLE-device (this process has no forced host devices, and
the guarded timing must stay comparable to the committed baseline,
which was measured single-device): the guarded key covers the engine's
bucketing/padding/vmapped dispatch, where its single-device win
(batching — a few compiled programs instead of one per cell) lives.
The multi-device shard_map / round-robin scheduling paths are
correctness-covered by tests and the CI dist-smoke identity check, and
their wall time is visible in the nightly workflow's sweep logs — they
are NOT part of this guarded number.
"""

from __future__ import annotations

from .common import emit, get_session, timeit

GRID = dict(topos=["sf(q=5)", "df(p=3)", "ft(k=8)"],
            routings=["ecmp", "letflow", "fatpaths"],
            patterns=["adversarial", "shuffle"])


def main(quick: bool = False) -> None:
    from repro.experiments.dist_sweep import dist_sweep

    session = get_session()
    ev = [f"transport(steps={200 if quick else 400})"]
    cells = session.grid(evaluators=ev, **GRID)
    n = len(cells)

    # Warm every artifact (and both engines' jit caches) once, so the
    # timed samples compare engine dispatch, not layer-stack builds.
    session.sweep(evaluators=ev, **GRID)
    dist_sweep(session, cells, devices=None)

    seq = timeit(lambda: session.sweep(evaluators=ev, **GRID),
                 n=3, warmup=0)
    dist = timeit(lambda: dist_sweep(session, cells, devices=None),
                  n=3, warmup=0)
    speedup = seq.median_us / max(dist.median_us, 1.0)
    emit("sweep/seq/grid", seq, f"cells={n}")
    emit("sweep/dist/grid", dist,
         f"cells={n} speedup={speedup:.2f} us_per_cell="
         f"{dist.median_us / n:.0f}")


if __name__ == "__main__":
    main()
