"""Fault-injection engine cost (CI-guarded).

Two guarded keys track the two fault paths a robustness sweep pays for:

  * ``failures/mask_apply``    — drawing a scenario mask and repairing a
    full layer stack against the masked adjacency (the per-scenario
    setup cost of a static degradation sweep; one batched semiring
    re-resolve for the whole stack);
  * ``failures/degraded_step`` — per-step cost of the transport scan
    with the mid-run link-down capacity lane active (one extra int32
    operand + one capacity select per step vs the pristine scan).

Derived columns carry the damage accounting (failed links, dead layers,
disconnected pairs) so the perf trajectory records WHAT was degraded
alongside how fast.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .common import emit, get_session, timeit

SF = "sf(q=5)"
FATPATHS = "fatpaths(n_layers=9,rho=0.6)"


def main(quick: bool = False) -> None:
    from repro.core import failures as F
    from repro.core import transport as TP

    session = get_session()
    topo = session.topology(SF)
    lr = session.routing(SF, FATPATHS, seed=1).routing
    adj = np.asarray(topo.adj, dtype=bool)
    key = F.scenario_key(1)

    # ---- mask + repair (CI-guarded): one static scenario end to end ----
    def scenario():
        dead = F.failure_mask(key, adj, 0.15, "bernoulli")
        return F.apply_failures(lr, dead, mode="repair", rate=0.15)

    us = timeit(scenario, n=3, warmup=1)
    _, rep = scenario()
    emit("failures/mask_apply/sf5", us,
         f"layers={lr.n_layers} failed={rep.failed_links} "
         f"deadlayers={rep.dead_layers} disc={rep.disconnected_pairs}")

    # ---- mid-run death lane (CI-guarded): per-step scan cost with the
    # link-down capacity select active, vs the pristine scan ------------
    wl = session.workload(SF, "permutation", seed=1)
    n_steps = 400
    dead = F.failure_mask(key, adj, 0.15, "bernoulli")
    hurt = dataclasses.replace(
        lr, link_down_step=F.link_down_schedule(dead, n_steps // 2))
    cfg = TP.SimConfig(n_steps=n_steps, adaptive_horizon=False)
    us_d = timeit(lambda: TP.simulate(topo, hurt, wl, cfg), n=3, warmup=1)
    us_p = timeit(lambda: TP.simulate(topo, lr, wl, cfg), n=1, warmup=1)
    emit("failures/degraded_step/sf5",
         dataclasses.replace(us_d, min_us=us_d.min_us / n_steps,
                             median_us=us_d.median_us / n_steps),
         f"steps={n_steps} n_flows={wl.n_flows} "
         f"pristine_us={us_p.min_us / n_steps:.1f} horizon=full")


if __name__ == "__main__":
    main()
