"""Fault-injection engine cost (CI-guarded).

Two guarded keys track the two fault paths a robustness sweep pays for:

  * ``failures/mask_apply``    — drawing a scenario mask and repairing a
    full layer stack against the masked adjacency (the per-scenario
    setup cost of a static degradation sweep; one batched semiring
    re-resolve for the whole stack);
  * ``failures/degraded_step`` — per-step cost of the transport scan
    with the mid-run link-down capacity lane active (one extra int32
    operand + one capacity select per step vs the pristine scan);
  * ``failures/churn_schedule`` — drawing one flapping-fabric renewal
    schedule (per-link fold_in uniforms + interleaved cumsum) for the
    whole fabric;
  * ``failures/churn_step``     — per-step cost of the scan with the
    churn lanes active (interval capacity select + the conv-gated
    pickability mask feeding the flowlet re-roll).

Derived columns carry the damage accounting (failed links, dead layers,
disconnected pairs, churn events) so the perf trajectory records WHAT
was degraded alongside how fast.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .common import emit, get_session, timeit

SF = "sf(q=5)"
FATPATHS = "fatpaths(n_layers=9,rho=0.6)"


def main(quick: bool = False) -> None:
    from repro.core import failures as F
    from repro.core import transport as TP

    session = get_session()
    topo = session.topology(SF)
    lr = session.routing(SF, FATPATHS, seed=1).routing
    adj = np.asarray(topo.adj, dtype=bool)
    key = F.scenario_key(1)

    # ---- mask + repair (CI-guarded): one static scenario end to end ----
    def scenario():
        dead = F.failure_mask(key, adj, 0.15, "bernoulli")
        return F.apply_failures(lr, dead, mode="repair", rate=0.15)

    us = timeit(scenario, n=3, warmup=1)
    _, rep = scenario()
    emit("failures/mask_apply/sf5", us,
         f"layers={lr.n_layers} failed={rep.failed_links} "
         f"deadlayers={rep.dead_layers} disc={rep.disconnected_pairs}")

    # ---- mid-run death lane (CI-guarded): per-step scan cost with the
    # link-down capacity select active, vs the pristine scan ------------
    wl = session.workload(SF, "permutation", seed=1)
    n_steps = 400
    dead = F.failure_mask(key, adj, 0.15, "bernoulli")
    hurt = dataclasses.replace(
        lr, link_down_step=F.link_down_schedule(dead, n_steps // 2))
    cfg = TP.SimConfig(n_steps=n_steps, adaptive_horizon=False)
    us_d = timeit(lambda: TP.simulate(topo, hurt, wl, cfg), n=3, warmup=1)
    us_p = timeit(lambda: TP.simulate(topo, lr, wl, cfg), n=1, warmup=1)
    emit("failures/degraded_step/sf5",
         dataclasses.replace(us_d, min_us=us_d.min_us / n_steps,
                             median_us=us_d.median_us / n_steps),
         f"steps={n_steps} n_flows={wl.n_flows} "
         f"pristine_us={us_p.min_us / n_steps:.1f} horizon=full")

    # ---- churn schedule draw (CI-guarded): one flapping scenario over
    # the full fabric ---------------------------------------------------
    def draw():
        return F.churn_schedule(key, adj, 0.3, pattern="flap",
                                mtbf=120.0, mttr=40.0, events=4)

    us_s = timeit(draw, n=3, warmup=1)
    summ = F.churn_summary(draw())
    emit("failures/churn_schedule/sf5", us_s,
         f"links={summ['churn_links']} events={summ['churn_events']} "
         f"proc=exp")

    # ---- churn lanes (CI-guarded): per-step scan cost with the
    # interval capacity select + conv pickability gating active ---------
    churned = dataclasses.replace(lr, link_churn=draw(), churn_conv=8)
    us_c = timeit(lambda: TP.simulate(topo, churned, wl, cfg),
                  n=3, warmup=1)
    emit("failures/churn_step/sf5",
         dataclasses.replace(us_c, min_us=us_c.min_us / n_steps,
                             median_us=us_c.median_us / n_steps),
         f"steps={n_steps} events={summ['churn_events']} conv=8 "
         f"horizon=full")


if __name__ == "__main__":
    main()
