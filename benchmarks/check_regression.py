"""CI perf gate: fail when guarded benchmark timings regress.

  PYTHONPATH=src python -m benchmarks.check_regression NEW.json \\
      [--baseline BENCH_PR5.json] [--threshold 1.25]

Compares timings for the guarded key patterns below against the
committed baseline (``BENCH_PR5.json``, produced by
``python -m benchmarks.run --quick --json``) — min-over-samples where a
row records one, else the median headline (see ``_us``).  The fail
decision is two-level: a guarded GROUP (one per pattern below) fails
when the geometric mean of its calibrated ratios exceeds ``threshold``;
a single row fails above ``threshold**2`` (see :func:`compare` for the
noise rationale).  A guarded key MISSING from either side also fails
(renaming a guarded benchmark must not silently disable its gate, and a
stale baseline must not pass it) — with one carve-out: a guarded GROUP
with no key in the baseline at all is a *new* guarded group (its PR
commits the refreshed baseline alongside), reported as a notice rather
than a failure so the new run can still be compared against an older
baseline (e.g. BENCH_PR5.json vs the PR4 baseline demonstrates the
fused-transport speedup on the keys both sides know).

The FULL baseline-vs-current table (every key present on either side,
guarded rows flagged) is printed on success as well as failure, so the
nightly job's uploaded log is inspectable without re-running anything.

Because the committed baseline and the CI runner are different
machines, raw microseconds are not comparable; both runs are normalised
by a calibration key (default: the ``kernels/pathcount`` row — a plain
jitted XLA matmul whose speed tracks the machine, not this repo's hot
paths).  Recalibrating the baseline when hardware or a guarded
workload deliberately changes:
``python -m benchmarks.run --quick --json BENCH_PR6.json`` (see
README "refreshing the bench baseline").

Guarded:
  * ``fig12/disjoint/…``        — bench_layers COLD layer-stack builds
                                  (the batched semiring build path);
  * ``transport/steptime/…``    — bench_transport per-step scan cost
                                  (fused waterfill + adaptive horizon,
                                  the default execution path);
  * ``transport/fusedstep/…``   — per-transport-mode step cost with the
                                  horizon forced full (isolates the
                                  fused water-filling step body);
  * ``transport/earlyexit/…``   — 4-seed vmapped sweep at paper-default
                                  depth (the adaptive horizon's win);
  * ``transport/openloop/…``    — dynamic-traffic cells (Poisson load,
                                  incast waves) through the activation
                                  lane of the fused scan;
  * ``sweep/dist/…``            — bench_sweep distributed-engine wall
                                  time for the whole quick grid (the
                                  scale keystone's contract);
  * ``failures/…``              — bench_failures fault-injection costs:
                                  scenario mask + stack repair, the
                                  per-step price of the mid-run
                                  link-down capacity lane, the churn
                                  renewal-schedule draw, and the
                                  per-step price of the churn lanes
                                  (interval capacity select + conv-
                                  gated re-pick mask);
  * ``kernels/sparse/…``        — bench_sparse blocked-engine programs:
                                  frontier APSP and the full blocked
                                  table build (the scale-smoke path);
  * ``paths/compressed_lookup/…`` — compressed forwarding-table lookup
                                  throughput (the host-side walk path).
"""

from __future__ import annotations

import argparse
import json
import math
import re
import sys

GUARDED = [r"^fig12/disjoint/", r"^transport/steptime/",
           r"^transport/fusedstep/", r"^transport/earlyexit/",
           r"^transport/openloop/", r"^transport/recovery/",
           r"^sweep/dist/", r"^failures/", r"^kernels/sparse/",
           r"^paths/compressed_lookup/"]
CALIBRATE = r"^kernels/pathcount/"


def _us(row: dict) -> float:
    """The comparison time for one bench row: the min-over-samples when
    the row carries one (``derived.min_us``, emitted by common.timeit),
    else the headline median.  Minima are the right gate statistic on
    shared/noisy runners: contention inflates samples but never deflates
    them, so min-vs-min drifts far less run-to-run than median-vs-median
    (observed 1.5x swings on guarded keys between idle runs of identical
    code)."""
    mn = row.get("derived", {}).get("min_us")
    return float(mn) if mn else float(row["us_per_call"])


def _calibration(baseline: dict, new: dict) -> float:
    """new-machine / baseline-machine speed factor from the calibration
    key (1.0 when it is missing on either side)."""
    pat = re.compile(CALIBRATE)
    for name in sorted(baseline):
        if pat.search(name) and name in new:
            b = _us(baseline[name])
            v = _us(new[name])
            if b > 0 and v > 0:
                return v / b
    return 1.0


def compare(baseline: dict, new: dict, threshold: float):
    """Returns (failures, rows, missing, cal).

    ``failures`` — human-readable regression descriptions, two-level:
    each guarded GROUP (one entry per pattern in ``GUARDED``) fails when
    the geometric mean of its calibrated ratios exceeds ``threshold``;
    an individual row only fails above ``threshold**2`` (per-row noise
    on small shared runners swings ~1.4x between idle runs of identical
    code; the group geomean drifts <1.1x, so the tight bound lives on
    the group statistic and the loose one catches single-row blowups).

    ``rows`` — ALL baseline-vs-new comparisons as (name, guarded,
    base_us, new_us, calibrated ratio), the full table, not only the
    guarded slice.  ``missing`` — guarded keys absent from EITHER side
    as (name, side) pairs (new-side missing = renamed benchmark,
    baseline-side missing = stale baseline — both must fail, not
    silently pass).  EXCEPTION: baseline-side misses whose whole guarded
    group is absent from the baseline are a NEW guarded group, returned
    separately as ``new_groups`` (a notice, not a failure — the older
    baseline simply predates that gate; see module docstring).
    ``cal`` — the machine calibration factor."""
    guard = re.compile("|".join(GUARDED))
    cal = _calibration(baseline, new)
    rows = []
    failures = []
    missing = []
    new_groups = []
    base_has_group = {pat: any(re.search(pat, n) for n in baseline)
                      for pat in GUARDED}
    groups = {pat: [] for pat in GUARDED}
    for name in sorted(set(baseline) | set(new)):
        guarded = bool(guard.search(name))
        if name not in new:
            if guarded:
                missing.append((name, "new run"))
            rows.append((name, guarded, _us(baseline[name]), float("nan"),
                         float("nan")))
            continue
        if name not in baseline:
            if guarded:
                pat = next(p for p in GUARDED if re.search(p, name))
                if base_has_group[pat]:
                    missing.append((name, "baseline"))
                else:
                    new_groups.append((name, pat))
            rows.append((name, guarded, float("nan"), _us(new[name]),
                         float("nan")))
            continue
        b = _us(baseline[name])
        v = _us(new[name])
        ratio = v / (b * cal) if b > 0 else float("inf")
        rows.append((name, guarded, b, v, ratio))
        if guarded:
            for pat in GUARDED:
                if re.search(pat, name):
                    groups[pat].append(ratio)
        # Per-row bound at threshold^2: single-row timing noise on small
        # shared runners routinely swings ~1.4x (measured between idle
        # runs of identical code), so an individual row only fails on a
        # blowup no noise produces.
        if guarded and ratio > threshold * threshold:
            failures.append(f"{name}: x{ratio:.2f} > per-row bound "
                            f"x{threshold * threshold:.2f}")
    # Group bound at threshold: the geometric mean over a guarded
    # group's rows averages the per-row noise away (measured group
    # drift < 1.1x where single rows drift 1.4x), so the tight
    # threshold applies to the group statistic — but ONLY when the
    # group is wide enough to average anything; a 1-2 key group's
    # geomean IS (nearly) a single row, so it gets the per-row bound,
    # not a false sense of averaging.
    for pat, ratios in groups.items():
        if not ratios:
            continue
        bound = threshold if len(ratios) >= 3 else threshold * threshold
        gm = math.prod(ratios) ** (1.0 / len(ratios))
        if gm > bound:
            failures.append(f"group {pat!r}: geomean x{gm:.2f} over "
                            f"{len(ratios)} key(s) > x{bound:.2f}")
    return failures, rows, missing, new_groups, cal


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="bench --json output to check")
    ap.add_argument("--baseline", default="BENCH_PR5.json")
    ap.add_argument("--threshold", type=float, default=1.25)
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.new) as f:
        new = json.load(f)

    failures, rows, missing, new_groups, cal = compare(baseline, new,
                                                       args.threshold)
    print(f"machine calibration factor: x{cal:.2f} ({CALIBRATE!r} key)")
    n_guarded = 0
    row_bound = args.threshold * args.threshold
    for name, guarded, b, v, ratio in rows:
        n_guarded += guarded and ratio == ratio    # both-sided comparisons
        mark = "[guard]" if guarded else "       "
        flag = " <-- REGRESSION" if guarded and ratio > row_bound else ""
        print(f"{mark} {name:45s} base={b:10.1f}us new={v:10.1f}us "
              f"x{ratio:5.2f} (calibrated){flag}")
    for name, pat in new_groups:
        print(f"NOTE: guarded key {name!r} opens a new group {pat!r} "
              "absent from this baseline (gates once the refreshed "
              "baseline is committed)")
    for name, side in missing:
        print(f"ERROR: guarded key {name!r} missing from {side}",
              file=sys.stderr)
    if not n_guarded and not missing:
        print("ERROR: no guarded keys matched — baseline stale?",
              file=sys.stderr)
        return 1
    if missing:
        print(f"{len(missing)} guarded benchmark(s) missing — a guarded "
              "key rename must update the committed baseline",
              file=sys.stderr)
        return 1
    if failures:
        for f in failures:
            print(f"REGRESSION: {f}", file=sys.stderr)
        print(f"{len(failures)} guarded regression(s) (group geomean "
              f">{(args.threshold - 1) * 100:.0f}% or single row "
              f">{(row_bound - 1) * 100:.0f}%)", file=sys.stderr)
        return 1
    print(f"perf gate OK ({n_guarded} guarded keys in {len(GUARDED)} "
          f"groups within {(args.threshold - 1) * 100:.0f}%; "
          f"{len(rows)} keys compared)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
