"""CI perf gate: fail when guarded benchmark timings regress.

  PYTHONPATH=src python -m benchmarks.check_regression NEW.json \\
      [--baseline BENCH_PR3.json] [--threshold 1.25]

Compares ``us_per_call`` for the guarded key patterns below against the
committed baseline (``BENCH_PR3.json``, produced by
``python -m benchmarks.run --quick --json``).  A guarded key regresses
when it is more than ``threshold`` times slower than the baseline after
machine calibration; a guarded key MISSING from the new run also fails
(renaming a guarded benchmark must not silently disable its gate).

Because the committed baseline and the CI runner are different
machines, raw microseconds are not comparable; both runs are normalised
by a calibration key (default: the ``kernels/pathcount`` row — a plain
jitted XLA matmul whose speed tracks the machine, not this repo's hot
paths).  Regenerate the baseline with
``python -m benchmarks.run --quick --json BENCH_PR3.json`` whenever a
guarded benchmark's workload deliberately changes.

Guarded:
  * ``fig12/disjoint/…``        — bench_layers COLD layer-stack builds
                                  (the batched semiring build path);
  * ``transport/steptime/…``    — bench_transport per-step scan cost
                                  (paths precomputed outside the scan).
"""

from __future__ import annotations

import argparse
import json
import re
import sys

GUARDED = [r"^fig12/disjoint/", r"^transport/steptime/"]
CALIBRATE = r"^kernels/pathcount/"


def _calibration(baseline: dict, new: dict) -> float:
    """new-machine / baseline-machine speed factor from the calibration
    key (1.0 when it is missing on either side)."""
    pat = re.compile(CALIBRATE)
    for name in sorted(baseline):
        if pat.search(name) and name in new:
            b = float(baseline[name]["us_per_call"])
            v = float(new[name]["us_per_call"])
            if b > 0 and v > 0:
                return v / b
    return 1.0


def compare(baseline: dict, new: dict, threshold: float):
    """Returns (failures, rows, missing): guarded keys over threshold,
    all guarded comparisons as (name, base_us, new_us, calibrated
    ratio), and guarded keys absent from the new run."""
    guard = re.compile("|".join(GUARDED))
    cal = _calibration(baseline, new)
    rows = []
    failures = []
    missing = []
    for name, base in sorted(baseline.items()):
        if not guard.search(name):
            continue
        if name not in new:
            missing.append(name)
            continue
        b = float(base["us_per_call"])
        v = float(new[name]["us_per_call"])
        ratio = v / (b * cal) if b > 0 else float("inf")
        rows.append((name, b, v, ratio))
        if ratio > threshold:
            failures.append((name, b, v, ratio))
    return failures, rows, missing, cal


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="bench --json output to check")
    ap.add_argument("--baseline", default="BENCH_PR3.json")
    ap.add_argument("--threshold", type=float, default=1.25)
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.new) as f:
        new = json.load(f)

    failures, rows, missing, cal = compare(baseline, new, args.threshold)
    print(f"machine calibration factor: x{cal:.2f} ({CALIBRATE!r} key)")
    for name, b, v, ratio in rows:
        flag = " <-- REGRESSION" if ratio > args.threshold else ""
        print(f"{name:45s} base={b:10.1f}us new={v:10.1f}us "
              f"x{ratio:.2f} (calibrated){flag}")
    for name in missing:
        print(f"ERROR: guarded key {name!r} missing from new run",
              file=sys.stderr)
    if not rows:
        print("ERROR: no guarded keys matched — baseline stale?",
              file=sys.stderr)
        return 1
    if missing:
        print(f"{len(missing)} guarded benchmark(s) missing — a guarded "
              "key rename must update BENCH_PR3.json", file=sys.stderr)
        return 1
    if failures:
        print(f"{len(failures)} guarded benchmark(s) regressed "
              f">{(args.threshold - 1) * 100:.0f}%", file=sys.stderr)
        return 1
    print(f"perf gate OK ({len(rows)} guarded keys within "
          f"{(args.threshold - 1) * 100:.0f}%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
