"""§8 adaptation: training-system collectives on a modelled cluster fabric.

The bridge from the paper to the training framework: the dry-run's
collective traffic (ring all-reduce for DP gradients, all-to-all for MoE
EP dispatch) is routed over Slim Fly / fat-tree fabrics with minimal-path
ECMP vs FatPaths layered+flowlet routing.

Claims demonstrated:
  * neighbour-pattern ring collectives are fabric-neutral (minimal paths
    suffice — FatPaths == ECMP);
  * all-to-all (the MoE EP dispatch == the paper's adversarial pattern)
    and skewed multi-ring schedules benefit from non-minimal layers;
  * the multi-ring gradient all-reduce (dist.collectives) spreads load
    across fabric layers (lower gini / bottleneck than a single ring of
    the same total bytes).
"""

from __future__ import annotations

from repro.dist.collectives import layer_strides

from .common import emit, get_session, timeit


def main(quick: bool = False) -> None:
    session = get_session()
    fabrics = [("sf11", "sf(q=11)")]
    if not quick:
        fabrics.append(("ft12", "ft(k=12)"))
    n_dev = 256
    nbytes = 1e9     # ~ a 500M-param bf16 gradient block

    for fname, tspec in fabrics:
        from repro.experiments import Session

        # Cold fabric construction (fresh session => layer stacks rebuilt).
        us = timeit(lambda: Session().fabric(tspec, n_layers=9, rho=0.6),
                    n=3, warmup=0)
        fb = session.fabric(tspec, n_layers=9, rho=0.6)
        for kind in ("all-reduce", "all-to-all"):
            e = fb.collective_time(kind, n_dev, nbytes, "ecmp")
            f = fb.collective_time(kind, n_dev, nbytes, "fatpaths")
            emit(f"fabric/{fname}/{kind}", us,
                 f"ecmp_ms={e.time_s * 1e3:.1f} fp_ms={f.time_s * 1e3:.1f} "
                 f"gini={e.util_gini:.2f}->{f.util_gini:.2f}")
        # single ring vs layered multi-ring schedule (same total bytes)
        one = fb.collective_time("all-reduce", n_dev, nbytes, "fatpaths",
                                 strides=(1,))
        multi = fb.collective_time("all-reduce", n_dev, nbytes, "fatpaths",
                                   strides=layer_strides(n_dev, 4))
        emit(f"fabric/{fname}/multiring", us.median_us,
             f"1ring_ms={one.time_s * 1e3:.1f} "
             f"4ring_ms={multi.time_s * 1e3:.1f} "
             f"links={one.n_links_used}->{multi.n_links_used}")


if __name__ == "__main__":
    main()
