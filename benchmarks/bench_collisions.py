"""Paper Fig 4: histogram of colliding paths per router pair.

Claim reproduced: for D>1 topologies with p = k'/D, collisions are <= ~3
for almost all router pairs even on 4x-oversubscribed patterns; D=1
(clique) sees high-multiplicity collisions => needs more path diversity.
"""

from __future__ import annotations

import numpy as np

from .common import emit, get_session, timeit


def collision_histogram(wl) -> np.ndarray:
    """Count flows per (src_router, dst_router) pair (a 'collision' is >1
    flow on the same pair — they share every minimal path, Fig 5 left)."""
    pairs = {}
    for s, t in zip(wl.src_router, wl.dst_router):
        pairs[(int(s), int(t))] = pairs.get((int(s), int(t)), 0) + 1
    return np.bincount(list(pairs.values()))


def main(quick: bool = False) -> None:
    session = get_session()
    cases = [("sf(q=5)", "SF(D=2)"), ("df(p=3)", "DF(D=3)"),
             ("clique(k=12)", "clique(D=1)")]
    patterns = ["permutation", "stencil", "permutation(rounds=4)"]
    for tspec, label in cases:
        for pspec in patterns:
            us = timeit(
                lambda: collision_histogram(session.workload(tspec, pspec,
                                                             seed=0)))
            h = collision_histogram(session.workload(tspec, pspec, seed=0))
            p99 = 1
            cum = np.cumsum(h) / max(h.sum(), 1)
            for k, c in enumerate(cum):
                if c >= 0.99:
                    p99 = k
                    break
            emit(f"fig4/{label}/{pspec}", us,
                 f"p99_collisions={p99} max={len(h) - 1}")


if __name__ == "__main__":
    main()
