"""Paper Fig 4: histogram of colliding paths per router pair.

Claim reproduced: for D>1 topologies with p = k'/D, collisions are <= ~3
for almost all router pairs even on 4x-oversubscribed patterns; D=1
(clique) sees high-multiplicity collisions => needs more path diversity.
"""

from __future__ import annotations

import numpy as np

from repro.core import topology as T
from repro.core import traffic as TR

from .common import emit, timeit


def collision_histogram(topo, pattern: str, n_rounds: int = 1,
                        seed: int = 0) -> np.ndarray:
    """Count flows per (src_router, dst_router) pair (a 'collision' is >1
    flow on the same pair — they share every minimal path, Fig 5 left)."""
    wl = TR.make_workload(topo, pattern, n_rounds=n_rounds, seed=seed)
    pairs = {}
    for s, t in zip(wl.src_router, wl.dst_router):
        pairs[(int(s), int(t))] = pairs.get((int(s), int(t)), 0) + 1
    return np.bincount(list(pairs.values()))


def main(quick: bool = False) -> None:
    cases = [
        (T.slim_fly(5), "SF(D=2)"),
        (T.dragonfly(3), "DF(D=3)"),
        (T.clique(12), "clique(D=1)"),
    ]
    for topo, label in cases:
        for pattern, rounds in (("permutation", 1), ("stencil", 1),
                                ("permutation", 4)):
            us = timeit(lambda: collision_histogram(topo, pattern, rounds),
                        n=1)
            h = collision_histogram(topo, pattern, rounds)
            p99 = 1
            cum = np.cumsum(h) / max(h.sum(), 1)
            for k, c in enumerate(cum):
                if c >= 0.99:
                    p99 = k
                    break
            emit(f"fig4/{label}/{pattern}x{rounds}", us,
                 f"p99_collisions={p99} max={len(h) - 1}")


if __name__ == "__main__":
    main()
