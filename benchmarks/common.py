"""Shared benchmark plumbing: timing + CSV emission + the shared Session.

All benchmark modules assemble their cells through one
:class:`repro.experiments.Session` (``get_session()``), so layer stacks,
ECMP tables, workloads and fabrics are built once across the whole
``benchmarks.run`` sweep instead of once per module.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Tuple, Union

ROWS: List[Tuple[str, float, str]] = []

_SESSION = None


def get_session():
    """The process-wide experiments Session shared by every benchmark."""
    global _SESSION
    if _SESSION is None:
        from repro.experiments import Session
        _SESSION = Session()
    return _SESSION


@dataclasses.dataclass(frozen=True)
class Timing:
    """Min/median wall time over n samples, in microseconds."""

    min_us: float
    median_us: float
    n: int


def emit(name: str, us: Union[float, "Timing"], derived: str = "") -> None:
    """Record + print one benchmark row.  ``us`` may be a raw duration or
    a :class:`Timing`, in which case the median is the headline number and
    the min rides along in the derived column."""
    if isinstance(us, Timing):
        extra = f"min_us={us.min_us:.1f} n={us.n}"
        derived = f"{derived} {extra}".strip()
        us = us.median_us
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def timeit(fn: Callable, n: int = 3, warmup: int = 1) -> Timing:
    """Wall time over ``n`` samples (median is the headline; a single
    sample has no median, hence the n>=3 default even in quick mode)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(max(1, n)):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return Timing(min_us=ts[0], median_us=ts[len(ts) // 2], n=len(ts))


# The paper's topology set at 'small' scale (§2.2.2), cost-matched —
# as experiment mini-specs, resolved through the shared Session.
SMALL_TOPOS = ["sf(q=5)", "df(p=3)", "xp(k=8)", "hx(l=2,s=6)", "ft(k=8)"]
SMALL_TOPOS_JF = SMALL_TOPOS + ["jfeq(of=sf(q=5))"]


def small_topologies(include_jf: bool = True):
    """The small cost-matched topology set, built via the Session."""
    session = get_session()
    specs = SMALL_TOPOS_JF if include_jf else SMALL_TOPOS
    return [session.topology(s) for s in specs]
