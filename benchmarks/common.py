"""Shared benchmark plumbing: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn: Callable, n: int = 3, warmup: int = 1) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def small_topologies(include_jf: bool = True):
    """The paper's topology set at 'small' scale (§2.2.2), cost-matched."""
    from repro.core import topology as T

    topos = [T.slim_fly(5), T.dragonfly(3), T.xpander(8), T.hyperx(2, 6),
             T.fat_tree(8)]
    if include_jf:
        topos.append(T.equivalent_jellyfish(topos[0], seed=0))
    return topos
