"""Paper Fig 2 / Fig 11 / Fig 14: the headline comparisons.

  * Fig 2  (randomised traffic):   SF+FatPaths vs cost-matched FT3+NDP.
  * Fig 11 (skewed, non-random):   non-minimal routing >> minimal.
  * Fig 14 (TCP stacks):           FatPaths vs ECMP vs LetFlow on TCP;
                                   purified (NDP) transport vs TCP/DCTCP.

Claims reproduced (qualitatively, flow-level simulator):
  * SF+FatPaths >= FT+NDP throughput at equal cost on randomized traffic;
  * minimal-only routing collapses on skewed traffic on SF (one minimal
    path!), non-minimal layers fix it;
  * purified transport beats TCP slow-start on short flows;
  * LetFlow == ECMP on SF (no minimal diversity to balance over).

Every cell is declared as an experiments-API spec and executed through
the shared Session (layer stacks built once across all figures).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .common import emit, get_session, timeit

SF = "sf(q=5)"
FT2X = "ft(k=8,oversub=2)"                 # cost-matched (§7.1.1)
FATPATHS = "fatpaths(n_layers=9,rho=0.6)"


def _emit_cell(name: str, rr, extra: str = "") -> None:
    m = rr.metrics
    derived = (f"p99us={m['fct_p99_us']:.0f} fin={m['finished']:.2f}"
               + (f" {extra}" if extra else ""))
    emit(name, m["fct_p50_us"], derived)


def main(quick: bool = False) -> None:
    session = get_session()
    ev = f"transport(steps={600 if quick else 2000})"
    ev2x = f"transport(steps={2 * (600 if quick else 2000)})"

    # ---- Fig 2: randomized workload, NDP-style transport everywhere -----
    for label, topo, scheme in (("sf+fatpaths", SF, FATPATHS),
                                ("ft+ndp-pr", FT2X, "letflow")):
        rr = session.run(topo, scheme, "permutation", ev, seed=1)
        _emit_cell(f"fig2/randomized/{label}", rr,
                   f"tput={rr.metrics['tput_gbs']:.2f}GB/s")

    # ---- Fig 11: skewed non-randomized; minimal vs non-minimal ----------
    for label, scheme in (("nonminimal", FATPATHS),
                          ("minimal", "minimal(n_layers=9)")):
        rr = session.run(SF, scheme, "adversarial", ev, seed=3)
        _emit_cell(f"fig11/skewed/sf+{label}", rr)
    rr = session.run(FT2X, "letflow", "adversarial", ev, seed=3)
    _emit_cell("fig11/skewed/ft+ndp", rr)

    # ---- collision microcase (Fig 5): ECMP == LetFlow << FatPaths -------
    for scheme, label in ((FATPATHS, "fatpaths"), ("letflow(n=4)", "letflow"),
                          ("ecmp(n=4)", "ecmp")):
        rr = session.run(SF, scheme, "collide", ev2x, seed=0)
        emit(f"fig5/collision/{label}", rr.metrics["fct_p50_us"],
             f"p99us={rr.metrics['fct_p99_us']:.0f}")

    # ---- Fig 14: TCP-stack comparison ------------------------------------
    steps = 600 if quick else 2000
    for transport in ("ndp", "tcp", "dctcp"):
        rr = session.run(SF, FATPATHS, "permutation",
                         f"transport(steps={steps},transport={transport})",
                         seed=5)
        _emit_cell(f"fig14/transport/{transport}", rr)
    for scheme, label in (("ecmp(n=4)", "ecmp"), ("letflow(n=4)", "letflow"),
                          (FATPATHS, "fatpaths")):
        rr = session.run(SF, scheme, "permutation",
                         f"transport(steps={steps},transport=tcp)", seed=5)
        _emit_cell(f"fig14/tcp-balancing/{label}", rr)

    # ---- scan step cost (CI-guarded): warm per-step time, paths
    # precomputed once in _prepare so it is independent of max_hops.
    # Default config = fused waterfill step + adaptive horizon, so this
    # key tracks the end-to-end per-nominal-step cost users actually pay.
    from repro.core import transport as TP

    topo = session.topology(SF)
    lr = session.routing(SF, FATPATHS, seed=1).routing
    wl = session.workload(SF, "permutation", seed=1)
    n_steps = 400
    cfg = TP.SimConfig(n_steps=n_steps)
    us = timeit(lambda: TP.simulate(topo, lr, wl, cfg), n=3, warmup=1)
    emit("transport/steptime/sf5",
         dataclasses.replace(us, min_us=us.min_us / n_steps,
                             median_us=us.median_us / n_steps),
         f"steps={n_steps} n_flows={wl.n_flows}")

    # ---- fused step cost per transport mode (CI-guarded): adaptive
    # horizon OFF, so these keys isolate the water-filling step body
    # (kernel layer) from the early-exit win measured above ---------------
    def _per_step(t):
        return dataclasses.replace(t, min_us=t.min_us / n_steps,
                                   median_us=t.median_us / n_steps)

    for mode in ("ndp", "tcp", "dctcp"):
        cfg_m = TP.SimConfig(n_steps=n_steps, transport=mode,
                             adaptive_horizon=False)
        us = timeit(lambda: TP.simulate(topo, lr, wl, cfg_m), n=3, warmup=1)
        emit(f"transport/fusedstep/{mode}", _per_step(us),
             f"steps={n_steps} n_flows={wl.n_flows} horizon=full")

    # ---- early-exit sweep sample (CI-guarded): a 4-sim-seed vmapped
    # sweep at the paper-default 2000 steps, where most cells finish (or
    # provably stall) long before the horizon; derived column records the
    # measured win over the same sweep forced to full horizon ------------
    cfg_e = TP.SimConfig(n_steps=2000)
    cfg_f = TP.SimConfig(n_steps=2000, adaptive_horizon=False)
    us_e = timeit(lambda: TP.simulate_seeds(topo, lr, wl, cfg_e, range(4)),
                  n=3, warmup=1)
    us_f = timeit(lambda: TP.simulate_seeds(topo, lr, wl, cfg_f, range(4)),
                  n=1, warmup=1)
    emit("transport/earlyexit/sweep4", us_e,
         f"steps=2000 seeds=4 fullhorizon_us={us_f.min_us:.0f} "
         f"speedup={us_f.min_us / us_e.min_us:.1f}")

    # ---- open-loop dynamic traffic (CI-guarded): continuous Poisson
    # arrivals and incast waves through the same fused adaptive scan;
    # tracks the cost of the activation lane end to end -------------------
    dyn_steps = 400 if quick else 1000
    for key, pattern in (("poisson", "load(level=0.5,window=192)"),
                         ("incast", "incast(fan_in=8,waves=4,wave_period=64)")):
        wl_d = session.workload(SF, pattern, seed=2)
        cfg_d = TP.SimConfig(n_steps=dyn_steps)
        us = timeit(lambda: TP.simulate(topo, lr, wl_d, cfg_d), n=3, warmup=1)
        emit(f"transport/openloop/{key}", us,
             f"steps={dyn_steps} n_flows={wl_d.n_flows}")

    # ---- loss-recovery lanes (CI-guarded): per-step cost of the PR 8
    # scan.  transport/recovery/rto arms the stall timer + RTO machine +
    # ECN lane on a pristine fabric; transport/recovery/escape runs the
    # full blackhole path (mid-run link death -> in-flight rollback ->
    # deterministic layer escape).  Horizon full, so both keys isolate
    # the lane cost against transport/fusedstep/* above; the derived
    # column records the recovery=off step for the overhead ratio.
    from repro.core import failures as F

    cfg_r = TP.SimConfig(n_steps=n_steps, recovery="on",
                         adaptive_horizon=False)
    us_r = timeit(lambda: TP.simulate(topo, lr, wl, cfg_r), n=3, warmup=1)
    us_off = timeit(lambda: TP.simulate(
        topo, lr, wl, dataclasses.replace(cfg_r, recovery="off")),
        n=1, warmup=1)
    emit("transport/recovery/rto", _per_step(us_r),
         f"steps={n_steps} n_flows={wl.n_flows} "
         f"off_us={us_off.min_us / n_steps:.1f} horizon=full")

    adj = np.asarray(topo.adj, dtype=bool)
    dead = F.failure_mask(F.scenario_key(1), adj, 0.15, "bernoulli")
    hurt = dataclasses.replace(
        lr, link_down_step=F.link_down_schedule(dead, n_steps // 2))
    us_e = timeit(lambda: TP.simulate(topo, hurt, wl, cfg_r), n=3, warmup=1)
    emit("transport/recovery/escape", _per_step(us_e),
         f"steps={n_steps} n_flows={wl.n_flows} "
         f"rto_us={us_r.min_us / n_steps:.1f} horizon=full")


if __name__ == "__main__":
    main()
