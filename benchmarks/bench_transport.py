"""Paper Fig 2 / Fig 11 / Fig 14: the headline comparisons.

  * Fig 2  (randomised traffic):   SF+FatPaths vs cost-matched FT3+NDP.
  * Fig 11 (skewed, non-random):   non-minimal routing >> minimal.
  * Fig 14 (TCP stacks):           FatPaths vs ECMP vs LetFlow on TCP;
                                   purified (NDP) transport vs TCP/DCTCP.

Claims reproduced (qualitatively, flow-level simulator):
  * SF+FatPaths >= FT+NDP throughput at equal cost on randomized traffic;
  * minimal-only routing collapses on skewed traffic on SF (one minimal
    path!), non-minimal layers fix it;
  * purified transport beats TCP slow-start on short flows;
  * LetFlow == ECMP on SF (no minimal diversity to balance over).
"""

from __future__ import annotations

import numpy as np

from repro.core import layers as L
from repro.core import topology as T
from repro.core import traffic as TR
from repro.core import transport as TP

from .common import emit, timeit


def run(topo, routing, wl, n_steps, **cfg_kw):
    res = TP.simulate(topo, routing, wl, TP.SimConfig(n_steps=n_steps,
                                                      **cfg_kw))
    return res.fct_stats(), res


def main(quick: bool = False) -> None:
    steps = 600 if quick else 2000
    sf = T.slim_fly(5)
    ft = T.fat_tree(8, oversubscription=2)     # cost-matched (§7.1.1)
    sf_fp = L.build_layers(sf, 9, 0.6, seed=0)
    ft_nh = TP.ecmp_routing(ft, n_tables=8, seed=0)

    # ---- Fig 2: randomized workload, NDP-style transport everywhere -----
    for label, topo, routing, bal in (
            ("sf+fatpaths", sf, sf_fp, "fatpaths"),
            ("ft+ndp-pr", ft, ft_nh, "letflow")):
        wl = TR.make_workload(topo, "permutation", seed=1, randomize=True,
                              flow_size=1 << 20)
        st, res = run(topo, routing, wl, steps, balancing=bal)
        tpf = np.nanmean(res.throughput_per_flow) / 1e9
        emit(f"fig2/randomized/{label}", st["p50"] * 1e6,
             f"p99us={st['p99'] * 1e6:.0f} tput={tpf:.2f}GB/s "
             f"fin={st['finished']:.2f}")

    # ---- Fig 11: skewed non-randomized; minimal vs non-minimal ----------
    sf_min = L.build_layers(sf, 9, 1.0, seed=0)     # rho=1: minimal only
    wl = TR.make_workload(sf, "adversarial", seed=3, randomize=False,
                          n_rounds=2, flow_size=1 << 20)
    for label, routing in (("nonminimal", sf_fp), ("minimal", sf_min)):
        st, _ = run(sf, routing, wl, steps, balancing="fatpaths")
        emit(f"fig11/skewed/sf+{label}", st["p50"] * 1e6,
             f"p99us={st['p99'] * 1e6:.0f} fin={st['finished']:.2f}")
    st, _ = run(ft, ft_nh, TR.make_workload(ft, "adversarial", seed=3,
                                            randomize=False, n_rounds=2,
                                            flow_size=1 << 20),
                steps, balancing="letflow")
    emit("fig11/skewed/ft+ndp", st["p50"] * 1e6,
         f"p99us={st['p99'] * 1e6:.0f} fin={st['finished']:.2f}")

    # ---- collision microcase (Fig 5): ECMP == LetFlow << FatPaths -------
    from repro.core import paths as P
    import jax.numpy as jnp
    ep2r = TR.endpoint_router_map(sf)
    dist = np.asarray(P.shortest_path_lengths(
        jnp.asarray(np.asarray(sf.adj, bool)), max_l=8))
    A, B = next((a, b) for a in range(sf.n_routers)
                for b in range(sf.n_routers) if dist[a, b] == 2)
    src = np.concatenate([np.where(ep2r == A)[0]] * 4)
    dst = np.tile(np.where(ep2r == B)[0], 4)
    wl_c = TR.FlowWorkload(src=src.astype(np.int32), dst=dst.astype(np.int32),
                           size=np.full(len(src), 4 * 2 ** 20),
                           start=np.zeros(len(src)),
                           src_router=ep2r[src].astype(np.int32),
                           dst_router=ep2r[dst].astype(np.int32))
    ecmp = TP.ecmp_routing(sf, n_tables=4, seed=0)
    for label, routing, bal in (("fatpaths", sf_fp, "fatpaths"),
                                ("letflow", ecmp, "letflow"),
                                ("ecmp", ecmp, "ecmp")):
        st, _ = run(sf, routing, wl_c, 2 * steps, balancing=bal)
        emit(f"fig5/collision/{label}", st["p50"] * 1e6,
             f"p99us={st['p99'] * 1e6:.0f}")

    # ---- Fig 14: TCP-stack comparison ------------------------------------
    wl = TR.make_workload(sf, "permutation", seed=5, flow_size=1 << 20)
    for transport in ("ndp", "tcp", "dctcp"):
        st, _ = run(sf, sf_fp, wl, steps, transport=transport,
                    balancing="fatpaths")
        emit(f"fig14/transport/{transport}", st["p50"] * 1e6,
             f"p99us={st['p99'] * 1e6:.0f} fin={st['finished']:.2f}")
    for bal, routing in (("ecmp", ecmp), ("letflow", ecmp),
                         ("fatpaths", sf_fp)):
        st, _ = run(sf, routing, wl, steps, transport="tcp", balancing=bal)
        emit(f"fig14/tcp-balancing/{bal}", st["p50"] * 1e6,
             f"p99us={st['p99'] * 1e6:.0f} fin={st['finished']:.2f}")


if __name__ == "__main__":
    main()
