"""Paper Fig 9: maximum achievable throughput (MCF LP) per layered scheme.

Claims reproduced:
  * SPAIN (tree layers) wins on fat trees, loses on low-diameter networks;
  * the PI-minimising variant >= the simple random variant;
  * layered (multi-path) >= single-path on every topology.
"""

from __future__ import annotations

from repro.core import layers as L
from repro.core import throughput as TH
from repro.core import topology as T
from repro.core import traffic as TR

from .common import emit, timeit


def main(quick: bool = False) -> None:
    topos = [T.slim_fly(5), T.xpander(8), T.fat_tree(8)]
    schemes = ["rand", "pi_min", "spain", "ksp"] if not quick \
        else ["rand", "spain"]
    for topo in topos:
        wl = TR.make_workload(topo, "permutation", seed=0,
                              frac_endpoints=0.55)   # paper: intensity 0.55
        for scheme in schemes:
            n = 5 if scheme != "spain" else 8
            lr = L.build_layers(topo, n, 0.6, scheme=scheme, seed=0)
            us = timeit(lambda: TH.mat_lp(lr, wl), n=1)
            res = TH.mat_lp(lr, wl)
            single = TH.mat_single_layer(lr, wl)
            emit(f"fig9/mat/{topo.name}/{scheme}", us,
                 f"T={res.throughput:.3f} T_single={single.throughput:.3f}")


if __name__ == "__main__":
    main()
