"""Paper Fig 9: maximum achievable throughput (MCF LP) per layered scheme.

Claims reproduced:
  * SPAIN (tree layers) wins on fat trees, loses on low-diameter networks;
  * the PI-minimising variant >= the simple random variant;
  * layered (multi-path) >= single-path on every topology.
"""

from __future__ import annotations

from repro.core import throughput as TH

from .common import emit, get_session, timeit


def main(quick: bool = False) -> None:
    session = get_session()
    topos = ["sf(q=5)", "xp(k=8)", "ft(k=8)"]
    schemes = ["rand", "pi_min", "spain", "ksp"] if not quick \
        else ["rand", "spain"]
    pattern = "permutation(frac=0.55)"     # paper: intensity 0.55
    for tspec in topos:
        for scheme in schemes:
            n = 5 if scheme != "spain" else 8
            rspec = f"fatpaths(n_layers={n},rho=0.6,scheme={scheme})"
            # The cell run yields the derived metrics; the timed region
            # is the MAT LP alone over the cell's cached artifacts (same
            # measurement as the seed benchmark).
            rr = session.run(tspec, rspec, pattern, "mat", seed=0)
            lr = session.routing(tspec, rspec, seed=0).routing
            wl = session.workload(tspec, pattern, seed=0)
            us = timeit(lambda: TH.mat_lp(lr, wl), warmup=0)
            topo = session.topology(tspec)
            emit(f"fig9/mat/{topo.name}/{scheme}", us,
                 f"T={rr.metrics['mat_T']:.3f} "
                 f"T_single={rr.metrics['mat_T_single']:.3f}")


if __name__ == "__main__":
    main()
