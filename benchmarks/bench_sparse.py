"""Block-sparse path engine (PR 9): frontier APSP, blocked table builds,
and compressed-table lookups.

The timed numbers are the blocked engine's jitted device programs — the
representation the scale-smoke CI job builds sf(q=29) through — with the
dense engine's output as the bit-identity check in the derived column.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layers as layers_mod
from repro.core import paths as paths_mod
from repro.core.topology import slim_fly

from .common import emit, timeit


def main(quick: bool = False) -> None:
    q = 11 if quick else 17
    topo = slim_fly(q)
    n = topo.n_routers
    lr = layers_mod.build_layers(topo, 5, 0.6, scheme="rand", seed=0,
                                 engine="dense", representation="dense")
    stack = jnp.asarray(np.asarray(lr.layer_adj, bool))
    max_l = 16

    # ---- frontier (wavefront) APSP over the layer stack -----------------
    f_apsp = lambda: jax.block_until_ready(
        paths_mod.apsp_batched(stack, max_l=max_l, engine="blocked"))
    us = timeit(f_apsp, n=3)
    d_b = np.asarray(f_apsp())
    d_d = np.asarray(paths_mod.apsp_batched(stack, max_l=max_l,
                                            engine="dense"))
    ok = np.array_equal(d_b, d_d)
    emit(f"kernels/sparse/apsp/sf{q}", us,
         f"layers={stack.shape[0]} n={n} exact={ok}")

    # ---- full blocked table build (APSP + chunked forwarding) -----------
    key = jax.random.PRNGKey(0)
    f_tab = lambda: jax.block_until_ready(paths_mod.layer_tables_batched(
        stack, key, max_l, engine="blocked")[0])
    us = timeit(f_tab, n=3)
    nh_b = np.asarray(f_tab())
    nh_d = np.asarray(paths_mod.layer_tables_batched(
        stack, key, max_l, engine="dense")[0])
    ok = np.array_equal(nh_b, nh_d)
    emit(f"kernels/sparse/tables/sf{q}", us, f"n={n} exact={ok}")

    # ---- compressed forwarding-table lookups ----------------------------
    ct = paths_mod.CompressedTables.from_dense(lr.nh)
    rng = np.random.default_rng(0)
    m = 50_000 if quick else 200_000
    li = rng.integers(lr.n_layers, size=m)
    s = rng.integers(n, size=m)
    t = rng.integers(n, size=m)
    us = timeit(lambda: ct.lookup(li, s, t), n=3)
    ok = np.array_equal(ct.lookup(li, s, t), lr.nh[li, s, t])
    ratio = ct.nbytes / lr.nh.nbytes
    emit(f"paths/compressed_lookup/sf{q}", us,
         f"m={m} mlookups_s={m / us.median_us:.1f} "
         f"bytes_ratio={ratio:.3f} exact={ok}")


if __name__ == "__main__":
    main()
