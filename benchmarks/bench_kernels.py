"""Appendix B kernels: path-count matmul, GF(p) matmul, flash attention.

On this CPU container the Pallas kernels run in interpret mode (correctness
only); the timed number is the jitted XLA reference path — the substrate's
actual CPU throughput — plus an allclose check against the kernel.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gfmm import gf_matmul
from repro.kernels.pathcount import pathcount_matmul
from repro.kernels.semiring import semiring_matmul

from .common import emit, timeit


def main(quick: bool = False) -> None:
    n = 256 if quick else 512
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.random((n, n), dtype=np.float32))

    fn = jax.jit(lambda x, y: ref.pathcount_ref(x, y))
    us = timeit(lambda: jax.block_until_ready(fn(a, a)), n=3)
    small = a[:128, :128]
    ok = np.allclose(pathcount_matmul(small, small, interpret=True),
                     ref.pathcount_ref(small, small), rtol=1e-5)
    emit(f"kernels/pathcount/{n}x{n}", us,
         f"gflops={2 * n ** 3 / us.median_us / 1e3:.1f} allclose={ok}")

    # ---- semiring engine: the path/layer pipeline's product -------------
    for sr in ("count", "bool", "minplus"):
        if sr == "bool":
            x = a > 0.5
        elif sr == "minplus":
            x = jnp.where(a < 0.2, a * 10, jnp.inf)
        else:
            x = a
        fs = jax.jit(lambda u, v, _sr=sr: ref.semiring_matmul_ref(u, v, _sr))
        us = timeit(lambda: jax.block_until_ready(fs(x, x)), n=3)
        xs = x[:128, :128]
        ok = np.allclose(
            np.asarray(semiring_matmul(xs, xs, sr, backend="pallas",
                                       interpret=True), dtype=np.float32),
            np.asarray(fs(xs, xs), dtype=np.float32), rtol=1e-5)
        emit(f"kernels/semiring/{sr}/{n}x{n}", us, f"allclose={ok}")

    ai = jnp.asarray(rng.integers(0, 1009, (n, n)), dtype=jnp.int32)
    fg = jax.jit(lambda x, y: ref.gf_matmul_ref(x, y, 1009))
    us = timeit(lambda: jax.block_until_ready(fg(ai, ai)), n=3)
    sm = ai[:128, :128]
    ok = np.array_equal(np.asarray(gf_matmul(sm, sm, interpret=True)),
                        np.asarray(ref.gf_matmul_ref(sm, sm, 1009)))
    emit(f"kernels/gfmm/{n}x{n}", us, f"allclose={ok}")

    s = 512 if quick else 1024
    q = jnp.asarray(rng.standard_normal((1, 8, s, 64), dtype=np.float32))
    fa = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    us = timeit(lambda: jax.block_until_ready(fa(q, q, q)), n=3)
    qs = q[:, :2, :128]
    ok = np.allclose(flash_attention(qs, qs, qs, causal=True, interpret=True),
                     ref.attention_ref(qs, qs, qs, causal=True), atol=2e-3)
    emit(f"kernels/flash_attention/s{s}", us, f"allclose={ok}")


if __name__ == "__main__":
    main()
