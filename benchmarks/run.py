"""Benchmark aggregator: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] \\
      [--json PATH]

Emits ``name,us_per_call,derived`` CSV lines per benchmark; ``--json``
additionally dumps ``{name: {us_per_call, derived, derived_raw}}`` so
the perf trajectory tracks quality (throughput, FCT, collisions)
alongside speed.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
import time
import traceback

MODULES = ["bench_diversity", "bench_collisions", "bench_layers",
           "bench_transport", "bench_throughput", "bench_kernels",
           "bench_sparse", "bench_fabric", "bench_sweep", "bench_failures"]

# k=v pairs whose value is a number (optionally with a trailing unit,
# e.g. "tput=2.74GB/s"), a bool, or nan/inf.  Keys are anchored at a
# word boundary from the left (start or whitespace) so digit-led names
# like "1ring_ms" parse whole and range values ("links=9->27") don't
# spawn phantom keys.
_DERIVED_RE = re.compile(
    r"(?:^|(?<=\s))([A-Za-z0-9_][\w.%'/-]*)="
    r"([-+]?(?:\d+\.?\d*|\.\d+)(?:[eE][-+]?\d+)?|True|False|nan|inf)")


def parse_derived(derived: str) -> dict:
    """Best-effort numeric parse of a derived-metrics string."""
    out = {}
    for key, val in _DERIVED_RE.findall(derived):
        if val in ("True", "False"):
            out[key] = val == "True"
        else:
            out[key] = float(val)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="",
                    help="dump {name: {us_per_call, derived}} to this path")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        print(f"# === {mod_name} ===", flush=True)
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            mod.main(quick=args.quick)
        except Exception as e:
            failures.append((mod_name, repr(e)))
            traceback.print_exc()
        print(f"# {mod_name} done in {time.time() - t0:.1f}s", flush=True)
    if args.json:
        from benchmarks.common import ROWS
        out = {name: {"us_per_call": us,
                      "derived": parse_derived(derived),
                      "derived_raw": derived}
               for name, us, derived in ROWS}
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"# wrote {len(ROWS)} rows to {args.json}", flush=True)
    if failures:
        for f in failures:
            print("FAILED:", f, file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
