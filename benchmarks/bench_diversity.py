"""Paper Fig 6 + Table 4: shortest-path scarcity and CDP/PI diversity.

Reproduced claims:
  * Fig 6 — in SF/DF most router pairs have exactly ONE minimal path;
    FT/HX show high minimal diversity.
  * Table 4 — CDP at d' as a fraction of k' (SF high mean, low 1% tail);
    PI small on average; JF equivalents more Gaussian.
"""

from __future__ import annotations

import numpy as np

from repro.core import diversity as DV
from repro.core import paths as P

from .common import SMALL_TOPOS_JF, emit, get_session, timeit


def main(quick: bool = False) -> None:
    session = get_session()
    n_cdp = 30 if quick else 80
    n_pi = 10 if quick else 30
    for tspec in SMALL_TOPOS_JF:
        topo = session.topology(tspec)
        dist, counts = P.min_path_stats(np.asarray(topo.adj))
        off = ~np.eye(topo.n_routers, dtype=bool)
        reach = dist[off] < 10_000
        single = float(((counts[off] == 1) & reach).sum()) / reach.sum()

        us = timeit(lambda: DV.cdp_pairs_sampled(topo, 3, 10, seed=0))
        rep = DV.diversity_report(topo, n_cdp=n_cdp, n_pi=n_pi)
        emit(f"fig6/single_minimal/{topo.name}", us,
             f"frac_single={single:.2f}")
        emit(f"table4/cdp/{topo.name}", us.median_us,
             f"d'={rep.d_prime} mean={rep.cdp_mean_frac:.2f}k' "
             f"tail1%={rep.cdp_tail_frac:.2f}k'")
        emit(f"table4/pi/{topo.name}", us.median_us,
             f"mean={rep.pi_mean_frac:.2f}k' tail={rep.pi_tail_frac:.2f}k' "
             f"tnl={rep.tnl:.0f}")


if __name__ == "__main__":
    main()
