"""Environment guard for stripped-env subprocesses (tests, dry-run).

Imported automatically by :mod:`site` whenever ``src/`` is on
``PYTHONPATH`` — which is exactly how the multi-device test subprocesses
and the dry-run launch python.  Forcing host-platform devices is a
CPU-only debugging mode, so pin the jax platform before jax can
initialize: a machine with libtpu installed but no TPU attached
otherwise spends minutes probing the TPU backend before falling back to
CPU (measured ~4m40s here, blowing the tests' subprocess budgets).

``repro.dist.compat.install()`` applies the same pin for processes that
import the library after jax; this hook covers the ones that never
import :mod:`repro.dist` at all.

Python imports only the first ``sitecustomize`` on ``sys.path``, so
after the guard this module chain-loads any sitecustomize it shadows
(virtualenv/distro hooks keep working with ``src`` on ``PYTHONPATH``).
"""

import os
import sys

if ("--xla_force_host_platform_device_count"
        in os.environ.get("XLA_FLAGS", "")):
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def _chain_shadowed_sitecustomize():
    here = os.path.dirname(os.path.abspath(__file__))
    for p in sys.path:
        full = os.path.abspath(p or ".")
        if full == here:
            continue
        cand = os.path.join(full, "sitecustomize.py")
        if os.path.isfile(cand):
            import runpy
            runpy.run_path(cand, run_name="sitecustomize")
            break


try:
    _chain_shadowed_sitecustomize()
except Exception:
    pass  # an import hook must never break interpreter startup
finally:
    del _chain_shadowed_sitecustomize
