"""Pallas TPU kernel: GF(p) modular matmul for connectivity propagation.

The Cheung et al. edge-connectivity algorithm (paper Appendix B.3) iterates
``M <- (M @ K + I) mod p`` over a finite field.  This kernel computes one
modular matmul ``C = (A @ B) mod p`` with per-K-tile reduction.

Two arithmetic modes (TPU hardware adaptation, DESIGN.md §2b):

* ``int32``: products p^2 and K-tile sums bk * p^2 must stay < 2^31, so
  p <= 4093 with bk <= 128.  Exact; int matmul is emulated on the MXU.
* ``f32``: uses the native f32 MXU; exact while bk * p^2 < 2^24, so
  p <= 251 with bk <= 256.  This is the fast TPU path; the field is smaller
  so the rank estimate's failure probability rises (still < E^2/p per
  Cheung's analysis — callers re-run with fresh coefficients to confirm).

The modulo is applied after every K tile, keeping the accumulator bounded.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gf_matmul", "GF_P_INT32", "GF_P_F32"]

GF_P_INT32 = 1009   # bk * p^2 = 128 * 1009^2 ~ 1.3e8 < 2^31
GF_P_F32 = 251      # bk * p^2 = 256 * 251^2 ~ 1.6e7 < 2^24


def _gfmm_kernel(a_ref, b_ref, o_ref, *, p: int, mode: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    if mode == "int32":
        prod = jax.lax.dot_general(
            a_ref[...].astype(jnp.int32), b_ref[...].astype(jnp.int32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        o_ref[...] = (o_ref[...] + prod % p) % p
    else:  # f32 MXU path
        prod = jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc = o_ref[...] + prod
        o_ref[...] = acc - jnp.floor(acc / p) * p


@functools.partial(jax.jit,
                   static_argnames=("p", "mode", "bm", "bn", "bk", "interpret"))
def gf_matmul(a: jnp.ndarray, b: jnp.ndarray, *, p: int = GF_P_INT32,
              mode: str = "int32", bm: int = 128, bn: int = 128,
              bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """(A @ B) mod p with per-tile modular reduction.

    Inputs must already be reduced mod p (values in [0, p)).
    """
    if mode == "int32":
        assert bk * p * p < 2**31, (bk, p)
        dt = jnp.int32
    elif mode == "f32":
        assert bk * p * p < 2**24, (bk, p)
        dt = jnp.float32
    else:
        raise ValueError(mode)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    a_p = jnp.zeros((mp, kp), dt).at[:m, :k].set(a.astype(dt))
    b_p = jnp.zeros((kp, np_), dt).at[:k, :n].set(b.astype(dt))

    out = pl.pallas_call(
        functools.partial(_gfmm_kernel, p=p, mode=mode),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), dt),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n].astype(jnp.int32)
