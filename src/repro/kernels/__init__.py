"""Pallas TPU kernels for the paper's compute hot spots + substrate.

* ``semiring``        — batched semiring matmul engine (Appendix B.1):
                        bool OR/AND, saturating f32 counting, (min, +).
                        The whole path/layer pipeline routes through it.
* ``pathcount``       — historical entry point, now the ``"count"``
                        instance of the semiring engine.
* ``gfmm``            — GF(p) modular matmul, Cheung connectivity (App. B.3).
* ``flash_attention`` — online-softmax attention (GQA/window/softcap), the
                        LM substrate's dominant kernel.

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles.
Validated with interpret=True on CPU; TPU (Mosaic) is the target.
"""

from . import ops, ref  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .gfmm import gf_matmul  # noqa: F401
from .pathcount import pathcount_matmul  # noqa: F401
from .semiring import semiring_matmul  # noqa: F401
