"""Pallas TPU kernels for the paper's compute hot spots + substrate.

* ``semiring``        — batched semiring matmul engine (Appendix B.1):
                        bool OR/AND, saturating f32 counting, (min, +).
                        The whole path/layer pipeline routes through it.
* ``sparse``          — block-sparse variant of the semiring engine:
                        per-tile occupancy bitmaps skip empty blocks,
                        bit-identical to the dense kernel (empty tiles
                        contribute the additive identity exactly).
* ``waterfill``       — fused max-min water-filling transport step
                        (§7.1.3): one kernel per simulator step covering
                        the path-edge scatter, fair-share gather, hop-min
                        and every refinement iteration.
* ``pathcount``       — historical entry point, now the ``"count"``
                        instance of the semiring engine.
* ``gfmm``            — GF(p) modular matmul, Cheung connectivity (App. B.3).
* ``flash_attention`` — online-softmax attention (GQA/window/softcap), the
                        LM substrate's dominant kernel.

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles.
Validated with interpret=True on CPU; TPU (Mosaic) is the target.

Backend selection is shared across every kernel here: one
``REPRO_KERNEL_BACKEND`` env var (``pallas`` | ``ref``) overrides the
auto choice (pallas on TPU, the jnp oracle elsewhere, where XLA's native
ops beat an interpreted kernel).  ``REPRO_SEMIRING_BACKEND`` is kept as
a deprecated alias from when the semiring engine was the only dispatcher.
"""

import os
import warnings
from typing import Optional

__all__ = ["kernel_backend", "interpret_default", "flash_attention",
           "gf_matmul", "pathcount_matmul", "semiring_matmul",
           "sparse_semiring_matmul", "tile_occupancy",
           "waterfill_step", "ops", "ref"]

_BACKENDS = ("pallas", "ref")


def kernel_backend() -> str:
    """The backend every kernel dispatcher defaults to: ``pallas`` on
    TPU, ``ref`` (jnp/XLA) elsewhere; ``REPRO_KERNEL_BACKEND=pallas|ref``
    overrides (``REPRO_SEMIRING_BACKEND`` is honoured as a deprecated
    alias)."""
    env = os.environ.get("REPRO_KERNEL_BACKEND", "")
    if env not in _BACKENDS:
        legacy = os.environ.get("REPRO_SEMIRING_BACKEND", "")
        if legacy in _BACKENDS:
            warnings.warn(
                "REPRO_SEMIRING_BACKEND is deprecated; it now selects the "
                "backend for ALL kernels — use REPRO_KERNEL_BACKEND",
                DeprecationWarning, stacklevel=2)
            env = legacy
    if env in _BACKENDS:
        return env
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def interpret_default(flag: Optional[bool]) -> bool:
    """Resolve an ``interpret=`` argument: explicit flag wins, then
    ``REPRO_PALLAS_INTERPRET=0|1``, else compile the Mosaic kernel on TPU
    and interpret elsewhere — the auto backend must never leave a TPU
    silently interpreting."""
    if flag is not None:
        return flag
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "")
    if env in ("0", "1"):
        return env == "1"
    import jax
    return jax.default_backend() != "tpu"


from . import ops, ref  # noqa: F401,E402
from .flash_attention import flash_attention  # noqa: F401,E402
from .gfmm import gf_matmul  # noqa: F401,E402
from .pathcount import pathcount_matmul  # noqa: F401,E402
from .semiring import semiring_matmul  # noqa: F401,E402
from .sparse import sparse_semiring_matmul, tile_occupancy  # noqa: F401,E402
from .waterfill import waterfill_step  # noqa: F401,E402
