"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

These are also the CPU fast path: on non-TPU backends the semiring
engine dispatches here, where XLA's native (batched) matmul beats an
interpreted Pallas kernel by orders of magnitude.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["pathcount_ref", "gf_matmul_ref", "attention_ref",
           "semiring_matmul_ref", "sparse_semiring_matmul_ref",
           "waterfill_ref"]


def pathcount_ref(a: jnp.ndarray, b: jnp.ndarray, sat: float = 3.0e38) -> jnp.ndarray:
    """min(A @ B, sat) in f32 (exact below 2**24)."""
    return jnp.minimum(
        a.astype(jnp.float32) @ b.astype(jnp.float32), jnp.float32(sat))


def _minplus_2d(a: jnp.ndarray, b: jnp.ndarray, chunk: int = 64) -> jnp.ndarray:
    """(min, +) product, row-chunked so the (m, k, n) broadcast never
    materialises whole (mirrors the kernel's tiling)."""
    m, k = a.shape
    mp = -(-m // chunk) * chunk
    a_p = jnp.full((mp, k), jnp.inf, jnp.float32).at[:m].set(
        a.astype(jnp.float32))
    rows = a_p.reshape(mp // chunk, chunk, k)
    out = jax.lax.map(
        lambda r: (r[:, :, None] + b.astype(jnp.float32)[None, :, :]).min(axis=1),
        rows)
    return out.reshape(mp, b.shape[1])[:m]


def semiring_matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
                        semiring: str = "count",
                        sat: float = 3.0e38) -> jnp.ndarray:
    """Oracle semantics for :func:`repro.kernels.semiring.semiring_matmul`;
    operands may carry one leading batch dimension."""
    if a.ndim == 3 or b.ndim == 3:
        if a.ndim == 2:
            a = jnp.broadcast_to(a[None], (b.shape[0],) + a.shape)
        if b.ndim == 2:
            b = jnp.broadcast_to(b[None], (a.shape[0],) + b.shape)
    if semiring == "count":
        prod = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
        return jnp.minimum(prod, jnp.float32(sat))
    if semiring == "bool":
        prod = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
        return prod > 0
    if semiring == "minplus":
        if a.ndim == 3:
            return jax.vmap(_minplus_2d)(a, b)
        return _minplus_2d(a, b)
    raise ValueError(f"unknown semiring {semiring!r}")


def sparse_semiring_matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
                               semiring: str = "count",
                               sat: float = 3.0e38) -> jnp.ndarray:
    """Oracle for :func:`repro.kernels.sparse.sparse_semiring_matmul`.

    The block-sparse kernel skips tile pairs where either operand block
    is entirely the additive identity; such blocks contribute exactly
    the identity to the K reduction (x + 0 = x for non-negative counts,
    min(inf, x) = x), so the sparse product is bitwise equal to the
    dense product and the dense oracle IS the sparse oracle.  On CPU
    this is also the fast path: XLA's native matmul absorbs identity
    blocks faster than any python-side block filtering could."""
    return semiring_matmul_ref(a, b, semiring, sat=sat)


def waterfill_ref(edges: jnp.ndarray, w: jnp.ndarray, desired: jnp.ndarray,
                  cap: jnp.ndarray, fair_iters: int = 2,
                  active: Optional[jnp.ndarray] = None,
                  want_util: bool = False):
    """Oracle for :func:`repro.kernels.waterfill.waterfill_step`.

    One max-min water-filling transport step over virtual links:

    * ``edges`` (F, S) int32 — link id per flow per hop slot; the LAST id
      (``cap.shape[0] - 1``) is the write-only trash slot (inactive flows
      and padding point there; it is excluded from every min);
    * ``w`` (F,) — flow weights (1 = sends this step, 0 = inert);
    * ``desired`` (F,) — requested rate in line units;
    * ``cap`` (E,) — link capacities in line units;
    * ``active`` (F,) bool, optional — the dynamic-traffic lane: rows
      with ``active=False`` have their edges mapped to the trash slot
      and weight/desire zeroed INSIDE the step (so do rows whose edge
      id is the -1 walk padding).  This reproduces exactly what callers
      used to do host-side (select edges to trash for inactive flows)
      and keeps their fair share at +inf — an inactive flow sees an
      uncongested network, which the tcp/dctcp rate dynamics rely on.
      ``active=None`` means all rows are active and edge ids are taken
      as-is (the pre-dynamic-lane contract).
    * ``want_util`` — the ECN lane (PR 8): additionally return each
      flow's worst link *demand utilization* — max over its live edges
      of ``load / cap``, where ``load`` is the first refinement round's
      scatter of provisional demands (``min(desired, fair share)``; the
      round-0 claim counts when ``fair_iters == 0``) — the link-load
      congestion signal the dctcp recovery path marks on.  A link whose
      demand approaches capacity reports util -> 1 (DCTCP's marking
      regime); rows with no live edge report 0.0 (an idle flow sees an
      unloaded network).  Trace-time flag: ``want_util=False`` builds
      the exact two-output program that predates the lane.

    Returns ``(sent, share)`` — or ``(sent, share, util)`` with
    ``want_util`` — where ``sent`` is the achieved rate after
    ``fair_iters`` feasibility refinements (never oversubscribing any
    link) and ``share`` the raw fair-share signal (the congestion
    feedback transports consume).
    """
    e_tot = cap.shape[0]
    w = w.astype(jnp.float32)
    if active is not None:
        actf = active.astype(jnp.float32)
        edges = jnp.where(active[:, None] & (edges >= 0), edges, e_tot - 1)
        w = w * actf
        desired = desired * actf
    live = edges < e_tot - 1
    count = jnp.zeros(e_tot, jnp.float32).at[edges].add(
        jnp.broadcast_to(w[:, None], edges.shape))
    fair = cap / jnp.maximum(count, 1e-9)
    share = jnp.min(jnp.where(live, fair[edges], jnp.inf), axis=1)
    util = None
    if want_util and fair_iters == 0:
        link_util = count / jnp.maximum(cap, 1e-9)
        util = jnp.max(jnp.where(live, link_util[edges], 0.0), axis=1)
    d = jnp.minimum(desired, share)
    for it in range(fair_iters):
        load = jnp.zeros(e_tot, jnp.float32).at[edges].add(
            jnp.broadcast_to(d[:, None], edges.shape))
        if want_util and it == 0:
            link_util = load / jnp.maximum(cap, 1e-9)
            util = jnp.max(jnp.where(live, link_util[edges], 0.0), axis=1)
        scale = jnp.minimum(1.0, cap / jnp.maximum(load, 1e-9))
        s = jnp.min(jnp.where(live, scale[edges], jnp.inf), axis=1)
        s = jnp.where(jnp.isfinite(s), s, 0.0)
        d = d * s
    if want_util:
        return d, share, util
    return d, share


def gf_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, p: int) -> jnp.ndarray:
    """(A @ B) mod p, exact via float64-free int path: accumulate in chunks
    small enough that int32 cannot overflow (mirrors the kernel's tiling)."""
    a = a.astype(jnp.int64) % p
    b = b.astype(jnp.int64) % p
    return ((a @ b) % p).astype(jnp.int32)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0, softcap: float = 0.0,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Naive (materialised-logits) attention with GQA/window/softcap."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    if scale is None:
        scale = float(d) ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    denom = p.sum(axis=-1, keepdims=True)
    p = p / jnp.where(denom == 0, 1.0, denom)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
