"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

__all__ = ["pathcount_ref", "gf_matmul_ref", "attention_ref"]


def pathcount_ref(a: jnp.ndarray, b: jnp.ndarray, sat: float = 3.0e38) -> jnp.ndarray:
    """min(A @ B, sat) in f32 (exact below 2**24)."""
    return jnp.minimum(
        a.astype(jnp.float32) @ b.astype(jnp.float32), jnp.float32(sat))


def gf_matmul_ref(a: jnp.ndarray, b: jnp.ndarray, p: int) -> jnp.ndarray:
    """(A @ B) mod p, exact via float64-free int path: accumulate in chunks
    small enough that int32 cannot overflow (mirrors the kernel's tiling)."""
    a = a.astype(jnp.int64) % p
    b = b.astype(jnp.int64) % p
    return ((a @ b) % p).astype(jnp.int32)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0, softcap: float = 0.0,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """Naive (materialised-logits) attention with GQA/window/softcap."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    if scale is None:
        scale = float(d) ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    denom = p.sum(axis=-1, keepdims=True)
    p = p / jnp.where(denom == 0, 1.0, denom)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
