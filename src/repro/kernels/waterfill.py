"""Fused max-min water-filling transport step (paper §7.1.3).

The flow-level simulator's per-step inner loop is a scatter/gather
ping-pong over virtual links: scatter flow weights to count link
claimants, gather each link's fair share back, take the per-flow min
across hop slots, then repeat ``fair_iters`` times with the provisional
demands to keep every link feasible.  Expressed in jnp that is
``2 * (1 + fair_iters)`` scatter/gather dispatches per simulated step —
the dominant cost of every transport sweep cell after the path engine
(PR 3) moved path derivation out of the scan.

This module fuses the WHOLE step into one tiled Pallas kernel over the
``(F, S)`` path-edge layout (S = hop slots + injection + ejection NIC):

* grid ``(1 + fair_iters, 2, F_tiles)`` — rounds x {scatter, reduce}
  phases x flow tiles, executed sequentially on a TPU core; ALL state
  that crosses rounds or flow tiles (link loads, provisional per-flow
  demands, fair shares) lives in VMEM scratch, because the output
  blocks are revisited at non-consecutive grid iterations and are
  therefore write-only (each visit writes the scratch state; the final
  sweep's write-back is the refined result);
* the scatter phase accumulates per-link claims through a one-hot
  compare against a lane iota, tile by tile over the link axis (the
  standard MXU/VPU scatter-as-matmul layout — no serialized scatter);
* the reduce phase re-reads the accumulated loads, forms fair shares
  (round 0) or feasibility scales (later rounds), gathers them back
  through the same one-hot tiles and takes the masked min across hop
  slots — the trash link (id ``e_tot - 1``) never enters a min;
* round 0 writes the fair-share signal (``share``, the congestion
  feedback) and the provisional demand; later rounds refine the demand
  in place (``sent``).

The jnp oracle (:func:`repro.kernels.ref.waterfill_ref`) is the CPU
fast path — XLA's native scatter beats an interpreted kernel — and the
backend convention matches :mod:`repro.kernels.semiring`:
auto (``pallas`` on TPU, ``ref`` elsewhere), overridable via
``REPRO_KERNEL_BACKEND`` or an explicit ``backend=`` argument.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import interpret_default, kernel_backend, ref

__all__ = ["waterfill_step"]


def _waterfill_kernel(edges_ref, w_ref, desired_ref, active_ref, cap_ref,
                      *refs, e_tot: int, be: int, n_e_tiles: int, bf: int,
                      want_util: bool, util_round: int):
    # The ECN lane (PR 8) adds one output (per-flow worst link demand
    # utilization, read off the ``util_round`` scatter — the first
    # demand refinement round, whose loads are the provisional demands)
    # and one VMEM scratch; ``want_util`` is a TRACE-TIME flag, so the
    # False program is structurally identical to the pre-lane kernel.
    if want_util:
        (sent_ref, share_ref, util_ref, load_ref, d_ref, adv_ref,
         u_ref) = refs
    else:
        sent_ref, share_ref, load_ref, d_ref, adv_ref = refs
    r = pl.program_id(0)          # water-filling round (0 = fair share)
    p = pl.program_id(1)          # 0 = scatter loads, 1 = reduce per flow
    t = pl.program_id(2)          # flow tile
    # Dynamic-traffic active lane, fused into the step: inactive rows
    # (and -1 walk-padding slots) collapse to the write-only trash link,
    # and their weight/desire is zeroed — the same masking transport
    # callers used to materialise host-side, now free inside the kernel.
    act = active_ref[...] > 0.0                              # (bf, 1) bool
    actf = act.astype(jnp.float32)
    edges = edges_ref[...]                                   # (bf, S) int32
    edges = jnp.where(act & (edges >= 0), edges, e_tot - 1)
    _, s = edges.shape
    # ALL cross-round/cross-tile state lives in VMEM scratch (load_ref:
    # link loads; d_ref/adv_ref: per-flow demand and fair share).  The
    # output blocks are revisited at every (r, p) — NON-consecutive grid
    # iterations — so they are write-only and written on every visit;
    # only the final sweep's values survive the last write-back, which is
    # exactly the refined result.  (Reading an output block back after a
    # non-consecutive revisit is undefined on compiled Mosaic.)
    rows = pl.ds(t * bf, bf)

    @pl.when(p == 0)
    def _scatter():
        @pl.when(t == 0)
        def _reset():
            load_ref[...] = jnp.zeros_like(load_ref)

        # Round 0 claims with the flow weight; later rounds re-scatter the
        # provisional demand scratch (written by round r-1's reduce phase).
        val = jnp.where(r == 0, w_ref[...] * actf, d_ref[rows])  # (bf, 1)

        def etile(ei, _):
            ids = ei * be + jax.lax.broadcasted_iota(jnp.int32, (1, 1, be), 2)
            onehot = edges[:, :, None] == ids                # (bf, S, be)
            contrib = jnp.sum(jnp.where(onehot, val[:, 0:1, None], 0.0),
                              axis=(0, 1))[None, :]          # (1, be)
            load_ref[:, pl.ds(ei * be, be)] = (
                load_ref[:, pl.ds(ei * be, be)] + contrib)
            return 0

        jax.lax.fori_loop(0, n_e_tiles, etile, 0)

    @pl.when(p == 1)
    def _reduce():
        def etile(ei, acc):
            ids = ei * be + jax.lax.broadcasted_iota(jnp.int32, (1, 1, be), 2)
            onehot = edges[:, :, None] == ids                # (bf, S, be)
            cap_t = cap_ref[:, pl.ds(ei * be, be)]           # (1, be)
            load_t = load_ref[:, pl.ds(ei * be, be)]
            per_link = cap_t / jnp.maximum(load_t, 1e-9)     # fair (round 0)
            per_link = jnp.where(r == 0, per_link,
                                 jnp.minimum(1.0, per_link))  # scale (r > 0)
            # Each edge id hits exactly one link tile, so summing the
            # masked broadcasts across tiles IS the gather.
            if want_util:
                acc, acc_u = acc
                # Accumulated every round, but only the ``util_round``
                # value is consumed (u_ref is written under that round).
                per_util = load_t / jnp.maximum(cap_t, 1e-9)
                acc_u = acc_u + jnp.sum(
                    jnp.where(onehot, per_util[0][None, None, :], 0.0),
                    axis=2)
                return (acc + jnp.sum(
                    jnp.where(onehot, per_link[0][None, None, :], 0.0),
                    axis=2), acc_u)
            return acc + jnp.sum(
                jnp.where(onehot, per_link[0][None, None, :], 0.0), axis=2)

        acc0 = jnp.zeros((bf, s), jnp.float32)
        g = jax.lax.fori_loop(0, n_e_tiles, etile,
                              (acc0, acc0) if want_util else acc0)  # (bf, S)
        if want_util:
            g, g_util = g
        live = edges < e_tot - 1                  # trash never enters a min
        m = jnp.min(jnp.where(live, g, jnp.inf), axis=1, keepdims=True)

        @pl.when(r == 0)
        def _round0():
            adv_ref[rows] = m
            d_ref[rows] = jnp.minimum(desired_ref[...] * actf, m)

        @pl.when(r > 0)
        def _refine():
            d_ref[rows] = d_ref[rows] * jnp.where(jnp.isfinite(m), m, 0.0)

        if want_util:
            @pl.when(r == util_round)
            def _util():
                u_ref[rows] = jnp.max(jnp.where(live, g_util, 0.0),
                                      axis=1, keepdims=True)

        sent_ref[...] = d_ref[rows]
        share_ref[...] = adv_ref[rows]
        if want_util:
            util_ref[...] = u_ref[rows]


@functools.partial(jax.jit, static_argnames=("e_tot", "fair_iters", "bf",
                                             "be", "interpret", "want_util"))
def _pallas_waterfill(edges, w, desired, active, cap, *, e_tot: int,
                      fair_iters: int, bf: int, be: int, interpret: bool,
                      want_util: bool = False):
    f, s = edges.shape
    fp = -(-max(f, 1) // bf) * bf
    ep = -(-e_tot // be) * be
    # Flow padding: inactive rows (the kernel's active lane maps their
    # edges to trash and zeroes weight/desire) = an exact no-op on every
    # link sum and every min.  Link padding: capacity 1, no edge id ever
    # points past e_tot - 1.
    edges_p = jnp.full((fp, s), e_tot - 1, jnp.int32).at[:f].set(
        edges.astype(jnp.int32))
    w_p = jnp.zeros((fp, 1), jnp.float32).at[:f, 0].set(
        w.astype(jnp.float32))
    d_p = jnp.zeros((fp, 1), jnp.float32).at[:f, 0].set(
        desired.astype(jnp.float32))
    act_p = jnp.zeros((fp, 1), jnp.float32).at[:f, 0].set(
        active.astype(jnp.float32))
    cap_p = jnp.ones((1, ep), jnp.float32).at[0, :e_tot].set(
        cap.astype(jnp.float32))

    flow_tile = lambda r, p, t: (t, 0)      # noqa: E731
    n_out = 3 if want_util else 2
    out = pl.pallas_call(
        functools.partial(_waterfill_kernel, e_tot=e_tot, be=be,
                          n_e_tiles=ep // be, bf=bf, want_util=want_util,
                          util_round=min(1, fair_iters)),
        grid=(1 + fair_iters, 2, fp // bf),
        in_specs=[
            pl.BlockSpec((bf, s), flow_tile),
            pl.BlockSpec((bf, 1), flow_tile),
            pl.BlockSpec((bf, 1), flow_tile),
            pl.BlockSpec((bf, 1), flow_tile),
            pl.BlockSpec((1, ep), lambda r, p, t: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((bf, 1), flow_tile)] * n_out,
        out_shape=[jax.ShapeDtypeStruct((fp, 1), jnp.float32)] * n_out,
        scratch_shapes=[pltpu.VMEM((1, ep), jnp.float32),
                        pltpu.VMEM((fp, 1), jnp.float32),
                        pltpu.VMEM((fp, 1), jnp.float32)]
        + ([pltpu.VMEM((fp, 1), jnp.float32)] if want_util else []),
        interpret=interpret,
    )(edges_p, w_p, d_p, act_p, cap_p)
    return tuple(o[:f, 0] for o in out)


def waterfill_step(edges: jnp.ndarray, w: jnp.ndarray, desired: jnp.ndarray,
                   cap: jnp.ndarray, *, active: Optional[jnp.ndarray] = None,
                   fair_iters: int = 2, backend: Optional[str] = None,
                   interpret: Optional[bool] = None, bf: int = 128,
                   be: int = 512,
                   want_util: bool = False) -> Tuple[jnp.ndarray, ...]:
    """One fused water-filling step: ``(sent, share)`` per flow.

    ``edges`` is the (F, S) virtual-link layout (S = hop slots + NIC
    slots; id ``cap.shape[0] - 1`` is the write-only trash slot), ``w``
    the 0/1 flow weights, ``desired`` the requested rates and ``cap``
    the link capacities, all in line-rate units.  ``active`` is the
    optional (F,) dynamic-traffic mask: inactive rows are masked to the
    trash slot INSIDE the step (their share comes back +inf), so callers
    with arrival/departure lanes pass raw path edges (which may contain
    -1 padding) plus the mask instead of materialising a masked edge
    tensor per step.  ``want_util=True`` (the ECN lane) returns
    ``(sent, share, util)`` where ``util`` is each flow's worst link
    demand utilization (first-refinement load over capacity) — the
    trace-time flag compiles an extra output in both backends, and
    False compiles the exact pre-lane program.
    ``backend=None`` picks :func:`repro.kernels.kernel_backend`;
    semantics are defined by :func:`repro.kernels.ref.waterfill_ref`.
    """
    backend = backend or kernel_backend()
    if backend not in ("pallas", "ref"):
        raise ValueError(f"unknown backend {backend!r}; "
                         "choose 'pallas' or 'ref'")
    if backend == "ref":
        return ref.waterfill_ref(edges, w, desired, cap,
                                 fair_iters=fair_iters, active=active,
                                 want_util=want_util)
    act = (jnp.ones(edges.shape[0], jnp.float32) if active is None
           else active.astype(jnp.float32))
    return _pallas_waterfill(edges, w, desired, act, cap,
                             e_tot=int(cap.shape[0]),
                             fair_iters=int(fair_iters), bf=bf, be=be,
                             interpret=interpret_default(interpret),
                             want_util=want_util)
