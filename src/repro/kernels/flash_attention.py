"""Pallas TPU kernel: flash attention (online softmax) for the LM substrate.

Supports the features the assigned architectures need:
  * GQA (n_kv_heads <= n_heads, grouped lookup),
  * causal masking (decoder LMs) or none (HuBERT encoder),
  * sliding-window causal masking (gemma2 local layers),
  * logit soft-capping (gemma2),
  * arbitrary scale (RoPE'd q/k are produced by the model).

Tiling: grid (B, H, Sq/bq, Sk/bk) with the KV dimension innermost; running
max / denominator / accumulator live in VMEM scratch and persist across the
sequential KV grid steps (canonical Pallas flash reduction).  Q/K/V blocks
are (bq, d) / (bk, d) VMEM tiles; d padded to a lane multiple of 128.

The pure-jnp oracle is ``repro.kernels.ref.attention_ref``; tests sweep
shapes, dtypes, GQA groups, windows and softcap against it in interpret
mode (this container is CPU-only; TPU is the target).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory-space scratch specs (work under interpret mode too)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

__all__ = ["flash_attention"]

_LANES = 128
_NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  bq: int, bk: int, nk: int, sk: int):
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    iq = pl.program_id(2)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk                                  # KV padding
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[:, :1]                             # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # Rows with no unmasked key so far keep m == -inf; all terms below are
    # explicitly zeroed for them so no NaNs can form.
    dead = m_new == _NEG_INF
    p = jnp.where(mask, jnp.exp(s - jnp.where(dead, 0.0, m_new)), 0.0)
    alpha = jnp.where(dead, 0.0, jnp.exp(m_prev - m_new))
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v_ref[0, 0].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = alpha * acc_ref[...] + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(jk == nk - 1)
    def _fin():
        l = l_ref[:, :1]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "bq", "bk",
                     "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: Optional[float] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """Flash attention over (B, H, S, D) tensors with GQA via head grouping.

    Args:
      q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) with H % Hkv == 0.
      window: if > 0, causal sliding window of this many positions.
      softcap: if > 0, gemma2-style logit soft-capping.
    Returns (B, H, Sq, D) in q's dtype.
    """
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    if scale is None:
        scale = float(d) ** -0.5

    sq_p = -(-sq // bq) * bq
    sk_p = -(-sk // bk) * bk
    d_p = -(-d // _LANES) * _LANES
    qp = jnp.zeros((b, h, sq_p, d_p), q.dtype).at[:, :, :sq, :d].set(q)
    kp = jnp.zeros((b, hkv, sk_p, d_p), k.dtype).at[:, :, :sk, :d].set(k)
    vp = jnp.zeros((b, hkv, sk_p, d_p), v.dtype).at[:, :, :sk, :d].set(v)
    nq, nk = sq_p // bq, sk_p // bk

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk, sk=sk)
    scratch = [
        _VMEM((bq, _LANES), jnp.float32),   # running max
        _VMEM((bq, _LANES), jnp.float32),   # running denominator
        _VMEM((bq, d_p), jnp.float32),      # output accumulator
    ]
    out = pl.pallas_call(
        kern,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d_p), lambda bb, hh, ii, jj: (bb, hh, ii, 0)),
            pl.BlockSpec((1, 1, bk, d_p),
                         lambda bb, hh, ii, jj, g=group: (bb, hh // g, jj, 0)),
            pl.BlockSpec((1, 1, bk, d_p),
                         lambda bb, hh, ii, jj, g=group: (bb, hh // g, jj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d_p),
                               lambda bb, hh, ii, jj: (bb, hh, ii, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, d_p), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :sq, :d]
