"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on TPU
deployments set ``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to
compile the Mosaic kernels.
"""

from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from .flash_attention import flash_attention
from .gfmm import GF_P_F32, GF_P_INT32, gf_matmul
from .pathcount import SAT, pathcount_matmul

__all__ = ["path_counts_power", "gf_power_sum", "attention", "SAT",
           "GF_P_INT32", "GF_P_F32"]


def _interp(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def path_counts_power(adj: jnp.ndarray, l: int, *,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """A^l walk counts via the pathcount kernel (Theorem 1)."""
    a = adj.astype(jnp.float32)
    out = a
    for _ in range(l - 1):
        out = pathcount_matmul(out, a, interpret=_interp(interpret))
    return out


def gf_power_sum(k_mat: jnp.ndarray, l: int, p: int = GF_P_INT32,
                 mode: str = "int32", *,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """sum_{i=0}^{l-1} K^i mod p via Horner (M <- M K + I), the Cheung
    connectivity propagation matrix (Appendix B.3)."""
    e = k_mat.shape[0]
    eye = jnp.eye(e, dtype=jnp.int32)
    m = eye
    for _ in range(l - 1):
        m = gf_matmul(m, k_mat, p=p, mode=mode, interpret=_interp(interpret))
        m = (m + eye) % p
    return m


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0, scale: Optional[float] = None,
              bq: int = 128, bk: int = 128,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """Flash attention (GQA/causal/window/softcap); see kernel docstring."""
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale, bq=bq, bk=bk,
                           interpret=_interp(interpret))
