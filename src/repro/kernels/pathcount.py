"""Pallas TPU kernel: saturating path-count matmul (paper Appendix B.1).

Computes ``C = min(A @ B, SAT)`` where A, B hold walk counts (Theorem 1:
powers of the adjacency matrix count walks).  Counts are f32 — exact below
2**24, saturating at ``SAT`` far above any diversity threshold the paper
uses — so the MXU's native f32 path does the work, which is the TPU-correct
adaptation of "integer path counting" (no int64 on TPU; int32 matmul is
emulated and slow).

Tiling: (bm, bk) x (bk, bn) MXU tiles, K innermost grid dimension with the
output block revisited and accumulated in place (standard Pallas reduction
pattern); saturation is applied per K-step, which is semantics-preserving
because SAT + x -> inf -> min(...) == SAT (monotone absorbing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pathcount_matmul", "SAT"]

SAT = 3.0e38


def _pathcount_kernel(a_ref, b_ref, o_ref, *, sat: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] = jnp.minimum(o_ref[...] + prod, sat)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "sat", "interpret"))
def pathcount_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                     bn: int = 128, bk: int = 128, sat: float = SAT,
                     interpret: bool = True) -> jnp.ndarray:
    """min(A @ B, sat) with (bm, bn, bk) VMEM tiling.

    Inputs are zero-padded to tile multiples; the pad region contributes
    zeros to the accumulation (exact).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    a_p = jnp.zeros((mp, kp), jnp.float32).at[:m, :k].set(a.astype(jnp.float32))
    b_p = jnp.zeros((kp, np_), jnp.float32).at[:k, :n].set(b.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_pathcount_kernel, sat=sat),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
