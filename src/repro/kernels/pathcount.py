"""Pallas TPU kernel: saturating path-count matmul (paper Appendix B.1).

Computes ``C = min(A @ B, SAT)`` where A, B hold walk counts (Theorem 1:
powers of the adjacency matrix count walks).  Counts are f32 — exact below
2**24, saturating at ``SAT`` far above any diversity threshold the paper
uses — so the MXU's native f32 path does the work, which is the TPU-correct
adaptation of "integer path counting" (no int64 on TPU; int32 matmul is
emulated and slow).

Tiling: (bm, bk) x (bk, bn) MXU tiles, K innermost grid dimension with the
output block revisited and accumulated in place (standard Pallas reduction
pattern); saturation is applied per K-step, which is semantics-preserving
because SAT + x -> inf -> min(...) == SAT (monotone absorbing).

The kernel body now lives in :mod:`repro.kernels.semiring` (the
``"count"`` semiring); this module keeps the historical entry point.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["pathcount_matmul", "SAT"]

SAT = 3.0e38


def pathcount_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                     bn: int = 128, bk: int = 128, sat: float = SAT,
                     interpret: bool = True) -> jnp.ndarray:
    """min(A @ B, sat) with (bm, bn, bk) VMEM tiling.

    Now a thin wrapper over the ``"count"`` instance of
    :func:`repro.kernels.semiring.semiring_matmul` — the generalised
    engine this kernel grew into; new code should call that directly.
    """
    from .semiring import semiring_matmul

    return semiring_matmul(a, b, "count", sat=sat, bm=bm, bn=bn, bk=bk,
                           backend="pallas", interpret=interpret)
