"""Batched semiring matmul engine (paper Appendix B.1).

One tiled Pallas TPU kernel, parameterised over the three semirings the
path/layer pipeline uses:

* ``"count"``   — (min(·+·, SAT), ×) over f32: saturating walk counting
                  (Theorem 1).  Exact below 2**24; SAT is an absorbing
                  ceiling far above every diversity threshold.
* ``"bool"``    — (OR, AND): reachability products.  Implemented as the
                  count semiring saturated at 1.0 so the MXU still does
                  the work (bool matmul has no MXU path).
* ``"minplus"`` — (min, +) over f32 with +inf as the additive identity:
                  weighted shortest-path relaxation (the ``ksp`` layer
                  scheme).  No MXU mapping exists, so the kernel walks
                  the K tile with a VPU broadcast-min recurrence.

``semiring_matmul`` accepts 2-D operands or stacked (L, N, K) x (L, K, M)
batches — the batched form is what the layer-stack builder feeds it —
and dispatches between the Pallas kernel (TPU, or ``interpret=True`` for
testing) and the pure-jnp oracle in :mod:`repro.kernels.ref` (CPU: XLA's
native matmul is the fast path there).  The grid/tiling follows the
``pathcount`` reduction pattern: K innermost, output block revisited and
combined in place, which is semantics-preserving for all three semirings
because each combine is monotone and absorbing (SAT + x stays SAT;
min(inf, x) = x).

``pathcount_matmul`` in :mod:`repro.kernels.pathcount` is now a thin
wrapper over the ``"count"`` instance of this engine.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import interpret_default, kernel_backend, ref

__all__ = ["semiring_matmul", "SEMIRINGS", "SAT", "default_backend"]

SAT = 3.0e38

SEMIRINGS = ("count", "bool", "minplus")

# Additive identity per semiring — also the pad value for both operands
# (pads must be absorbed by the K reduction: 0-blocks add nothing to a
# counting product; +inf blocks never win a min).
_ZERO = {"count": 0.0, "bool": 0.0, "minplus": jnp.inf}


def default_backend() -> str:
    """``pallas`` on TPU, ``ref`` (jnp/XLA) elsewhere; override with
    ``REPRO_KERNEL_BACKEND=pallas|ref`` (the shared kernel-suite switch;
    ``REPRO_SEMIRING_BACKEND`` survives as a deprecated alias)."""
    return kernel_backend()


_interp = interpret_default


# -----------------------------------------------------------------------------
# The kernel.
# -----------------------------------------------------------------------------
def _semiring_kernel(a_ref, b_ref, o_ref, *, semiring: str, sat: float,
                     bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, _ZERO[semiring])

    if semiring in ("count", "bool"):
        ceil = 1.0 if semiring == "bool" else sat
        prod = jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[...] = jnp.minimum(o_ref[...] + prod, ceil)
    else:  # minplus: VPU broadcast-min over the K tile
        a = a_ref[...]
        b = b_ref[...]

        def body(k, acc):
            return jnp.minimum(acc, a[:, k][:, None] + b[k, :][None, :])

        o_ref[...] = jax.lax.fori_loop(0, bk, body, o_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("semiring", "bm", "bn", "bk", "sat",
                                    "interpret"))
def _pallas_matmul(a, b, *, semiring: str, bm: int, bn: int, bk: int,
                   sat: float, interpret: bool):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    zero = jnp.float32(_ZERO[semiring])
    a_p = jnp.full((mp, kp), zero).at[:m, :k].set(a.astype(jnp.float32))
    b_p = jnp.full((kp, np_), zero).at[:k, :n].set(b.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_semiring_kernel, semiring=semiring, sat=sat,
                          bk=bk),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


# -----------------------------------------------------------------------------
# Public dispatch.
# -----------------------------------------------------------------------------
def semiring_matmul(a: jnp.ndarray, b: jnp.ndarray, semiring: str = "count",
                    *, sat: float = SAT, bm: int = 128, bn: int = 128,
                    bk: int = 128, backend: Optional[str] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Semiring product ``A ⊗ B``; operands may carry one leading batch dim.

    ``bool`` accepts/returns bool arrays; ``count``/``minplus`` work in
    f32.  ``backend=None`` picks :func:`default_backend`.
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}; "
                         f"choose from {SEMIRINGS}")
    backend = backend or default_backend()
    if backend not in ("pallas", "ref"):
        raise ValueError(f"unknown backend {backend!r}; "
                         "choose 'pallas' or 'ref'")
    if backend == "ref":
        return ref.semiring_matmul_ref(a, b, semiring, sat=sat)
    fn = functools.partial(_pallas_matmul, semiring=semiring, bm=bm, bn=bn,
                           bk=bk, sat=sat, interpret=_interp(interpret))
    if a.ndim == 3 or b.ndim == 3:
        if a.ndim == 2:
            a = jnp.broadcast_to(a[None], (b.shape[0],) + a.shape)
        if b.ndim == 2:
            b = jnp.broadcast_to(b[None], (a.shape[0],) + b.shape)
        out = jax.vmap(fn)(a, b)
    else:
        out = fn(a, b)
    if semiring == "bool":
        return out > 0.5
    return out
