"""Block-sparse semiring matmul: the tiled engine with empty tiles skipped.

Adjacency stacks on low-diameter topologies are extremely sparse — a
Slim Fly router talks to k' ~ 3q/2 of its 2q^2 peers, so fewer than 3%
of a padded (N, N) tile grid carries any edge at all.  The dense engine
in :mod:`repro.kernels.semiring` still streams every tile through the
MXU.  This variant takes the same operands plus a per-tile occupancy
bitmap (one int32 per (bm, bk) / (bk, bn) block) and predicates the
whole combine on ``a_occ & b_occ``: a tile pair where either side is
entirely the additive identity is skipped without reading it into the
MXU.

Skipping is *bit-exact*, not approximate: an all-identity block
contributes exactly the additive identity to the K reduction (0-blocks
add nothing to a counting product, +inf blocks never win a min), so the
output of the sparse kernel equals the dense kernel's output bitwise.
That identity-absorption argument is also why the CPU fast path under
the shared ``REPRO_KERNEL_BACKEND`` convention is simply the dense jnp
oracle (:func:`repro.kernels.ref.sparse_semiring_matmul_ref`): XLA's
native matmul is already the fastest way to absorb identity blocks on
CPU, and the frontier-APSP mode in :mod:`repro.core.paths` is where the
CPU-side sparsity win actually lives.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import interpret_default, kernel_backend, ref
from .semiring import SAT, SEMIRINGS, _ZERO

__all__ = ["sparse_semiring_matmul", "tile_occupancy"]

_interp = interpret_default


def tile_occupancy(x: jnp.ndarray, bm: int, bk: int,
                   semiring: str = "count") -> jnp.ndarray:
    """Per-tile occupancy bitmap: ``occ[i, k] != 0`` iff block (i, k) of
    ``x`` holds any non-identity entry.  ``x`` must already be padded to
    tile multiples (the pad value is the additive identity, so pads never
    set a bit)."""
    m, k = x.shape
    assert m % bm == 0 and k % bk == 0, (x.shape, bm, bk)
    tiles = x.reshape(m // bm, bm, k // bk, bk)
    if semiring == "minplus":
        live = tiles < jnp.inf
    else:
        live = tiles != 0
    return live.any(axis=(1, 3)).astype(jnp.int32)


# -----------------------------------------------------------------------------
# The kernel: the dense semiring combine, gated on the occupancy product.
# -----------------------------------------------------------------------------
def _sparse_semiring_kernel(ao_ref, bo_ref, a_ref, b_ref, o_ref, *,
                            semiring: str, sat: float, bk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, _ZERO[semiring])

    occupied = (ao_ref[0, 0] != 0) & (bo_ref[0, 0] != 0)

    if semiring in ("count", "bool"):
        ceil = 1.0 if semiring == "bool" else sat

        @pl.when(occupied)
        def _combine():
            prod = jax.lax.dot_general(
                a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            o_ref[...] = jnp.minimum(o_ref[...] + prod, ceil)
    else:  # minplus: VPU broadcast-min over the K tile

        @pl.when(occupied)
        def _combine():
            a = a_ref[...]
            b = b_ref[...]

            def body(k, acc):
                return jnp.minimum(acc, a[:, k][:, None] + b[k, :][None, :])

            o_ref[...] = jax.lax.fori_loop(0, bk, body, o_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("semiring", "bm", "bn", "bk", "sat",
                                    "interpret"))
def _pallas_sparse_matmul(a, b, *, semiring: str, bm: int, bn: int, bk: int,
                          sat: float, interpret: bool):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    zero = jnp.float32(_ZERO[semiring])
    a_p = jnp.full((mp, kp), zero).at[:m, :k].set(a.astype(jnp.float32))
    b_p = jnp.full((kp, np_), zero).at[:k, :n].set(b.astype(jnp.float32))
    a_occ = tile_occupancy(a_p, bm, bk, semiring)
    b_occ = tile_occupancy(b_p, bk, bn, semiring)

    out = pl.pallas_call(
        functools.partial(_sparse_semiring_kernel, semiring=semiring,
                          sat=sat, bk=bk),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_occ, b_occ, a_p, b_p)
    return out[:m, :n]


# -----------------------------------------------------------------------------
# Public dispatch — mirrors semiring_matmul.
# -----------------------------------------------------------------------------
def sparse_semiring_matmul(a: jnp.ndarray, b: jnp.ndarray,
                           semiring: str = "count", *, sat: float = SAT,
                           bm: int = 128, bn: int = 128, bk: int = 128,
                           backend: Optional[str] = None,
                           interpret: Optional[bool] = None) -> jnp.ndarray:
    """Block-sparse semiring product ``A ⊗ B``; bit-identical to
    :func:`repro.kernels.semiring.semiring_matmul` on any input (empty
    tiles contribute exactly the additive identity).  Operands may carry
    one leading batch dim; ``backend=None`` follows the shared
    ``REPRO_KERNEL_BACKEND`` convention."""
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}; "
                         f"choose from {SEMIRINGS}")
    backend = backend or kernel_backend()
    if backend not in ("pallas", "ref"):
        raise ValueError(f"unknown backend {backend!r}; "
                         "choose 'pallas' or 'ref'")
    if backend == "ref":
        return ref.sparse_semiring_matmul_ref(a, b, semiring, sat=sat)
    fn = functools.partial(_pallas_sparse_matmul, semiring=semiring, bm=bm,
                           bn=bn, bk=bk, sat=sat, interpret=_interp(interpret))
    if a.ndim == 3 or b.ndim == 3:
        if a.ndim == 2:
            a = jnp.broadcast_to(a[None], (b.shape[0],) + a.shape)
        if b.ndim == 2:
            b = jnp.broadcast_to(b[None], (a.shape[0],) + b.shape)
        out = jax.vmap(fn)(a, b)
    else:
        out = fn(a, b)
    if semiring == "bool":
        return out > 0.5
    return out
