from .engine import ServeConfig, ServingEngine, make_prefill_step, make_decode_step  # noqa: F401
