"""Batched serving: prefill + decode steps and a request-batching engine.

``serve_step`` semantics per the task spec: the ``decode_*`` / ``long_*``
dry-run shapes lower ``make_decode_step`` — one new token against a KV/state
cache of ``seq_len`` — and ``prefill_*`` shapes lower ``make_prefill_step``
(full forward writing the cache).

Cache kinds come from the model family (models.model.init_cache):
GQA KV pages, MLA compressed latents (DeepSeek-V2), Mamba2/RWKV recurrent
state.  For encoder-only archs (hubert) there is no decode step — the
engine exposes ``encode`` only.

The `ServingEngine` is a minimal continuous-batching driver used by
examples/serve_batch.py: fixed-size slot table, greedy sampling,
per-request completion tracking. FatPaths tie-in: the engine's slot→replica
assignment reuses flowlet-style balancing (pick the least-loaded replica of
those whose "layer" can serve; see examples).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import Runtime
from ..models import model as model_mod
from ..models.config import ModelConfig

__all__ = ["ServeConfig", "make_prefill_step", "make_decode_step",
           "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int
    max_len: int
    temperature: float = 0.0      # 0 => greedy
    cache_dtype: str = "bfloat16"


def make_prefill_step(cfg: ModelConfig, rt: Runtime, sc: ServeConfig):
    """(params, tokens|embeds) -> (last-token logits, primed cache)."""

    def prefill(params, batch: Dict[str, Any]):
        dtype = jnp.bfloat16 if sc.cache_dtype == "bfloat16" else jnp.float32
        cache = model_mod.init_cache(cfg, rt, sc.batch, sc.max_len, dtype)
        logits, cache, _ = model_mod.forward(params, cfg, rt, batch,
                                             cache=cache)
        return logits[:, -1], cache

    return prefill


def make_decode_step(cfg: ModelConfig, rt: Runtime, sc: ServeConfig):
    """(params, cache, last_token) -> (next_token, logits, cache)."""
    assert cfg.decoder, f"{cfg.name} is encoder-only: no decode step"

    def decode(params, cache, tokens):
        # frontend archs decode from (stubbed) per-step embeddings
        key = "embeds" if cfg.frontend is not None else "tokens"
        batch = {key: tokens}
        logits, cache, _ = model_mod.forward(params, cfg, rt, batch,
                                             cache=cache)
        lg = logits[:, -1].astype(jnp.float32)
        if cfg.final_softcap:
            lg = cfg.final_softcap * jnp.tanh(lg / cfg.final_softcap)
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return nxt, lg, cache

    return decode


class ServingEngine:
    """Continuous batching over a fixed slot table (single replica)."""

    def __init__(self, cfg: ModelConfig, rt: Runtime, params,
                 sc: ServeConfig):
        self.cfg, self.rt, self.sc = cfg, rt, sc
        self.params = params
        self.prefill = jax.jit(make_prefill_step(cfg, rt, sc))
        self.decode = jax.jit(make_decode_step(cfg, rt, sc)) if cfg.decoder \
            else None
        self.reset()

    def reset(self) -> None:
        self.cache = None
        self.last = np.zeros(self.sc.batch, np.int32)
        self.done = np.ones(self.sc.batch, bool)
        self.outputs: List[List[int]] = [[] for _ in range(self.sc.batch)]
        self.budget = np.zeros(self.sc.batch, np.int32)

    def submit(self, prompts: List[np.ndarray], max_new: int = 16) -> None:
        """Prefill a full batch of prompts (right-aligned to equal length)."""
        b, cfg = self.sc.batch, self.cfg
        assert len(prompts) <= b
        width = max(len(p) for p in prompts)
        toks = np.zeros((b, width), np.int32)
        for i, p in enumerate(prompts):
            toks[i, -len(p):] = p
        logits, self.cache = self.prefill(self.params, {"tokens": jnp.asarray(toks)})
        self.last = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        self.done = np.arange(b) >= len(prompts)
        self.budget = np.full(b, max_new, np.int32)
        for i in range(len(prompts)):
            self.outputs[i] = [int(self.last[i])]

    def step(self) -> bool:
        """One decode step for every live slot; returns whether any live."""
        if self.decode is None:
            raise RuntimeError("encoder-only model")
        nxt, _, self.cache = self.decode(self.params, self.cache,
                                         jnp.asarray(self.last[:, None]))
        nxt = np.asarray(nxt)
        self.budget -= 1
        for i in range(self.sc.batch):
            if not self.done[i]:
                self.outputs[i].append(int(nxt[i]))
                if self.budget[i] <= 0:
                    self.done[i] = True
        self.last = nxt
        return bool((~self.done).any())

    def run(self, prompts: List[np.ndarray], max_new: int = 16
            ) -> List[List[int]]:
        self.submit(prompts, max_new)
        while self.step():
            pass
        return self.outputs[:len(prompts)]
