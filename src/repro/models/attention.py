"""Attention blocks: GQA + RoPE/M-RoPE + window + softcap, with
memory-bounded chunked softmax (train/prefill) and a sequence-sharded,
LSE-combined decode path (serving).

Three execution paths, one semantics (oracle: kernels/ref.attention_ref):
  * dense      — small shapes (unit tests, smoke configs);
  * chunked    — online softmax over KV chunks via lax.scan; per-device
                 peak memory O(Sq * chunk) — what makes prefill_32k /
                 train_4k compile within HBM on the dry-run meshes;
                 optional ``block_skip`` (hillclimb: skip fully-masked
                 causal chunks by scanning q-blocks over a growing prefix);
  * Pallas     — kernels/flash_attention on real TPU (same math).

Decode uses a KV cache sharded over the *model* axis on the sequence
dimension: each shard attends to its local chunk and the partial outputs
are merged with a log-sum-exp combine (psum over 'model') — this is what
keeps decode_32k caches (and MLA latent caches) inside per-device HBM.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import P, Runtime
from . import common
from .config import ModelConfig

NEG_INF = float(np.finfo(np.float32).min)


# -----------------------------------------------------------------------------
# Parameter init / specs.
# -----------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.truncnorm(ks[0], (d, h, dh), dtype),
        "wk": common.truncnorm(ks[1], (d, kv, dh), dtype),
        "wv": common.truncnorm(ks[2], (d, kv, dh), dtype),
        "wo": common.truncnorm(ks[3], (h, dh, d), dtype,
                               scale=0.02 / np.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((kv, dh), dtype)
        p["bv"] = jnp.zeros((kv, dh), dtype)
    return p


def attn_specs(rt: Runtime, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s = {
        "wq": rt.spec_div(("fsdp", "tp", None), (d, h, dh)),
        "wk": rt.spec_div(("fsdp", "tp", None), (d, kv, dh)),
        "wv": rt.spec_div(("fsdp", "tp", None), (d, kv, dh)),
        "wo": rt.spec_div(("tp", None, "fsdp"), (h, dh, d)),
    }
    if cfg.qkv_bias:
        s["bq"] = rt.spec_div(("tp", None), (h, dh))
        s["bk"] = rt.spec_div(("tp", None), (kv, dh))
        s["bv"] = rt.spec_div(("tp", None), (kv, dh))
    return s


# -----------------------------------------------------------------------------
# Core softmax-attention paths.
# -----------------------------------------------------------------------------
def dense_attention(q, k, v, *, causal: bool, window: int, softcap: float,
                    scale: float, q_offset=0) -> jnp.ndarray:
    """(B,H,Sq,D) x (B,Hkv,Sk,D): materialised logits (small shapes only)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, window: int, softcap: float,
                      scale: float, chunk: int = 512, q_offset=0,
                      block_skip: bool = False) -> jnp.ndarray:
    """Online-softmax attention scanning KV chunks (flash semantics).

    With ``block_skip`` (causal only) the computation runs per q-block over
    a *growing KV prefix* (static slices), skipping fully-masked chunks —
    ~2x fewer FLOPs at Sq == Sk, at the cost of an unrolled q loop.
    """
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    if sk <= chunk:
        return dense_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale, q_offset=q_offset)
    if block_skip and causal and sq == sk and q_offset == 0:
        outs = []
        nq = -(-sq // chunk)
        for i in range(nq):
            q0, q1 = i * chunk, min(sq, (i + 1) * chunk)
            kv_end = q1 if window <= 0 else q1  # window still needs prefix
            kv_start = 0 if window <= 0 else max(0, q0 - window)
            o = chunked_attention(
                q[:, :, q0:q1], k[:, :, kv_start:kv_end], v[:, :, kv_start:kv_end],
                causal=True, window=window, softcap=softcap, scale=scale,
                chunk=chunk, q_offset=q0 - kv_start, block_skip=False)
            outs.append(o)
        return jnp.concatenate(outs, axis=2)

    dv = v.shape[-1]                       # MLA: v dim != qk dim
    sk_pad = -(-sk // chunk) * chunk
    nc = sk_pad // chunk
    kp = jnp.zeros((b, hkv, sk_pad, d), k.dtype).at[:, :, :sk].set(k)
    vp = jnp.zeros((b, hkv, sk_pad, dv), v.dtype).at[:, :, :sk].set(v)
    ks = kp.reshape(b, hkv, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    vs = vp.reshape(b, hkv, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    qpos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        ci, kc, vc = inp
        kc = jnp.repeat(kc, g, axis=1)                 # (B, H, C, D)
        vc = jnp.repeat(vc, g, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = (kpos[None, :] < sk)
        mask = jnp.broadcast_to(mask, (sq, chunk))
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])
        if window > 0:
            mask = mask & ((qpos[:, None] - kpos[None, :]) < window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        acc = alpha * acc + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nc), ks, vs))
    denom = jnp.where(l == 0.0, 1.0, l)
    return (acc / denom).astype(q.dtype)


# -----------------------------------------------------------------------------
# Flash attention with a custom VJP (the jnp twin of the Pallas kernel).
#
# A scan-based online-softmax forward whose *backward recomputes* the chunk
# probabilities instead of letting JAX save the stacked (B,H,Sq,chunk) P
# matrices for the scan transpose — without this, every layer instance
# stashes ~GBs of P during training (measured: 11.8 GiB/device at
# gemma2-27b train_4k).  Residuals: q, k, v, out, lse — exactly what the
# TPU flash kernel keeps.
# -----------------------------------------------------------------------------
def _chunk_mask(qpos, kpos, sk, causal, window):
    mask = (kpos[None, :] < sk)
    mask = jnp.broadcast_to(mask, (qpos.shape[0], kpos.shape[0]))
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window > 0:
        mask = mask & ((qpos[:, None] - kpos[None, :]) < window)
    return mask


def _flash_fwd_scan(q, k, v, causal, window, softcap, scale, chunk, q_offset):
    """Returns (out f32, lse f32) via online softmax over kv chunks."""
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    dv = v.shape[-1]
    sk_pad = -(-sk // chunk) * chunk
    nc = sk_pad // chunk
    kp = jnp.zeros((b, hkv, sk_pad, d), k.dtype).at[:, :, :sk].set(k)
    vp = jnp.zeros((b, hkv, sk_pad, dv), v.dtype).at[:, :, :sk].set(v)
    ks = kp.reshape(b, hkv, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    vs = vp.reshape(b, hkv, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    qpos = q_offset + jnp.arange(sq)
    qf = q.astype(jnp.float32)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        ci, kc, vc = inp
        kc = jnp.repeat(kc, g, axis=1)
        vc = jnp.repeat(vc, g, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc.astype(jnp.float32)) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = _chunk_mask(qpos, kpos, sk, causal, window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        acc = alpha * acc + jnp.einsum("bhqk,bhkd->bhqd", p,
                                       vc.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nc), ks, vs))
    denom = jnp.where(l == 0.0, 1.0, l)
    lse = m + jnp.log(denom)
    return acc / denom, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_chunked(q, k, v, causal, window, softcap, scale, chunk, q_offset):
    out, _ = _flash_fwd_scan(q, k, v, causal, window, softcap, scale, chunk,
                             q_offset)
    return out.astype(q.dtype)


def _flash_chunked_fwd(q, k, v, causal, window, softcap, scale, chunk,
                       q_offset):
    out, lse = _flash_fwd_scan(q, k, v, causal, window, softcap, scale,
                               chunk, q_offset)
    return out.astype(q.dtype), (q, k, v, out, lse)


def _flash_chunked_bwd(causal, window, softcap, scale, chunk, q_offset,
                       res, dout):
    q, k, v, out, lse = res
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    dv = v.shape[-1]
    sk_pad = -(-sk // chunk) * chunk
    nc = sk_pad // chunk
    kp = jnp.zeros((b, hkv, sk_pad, d), k.dtype).at[:, :, :sk].set(k)
    vp = jnp.zeros((b, hkv, sk_pad, dv), v.dtype).at[:, :, :sk].set(v)
    ks = kp.reshape(b, hkv, nc, chunk, d).transpose(2, 0, 1, 3, 4)
    vs = vp.reshape(b, hkv, nc, chunk, dv).transpose(2, 0, 1, 3, 4)
    qpos = q_offset + jnp.arange(sq)
    qf = q.astype(jnp.float32)
    do = dout.astype(jnp.float32)
    delta = jnp.sum(do * out, axis=-1, keepdims=True)      # (B,H,Sq,1)

    def step(dq_acc, inp):
        ci, kc, vc = inp
        kcr = jnp.repeat(kc, g, axis=1).astype(jnp.float32)
        vcr = jnp.repeat(vc, g, axis=1).astype(jnp.float32)
        u = jnp.einsum("bhqd,bhkd->bhqk", qf, kcr) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(u / softcap)
            dsdu = 1.0 - jnp.square(s / softcap)
        else:
            s = u
            dsdu = None
        kpos = ci * chunk + jnp.arange(chunk)
        mask = _chunk_mask(qpos, kpos, sk, causal, window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jnp.where(mask[None, None], jnp.exp(s - lse), 0.0)
        dv_c = jnp.einsum("bhqk,bhqd->bhkd", p, do)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do, vcr)
        ds = p * (dp - delta)
        if dsdu is not None:
            ds = ds * dsdu
        dq_acc = dq_acc + jnp.einsum("bhqk,bhkd->bhqd", ds, kcr) * scale
        dk_c = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) * scale
        # GQA: fold grouped heads back onto kv heads
        dk_c = dk_c.reshape(b, hkv, g, chunk, d).sum(axis=2)
        dv_c = dv_c.reshape(b, hkv, g, chunk, dv).sum(axis=2)
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((b, h, sq, d), jnp.float32)
    dq, (dk_s, dv_s) = jax.lax.scan(step, dq0, (jnp.arange(nc), ks, vs))
    dk = dk_s.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk_pad, d)[:, :, :sk]
    dvv = dv_s.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk_pad, dv)[:, :, :sk]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dvv.astype(v.dtype))


flash_chunked.defvjp(_flash_chunked_fwd, _flash_chunked_bwd)


# -----------------------------------------------------------------------------
# Full attention block (projections + rope + residual-ready output).
# -----------------------------------------------------------------------------
def attn_apply(params, cfg: ModelConfig, rt: Runtime, x, positions, *,
               window: int = 0, cache: Optional[dict] = None,
               chunk: int = 512, block_skip: bool = False):
    """x: (B, S, D).  Returns (out, new_cache).

    Train/prefill when cache is None (or being filled); decode when x has
    S == 1 and a cache dict {"k","v","pos"} is provided.
    """
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    pos2d = positions if positions.ndim != 3 else positions
    q = common.apply_rope(q, pos2d, cfg.rope_theta, cfg.mrope_sections)
    k = common.apply_rope(k, pos2d, cfg.rope_theta, cfg.mrope_sections)
    scale = float(dh) ** -0.5

    if cache is not None and s == 1:
        out, new_cache = _decode_attend(cfg, rt, q, k, v, cache, window, scale)
        o = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return o, new_cache

    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    qh = rt.shard(qh, "fsdp", "tp", None, None)
    if kh.shape[2] > chunk:
        # flash path with custom VJP: backward recomputes per-chunk P
        # (saving q,k,v,out,lse only) — the jnp twin of the Pallas kernel.
        out = flash_chunked(qh, kh, vh, cfg.causal, window,
                            cfg.attn_softcap, scale, chunk, 0)
    else:
        out = dense_attention(qh, kh, vh, causal=cfg.causal, window=window,
                              softcap=cfg.attn_softcap, scale=scale)
    out = out.transpose(0, 2, 1, 3)
    o = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    new_cache = None
    if cache is not None:  # prefill fill-up
        new_cache = _fill_cache(rt, cache, k, v, s, window)
    return o, new_cache


def init_kv_cache(rt: Runtime, cfg: ModelConfig, batch: int, length: int,
                  window: int = 0, dtype=jnp.bfloat16):
    """Cache leaves: k/v (B, L, KV, dh) with L sharded on the model axis."""
    l = length if window <= 0 else min(length, window)
    l = max(l, rt.tp_size)
    kv, dh = cfg.n_kv_heads, cfg.d_head
    shape = (batch, l, kv, dh)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def kv_cache_specs(rt: Runtime, cfg: ModelConfig, batch: int, length: int,
                   window: int = 0):
    l = length if window <= 0 else min(length, window)
    l = max(l, rt.tp_size)
    seq_entry = "tp" if rt.seq_sharded_decode else None
    spec = rt.spec_div(("fsdp", seq_entry, None, None),
                       (batch, l, cfg.n_kv_heads, cfg.d_head))
    return {"k": spec, "v": spec, "pos": P()}


def _fill_cache(rt, cache, k, v, s, window):
    """Prefill: write the (last window of the) sequence into the cache."""
    l = cache["k"].shape[1]
    if s >= l:
        ks, vs = k[:, s - l:], v[:, s - l:]
        newk = ks.astype(cache["k"].dtype)
        newv = vs.astype(cache["v"].dtype)
    else:
        newk = cache["k"].at[:, :s].set(k.astype(cache["k"].dtype))
        newv = cache["v"].at[:, :s].set(v.astype(cache["v"].dtype))
    return {"k": newk, "v": newv, "pos": jnp.asarray(s, jnp.int32)}


def _decode_attend(cfg: ModelConfig, rt: Runtime, q, k_new, v_new, cache,
                   window: int, scale: float):
    """One-token decode over a sequence-sharded cache with LSE combine.

    q: (B, 1, H, dh); cache k/v: (B, L, KV, dh) sharded (fsdp, tp, -, -).
    The new token's k/v is written at ``pos % L`` (ring buffer for windowed
    layers); each model shard attends to its local chunk; partial outputs
    are merged with the standard log-sum-exp weighting via psum('model').
    """
    b, _, h, dh = q.shape
    l = cache["k"].shape[1]
    pos = cache["pos"]
    slot = jnp.mod(pos, l)

    def body(q_, knew_, vnew_, kc, vc, pos_, slot_):
        ax = rt.model_axis
        nshards = rt.tp_size
        l_loc = kc.shape[1]
        shard = (jax.lax.axis_index(ax)
                 if rt.mesh is not None and rt.tp_size > 1
                 and rt.seq_sharded_decode else 0)
        start = shard * l_loc
        # scatter the new token into the owning shard's chunk
        local_idx = jnp.clip(slot_ - start, 0, l_loc - 1)
        owns = (slot_ >= start) & (slot_ < start + l_loc)
        kc = jnp.where(owns,
                       jax.lax.dynamic_update_slice_in_dim(
                           kc, knew_.astype(kc.dtype), local_idx, axis=1),
                       kc)
        vc = jnp.where(owns,
                       jax.lax.dynamic_update_slice_in_dim(
                           vc, vnew_.astype(vc.dtype), local_idx, axis=1),
                       vc)
        # local attention over the chunk
        g = h // cfg.n_kv_heads
        kk = jnp.repeat(kc, g, axis=2)                 # (B, Lc, H, dh)
        vv = jnp.repeat(vc, g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_.astype(jnp.float32),
                       kk.astype(jnp.float32)) * scale
        if cfg.attn_softcap > 0:
            s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
        kpos = start + jnp.arange(l_loc)
        valid = kpos[None, None, None, :] <= jnp.maximum(pos_, slot_)
        # ring semantics: every stored slot is within the window by
        # construction; only not-yet-written slots are masked.
        written = kpos[None, None, None, :] < jnp.minimum(pos_ + 1, l)
        s = jnp.where(written & valid | (kpos[None, None, None, :] == slot_),
                      s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe), 0.0)
        lsum = p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bhqk,bkhd->bhqd", p, vv.astype(jnp.float32))
        if rt.mesh is not None and rt.tp_size > 1 \
                and rt.seq_sharded_decode:
            gm = jax.lax.pmax(m, ax)
            w = jnp.where(jnp.isfinite(m), jnp.exp(m - gm), 0.0)
            o = jax.lax.psum(o * w, ax)
            lsum = jax.lax.psum(lsum * w, ax)
        o = o / jnp.where(lsum == 0, 1.0, lsum)
        return o.transpose(0, 2, 1, 3).astype(q_.dtype), kc, vc

    if rt.mesh is not None and rt.tp_size > 1 and rt.seq_sharded_decode:
        l_len = cache["k"].shape[1]
        # batch shards over fsdp only when divisible (long_500k has B=1)
        cache_spec = rt.spec_div(("fsdp", "tp", None, None),
                                 (b, l_len, cfg.n_kv_heads, dh))
        rep4 = rt.spec_div(("fsdp", None, None, None), (b, 1, 1, 1))
        body_m = rt.shard_map(
            body,
            in_specs=(rep4, rep4, rep4, cache_spec, cache_spec, P(), P()),
            out_specs=(rep4, cache_spec, cache_spec))
    else:
        body_m = body
    out, k_c, v_c = body_m(q, k_new, v_new, cache["k"], cache["v"], pos, slot)
    return out, {"k": k_c, "v": v_c, "pos": pos + 1}
