"""Mixture-of-Experts block (DeepSeek-V2 160e top-6 + 2 shared; OLMoE 64e top-8).

Default execution (``moe_mode='tp'``): *tensor-parallel experts* under
shard_map — tokens stay on their data shard; every expert's FFN dimension
is sharded over the model axis; dispatch is a local sort + ragged_dot
(dropless, token-choice); partial outputs psum over 'model'.  No token ever
crosses the data axes, so the only collective is the model-axis reduction —
predictable and compile-friendly at 512 devices.

Alternative (``moe_mode='ep'``): expert parallelism with fixed-capacity
all_to_all dispatch over the model axis (each model shard owns E/tp whole
experts).  This is the paper-relevant mode: all_to_all is exactly the
adversarial traffic pattern FatPaths targets (DESIGN.md §4); the EP-vs-TP
trade is one of the §Perf hillclimb subjects.

Aux outputs: load-balance loss (switch-style) returned to the caller.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import P, Runtime
from . import common
from .config import ModelConfig


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": common.truncnorm(ks[0], (d, m.n_experts), dtype),
        "w1": common.truncnorm(ks[1], (m.n_experts, d, m.d_ff_expert), dtype),
        "w3": common.truncnorm(ks[2], (m.n_experts, d, m.d_ff_expert), dtype),
        "w2": common.truncnorm(ks[3], (m.n_experts, m.d_ff_expert, d), dtype,
                               scale=0.02 / np.sqrt(2 * cfg.n_layers)),
    }
    if m.n_shared > 0:
        f_sh = m.n_shared * m.d_ff_shared
        p["shared"] = common.mlp_init(ks[4], d, f_sh, dtype)
    return p


def moe_specs(rt: Runtime, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    if rt.moe_mode == "ep" and rt.tp_size > 1 \
            and m.n_experts % rt.tp_size == 0:
        # EP: experts live whole on their model shard AND are ZeRO-sharded
        # over fsdp (storage matches the dispatch layout up to the fsdp
        # all-gather of the *local* experts).  We measured the ZeRO-1
        # alternative (whole local experts, no per-layer AG): at dsv2
        # scale expert weights are 98% of 236B params, so whole storage is
        # 170 GiB/device — refuted; the per-layer AG is the right trade
        # (EXPERIMENTS.md §Perf iter dsv2#5).
        s = {
            "router": rt.spec_div(("fsdp", None), (d, m.n_experts)),
            "w1": rt.spec_div(("tp", "fsdp", None),
                              (m.n_experts, d, m.d_ff_expert)),
            "w3": rt.spec_div(("tp", "fsdp", None),
                              (m.n_experts, d, m.d_ff_expert)),
            "w2": rt.spec_div(("tp", None, "fsdp"),
                              (m.n_experts, m.d_ff_expert, d)),
        }
    else:
        s = {
            "router": rt.spec_div(("fsdp", None), (d, m.n_experts)),
            "w1": rt.spec_div((None, "fsdp", "tp"),
                              (m.n_experts, d, m.d_ff_expert)),
            "w3": rt.spec_div((None, "fsdp", "tp"),
                              (m.n_experts, d, m.d_ff_expert)),
            "w2": rt.spec_div((None, "tp", "fsdp"),
                              (m.n_experts, m.d_ff_expert, d)),
        }
    if m.n_shared > 0:
        f_sh = m.n_shared * m.d_ff_shared
        s["shared"] = common.mlp_specs(rt, d, f_sh)
    return s


def _route(x_flat, router_w, m, dtype):
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)
    if m.router_scale:
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance aux: E * sum_e f_e * p_e
    f_e = jnp.zeros(m.n_experts).at[topi.reshape(-1)].add(1.0)
    f_e = f_e / jnp.maximum(f_e.sum(), 1.0)
    p_e = probs.mean(axis=0)
    aux = m.n_experts * jnp.sum(f_e * p_e)
    return topw.astype(dtype), topi.astype(jnp.int32), aux


def _expert_ffn_sorted(xs, gs, w1, w3, w2, dtype):
    """ragged_dot pipeline over expert-sorted rows."""
    h1 = jax.lax.ragged_dot(xs, w1.astype(dtype), gs)
    h3 = jax.lax.ragged_dot(xs, w3.astype(dtype), gs)
    hs = jax.nn.silu(h1) * h3
    return jax.lax.ragged_dot(hs, w2.astype(dtype), gs)


def _moe_body_tp(cfg: ModelConfig, rt: Runtime, do_psum: bool):
    m = cfg.moe

    def body(x, router_w, w1, w3, w2, shared):
        b, s, d = x.shape
        dt = x.dtype
        x_flat = x.reshape(-1, d)
        t = x_flat.shape[0]
        topw, topi, aux = _route(x_flat, router_w, m, dt)
        eid = topi.reshape(-1)                             # (T*k,)
        xr = jnp.repeat(x_flat, m.top_k, axis=0)           # token-major
        order = jnp.argsort(eid)
        xs = xr[order]
        gs = jnp.zeros((m.n_experts,), jnp.int32).at[eid].add(1)
        ys = _expert_ffn_sorted(xs, gs, w1, w3, w2, dt)
        y = jnp.zeros_like(ys).at[order].set(ys)
        y = (y.reshape(t, m.top_k, d)
             * topw[..., None].astype(dt)).sum(axis=1)
        if shared is not None:
            y = y + common.mlp_apply(shared, x, act="silu").reshape(t, d)
        if do_psum:
            y = jax.lax.psum(y, rt.model_axis)
            aux = jax.lax.pmean(aux, rt.model_axis)
            for a in rt.data_axes:
                aux = jax.lax.pmean(aux, a)
        return y.reshape(b, s, d), aux

    return body


def _moe_body_ep(cfg: ModelConfig, rt: Runtime):
    """Expert-parallel body: fixed-capacity all_to_all over the model axis.

    Each model shard owns E/tp whole experts (full FFN width).  Tokens are
    bucketed by destination shard, padded to a fixed capacity, exchanged
    with all_to_all, processed with ragged_dot over local experts, and sent
    back.  Overflowing tokens are dropped (capacity_factor controls slack) —
    the classic EP trade; aux loss keeps the router balanced.
    """
    m = cfg.moe

    def body(x, router_w, w1, w3, w2, shared):
        b, s, d = x.shape
        dt = x.dtype
        ax = rt.model_axis
        nsh = rt.tp_size
        e_loc = m.n_experts // nsh
        x_flat = x.reshape(-1, d)
        t = x_flat.shape[0]
        topw, topi, aux = _route(x_flat, router_w, m, dt)
        eid = topi.reshape(-1)
        dest = eid // e_loc                                # (T*k,)
        cap = int(np.ceil(t * m.top_k / nsh * m.capacity_factor))
        xr = jnp.repeat(x_flat, m.top_k, axis=0)
        # stable sort by dest; rank within dest bucket
        order = jnp.argsort(dest)
        dsort = dest[order]
        esort = eid[order]
        xsort = xr[order]
        pos_in_bucket = jnp.arange(t * m.top_k) - jnp.searchsorted(
            dsort, dsort, side="left")
        keep = pos_in_bucket < cap
        # scatter into (nsh, cap, D) send buffers (dropped rows -> trash row)
        slot = jnp.where(keep, dsort * cap + pos_in_bucket, nsh * cap)
        send = jnp.zeros((nsh * cap + 1, d), dt).at[slot].set(xsort)[:-1]
        send_e = jnp.full((nsh * cap + 1,), -1, jnp.int32).at[slot].set(
            jnp.where(keep, esort, -1))[:-1]
        send = send.reshape(nsh, cap, d)
        send_e = send_e.reshape(nsh, cap)
        recv = jax.lax.all_to_all(send, ax, split_axis=0, concat_axis=0,
                                  tiled=False)
        recv_e = jax.lax.all_to_all(send_e, ax, split_axis=0, concat_axis=0,
                                    tiled=False)
        rtok = recv.reshape(nsh * cap, d)
        re = recv_e.reshape(nsh * cap)
        shard = jax.lax.axis_index(ax)
        le = jnp.where(re < 0, e_loc, re - shard * e_loc)  # local expert id
        # fixed-capacity batched expert matmul: pad each local expert's rows
        # to cap_e and run ONE (e_loc, cap_e, d) x (e_loc, d, f) einsum —
        # exact active FLOPs (x capacity slack), unlike ragged_dot whose
        # XLA:CPU lowering densifies over every group.
        cap_e = int(np.ceil(nsh * cap / e_loc * m.capacity_factor))
        order2 = jnp.argsort(le)
        le_s = le[order2]
        x_s = rtok[order2]
        pos_e = jnp.arange(nsh * cap) - jnp.searchsorted(le_s, le_s,
                                                         side="left")
        keep2 = (pos_e < cap_e) & (le_s < e_loc)
        slot2 = jnp.where(keep2, le_s * cap_e + pos_e, e_loc * cap_e)
        xbuf = jnp.zeros((e_loc * cap_e + 1, d), dt).at[slot2].set(x_s)[:-1]
        xbuf = xbuf.reshape(e_loc, cap_e, d)
        h1 = jnp.einsum("ecd,edf->ecf", xbuf, w1.astype(dt))
        h3 = jnp.einsum("ecd,edf->ecf", xbuf, w3.astype(dt))
        hs = jax.nn.silu(h1) * h3
        ybuf = jnp.einsum("ecf,efd->ecd", hs, w2.astype(dt))
        yflat = ybuf.reshape(e_loc * cap_e, d)
        y_s = jnp.where(keep2[:, None],
                        yflat[jnp.minimum(slot2, e_loc * cap_e - 1)], 0.0)
        yr = jnp.zeros_like(y_s).at[order2].set(y_s).reshape(nsh, cap, d)
        back = jax.lax.all_to_all(yr, ax, split_axis=0, concat_axis=0,
                                  tiled=False).reshape(nsh * cap, d)
        # gather back into sorted-by-dest order, then unsort
        ysort = jnp.where(keep[:, None],
                          back[jnp.where(keep, slot, 0)], 0.0)
        y = jnp.zeros((t * m.top_k, d), dt).at[order].set(ysort)
        y = (y.reshape(t, m.top_k, d) * topw[..., None].astype(dt)).sum(axis=1)
        if shared is not None:
            # shared experts run replicated across the model axis in EP mode
            y = y + common.mlp_apply(shared, x, act="silu").reshape(t, d)
        aux = jax.lax.pmean(aux, ax)
        for a in rt.data_axes:
            aux = jax.lax.pmean(aux, a)
        return y.reshape(b, s, d), aux

    return body


def moe_apply(params, cfg: ModelConfig, rt: Runtime, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss)."""
    m = cfg.moe
    shared = params.get("shared")
    if rt.mesh is None or rt.tp_size == 1:
        # no model axis: plain pjit path (GSPMD shards over the data axes)
        body = _moe_body_tp(cfg, rt, do_psum=False)
        return body(x, params["router"], params["w1"], params["w3"],
                    params["w2"], shared)

    mode = rt.moe_mode
    fs = rt.fsdp
    tp = rt.tp
    s_len = x.shape[1]
    if mode == "ep" and s_len % max(rt.tp_size, 1) != 0:
        mode = "tp"   # decode with S=1: EP dispatch degenerates; use TP
    x_spec = P(fs, None, None)
    if mode == "tp":
        body = _moe_body_tp(cfg, rt, do_psum=True)
        expert_specs = (P(None, None, tp), P(None, None, tp), P(None, tp, None))
        shared_spec = {"wi": P(None, None, tp), "wo": P(tp, None)}
    elif mode == "ep":
        body = _moe_body_ep(cfg, rt)
        # tokens are SPLIT over the model axis (sequence dim) before the
        # all_to_all — each model shard dispatches only its own rows; with
        # sequence parallelism this is exactly the residual sharding, so no
        # resharding happens at the block boundary.
        x_spec = P(fs, tp, None)
        e_spec = P(tp, None, None)  # experts split over model shards
        expert_specs = (e_spec, e_spec, e_spec)
        shared_spec = {"wi": P(None, None, None), "wo": P(None, None)}
    else:
        raise ValueError(mode)
    out_specs = (x_spec, P())
    # cast expert weights to the activation dtype BEFORE shard_map so the
    # fsdp all-gather of the (dominant) expert params moves bf16, not f32
    dt = x.dtype
    w1, w3, w2 = (params["w1"].astype(dt), params["w3"].astype(dt),
                  params["w2"].astype(dt))
    if shared is None:
        fn = rt.shard_map(
            lambda a, rw, w1, w3, w2: body(a, rw, w1, w3, w2, None),
            in_specs=(x_spec, P(None, None)) + expert_specs,
            out_specs=out_specs)
        return fn(x, params["router"], w1, w3, w2)
    shared_c = jax.tree.map(lambda w: w.astype(dt), shared)
    fn = rt.shard_map(
        body,
        in_specs=(x_spec, P(None, None)) + expert_specs + (shared_spec,),
        out_specs=out_specs)
    return fn(x, params["router"], w1, w3, w2, shared_c)
