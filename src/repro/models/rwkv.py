"""RWKV6 "Finch" block (arXiv:2404.05892): attention-free time mix with
data-dependent decay + squared-ReLU channel mix.

Time mix per head (K = V = head_dim):
    w_t = exp(-exp(w0 + tanh(xw_t @ A) @ B))      (data-dependent decay, LoRA)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
followed by a per-head RMS norm, SiLU gate g, and output projection.
Token-shift mixing (static mu per r/k/v/g/w) precedes every projection.

The recurrence is computed with an exact sequential ``lax.scan``: RWKV6's
*per-channel* decay makes the chunked-parallel (GLA-style) form numerically
explosive without a custom kernel (exp(+cumsum) factors) — on TPU the right
answer is a Pallas chunked-GLA kernel (future work, see DESIGN.md); here the
scan is both the reference semantics and the shipped implementation.  The
state is O(H*K*V) per sequence — this is what makes rwkv6 runnable at
``long_500k`` where attention archs are skipped.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import Runtime
from . import common
from .config import ModelConfig


def rwkv_init(key, cfg: ModelConfig, dtype=jnp.float32):
    r = cfg.rwkv
    d = cfg.d_model
    f = cfg.d_ff
    nh = d // r.head_dim
    ks = jax.random.split(key, 10)
    scale_o = 0.02 / np.sqrt(2 * cfg.n_layers)
    return {
        "tm": {  # time mix
            "mu": common.truncnorm(ks[0], (5, d), dtype, scale=0.1),  # r,k,v,g,w
            "wr": common.truncnorm(ks[1], (d, d), dtype),
            "wk": common.truncnorm(ks[2], (d, d), dtype),
            "wv": common.truncnorm(ks[3], (d, d), dtype),
            "wg": common.truncnorm(ks[4], (d, d), dtype),
            "w0": jnp.asarray(np.linspace(-6.0, -0.5, d), dtype),
            "wa": common.truncnorm(ks[5], (d, r.decay_lora), dtype),
            "wb": common.truncnorm(ks[6], (r.decay_lora, d), dtype),
            "u": common.truncnorm(ks[7], (nh, r.head_dim), dtype, scale=0.3),
            "ln": common.rmsnorm_init(ks[7], d, dtype),
            "wo": common.truncnorm(ks[8], (d, d), dtype, scale=scale_o),
        },
        "cm": {  # channel mix
            "mu": common.truncnorm(ks[9], (2, d), dtype, scale=0.1),  # k, r
            "wk": common.truncnorm(ks[9], (d, f), dtype),
            "wv": common.truncnorm(ks[0], (f, d), dtype, scale=scale_o),
            "wr": common.truncnorm(ks[1], (d, d), dtype),
        },
    }


def rwkv_specs(rt: Runtime, cfg: ModelConfig):
    r = cfg.rwkv
    d, f = cfg.d_model, cfg.d_ff
    nh = d // r.head_dim
    dd = rt.spec_div(("fsdp", "tp"), (d, d))
    return {
        "tm": {
            "mu": rt.spec_div((None, "fsdp"), (5, d)),
            "wr": dd, "wk": dd, "wv": dd, "wg": dd,
            "w0": rt.spec_div(("fsdp",), (d,)),
            "wa": rt.spec_div(("fsdp", None), (d, r.decay_lora)),
            "wb": rt.spec_div((None, "fsdp"), (r.decay_lora, d)),
            "u": rt.spec_div(("tp", None), (nh, r.head_dim)),
            "ln": common.rmsnorm_specs(rt),
            "wo": rt.spec_div(("tp", "fsdp"), (d, d)),
        },
        "cm": {
            "mu": rt.spec_div((None, "fsdp"), (2, d)),
            "wk": rt.spec_div(("fsdp", "tp"), (d, f)),
            "wv": rt.spec_div(("tp", "fsdp"), (f, d)),
            "wr": dd,
        },
    }


def _token_shift(x, last: Optional[jnp.ndarray]):
    """x_{t-1} with either zero or cached boundary token."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        # cache dtype (f32) must not contaminate the bf16 stream
        prev = jnp.concatenate([last.astype(x.dtype)[:, None, :], x[:, :-1]],
                               axis=1)
    return prev


def time_mix(p, cfg: ModelConfig, rt: Runtime, x, state, last):
    """x: (B, L, D); state: (B, H, K, V) or None; last: (B, D) or None."""
    r_cfg = cfg.rwkv
    b, l, d = x.shape
    nh = d // r_cfg.head_dim
    hd = r_cfg.head_dim
    dt = x.dtype
    prev = _token_shift(x, last)
    mu = p["mu"].astype(dt)
    xr = x + (prev - x) * mu[0]
    xk = x + (prev - x) * mu[1]
    xv = x + (prev - x) * mu[2]
    xg = x + (prev - x) * mu[3]
    xw = x + (prev - x) * mu[4]
    r = jnp.einsum("bld,de->ble", xr, p["wr"].astype(dt))
    k = jnp.einsum("bld,de->ble", xk, p["wk"].astype(dt))
    v = jnp.einsum("bld,de->ble", xv, p["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("bld,de->ble", xg, p["wg"].astype(dt)))
    lora = jnp.tanh(jnp.einsum("bld,dr->blr", xw, p["wa"].astype(dt)))
    wlog = (p["w0"].astype(jnp.float32)
            + jnp.einsum("blr,re->ble", lora,
                         p["wb"].astype(dt)).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wlog))                        # (B, L, D) in (0,1)

    rh = r.reshape(b, l, nh, hd).astype(jnp.float32)
    kh = k.reshape(b, l, nh, hd).astype(jnp.float32)
    vh = v.reshape(b, l, nh, hd).astype(jnp.float32)
    wh = w.reshape(b, l, nh, hd)
    u = p["u"].astype(jnp.float32)

    def step(s, inp):
        rt_, kt, vt, wt = inp                          # (B, H, K) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt_, s + u[None, :, :, None] * kv)
        s = s * wt[..., None] + kv
        return s, yt

    if state is None:
        state = jnp.zeros((b, nh, hd, hd), jnp.float32)
    xs = (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
          vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, l, d).astype(dt)
    y = common.rmsnorm(p["ln"], y, cfg.norm_eps) * g
    out = jnp.einsum("bld,de->ble", y, p["wo"].astype(dt))
    return out, state, x[:, -1, :].astype(jnp.float32)


def channel_mix(p, cfg: ModelConfig, x, last):
    dt = x.dtype
    prev = _token_shift(x, last)
    mu = p["mu"].astype(dt)
    xk = x + (prev - x) * mu[0]
    xr = x + (prev - x) * mu[1]
    k = jnp.einsum("bld,df->blf", xk, p["wk"].astype(dt))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("blf,fd->bld", k, p["wv"].astype(dt))
    r = jax.nn.sigmoid(jnp.einsum("bld,de->ble", xr, p["wr"].astype(dt)))
    return r * kv, x[:, -1, :].astype(jnp.float32)


def rwkv_apply(params, cfg: ModelConfig, rt: Runtime, x, *,
               cache: Optional[dict] = None) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full RWKV6 block: time mix + channel mix with their residuals.

    cache: {"state": (B,H,K,V), "tm_last": (B,D), "cm_last": (B,D)}.
    """
    st = cache["state"] if cache is not None else None
    tl = cache["tm_last"] if cache is not None else None
    cl = cache["cm_last"] if cache is not None else None
    h, new_state, new_tl = time_mix(params["tm"], cfg, rt, x, st, tl)
    x = x + h
    h2, new_cl = channel_mix(params["cm"], cfg, x, cl)
    out = x + h2
    new_cache = None
    if cache is not None:
        new_cache = {"state": new_state, "tm_last": new_tl, "cm_last": new_cl}
    return out, new_cache


def init_rwkv_cache(rt: Runtime, cfg: ModelConfig, batch: int):
    r = cfg.rwkv
    d = cfg.d_model
    nh = d // r.head_dim
    return {
        "state": jnp.zeros((batch, nh, r.head_dim, r.head_dim), jnp.float32),
        "tm_last": jnp.zeros((batch, d), jnp.float32),
        "cm_last": jnp.zeros((batch, d), jnp.float32),
    }


def rwkv_cache_specs(rt: Runtime, cfg: ModelConfig, batch: int):
    r = cfg.rwkv
    d = cfg.d_model
    nh = d // r.head_dim
    return {
        "state": rt.spec_div(("fsdp", "tp", None, None),
                             (batch, nh, r.head_dim, r.head_dim)),
        "tm_last": rt.spec_div(("fsdp", None), (batch, d)),
        "cm_last": rt.spec_div(("fsdp", None), (batch, d)),
    }
