"""Shared model components: norms, RoPE (+M-RoPE), MLPs, embeddings, init.

Parameters are plain nested dicts of jnp arrays; every init function has a
twin ``*_specs`` builder returning the matching PartitionSpec tree (tests
assert the trees are structurally identical).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import Runtime

Init = jax.nn.initializers


def truncnorm(key, shape, dtype, scale: float = 0.02):
    return Init.truncated_normal(stddev=scale)(key, shape, dtype)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---- RMSNorm -----------------------------------------------------------------
def rmsnorm_init(key, d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm_specs(rt: Runtime):
    return {"scale": rt.spec(None)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---- RoPE --------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               sections: Optional[Tuple[int, int, int]] = None) -> jnp.ndarray:
    """Rotate (B, S, H, D) by positions.

    positions: (B, S) for standard RoPE, or (3, B, S) for M-RoPE
    (qwen2-vl temporal/height/width sections of the half-dim).
    """
    b, s, h, d = x.shape
    freqs = rope_freqs(d, theta)                       # (d/2,)
    if sections is None:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d/2)
    else:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        parts = []
        start = 0
        for sec, pos in zip(sections, positions):
            parts.append(pos[..., None].astype(jnp.float32) * freqs[start:start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)          # (B,S,d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---- Gated MLP (SwiGLU / GeGLU) ----------------------------------------------
def mlp_init(key, d: int, f: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "wi": truncnorm(k1, (d, 2, f), dtype),    # [gate; up] fused
        "wo": truncnorm(k2, (f, d), dtype, scale=0.02 / np.sqrt(2)),
    }


def mlp_specs(rt: Runtime, d: int, f: int):
    return {"wi": rt.spec_div(("fsdp", None, "tp"), (d, 2, f)),
            "wo": rt.spec_div(("tp", "fsdp"), (f, d))}


def mlp_apply(params, x, act: str = "silu"):
    dt = x.dtype
    h = jnp.einsum("bsd,dcf->bscf", x, params["wi"].astype(dt))
    gate, up = h[:, :, 0], h[:, :, 1]
    g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate, approximate=True)
    return jnp.einsum("bsf,fd->bsd", g * up, params["wo"].astype(dt))


@jax.custom_vjp
def _cast_grad_bf16(x):
    return x


def _cgb_fwd(x):
    return x, None


def _cgb_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


_cast_grad_bf16.defvjp(_cgb_fwd, _cgb_bwd)


def cast_cotangent_bf16(x):
    """Identity whose backward casts the cotangent to bf16.

    Placed at the logits: the loss math stays f32, but the gradient flowing
    back through the layer stack is bf16 — halves backward HBM traffic and
    wire bytes (the f32 cotangent otherwise contaminates every residual add
    all the way down; measured in EXPERIMENTS.md §Perf).
    """
    return _cast_grad_bf16(x)


# ---- Embedding / unembedding ---------------------------------------------------
def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"tok": truncnorm(key, (vocab, d), dtype)}


def embed_specs(rt: Runtime, vocab: int, d: int):
    if rt.tp_size > 1:
        return {"tok": rt.spec_div(("tp", "fsdp"), (vocab, d))}
    # pure-FSDP: shard d (a vocab-sharded table forces XLA to all-gather
    # the full f32 table for the row gather — measured 4.4 GiB at 256k
    # vocab; with d sharded the row gather is shard-local)
    return {"tok": rt.spec_div((None, "fsdp"), (vocab, d))}


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  softcap: float = 0.0) -> jnp.ndarray:
    """Mean token cross-entropy in f32 (with optional final logit softcap)."""
    lf = logits.astype(jnp.float32)
    if softcap > 0:
        lf = softcap * jnp.tanh(lf / softcap)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
