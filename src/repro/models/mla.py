"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Faithful structure:
  * q: low-rank  x -> W_DQ (q_lora) -> norm -> W_UQ -> per-head [nope|rope]
  * kv: latent   x -> W_DKV (kv_lora) -> norm  (cached!)
                 latent -> W_UKV -> per-head [k_nope | v]
  * shared rope key: x -> W_KR (rope_dim), RoPE'd, shared across heads.

Train/prefill expands k/v from the latent (chunked attention).  Decode uses
the *absorbed* form: q_nope is folded through W_UK so attention logits and
values are computed directly against the compressed latent cache — the
cache stays (B, S, kv_lora + rope_dim), the paper-accurate memory win.
The latent cache is sequence-sharded over the model axis with LSE combine,
like the GQA decode path.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import P, Runtime
from . import common
from .attention import NEG_INF, chunked_attention, flash_chunked
from .config import ModelConfig


def mla_init(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "wdq": common.truncnorm(ks[0], (d, m.q_lora), dtype),
        "q_ln": common.rmsnorm_init(ks[1], m.q_lora, dtype),
        "wuq": common.truncnorm(ks[1], (m.q_lora, h, m.nope_dim + m.rope_dim), dtype),
        "wdkv": common.truncnorm(ks[2], (d, m.kv_lora), dtype),
        "kv_ln": common.rmsnorm_init(ks[3], m.kv_lora, dtype),
        "wukv": common.truncnorm(ks[4], (m.kv_lora, h, m.nope_dim + m.v_dim), dtype),
        "wkr": common.truncnorm(ks[5], (d, m.rope_dim), dtype),
        "wo": common.truncnorm(ks[6], (h, m.v_dim, d), dtype,
                               scale=0.02 / np.sqrt(2 * cfg.n_layers)),
    }


def mla_specs(rt: Runtime, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    return {
        "wdq": rt.spec_div(("fsdp", "tp"), (d, m.q_lora)),
        "q_ln": common.rmsnorm_specs(rt),
        "wuq": rt.spec_div(("fsdp", "tp", None), (m.q_lora, h, m.nope_dim + m.rope_dim)),
        "wdkv": rt.spec_div(("fsdp", None), (d, m.kv_lora)),
        "kv_ln": common.rmsnorm_specs(rt),
        "wukv": rt.spec_div(("fsdp", "tp", None), (m.kv_lora, h, m.nope_dim + m.v_dim)),
        "wkr": rt.spec_div(("fsdp", None), (d, m.rope_dim)),
        "wo": rt.spec_div(("tp", None, "fsdp"), (h, m.v_dim, d)),
    }


def mla_apply(params, cfg: ModelConfig, rt: Runtime, x, positions, *,
              cache: Optional[dict] = None, chunk: int = 512,
              block_skip: bool = False):
    """x: (B, S, D) -> (out, new_cache)."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    dt = x.dtype

    cq = common.rmsnorm(params["q_ln"], jnp.einsum("bsd,dr->bsr", x,
                                                   params["wdq"].astype(dt)),
                        cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"].astype(dt))
    q_nope, q_rope = q[..., :m.nope_dim], q[..., m.nope_dim:]
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)

    latent = common.rmsnorm(params["kv_ln"],
                            jnp.einsum("bsd,dr->bsr", x, params["wdkv"].astype(dt)),
                            cfg.norm_eps)
    k_rope = common.apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["wkr"].astype(dt))[:, :, None, :],
        positions, cfg.rope_theta)[:, :, 0]            # (B, S, rope_dim)

    scale = float(m.nope_dim + m.rope_dim) ** -0.5

    if cache is not None and s == 1:
        out, new_cache = _mla_decode(params, cfg, rt, q_nope, q_rope, latent,
                                     k_rope, cache, scale)
        o = jnp.einsum("bshv,hvd->bsd", out, params["wo"].astype(dt))
        return o, new_cache

    # Train/prefill: expand k/v from latent, run chunked attention.
    kv = jnp.einsum("bsr,rhk->bshk", latent, params["wukv"].astype(dt))
    k_nope, v = kv[..., :m.nope_dim], kv[..., m.nope_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.rope_dim))],
        axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    qh = rt.shard(qfull.transpose(0, 2, 1, 3), "fsdp", "tp", None, None)
    # pin k/v (and hence the flash-VJP residuals) to head-sharded layout
    kh = rt.shard_spec(k.transpose(0, 2, 1, 3),
                       rt.spec_div(("fsdp", "tp", None, None),
                                   (b, h, s, m.nope_dim + m.rope_dim)))
    vh = rt.shard_spec(v.transpose(0, 2, 1, 3),
                       rt.spec_div(("fsdp", "tp", None, None),
                                   (b, h, s, m.v_dim)))
    if kh.shape[2] > chunk:
        out = flash_chunked(qh, kh, vh, cfg.causal, 0, cfg.attn_softcap,
                            scale, chunk, 0)
    else:
        out = chunked_attention(qh, kh, vh, causal=cfg.causal, window=0,
                                softcap=cfg.attn_softcap, scale=scale,
                                chunk=chunk, block_skip=block_skip)
    o = jnp.einsum("bhsv,hvd->bsd", out, params["wo"].astype(dt))
    new_cache = None
    if cache is not None:
        l = cache["latent"].shape[1]
        lat = jnp.concatenate([latent, k_rope], axis=-1)
        new_cache = {
            "latent": cache["latent"].at[:, :min(s, l)].set(
                lat[:, :min(s, l)].astype(cache["latent"].dtype)),
            "pos": jnp.asarray(s, jnp.int32),
        }
    return o, new_cache


def init_mla_cache(rt: Runtime, cfg: ModelConfig, batch: int, length: int,
                   dtype=jnp.bfloat16):
    m = cfg.mla
    return {"latent": jnp.zeros((batch, length, m.kv_lora + m.rope_dim), dtype),
            "pos": jnp.zeros((), jnp.int32)}


def mla_cache_specs(rt: Runtime, cfg: ModelConfig, batch: int, length: int):
    m = cfg.mla
    seq_entry = "tp" if rt.seq_sharded_decode else None
    return {"latent": rt.spec_div(("fsdp", seq_entry, None),
                                  (batch, length, m.kv_lora + m.rope_dim)),
            "pos": P()}


def _mla_decode(params, cfg: ModelConfig, rt: Runtime, q_nope, q_rope, latent,
                k_rope, cache, scale):
    """Absorbed decode against the sequence-sharded latent cache.

    q_abs[h] = q_nope[h] @ W_UK[h]^T  (fold key up-projection into q), so
      logits = q_abs . latent + q_rope . k_rope_cache
      o_lat  = softmax(logits) @ latent        (kv_lora dims)
      o[h]   = o_lat @ W_UV[h]                 (v_dim dims)
    """
    m = cfg.mla
    b = q_nope.shape[0]
    h = cfg.n_heads
    dt = q_nope.dtype
    wuk = params["wukv"][..., :m.nope_dim].astype(dt)   # (r, h, nope)
    wuv = params["wukv"][..., m.nope_dim:].astype(dt)   # (r, h, v)
    # absorb: q_abs (B, 1, H, r)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, wuk)
    new_entry = jnp.concatenate([latent, k_rope], axis=-1)  # (B, 1, r+rope)
    pos = cache["pos"]
    lcache = cache["latent"]
    l = lcache.shape[1]

    def body(qa, qr, new_, lc, pos_):
        ax = rt.model_axis
        l_loc = lc.shape[1]
        shard = (jax.lax.axis_index(ax)
                 if rt.mesh is not None and rt.tp_size > 1
                 and rt.seq_sharded_decode else 0)
        start = shard * l_loc
        local_idx = jnp.clip(pos_ - start, 0, l_loc - 1)
        owns = (pos_ >= start) & (pos_ < start + l_loc)
        lc = jnp.where(owns, jax.lax.dynamic_update_slice_in_dim(
            lc, new_.astype(lc.dtype), local_idx, axis=1), lc)
        lat_c = lc[..., :m.kv_lora].astype(jnp.float32)     # (B, Lc, r)
        kr_c = lc[..., m.kv_lora:].astype(jnp.float32)      # (B, Lc, rope)
        s1 = jnp.einsum("bshr,bkr->bhsk", qa.astype(jnp.float32), lat_c)
        s2 = jnp.einsum("bshr,bkr->bhsk", qr.astype(jnp.float32), kr_c)
        s = (s1 + s2) * scale
        kpos = start + jnp.arange(l_loc)
        written = kpos[None, None, None, :] <= pos_
        s = jnp.where(written, s, NEG_INF)
        mx = jnp.max(s, axis=-1, keepdims=True)
        mx_safe = jnp.where(jnp.isfinite(mx), mx, 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - mx_safe), 0.0)
        lsum = p.sum(axis=-1, keepdims=True)
        o_lat = jnp.einsum("bhsk,bkr->bhsr", p, lat_c)
        if rt.mesh is not None and rt.tp_size > 1 \
                and rt.seq_sharded_decode:
            gm = jax.lax.pmax(mx, ax)
            w = jnp.where(jnp.isfinite(mx), jnp.exp(mx - gm), 0.0)
            o_lat = jax.lax.psum(o_lat * w, ax)
            lsum = jax.lax.psum(lsum * w, ax)
        o_lat = o_lat / jnp.where(lsum == 0, 1.0, lsum)
        return o_lat.astype(qa.dtype), lc

    if rt.mesh is not None and rt.tp_size > 1 and rt.seq_sharded_decode:
        fs = rt.fsdp
        cache_spec = P(fs, rt.tp, None)
        rep = P(fs, None, None, None)
        rep3 = P(fs, None, None)
        body_m = rt.shard_map(
            body, in_specs=(rep, rep, rep3, cache_spec, P()),
            out_specs=(rep, cache_spec))
    else:
        body_m = body
    o_lat, lc = body_m(q_abs, q_rope, new_entry, lcache, pos)
    out = jnp.einsum("bhsr,rhv->bshv", o_lat, wuv)
    return out, {"latent": lc, "pos": pos + 1}
