"""Model configuration for the unified LM substrate.

One :class:`ModelConfig` describes every assigned architecture; the block
stack is a repeating ``layer_pattern`` unit over block kinds:

  * ``g`` — global (full) attention block
  * ``l`` — local sliding-window attention block (gemma2)
  * ``a`` — *shared* attention block (zamba2: one weight set reused)
  * ``m`` — Mamba2 (SSD) block
  * ``r`` — RWKV6 (Finch) block

``n_layers`` must be divisible by ``len(layer_pattern)``; the stack scans
over ``n_layers / len(pattern)`` repetitions of the unit (bounded compile
time for 40+-layer models).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0          # per shared expert (dsv2: == d_ff_expert)
    router_scale: bool = True     # normalise top-k weights
    capacity_factor: float = 1.25  # only used by the capacity fallback path


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    nope_dim: int = 128
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int

    layer_pattern: str = "g"
    causal: bool = True
    rope_theta: float = 1e6
    qkv_bias: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    window: int = 4096              # for 'l' blocks
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl
    post_norms: bool = False        # gemma2 post-block norms
    embed_scale: bool = False       # gemma2 sqrt(d) embedding scaling
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None

    frontend: Optional[str] = None  # None | 'audio' | 'vision'
    frontend_dim: int = 0           # stub input embedding dim

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"         # compute/activation dtype
    param_dtype: str = "float32"

    remat: str = "full"             # none | dots | full
    scan_layers: bool = True

    def __post_init__(self):
        assert self.n_layers % len(self.layer_pattern) == 0, (
            self.name, self.n_layers, self.layer_pattern)
        if "m" in self.layer_pattern:
            assert self.ssm is not None
        if "r" in self.layer_pattern:
            assert self.rwkv is not None
        if self.family == "moe":
            assert self.moe is not None

    @property
    def pattern_repeats(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm.head_dim if self.ssm else 0

    @property
    def decoder(self) -> bool:
        """Whether the arch has an autoregressive decode step."""
        return self.causal

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, f, v, h, kv, dh = (self.d_model, self.d_ff, self.vocab,
                              self.n_heads, self.n_kv_heads, self.d_head)
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += d * v
        per_unit = 0
        for ch in self.layer_pattern:
            if ch in ("g", "l"):
                if self.mla:
                    m = self.mla
                    per_unit += d * m.q_lora + m.q_lora * h * (m.nope_dim + m.rope_dim)
                    per_unit += d * m.kv_lora + m.kv_lora * h * (m.nope_dim + m.v_dim)
                    per_unit += d * m.rope_dim + h * m.v_dim * d
                else:
                    per_unit += d * (h + 2 * kv) * dh + h * dh * d
                if self.moe is not None:
                    per_unit += d * self.moe.n_experts
                    per_unit += self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                    per_unit += self.moe.n_shared * 3 * d * self.moe.d_ff_shared
                else:
                    per_unit += 3 * d * f
            elif ch == "a":  # shared attention: counted once below
                pass
            elif ch == "m":
                s = self.ssm
                din = self.d_inner_ssm
                nh = self.n_ssm_heads
                per_unit += d * (2 * din + 2 * s.d_state + nh)
                per_unit += din * d + 3 * nh
            elif ch == "r":
                per_unit += 5 * d * d + 2 * d * self.rwkv.decay_lora  # time mix
                per_unit += 2 * d * f + d * d                          # channel mix
        total += per_unit * self.pattern_repeats
        if "a" in self.layer_pattern:
            total += d * (h + 2 * kv) * dh + h * dh * d + 3 * d * f
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        inactive = (m.n_experts - m.top_k) * 3 * self.d_model * m.d_ff_expert
        return int(self.param_count() - inactive * self._n_moe_layers())

    def _n_moe_layers(self) -> int:
        return sum(1 for ch in self.layer_pattern if ch in "gl") * self.pattern_repeats
