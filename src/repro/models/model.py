"""Unified model assembly for all assigned architectures.

The layer stack is ``cfg.pattern_repeats`` repetitions of the
``cfg.layer_pattern`` unit, executed as a single ``jax.lax.scan`` over
stacked per-repeat parameters (bounded HLO size at 40-60 layers — essential
for 512-device dry-run compiles).  Heterogeneous units (gemma2 "lg",
zamba2 "mmmmma") apply each unit position in sequence inside the scan body;
the 'a' (shared attention) weights live *outside* the scan and are reused
by every repeat (zamba2 semantics), while its KV caches stay per-repeat.

Public API:
  init_params / param_specs / init_cache / cache_specs
  forward(params, cfg, rt, batch, cache=None)  -> logits (+ new cache)
  loss_fn(params, cfg, rt, batch)              -> (loss, metrics)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import P, Runtime
from . import attention as attn_mod
from . import common, mla, moe, rwkv, ssm
from .config import ModelConfig

AUX_COEF = 0.01


# -----------------------------------------------------------------------------
# Per-unit-position block init/specs.
# -----------------------------------------------------------------------------
def _block_init(key, cfg: ModelConfig, char: str, dtype):
    ks = jax.random.split(key, 4)
    if char in ("g", "l"):
        p = {"ln1": common.rmsnorm_init(ks[0], cfg.d_model, dtype),
             "ln2": common.rmsnorm_init(ks[1], cfg.d_model, dtype)}
        if cfg.mla is not None:
            p["attn"] = mla.mla_init(ks[2], cfg, dtype)
        else:
            p["attn"] = attn_mod.attn_init(ks[2], cfg, dtype)
        if cfg.moe is not None:
            p["moe"] = moe.moe_init(ks[3], cfg, dtype)
        else:
            p["mlp"] = common.mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype)
        if cfg.post_norms:
            p["ln1_post"] = common.rmsnorm_init(ks[0], cfg.d_model, dtype)
            p["ln2_post"] = common.rmsnorm_init(ks[1], cfg.d_model, dtype)
        return p
    if char == "a":
        return {}  # shared weights live outside the scan
    if char == "m":
        return {"ln1": common.rmsnorm_init(ks[0], cfg.d_model, dtype),
                "ssm": ssm.ssm_init(ks[1], cfg, dtype)}
    if char == "r":
        return {"ln1": common.rmsnorm_init(ks[0], cfg.d_model, dtype),
                "ln2": common.rmsnorm_init(ks[1], cfg.d_model, dtype),
                "rwkv": rwkv.rwkv_init(ks[2], cfg, dtype)}
    raise ValueError(char)


def _block_specs(rt: Runtime, cfg: ModelConfig, char: str):
    if char in ("g", "l"):
        s = {"ln1": common.rmsnorm_specs(rt), "ln2": common.rmsnorm_specs(rt)}
        s["attn"] = (mla.mla_specs(rt, cfg) if cfg.mla is not None
                     else attn_mod.attn_specs(rt, cfg))
        if cfg.moe is not None:
            s["moe"] = moe.moe_specs(rt, cfg)
        else:
            s["mlp"] = common.mlp_specs(rt, cfg.d_model, cfg.d_ff)
        if cfg.post_norms:
            s["ln1_post"] = common.rmsnorm_specs(rt)
            s["ln2_post"] = common.rmsnorm_specs(rt)
        return s
    if char == "a":
        return {}
    if char == "m":
        return {"ln1": common.rmsnorm_specs(rt), "ssm": ssm.ssm_specs(rt, cfg)}
    if char == "r":
        return {"ln1": common.rmsnorm_specs(rt), "ln2": common.rmsnorm_specs(rt),
                "rwkv": rwkv.rwkv_specs(rt, cfg)}
    raise ValueError(char)


def _shared_block_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    return {"ln1": common.rmsnorm_init(ks[0], cfg.d_model, dtype),
            "attn": attn_mod.attn_init(ks[1], cfg, dtype),
            "ln2": common.rmsnorm_init(ks[2], cfg.d_model, dtype),
            "mlp": common.mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype)}


def _shared_block_specs(rt: Runtime, cfg: ModelConfig):
    return {"ln1": common.rmsnorm_specs(rt),
            "attn": attn_mod.attn_specs(rt, cfg),
            "ln2": common.rmsnorm_specs(rt),
            "mlp": common.mlp_specs(rt, cfg.d_model, cfg.d_ff)}


# -----------------------------------------------------------------------------
# Model-level init / specs.
# -----------------------------------------------------------------------------
def init_params(cfg: ModelConfig, rt: Runtime, key) -> Dict[str, Any]:
    dtype = common.dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, len(cfg.layer_pattern) + 4)
    params: Dict[str, Any] = {}
    if cfg.frontend is None:
        params["embed"] = common.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype)
    elif cfg.frontend == "vision":
        # VLM: patch embeddings come from the (stubbed) vision tower, text
        # tokens from the embedding table; input_specs supplies fused embeds.
        params["frontend"] = {
            "proj": common.truncnorm(keys[0], (cfg.frontend_dim, cfg.d_model), dtype)}
        params["embed"] = common.embed_init(keys[-4], cfg.vocab, cfg.d_model, dtype)
    else:  # audio encoder: frame embeddings only
        params["frontend"] = {
            "proj": common.truncnorm(keys[0], (cfg.frontend_dim, cfg.d_model), dtype)}

    blocks = {}
    r = cfg.pattern_repeats
    for i, ch in enumerate(cfg.layer_pattern):
        ki = jax.random.split(keys[i + 1], r)
        stacked = jax.vmap(lambda k: _block_init(k, cfg, ch, dtype))(ki)
        blocks[str(i)] = stacked
    params["blocks"] = blocks
    if "a" in cfg.layer_pattern:
        params["shared_attn"] = _shared_block_init(keys[-3], cfg, dtype)
    params["final_norm"] = common.rmsnorm_init(keys[-2], cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": common.truncnorm(keys[-1], (cfg.d_model, cfg.vocab), dtype)}
    return params


def param_specs(cfg: ModelConfig, rt: Runtime) -> Dict[str, Any]:
    specs: Dict[str, Any] = {}
    if cfg.frontend is None:
        specs["embed"] = common.embed_specs(rt, cfg.vocab, cfg.d_model)
    elif cfg.frontend == "vision":
        specs["frontend"] = {
            "proj": rt.spec_div(("fsdp", "tp"), (cfg.frontend_dim, cfg.d_model))}
        specs["embed"] = common.embed_specs(rt, cfg.vocab, cfg.d_model)
    else:
        specs["frontend"] = {
            "proj": rt.spec_div(("fsdp", "tp"), (cfg.frontend_dim, cfg.d_model))}

    def stack(spec_tree):
        return jax.tree.map(lambda s: P(*((None,) + tuple(s))), spec_tree,
                            is_leaf=lambda s: isinstance(s, P))

    blocks = {}
    for i, ch in enumerate(cfg.layer_pattern):
        blocks[str(i)] = stack(_block_specs(rt, cfg, ch))
    specs["blocks"] = blocks
    if "a" in cfg.layer_pattern:
        specs["shared_attn"] = _shared_block_specs(rt, cfg)
    specs["final_norm"] = common.rmsnorm_specs(rt)
    if not cfg.tie_embeddings:
        head_entries = ("fsdp", "tp") if rt.tp_size > 1 else (None, "fsdp")
        specs["lm_head"] = {
            "w": rt.spec_div(head_entries, (cfg.d_model, cfg.vocab))}
    return specs


# -----------------------------------------------------------------------------
# Caches.
# -----------------------------------------------------------------------------
def _block_cache(rt: Runtime, cfg: ModelConfig, char: str, batch: int,
                 length: int, dtype=jnp.bfloat16):
    if char == "g":
        if cfg.mla is not None:
            return mla.init_mla_cache(rt, cfg, batch, length, dtype)
        return attn_mod.init_kv_cache(rt, cfg, batch, length, 0, dtype)
    if char in ("l", "a"):
        return attn_mod.init_kv_cache(rt, cfg, batch, length, cfg.window, dtype)
    if char == "m":
        return ssm.init_ssm_cache(rt, cfg, batch)
    if char == "r":
        return rwkv.init_rwkv_cache(rt, cfg, batch)
    raise ValueError(char)


def _block_cache_specs(rt: Runtime, cfg: ModelConfig, char: str, batch: int,
                       length: int):
    if char == "g":
        if cfg.mla is not None:
            return mla.mla_cache_specs(rt, cfg, batch, length)
        return attn_mod.kv_cache_specs(rt, cfg, batch, length, 0)
    if char in ("l", "a"):
        return attn_mod.kv_cache_specs(rt, cfg, batch, length, cfg.window)
    if char == "m":
        return ssm.ssm_cache_specs(rt, cfg, batch)
    if char == "r":
        return rwkv.rwkv_cache_specs(rt, cfg, batch)
    raise ValueError(char)


def init_cache(cfg: ModelConfig, rt: Runtime, batch: int, length: int,
               dtype=jnp.bfloat16):
    r = cfg.pattern_repeats
    out = {}
    for i, ch in enumerate(cfg.layer_pattern):
        one = _block_cache(rt, cfg, ch, batch, length, dtype)
        out[str(i)] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (r,) + x.shape), one)
    return out


def cache_specs(cfg: ModelConfig, rt: Runtime, batch: int, length: int):
    out = {}
    for i, ch in enumerate(cfg.layer_pattern):
        one = _block_cache_specs(rt, cfg, ch, batch, length)
        out[str(i)] = jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), one,
            is_leaf=lambda s: isinstance(s, P))
    return out


# -----------------------------------------------------------------------------
# Forward.
# -----------------------------------------------------------------------------
def _apply_block(bp, cfg: ModelConfig, rt: Runtime, char: str, x, positions,
                 cache, shared, *, block_skip: bool):
    """One block; returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    if char in ("g", "l", "a"):
        p = shared if char == "a" else bp
        h = common.rmsnorm(p["ln1"], x, cfg.norm_eps)
        window = cfg.window if char in ("l", "a") and cfg.window > 0 else 0
        if cfg.mla is not None and char != "a":
            h, new_c = mla.mla_apply(p["attn"], cfg, rt, h, positions,
                                     cache=cache, block_skip=block_skip)
        else:
            h, new_c = attn_mod.attn_apply(p["attn"], cfg, rt, h, positions,
                                           window=window, cache=cache,
                                           block_skip=block_skip)
        if cfg.post_norms:
            h = common.rmsnorm(p["ln1_post"], h, cfg.norm_eps)
        x = x + h
        h = common.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if char != "a" and cfg.moe is not None:
            h, aux = moe.moe_apply(p["moe"], cfg, rt, h)
        else:
            h = common.mlp_apply(p["mlp"], h)
        if cfg.post_norms:
            h = common.rmsnorm(p["ln2_post"], h, cfg.norm_eps)
        x = x + h
        return x, new_c, aux
    if char == "m":
        h = common.rmsnorm(bp["ln1"], x, cfg.norm_eps)
        h, new_c = ssm.ssm_apply(bp["ssm"], cfg, rt, h, cache=cache)
        return x + h, new_c, aux
    if char == "r":
        # rwkv block applies its own internal residuals on normed streams
        h1 = common.rmsnorm(bp["ln1"], x, cfg.norm_eps)
        st = cache["state"] if cache is not None else None
        tl = cache["tm_last"] if cache is not None else None
        h, new_state, new_tl = rwkv.time_mix(bp["rwkv"]["tm"], cfg, rt, h1,
                                             st, tl)
        x = x + h
        h2 = common.rmsnorm(bp["ln2"], x, cfg.norm_eps)
        cl = cache["cm_last"] if cache is not None else None
        h, new_cl = rwkv.channel_mix(bp["rwkv"]["cm"], cfg, x=h2, last=cl)
        x = x + h
        new_c = None
        if cache is not None:
            new_c = {"state": new_state, "tm_last": new_tl, "cm_last": new_cl}
        return x, new_c, aux
    raise ValueError(char)


def forward(params, cfg: ModelConfig, rt: Runtime, batch: Dict[str, Any],
            cache: Optional[dict] = None, *, block_skip: bool = False):
    """Returns logits (B, S, V) and, if cache given, the updated cache."""
    dt = common.dtype_of(cfg.dtype)
    if cfg.frontend is None:
        tokens = batch["tokens"]
        x = params["embed"]["tok"].astype(dt)[tokens]
    else:
        x = jnp.einsum("bsf,fd->bsd", batch["embeds"].astype(dt),
                       params["frontend"]["proj"].astype(dt))
    if cfg.embed_scale:
        x = x * jnp.asarray(float(cfg.d_model) ** 0.5, dt)
    # Residual-stream sharding: batch over fsdp; with sequence parallelism
    # the sequence dim additionally shards over the model axis between
    # blocks (norms/residuals/saved carries shrink tp×; attention/matmul
    # boundaries gather, emitted by GSPMD).
    _res_spec = ("fsdp", "tp", None) if (rt.sequence_parallel and
                                         x.shape[1] % max(rt.tp_size, 1) == 0) \
        else ("fsdp", None, None)
    x = rt.shard(x, *_res_spec)

    if "positions" in batch:
        positions = batch["positions"]
    else:
        b, s = x.shape[:2]
        if cache is not None and s == 1:
            pos0 = None
            for i in range(len(cfg.layer_pattern)):
                ci = cache[str(i)]
                if isinstance(ci, dict) and "pos" in ci:
                    pos0 = ci["pos"][0]
                    break
            if pos0 is None:
                pos0 = jnp.zeros((), jnp.int32)
            positions = jnp.broadcast_to(pos0[None, None], (b, 1)).astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                         (b, s))

    if cfg.mrope_sections is not None and positions.ndim == 2:
        # text-only default: temporal == h == w position (qwen2-vl semantics
        # for pure-text spans; vision spans pass explicit (3, B, S)).
        positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)

    shared = params.get("shared_attn")
    unit = cfg.layer_pattern
    r = cfg.pattern_repeats

    # Per-block inner checkpoints: the unit scan remat recomputes a whole
    # unit during backward and would otherwise linearise every block at
    # once — at zamba2's 19-block unit that is 19 blocks of SSM internals
    # live simultaneously (26.5 GiB/device measured).  With the inner
    # boundary, peak = one block's internals + the unit's carries.
    _inner_ckpt = cfg.remat != "none" and len(cfg.layer_pattern) > 2

    def _block(bp, ch, xc, c_i):
        return _apply_block(bp, cfg, rt, ch, xc, positions, c_i, shared,
                            block_skip=block_skip)

    def unit_body(carry, xs):
        xc, aux_acc = carry
        bps, caches = xs
        new_caches = {}
        for i, ch in enumerate(unit):
            c_i = caches.get(str(i)) if caches is not None else None
            fn = (jax.checkpoint(functools.partial(_block, ch=ch),
                                 policy=jax.checkpoint_policies.nothing_saveable,
                                 static_argnums=())
                  if _inner_ckpt else functools.partial(_block, ch=ch))
            xc, nc, aux = fn(bps[str(i)], xc=xc, c_i=c_i)
            xc = rt.shard(xc, *_res_spec)
            if nc is not None:
                new_caches[str(i)] = nc
            aux_acc = aux_acc + aux
        return (xc, aux_acc), (new_caches if new_caches else None)

    body = unit_body
    if cfg.remat == "full":
        body = jax.checkpoint(
            unit_body, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            unit_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if cfg.scan_layers and r > 1:
        (x, aux_total), new_cache = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (params["blocks"], cache))
    else:
        aux_total = jnp.float32(0.0)
        new_caches = []
        for j in range(r):
            bps = jax.tree.map(lambda p: p[j], params["blocks"])
            cj = (jax.tree.map(lambda c: c[j], cache)
                  if cache is not None else None)
            (x, aux_total), nc = body((x, aux_total), (bps, cj))
            new_caches.append(nc)
        if cache is not None and new_caches[0] is not None:
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        else:
            new_cache = None

    x = common.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["w"].astype(x.dtype))
    if x.dtype == jnp.bfloat16:
        logits = common.cast_cotangent_bf16(logits)
    logits = rt.shard_spec(logits, rt.spec_div(
        ("fsdp", None, "tp"), (logits.shape[0], logits.shape[1], cfg.vocab)))
    if cache is not None:
        return logits, new_cache, aux_total
    return logits, aux_total


def loss_fn(params, cfg: ModelConfig, rt: Runtime,
            batch: Dict[str, Any]) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(params, cfg, rt, batch)
    if cfg.causal and cfg.frontend is None:
        # next-token prediction: shift within the provided tokens
        loss = common.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                                    cfg.final_softcap)
    else:
        loss = common.cross_entropy(logits, batch["labels"], cfg.final_softcap)
    total = loss + AUX_COEF * aux
    return total, {"ce": loss, "aux": aux}
