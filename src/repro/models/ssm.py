"""Mamba2 (SSD) block for Zamba2 (arXiv:2411.15242 / 2405.21060).

Recurrence per head h (P = head_dim, N = d_state):
    h_t = a_t * h_{t-1} + dt_t * x_t (outer) B_t        h: (P, N)
    y_t = (h_t . C_t) + D * x_t
with a_t = exp(-exp(A_log) * dt_t), dt_t = softplus(dt_raw + dt_bias),
B_t/C_t shared across heads (n_groups = 1), depthwise causal conv (width 4)
over the (x, B, C) channels, and a gated RMSNorm before out-projection.

Two paths, equal semantics (tests compare them):
  * ``ssd_scan``    — exact sequential lax.scan (oracle + decode step);
  * ``ssd_chunked`` — SSD block-decomposition: within-chunk (Q x Q) decay
    matrices (scalar per-head decay keeps this numerically safe: all
    exponents are <= 0) + an inter-chunk state scan.  This is the
    compile-time- and memory-bounded path used for training.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import Runtime
from . import common
from .config import ModelConfig


def ssm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    din = cfg.d_inner_ssm
    nh = cfg.n_ssm_heads
    conv_dim = din + 2 * s.d_state
    ks = jax.random.split(key, 4)
    return {
        "in_proj": common.truncnorm(ks[0], (d, 2 * din + 2 * s.d_state + nh), dtype),
        "conv_w": common.truncnorm(ks[1], (s.conv_width, conv_dim), dtype, scale=0.2),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.linspace(1e-3, 0.1, nh))), dtype),
        "A_log": jnp.asarray(np.log(np.linspace(1.0, 16.0, nh)), dtype),
        "D": jnp.ones((nh,), dtype),
        "norm": common.rmsnorm_init(ks[2], din, dtype),
        "out_proj": common.truncnorm(ks[3], (din, d), dtype,
                                     scale=0.02 / np.sqrt(2 * cfg.n_layers)),
    }


def ssm_specs(rt: Runtime, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    din = cfg.d_inner_ssm
    nh = cfg.n_ssm_heads
    conv_dim = din + 2 * s.d_state
    return {
        "in_proj": rt.spec_div(("fsdp", "tp"), (d, 2 * din + 2 * s.d_state + nh)),
        "conv_w": rt.spec_div((None, "tp"), (s.conv_width, conv_dim)),
        "conv_b": rt.spec_div(("tp",), (conv_dim,)),
        "dt_bias": rt.spec_div(("tp",), (nh,)),
        "A_log": rt.spec_div(("tp",), (nh,)),
        "D": rt.spec_div(("tp",), (nh,)),
        "norm": common.rmsnorm_specs(rt),
        "out_proj": rt.spec_div(("tp", "fsdp"), (din, d)),
    }


def _split_proj(cfg: ModelConfig, proj):
    s = cfg.ssm
    din = cfg.d_inner_ssm
    nh = cfg.n_ssm_heads
    z = proj[..., :din]
    x = proj[..., din:2 * din]
    b = proj[..., 2 * din:2 * din + s.d_state]
    c = proj[..., 2 * din + s.d_state:2 * din + 2 * s.d_state]
    dt = proj[..., 2 * din + 2 * s.d_state:]
    return z, x, b, c, dt


def _causal_conv(u, w, bias, conv_cache=None):
    """Depthwise causal conv, width W: (B, L, C) with (W, C) filters."""
    wdt = u.dtype
    width = w.shape[0]
    if conv_cache is not None:
        u_ext = jnp.concatenate([conv_cache.astype(wdt), u], axis=1)
    else:
        u_ext = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(width):
        sl = u_ext[:, i:i + u.shape[1]]
        out = out + sl * w[i].astype(wdt)
    new_cache = u_ext[:, -(width - 1):] if width > 1 else None
    return jax.nn.silu(out + bias.astype(wdt)), new_cache


def ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int):
    """SSD forward. x: (B, L, H, P); dt: (B, L, H); b, c: (B, L, N)."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    q = chunk
    nc = -(-l // q)
    pad = nc * q - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32
    xq = x.reshape(bsz, nc, q, h, p).astype(f32)
    dtq = dt.reshape(bsz, nc, q, h).astype(f32)
    bq = b.reshape(bsz, nc, q, n).astype(f32)
    cq = c.reshape(bsz, nc, q, n).astype(f32)
    loga = -jnp.exp(a_log.astype(f32))[None, None, None, :] * dtq  # (B,nc,Q,H)
    la = jnp.cumsum(loga, axis=2)                                  # inclusive
    # intra-chunk: G[b,c,h,i,j] = (C_i.B_j) exp(la_i - la_j) dt_j, i >= j
    cb = jnp.einsum("bcin,bcjn->bcij", cq, bq)
    la_h = la.transpose(0, 1, 3, 2)                                 # (B,nc,H,Q)
    mask = jnp.tril(jnp.ones((q, q), bool))
    ldiff = la_h[:, :, :, :, None] - la_h[:, :, :, None, :]          # (B,nc,H,i,j)
    decay = jnp.exp(jnp.where(mask, ldiff, -jnp.inf))
    g = cb[:, :, None] * decay
    g = g * dtq.transpose(0, 1, 3, 2)[:, :, :, None, :]             # dt_j
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", g, xq)
    # chunk states: S_c = sum_j exp(la_end - la_j) dt_j x_j (outer) B_j
    la_end = la[:, :, -1:, :]                                        # (B,nc,1,H)
    w_end = jnp.exp(la_end - la) * dtq                               # (B,nc,Q,H)
    s_c = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", w_end, xq, bq)
    # inter-chunk scan
    decay_chunk = jnp.exp(la_end[:, :, 0, :])                        # (B,nc,H)

    def scan_fn(s_in, inp):
        dchunk, s_new = inp
        s_out = s_in * dchunk[..., None, None] + s_new
        return s_out, s_in

    s0 = jnp.zeros((bsz, h, p, n), f32)
    _, s_ins = jax.lax.scan(
        scan_fn, s0,
        (decay_chunk.transpose(1, 0, 2), s_c.transpose(1, 0, 2, 3, 4)))
    s_ins = s_ins.transpose(1, 0, 2, 3, 4)                           # (B,nc,H,P,N)
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp", jnp.exp(la), cq, s_ins)
    y = (y_intra + y_inter).reshape(bsz, nc * q, h, p)
    if pad:
        y = y[:, :l]
    y = y + x[:, :l].astype(f32) * d_skip.astype(f32)[None, None, :, None]
    return y


def ssd_scan(x, dt, a_log, b, c, d_skip, state=None):
    """Exact sequential recurrence; also the decode step (L == 1)."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    f32 = jnp.float32
    a = jnp.exp(-jnp.exp(a_log.astype(f32))[None, None, :] * dt.astype(f32))

    def step(s, inp):
        xt, at, dtt, bt, ct = inp
        s = s * at[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt, bt)
        yt = jnp.einsum("bhpn,bn->bhp", s, ct)
        return s, yt

    if state is None:
        state = jnp.zeros((bsz, h, p, n), f32)
    xs = (x.transpose(1, 0, 2, 3).astype(f32), a.transpose(1, 0, 2),
          dt.transpose(1, 0, 2).astype(f32), b.transpose(1, 0, 2).astype(f32),
          c.transpose(1, 0, 2).astype(f32))
    state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3)
    y = y + x.astype(f32) * d_skip.astype(f32)[None, None, :, None]
    return y, state


def ssm_apply(params, cfg: ModelConfig, rt: Runtime, x, *,
              cache: Optional[dict] = None) -> Tuple[jnp.ndarray, Optional[dict]]:
    """x: (B, S, D) -> (out, new_cache)."""
    s = cfg.ssm
    bsz, l, d = x.shape
    din = cfg.d_inner_ssm
    nh = cfg.n_ssm_heads
    dt_ = x.dtype
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    z, xi, b, c, dtr = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xi, b, c], axis=-1)
    conv_cache = cache.get("conv") if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], conv_cache)
    xi = conv_out[..., :din].reshape(bsz, l, nh, s.head_dim)
    b = conv_out[..., din:din + s.d_state]
    c = conv_out[..., din + s.d_state:]
    dtv = jax.nn.softplus(dtr.astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))
    xi = rt.shard(xi, "fsdp", None, "tp", None)

    new_cache = None
    if cache is not None and l == 1:
        y, new_state = ssd_scan(xi, dtv, params["A_log"], b, c, params["D"],
                                state=cache["state"])
        new_cache = {"state": new_state, "conv": new_conv}
    elif l <= 2 * s.chunk:
        y, final_state = ssd_scan(xi, dtv, params["A_log"], b, c, params["D"])
        if cache is not None:
            new_cache = {"state": final_state, "conv": new_conv}
    else:
        y = ssd_chunked(xi, dtv, params["A_log"], b, c, params["D"], s.chunk)
        if cache is not None:
            _, final_state = ssd_scan(xi, dtv, params["A_log"], b, c,
                                      params["D"])
            new_cache = {"state": final_state, "conv": new_conv}
    y = y.reshape(bsz, l, din).astype(dt_)
    y = common.rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    return out, new_cache


def init_ssm_cache(rt: Runtime, cfg: ModelConfig, batch: int,
                   dtype=jnp.float32):
    s = cfg.ssm
    din = cfg.d_inner_ssm
    return {
        "state": jnp.zeros((batch, cfg.n_ssm_heads, s.head_dim, s.d_state),
                           jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, din + 2 * s.d_state),
                          dtype),
    }


def ssm_cache_specs(rt: Runtime, cfg: ModelConfig, batch: int):
    s = cfg.ssm
    din = cfg.d_inner_ssm
    return {
        "state": rt.spec_div(("fsdp", "tp", None, None),
                             (batch, cfg.n_ssm_heads, s.head_dim, s.d_state)),
        "conv": rt.spec_div(("fsdp", None, "tp"),
                            (batch, s.conv_width - 1, din + 2 * s.d_state)),
    }
