"""qwen2-vl-7b — VLM decoder backbone with M-RoPE.

[arXiv:2409.12191] 28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064;
M-RoPE splits the 128-d rotary dim into (temporal, h, w) = (16, 24, 24)
sections.  The vision tower is a STUB per the task spec: ``input_specs``
supplies fused patch/text embeddings (1280-d, the ViT hidden size); the
backbone projects and decodes.  Dynamic resolution shows up only as the
sequence length of the supplied embeddings.
"""

from ..models.config import ModelConfig

ARCH = "qwen2-vl-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_head=128,
        d_ff=18944, vocab=152064,
        qkv_bias=True, rope_theta=1e6,
        mrope_sections=(16, 24, 24),
        frontend="vision", frontend_dim=1280,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=160, vocab=512,
        qkv_bias=True, rope_theta=1e6,
        mrope_sections=(4, 6, 6),
        frontend="vision", frontend_dim=32,
        dtype="float32", remat="none",
    )
