"""qwen2.5-32b — dense decoder, GQA 40:8, QKV bias.

[hf:Qwen/Qwen2.5-32B] 64L d_model=5120 40H (kv=8) d_ff=27648 vocab=152064.
"""

from ..models.config import ModelConfig

ARCH = "qwen2.5-32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
        d_ff=27648, vocab=152064,
        qkv_bias=True, rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, d_head=16,
        d_ff=192, vocab=512,
        qkv_bias=True, rope_theta=1e6, dtype="float32", remat="none",
    )
