"""yi-9b — llama-architecture dense decoder, GQA 32:4.

[arXiv:2403.04652] 48L d_model=4096 32H (kv=4) d_ff=11008 vocab=64000.
"""

from ..models.config import ModelConfig

ARCH = "yi-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, d_head=128,
        d_ff=11008, vocab=64000, rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=160, vocab=512, rope_theta=1e4, dtype="float32", remat="none",
    )
