"""gemma2-27b — dense decoder with local/global alternation + softcaps.

[arXiv:2408.00118] 46L d_model=4608 32H (kv=16) d_ff=36864 vocab=256000;
sliding window 4096 on local layers, attn softcap 50, final softcap 30,
post-block RMSNorms, sqrt(d) embedding scaling.  Layer pattern 'lg'
(local, global) × 23.
"""

from ..models.config import ModelConfig

ARCH = "gemma2-27b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_head=128,
        d_ff=36864, vocab=256000,
        layer_pattern="lg", window=4096,
        attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, embed_scale=True, rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=192, vocab=512,
        layer_pattern="lg", window=16,
        attn_softcap=50.0, final_softcap=30.0,
        post_norms=True, embed_scale=True, rope_theta=1e4,
        dtype="float32", remat="none",
    )
