"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay.

[arXiv:2404.05892] 32L d_model=4096 d_ff=14336 vocab=65536; head dim 64
(64 heads), LoRA-factored decay/token-shift mixers.  Recurrent state =>
``long_500k`` runs (O(1) state per layer).
"""

from ..models.config import ModelConfig, RWKVConfig

ARCH = "rwkv6-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="ssm",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_head=64,
        d_ff=14336, vocab=65536,
        layer_pattern="r",
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk=256),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=160, vocab=512,
        layer_pattern="r",
        rwkv=RWKVConfig(head_dim=16, decay_lora=16, chunk=16),
        dtype="float32", remat="none",
    )
