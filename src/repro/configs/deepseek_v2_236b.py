"""deepseek-v2-236b — MoE with Multi-head Latent Attention (MLA).

[arXiv:2405.04434] 60L d_model=5120 128H d_ff_expert=1536 vocab=102400;
160 routed experts top-6 + 2 shared; MLA: kv_lora=512, q_lora=1536,
rope_dim=64, nope_dim=128, v_dim=128 (decode caches the 512-d compressed
latent + 64-d rope key instead of full KV).  Simplification vs HF ckpt:
every layer is MoE (the real model's layer 0 is dense) — DESIGN.md §7.
"""

from ..models.config import MLAConfig, ModelConfig, MoEConfig

ARCH = "deepseek-v2-236b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_head=192,
        d_ff=1536, vocab=102400,
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                      n_shared=2, d_ff_shared=1536),
        mla=MLAConfig(q_lora=1536, kv_lora=512, rope_dim=64,
                      nope_dim=128, v_dim=128),
        rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=24,
        d_ff=64, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64,
                      n_shared=1, d_ff_shared=64),
        mla=MLAConfig(q_lora=32, kv_lora=16, rope_dim=8,
                      nope_dim=16, v_dim=16),
        rope_theta=1e4, dtype="float32", remat="none",
    )
