"""Architecture registry + per-(arch, shape) input specs for the dry-run.

``get_config(arch)`` / ``get_smoke(arch)`` return ModelConfigs;
``input_specs(cfg, shape, rt)`` returns ShapeDtypeStruct stand-ins (weak-
type-correct, shardable, no allocation) for every input of the step the
shape lowers:

  train_4k     -> {"tokens","labels"} (or {"embeds","labels"})
  prefill_32k  -> {"tokens"} (or {"embeds"})
  decode_*     -> ({"tokens"|"embeds"}: one step) + cache SDS tree

The partition specs for the batch come from ``batch_specs``; params/opt/
cache specs come from the model modules.
"""

from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import P, Runtime
from ..models.config import ModelConfig
from .shapes import SHAPES, Shape, applicable, cell_matrix  # noqa: F401

_MODULES = {
    "glm4-9b": "glm4_9b",
    "qwen2.5-32b": "qwen25_32b",
    "gemma2-27b": "gemma2_27b",
    "yi-9b": "yi_9b",
    "zamba2-1.2b": "zamba2_1p2b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "rwkv6-7b": "rwkv6_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "olmoe-1b-7b": "olmoe_1b_7b",
}

ARCHS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).config()


def get_smoke(arch: str) -> ModelConfig:
    return _mod(arch).smoke()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str, rt: Optional[Runtime] = None
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the step inputs of (cfg, shape)."""
    sh = SHAPES[shape]
    b = sh.global_batch
    if sh.kind == "train":
        if cfg.frontend is not None:
            return {"embeds": _sds((b, sh.seq_len, cfg.frontend_dim),
                                   jnp.bfloat16),
                    "labels": _sds((b, sh.seq_len), jnp.int32)}
        return {"tokens": _sds((b, sh.seq_len), jnp.int32),
                "labels": _sds((b, sh.seq_len), jnp.int32)}
    if sh.kind == "prefill":
        if cfg.frontend is not None:
            return {"embeds": _sds((b, sh.seq_len, cfg.frontend_dim),
                                   jnp.bfloat16)}
        return {"tokens": _sds((b, sh.seq_len), jnp.int32)}
    # decode: one new token against a seq_len cache
    if cfg.frontend is not None:
        return {"embeds": _sds((b, 1, cfg.frontend_dim), jnp.bfloat16)}
    return {"tokens": _sds((b, 1), jnp.int32)}


def batch_specs(cfg: ModelConfig, shape: str, rt: Runtime) -> Dict[str, P]:
    """PartitionSpecs matching input_specs (batch over fsdp)."""
    sh = SHAPES[shape]
    b = sh.global_batch
    fs = rt.fsdp if b % max(rt.fsdp_size, 1) == 0 else None
    out: Dict[str, P] = {}
    for k, v in input_specs(cfg, shape).items():
        out[k] = P(*((fs,) + (None,) * (len(v.shape) - 1)))
    return out
