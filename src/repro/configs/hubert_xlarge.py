"""hubert-xlarge — encoder-only audio transformer (w2v2 arch).

[arXiv:2106.07447] 48L d_model=1280 16H (kv=16) d_ff=5120, 504 cluster
targets.  The conv waveform frontend is a STUB per the task spec:
``input_specs`` supplies precomputed frame embeddings (512-d, the conv
extractor's output dim); the backbone projects and encodes them.
Bidirectional (``causal=False``) => no decode shapes.
"""

from ..models.config import ModelConfig

ARCH = "hubert-xlarge"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_head=80,
        d_ff=5120, vocab=504,
        causal=False, frontend="audio", frontend_dim=512, rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=160, vocab=64,
        causal=False, frontend="audio", frontend_dim=32,
        rope_theta=1e4, dtype="float32", remat="none",
    )
