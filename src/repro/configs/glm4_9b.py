"""glm4-9b — dense decoder, extreme GQA (32 q heads : 2 kv heads).

[hf:THUDM/glm-4-9b] 40L d_model=4096 32H (kv=2) d_ff=13696 vocab=151552,
RoPE (partial-rotary in HF; standard rotary here — noted in DESIGN.md),
attention bias on QKV.
"""

from ..models.config import ModelConfig

ARCH = "glm4-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_head=128,
        d_ff=13696, vocab=151552,
        qkv_bias=True, rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=160, vocab=512,
        qkv_bias=True, rope_theta=1e4, dtype="float32", remat="none",
    )
