"""Assigned input shapes and (arch × shape) applicability.

Shapes (task spec):
  train_4k      seq 4096,    global_batch 256   -> train_step
  prefill_32k   seq 32768,   global_batch 32    -> serve prefill
  decode_32k    seq 32768,   global_batch 128   -> serve decode (1 token,
                                                   KV/state cache of seq)
  long_500k     seq 524288,  global_batch 1     -> long-context decode

Skips (recorded in DESIGN.md §Arch-applicability):
  * decode shapes for encoder-only archs (hubert);
  * long_500k for pure/periodic full-attention archs — runnable only for
    the recurrent-state families (zamba2 hybrid, rwkv6).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["Shape", "SHAPES", "applicable", "cell_matrix"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# archs whose sequence mixing is sub-quadratic end to end
_SUBQUADRATIC = {"zamba2-1.2b", "rwkv6-7b"}
_ENCODER_ONLY = {"hubert-xlarge"}


def applicable(arch: str, shape: str) -> Tuple[bool, str]:
    """(runnable?, reason-if-skipped)."""
    sh = SHAPES[shape]
    if arch in _ENCODER_ONLY and sh.kind == "decode":
        return False, "encoder-only: no autoregressive decode step"
    if shape == "long_500k" and arch not in _SUBQUADRATIC:
        return False, "full attention is quadratic at 512k; per task spec " \
                      "long_500k runs only for SSM/hybrid/linear archs"
    return True, ""


def cell_matrix(arch_names) -> Dict[Tuple[str, str], Tuple[bool, str]]:
    return {(a, s): applicable(a, s) for a in arch_names for s in SHAPES}
