"""olmoe-1b-7b — fully-open MoE, 64 experts top-8, no shared experts.

[arXiv:2409.02060] 16L d_model=2048 16H (kv=16) d_ff_expert=1024
vocab=50304.
"""

from ..models.config import ModelConfig, MoEConfig

ARCH = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1024, vocab=50304,
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
        rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=64, vocab=512,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
        rope_theta=1e4, dtype="float32", remat="none",
    )
