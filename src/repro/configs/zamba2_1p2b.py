"""zamba2-1.2b — Mamba2 backbone + *shared* attention block.

[arXiv:2411.15242] 38L d_model=2048, ssm_state=64; the attention+MLP block
(32H kv=32, d_ff=8192) has ONE weight set reused at interleave points
(Zamba2's parameter-sharing trick).  Here: unit = 18 Mamba2 blocks + 1
shared-attention application, ×2 repeats = 38 layers.  The shared block
uses a 4096 sliding window so state stays O(window) — this is what makes
``long_500k`` runnable (recorded in DESIGN.md §Arch-applicability).
"""

from ..models.config import ModelConfig, SSMConfig

ARCH = "zamba2-1.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
        d_ff=8192, vocab=32000,
        layer_pattern="m" * 18 + "a", window=4096,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=256),
        rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH + "-smoke", family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=192, vocab=512,
        layer_pattern="mmma", window=16,
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
        rope_theta=1e4, dtype="float32", remat="none",
    )
