"""The mesh/layout contract: ``Runtime`` + the ``P`` partition-spec alias.

Every model/train/serve/data module programs against *logical* axis names:

* ``"fsdp"`` — the data/ZeRO axes (batch sharding + parameter sharding);
  may span several mesh axes (multi-pod: ``("pod", "data")``).
* ``"tp"``   — the tensor-parallel (model) axis; resolves to nothing when
  TP is disabled or the mesh has no model axis.

``Runtime`` resolves those names to the concrete mesh, applies the
divide-or-replicate rule (an axis entry is dropped when the dimension is
not divisible by the axis size — GSPMD would otherwise pad), and degrades
to single-device no-ops when ``mesh=None`` so the same model code runs
everywhere from a laptop CPU to a multi-pod dry-run.

Layout knobs (all recorded in the frozen dataclass so a Runtime value
fully determines the compiled program):

* ``tp_disabled``      — pure-FSDP relayout: the model axis is folded into
  the data axes (``rt.fsdp_size`` grows, ``rt.tp`` reports ``False``).
* ``sequence_parallel``— shard the residual stream's sequence dim over the
  model axis between blocks.
* ``moe_mode``         — ``"tp"`` (sharded-FFN experts) or ``"ep"``
  (all_to_all expert parallelism — the paper's adversarial pattern).
* ``seq_sharded_decode`` — decode-time KV/latent caches sharded over the
  model axis on the sequence dim (LSE-combined partial attention).
* ``collective_dtype`` — wire dtype for gradient reductions.
"""

from __future__ import annotations

import dataclasses
import functools
import operator
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["P", "Runtime", "host_device_runtime"]

# Logical entry names understood by spec()/spec_div()/shard().
_FSDP = "fsdp"
_TP = "tp"

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
           "float16": jnp.float16}

SpecEntry = Union[None, str]


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Frozen distribution contract: mesh + logical layout knobs."""

    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    tp_disabled: bool = False
    sequence_parallel: bool = False
    moe_mode: str = "tp"                  # tp | ep
    seq_sharded_decode: bool = True
    collective_dtype: str = "bfloat16"

    def __post_init__(self):
        object.__setattr__(self, "data_axes", tuple(self.data_axes))
        if self.mesh is not None:
            names = set(self.mesh.axis_names)
            missing = [a for a in self.data_axes if a not in names]
            if missing:
                raise ValueError(f"data_axes {missing} not in mesh axes "
                                 f"{tuple(self.mesh.axis_names)}")
        if self.moe_mode not in ("tp", "ep"):
            raise ValueError(f"moe_mode must be 'tp' or 'ep', "
                             f"got {self.moe_mode!r}")
        if self.collective_dtype not in _DTYPES:
            raise ValueError(f"collective_dtype must be one of "
                             f"{sorted(_DTYPES)}, got "
                             f"{self.collective_dtype!r}")

    # ---- axis resolution -----------------------------------------------------
    @functools.cached_property
    def _mesh_sizes(self) -> dict:
        return dict(self.mesh.shape) if self.mesh is not None else {}

    @functools.cached_property
    def fsdp_axes(self) -> Tuple[str, ...]:
        """The mesh axes acting as data/ZeRO axes.  With ``tp_disabled``
        the model axis is folded in (pure-FSDP relayout on the same
        physical mesh), whether or not the caller listed it."""
        axes = self.data_axes
        if (self.tp_disabled and self.model_axis in self._mesh_sizes
                and self.model_axis not in axes):
            axes = axes + (self.model_axis,)
        return axes

    @functools.cached_property
    def fsdp_size(self) -> int:
        if self.mesh is None:
            return 1
        return functools.reduce(
            operator.mul, (self._mesh_sizes[a] for a in self.fsdp_axes), 1)

    @functools.cached_property
    def tp_size(self) -> int:
        if (self.mesh is None or self.tp_disabled
                or self.model_axis in self.fsdp_axes):
            return 1
        return int(self._mesh_sizes.get(self.model_axis, 1))

    @property
    def fsdp(self):
        """Spec entry for the data axes: axis name, tuple of names, or
        None on a single device — usable directly inside ``P(...)``."""
        if self.mesh is None:
            return None
        axes = self.fsdp_axes
        return axes if len(axes) > 1 else axes[0]

    @property
    def tp(self):
        """Spec entry for the model axis when TP is active; reports
        ``False`` otherwise (never place the disabled value in a P — the
        resolvers below map ``"tp"`` to None for you)."""
        return self.model_axis if self.tp_size > 1 else False

    def _resolve(self, entry: SpecEntry):
        if entry is None:
            return None
        if entry == _FSDP:
            return self.fsdp
        if entry == _TP:
            return self.tp or None
        # raw mesh-axis name: pass through if it exists, else replicate
        return entry if entry in self._mesh_sizes else None

    def _entry_size(self, entry: SpecEntry) -> int:
        if entry is None:
            return 1
        if entry == _FSDP:
            return self.fsdp_size
        if entry == _TP:
            return self.tp_size
        return int(self._mesh_sizes.get(entry, 1))

    # ---- spec builders -------------------------------------------------------
    def spec(self, *entries: SpecEntry) -> P:
        """PartitionSpec from logical entries (no divisibility check)."""
        return P(*(self._resolve(e) for e in entries))

    def spec_div(self, entries: Sequence[SpecEntry],
                 shape: Sequence[int]) -> P:
        """PartitionSpec with the divide-or-replicate rule: an entry is
        kept only when the matching dimension is divisible by its axis
        size (and the axis is real, i.e. size > 1)."""
        if len(entries) != len(shape):
            raise ValueError(f"entries {entries!r} vs shape {shape!r}")
        out = []
        for e, d in zip(entries, shape):
            size = self._entry_size(e)
            out.append(self._resolve(e)
                       if size > 1 and int(d) % size == 0 else None)
        return P(*out)

    # ---- array placement -----------------------------------------------------
    def shard(self, x, *entries: SpecEntry):
        """Sharding constraint by logical entries (divide-or-replicate);
        identity on a single device."""
        if self.mesh is None:
            return x
        return self.shard_spec(x, self.spec_div(entries, x.shape))

    def shard_spec(self, x, spec: P):
        """Sharding constraint with an explicit PartitionSpec; identity on
        a single device."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def tree_sharding(self, spec_tree):
        """PartitionSpec tree -> NamedSharding tree (None without a mesh,
        which ``jax.jit``'s in_shardings accepts as "let XLA choose")."""
        if self.mesh is None:
            return None
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda s: isinstance(s, P))

    def shard_map(self, f, *, in_specs, out_specs, check_vma: bool = False):
        """``jax.shard_map`` over this runtime's mesh; identity wrapper on
        a single device (the body then sees the global arrays)."""
        if self.mesh is None:
            return f
        return jax.shard_map(f, mesh=self.mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

    # ---- misc ----------------------------------------------------------------
    def astype(self, x):
        """Cast to the collective wire dtype (``collective_dtype``)."""
        return x.astype(_DTYPES[self.collective_dtype])


def host_device_runtime(devices: Optional[int] = None,
                        axis: str = "data") -> Runtime:
    """A :class:`Runtime` over a 1-D mesh of ``devices`` local devices —
    the entry point for CPU-hosted data parallelism under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

    * ``devices`` ``None`` -> use every visible device;
    * ``devices <= 1``     -> ``Runtime(mesh=None)`` (single-device
      no-op degradation, same program, no shard_map);
    * asking for more devices than jax can see raises with the exact
      ``XLA_FLAGS`` incantation — the flag must be set *before* the
      first jax import of the process, it cannot be retrofitted (the
      experiments CLI sets it for you when run with ``--devices N``).
    """
    avail = jax.device_count()
    n = avail if devices is None else int(devices)
    if n <= 1:
        return Runtime(mesh=None, data_axes=(axis,))
    if n > avail:
        raise RuntimeError(
            f"asked for {n} devices but jax sees {avail}.  Forced host "
            f"devices must be configured before jax initializes: set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"(and JAX_PLATFORMS=cpu) in the environment, or launch via "
            f"`python -m repro.experiments sweep --devices {n}` which "
            f"sets both before importing jax.")
    mesh = Mesh(np.asarray(jax.devices()[:n]), (axis,))
    return Runtime(mesh=mesh, data_axes=(axis,))
