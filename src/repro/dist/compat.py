"""Small forward-compat shims over the installed jax.

The repo's code and tests use the modern spellings ``jax.shard_map(f,
mesh=..., in_specs=..., out_specs=..., check_vma=...)`` and
``jax.lax.axis_size(name)``.  On older jax (e.g. 0.4.x) ``shard_map``
lives in ``jax.experimental.shard_map`` with a ``check_rep`` keyword and
``axis_size`` does not exist.  ``install()`` patches the missing names
onto the jax namespace; it is idempotent and never overrides a native
implementation.
"""

from __future__ import annotations

import os

import jax

__all__ = ["install"]


def _axis_size(axis_name) -> int:
    # psum of a literal constant-folds to the (static) named-axis size and
    # accepts a tuple of names (returns the product).
    return jax.lax.psum(1, axis_name)


def install() -> None:
    # Forcing host-platform devices is a CPU-only debugging mode (the
    # multi-device tests and the 512-device dry-run).  Pin the platform
    # accordingly when the caller has not chosen one: otherwise a machine
    # with libtpu installed but no TPU attached burns minutes probing the
    # TPU backend before falling back to CPU.  jax snapshots JAX_PLATFORMS
    # at import, so update the live config too (no-op if the backend is
    # already initialized — then the choice was made before us anyway).
    if ("--xla_force_host_platform_device_count"
            in os.environ.get("XLA_FLAGS", "")
            and not os.environ.get("JAX_PLATFORMS")):
        os.environ["JAX_PLATFORMS"] = "cpu"
        try:
            if not jax.config.jax_platforms:
                jax.config.update("jax_platforms", "cpu")
        except Exception:
            pass

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      check_rep=None, **kw):
            if check_rep is None:
                # check_vma is the modern name for check_rep; default both
                # off — the replication checker predates several collectives
                # used here (layered ppermute chains, fixed-capacity a2a).
                check_rep = bool(check_vma) if check_vma is not None else False
            return _shard_map(f, mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = _axis_size
