"""repro.dist — the distribution API fusing the FatPaths core with the
training stack.

Three modules, three layers of the same idea (spread one logical flow over
many near-disjoint physical paths):

* :mod:`repro.dist.sharding`    — ``Runtime``: the frozen mesh/layout
  contract every model/train/serve/data module programs against, plus the
  ``P`` partition-spec alias.  Degrades to single-device no-ops when
  ``mesh=None``.
* :mod:`repro.dist.collectives` — FatPaths-layered collective schedules as
  ``shard_map``/``ppermute`` programs: coprime-stride multi-ring
  all-reduce / reduce-scatter / all-gather (one collective-permute chain
  per ring == one routing layer per flowlet class).
* :mod:`repro.dist.fabric`      — ``ClusterFabric``: maps collective
  traffic onto :mod:`repro.core` topologies under minimal-path ECMP vs
  FatPaths layered routing and reports bottleneck bytes / time / link-load
  spread, so mesh placement and the roofline can quantify the paper's
  claim on this system's own traffic.

Importing any submodule installs the small jax compatibility shims in
:mod:`repro.dist.compat` (``jax.shard_map`` / ``jax.lax.axis_size`` on
older jax), so test programs and callers can use the modern spellings.
"""

from . import compat  # noqa: F401  (installs jax shims on import)

compat.install()

from . import collectives, fabric, sharding  # noqa: E402,F401
from .collectives import (layer_strides, multiring_all_reduce,  # noqa: E402,F401
                          ring_all_gather, ring_reduce_scatter)
from .fabric import ClusterFabric, CollectiveReport, collective_flows  # noqa: E402,F401
from .sharding import P, Runtime, host_device_runtime  # noqa: E402,F401

__all__ = [
    "P",
    "Runtime",
    "host_device_runtime",
    "layer_strides",
    "multiring_all_reduce",
    "ring_reduce_scatter",
    "ring_all_gather",
    "ClusterFabric",
    "CollectiveReport",
    "collective_flows",
]
