"""Cluster-fabric model: collective traffic -> link loads under ECMP vs
FatPaths routing (paper §8 applied to this system's own collectives).

``ClusterFabric`` routes the flow set of a collective over a
:class:`repro.core.topology.Topology`:

* ``scheme="ecmp"``    — congestion-oblivious hashing: every flow splits
  equally over ``n_tables`` independently tie-broken *minimal-path*
  forwarding tables (:func:`repro.core.transport.ecmp_routing`).  Where
  minimal-path diversity is 1 (most pairs of a diameter-2 Slim Fly) the
  tables coincide and the split degenerates — the paper's collision
  pathology.
* ``scheme="fatpaths"``— congestion-aware flowlets over the FatPaths
  layer stack (:func:`repro.core.layers.build_layers`): candidate paths
  are the realised routes of every usable layer (minimal + non-minimal),
  and per-flow weights iterate toward the min-max link load — the steady
  state of flowlet re-routing away from hot links.

Endpoint NICs are modelled as injection/ejection links (scheme
independent), so incast patterns (all-to-one) bottleneck on the NIC for
both schemes exactly as on a real cluster.

The result, :class:`CollectiveReport`, carries ``bottleneck_bytes`` (max
bytes over any link), ``time_s`` (bottleneck / line rate), ``util_gini``
(spread of fabric-link loads) and ``n_links_used`` — consumed by the
roofline (``launch/roofline.py``), mesh placement (``launch/mesh.py``
device ordering) and ``benchmarks/bench_fabric``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import paths as paths_mod
from ..core.layers import LayeredRouting, build_layers
from ..core.topology import Topology
from ..core.traffic import endpoint_router_map
from ..core.transport import ecmp_routing

__all__ = ["ClusterFabric", "CollectiveReport", "collective_flows"]

Flow = Tuple[int, int, float]            # (src endpoint, dst endpoint, bytes)


# -----------------------------------------------------------------------------
# Collective -> endpoint flow sets.
# -----------------------------------------------------------------------------
def collective_flows(kind: str, n: int, nbytes: float,
                     strides: Sequence[int] = (1,)) -> List[Flow]:
    """Endpoint-level flows of one collective over ranks 0..n-1.

    ``nbytes`` is the per-rank payload.  Ring collectives follow the
    standard schedule volumes — all-reduce moves ``2 b (n-1)/n`` per ring
    link, all-gather/reduce-scatter half that — split over the given
    stride rings (``strides``), mirroring
    :func:`repro.dist.collectives.multiring_all_reduce`.
    """
    kind = kind.replace("-start", "")
    r = max(1, len(strides))
    flows: List[Flow] = []
    if kind in ("all-reduce", "all-gather", "reduce-scatter",
                "collective-permute"):
        if kind == "all-reduce":
            per_link = 2.0 * nbytes * (n - 1) / max(n, 1) / r
        elif kind == "collective-permute":
            per_link = float(nbytes) / r
        else:
            per_link = nbytes * (n - 1) / max(n, 1) / r
        for s in strides:
            for i in range(n):
                j = (i + s) % n
                if i != j:
                    flows.append((i, j, per_link))
        return flows
    if kind == "all-to-all":
        b = nbytes / max(n, 1)
        return [(i, j, b) for i in range(n) for j in range(n) if i != j]
    if kind == "all-to-one":
        return [(i, 0, float(nbytes)) for i in range(1, n)]
    raise ValueError(f"unknown collective kind {kind!r}")


# -----------------------------------------------------------------------------
# Report.
# -----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CollectiveReport:
    """Link-load summary of one collective on one fabric."""

    kind: str
    scheme: str
    n_ranks: int
    payload_bytes: float
    bottleneck_bytes: float    # max bytes over any (fabric or NIC) link
    time_s: float              # bottleneck / line rate
    util_gini: float           # Gini coefficient of fabric-link loads
    n_links_used: int          # directed fabric links carrying traffic
    fabric_bytes: float        # total bytes over fabric links

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _gini(loads: np.ndarray) -> float:
    total = float(loads.sum())
    if total <= 0 or len(loads) == 0:
        return 0.0
    x = np.sort(loads.astype(np.float64))
    n = len(x)
    cum = np.arange(1, n + 1) @ x
    return float(2.0 * cum / (n * total) - (n + 1) / n)


# -----------------------------------------------------------------------------
# Fabric.
# -----------------------------------------------------------------------------
class ClusterFabric:
    """A modelled cluster: topology + FatPaths layers + ECMP tables."""

    def __init__(self, topo: Topology, n_layers: int = 9, rho: float = 0.6,
                 seed: int = 0, layer_scheme: str = "rand",
                 n_tables: int = 8, line_rate: float = 12.5e9,
                 flowlet_quanta: int = 32,
                 layers: Optional["LayeredRouting"] = None,
                 ecmp: Optional["LayeredRouting"] = None):
        """``layers``/``ecmp`` accept prebuilt stacks (matching the other
        parameters) so a :class:`repro.experiments.Session` can share one
        stack between transport cells and the fabric model instead of
        rebuilding it here."""
        self.topo = topo
        self.n_layers = n_layers
        self.rho = rho
        self.seed = seed
        self.line_rate = line_rate
        self.flowlet_quanta = flowlet_quanta
        self.layers = layers if layers is not None else build_layers(
            topo, n_layers, rho, scheme=layer_scheme, seed=seed)
        self.ecmp = ecmp if ecmp is not None else ecmp_routing(
            topo, n_tables=n_tables, seed=seed)
        self.ep2r = endpoint_router_map(topo)
        self._eix = topo.edge_index_matrix()
        self._n_edges = int(topo.adj.sum())
        reachable = self.layers.pathlen[self.layers.pathlen < 9000]
        self._max_hops = (int(reachable.max()) if reachable.size else 8) + 2
        self._path_cache: Dict[Tuple[str, int, int], List[np.ndarray]] = {}

    # ---- path candidates -----------------------------------------------------
    def _routing(self, scheme: str):
        if scheme == "fatpaths":
            return self.layers
        if scheme == "ecmp":
            return self.ecmp
        raise ValueError(f"unknown scheme {scheme!r} "
                         "(expected 'ecmp' or 'fatpaths')")

    def _pair_paths(self, scheme: str, s: int, t: int) -> List[np.ndarray]:
        """Per-layer/table edge-id paths for router pair (s, t).

        ECMP keeps duplicates (identical tables => the hash split
        concentrates); FatPaths deduplicates (the flowlet balancer sees a
        path, not a table id).
        """
        key = (scheme, s, t)
        hit = self._path_cache.get(key)
        if hit is not None:
            return hit
        routing = self._routing(scheme)
        out: List[np.ndarray] = []
        seen = set()
        for i in range(routing.n_layers):
            if not routing.reach[i, s, t]:
                continue
            seq = paths_mod.walk_paths(routing.nh[i], np.array([s]),
                                       np.array([t]), self._max_hops)[0]
            edges = []
            ok = True
            for a, b in zip(seq[:-1], seq[1:]):
                if a == t:
                    break
                if b < 0:
                    ok = False
                    break
                e = int(self._eix[a, b])
                if e < 0:
                    ok = False
                    break
                edges.append(e)
            # walk_paths repeats t once reached, so a successful walk ends
            # on t; anything else ran out of hops or hit a table hole
            if not ok or int(seq[-1]) != t:
                continue
            path = np.asarray(edges, dtype=np.int64)
            if scheme == "fatpaths":
                k = tuple(edges)
                if k in seen:
                    continue
                seen.add(k)
            out.append(path)
        if not out:
            out = [np.zeros((0,), dtype=np.int64)]
        self._path_cache[key] = out
        return out

    # ---- load assignment -----------------------------------------------------
    def _fabric_loads(self, scheme: str,
                      demands: Dict[Tuple[int, int], float]) -> np.ndarray:
        """Bytes per directed fabric edge for aggregated router demands."""
        load = np.zeros(self._n_edges, dtype=np.float64)
        pairs = [(st, b, self._pair_paths(scheme, *st))
                 for st, b in demands.items()]
        if scheme == "ecmp":
            for _, b, plist in pairs:
                w = b / len(plist)
                for p in plist:
                    np.add.at(load, p, w)
            return load
        # fatpaths: congestion-aware flowlets.  Each demand is chopped into
        # flowlet quanta; every quantum takes the candidate path (any
        # usable layer's route) with the smallest current bottleneck, ties
        # broken toward shorter paths.  Round-robin over demands so flows
        # adapt to each other — a deterministic fixed point of the
        # re-route-away-from-hot-links dynamics of §3.2.
        quanta = max(1, self.flowlet_quanta)
        for q in range(quanta):
            for _, b, plist in pairs:
                quantum = b / quanta
                best, best_cost = None, None
                for p in plist:
                    cost = (float(load[p].max()) if len(p) else 0.0, len(p))
                    if best is None or cost < best_cost:
                        best, best_cost = p, cost
                np.add.at(load, best, quantum)
        return load

    # ---- public API ----------------------------------------------------------
    def evaluate_flows(self, flows: Sequence[Flow], scheme: str = "fatpaths",
                       kind: str = "custom", n_ranks: int = 0,
                       payload_bytes: float = 0.0) -> CollectiveReport:
        """Route an explicit endpoint flow set and report link loads."""
        n_ep = self.topo.n_endpoints
        inj = np.zeros(n_ep, dtype=np.float64)
        ej = np.zeros(n_ep, dtype=np.float64)
        demands: Dict[Tuple[int, int], float] = {}
        for src, dst, b in flows:
            se, de = src % n_ep, dst % n_ep
            inj[se] += b
            ej[de] += b
            sr, tr = int(self.ep2r[se]), int(self.ep2r[de])
            if sr != tr:
                demands[(sr, tr)] = demands.get((sr, tr), 0.0) + b
        load = self._fabric_loads(scheme, demands) if demands else \
            np.zeros(self._n_edges)
        bottleneck = float(max(load.max() if len(load) else 0.0,
                               inj.max() if len(inj) else 0.0,
                               ej.max() if len(ej) else 0.0))
        return CollectiveReport(
            kind=kind, scheme=scheme, n_ranks=n_ranks,
            payload_bytes=payload_bytes,
            bottleneck_bytes=bottleneck,
            time_s=bottleneck / self.line_rate,
            util_gini=_gini(load),
            n_links_used=int((load > 1e-9).sum()),
            fabric_bytes=float(load.sum()),
        )

    def collective_time(self, kind: str, n: int, nbytes: float,
                        scheme: str = "fatpaths",
                        strides: Optional[Sequence[int]] = None
                        ) -> CollectiveReport:
        """Model one collective of ``n`` ranks x ``nbytes`` payload under
        the given routing scheme; ranks map to endpoints 0..n-1."""
        n = min(int(n), self.topo.n_endpoints)
        flows = collective_flows(kind, n, nbytes,
                                 strides if strides is not None else (1,))
        return self.evaluate_flows(flows, scheme=scheme,
                                   kind=kind.replace("-start", ""),
                                   n_ranks=n, payload_bytes=float(nbytes))
