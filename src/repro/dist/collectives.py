"""FatPaths-layered collective schedules as ppermute programs.

The paper spreads one logical flow over several near-disjoint routing
layers; the collective analogue runs one ring all-reduce per *stride
ring*: ring ``r`` visits the devices in order ``0, s_r, 2 s_r, ...``
(mod n), which on a fabric with FatPaths layers maps each ring onto a
different set of links.  Each ring moves ``1/R`` of the payload through
the classic reduce-scatter + all-gather schedule, so the total wire bytes
match a single ring exactly while the per-link load spreads R ways
(quantified against modelled fabrics in :mod:`repro.dist.fabric` and
``benchmarks/bench_fabric``).

All functions run inside ``shard_map`` over a named axis (or axis tuple).
Strides must be coprime with the axis size for a ring to visit every
device — :func:`layer_strides` generates such strides.
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "layer_strides",
    "ring_reduce_scatter",
    "ring_all_gather",
    "multiring_all_reduce",
]


def layer_strides(n: int, k: int) -> Tuple[int, ...]:
    """The first ``k`` positive ring strides coprime with ``n``.

    ``layer_strides(16, 3) == (1, 3, 5)``.  Every returned stride
    generates a Hamiltonian ring on n devices (gcd(s, n) == 1) — the
    software twin of the paper's routing layers.  The first
    ``phi(n)`` rings traverse distinct neighbour patterns; only when
    ``k`` exceeds the number of coprime residues (pigeonhole) do rings
    repeat a pattern mod n, and the payload still splits k ways.
    """
    if n <= 1:
        return (1,) * k
    out = []
    s = 1
    while len(out) < k:
        if math.gcd(s, n) == 1:
            out.append(s)
        s += 1
    return tuple(out)


def _check_stride(stride: int, n: int) -> None:
    """A non-coprime stride decomposes the ring into gcd(s, n) disjoint
    cycles and would silently drop contributions — fail at trace time."""
    if math.gcd(stride, n) != 1:
        raise ValueError(f"ring stride {stride} is not coprime with axis "
                         f"size {n} (use layer_strides)")


def _chunk(buf, idx):
    """buf: (n, m); idx: traced chunk index -> (m,)."""
    return jax.lax.dynamic_index_in_dim(buf, idx, axis=0, keepdims=False)


def ring_reduce_scatter(x, axis, stride: int):
    """Ring reduce-scatter over ``axis`` with the given stride.

    Flattens ``x`` (padding with zeros to a multiple of n) and runs the
    classic n-1-step ring schedule along the ring ``i -> (i + stride) %
    n``.  Returns the fully reduced chunk owned by this device: chunk
    index ``(i + stride) % n`` of the flattened payload — pass
    ``chunk_offset=stride`` to :func:`ring_all_gather` to reassemble.
    """
    n = jax.lax.axis_size(axis)
    flat = x.reshape(-1)
    if n == 1:
        return flat
    _check_stride(stride, n)
    m0 = flat.shape[0]
    m = -(-m0 // n) * n
    if m != m0:
        flat = jnp.concatenate([flat, jnp.zeros((m - m0,), flat.dtype)])
    chunks = flat.reshape(n, m // n)
    i = _ring_index(axis)
    perm = [(j, (j + stride) % n) for j in range(n)]
    # step k: send the running chunk (i - k*s) to the ring successor,
    # receive chunk (i - (k+1)*s) and fold in the local copy.
    cur = _chunk(chunks, i)
    for k in range(1, n):
        recv = jax.lax.ppermute(cur, axis, perm)
        cur = _chunk(chunks, (i - k * stride) % n) + recv
    return cur


def ring_all_gather(x, axis, stride: int, chunk_offset: int = 0):
    """Ring all-gather over ``axis`` with the given stride.

    ``x`` is this device's chunk; device ``i`` is assumed to hold chunk
    index ``(i + chunk_offset) % n``.  Returns the flat concatenation of
    all n chunks in chunk-index order (identical on every device), via
    n-1 ppermute steps along the same ring as the reduce-scatter.
    """
    n = jax.lax.axis_size(axis)
    chunk = x.reshape(-1)
    if n == 1:
        return chunk
    _check_stride(stride, n)
    m = chunk.shape[0]
    i = _ring_index(axis)
    perm = [(j, (j + stride) % n) for j in range(n)]
    out = jnp.zeros((n, m), chunk.dtype)
    cur = chunk
    out = jax.lax.dynamic_update_slice_in_dim(
        out, cur[None], (i + chunk_offset) % n, axis=0)
    for k in range(1, n):
        cur = jax.lax.ppermute(cur, axis, perm)
        # the chunk arriving at step k originated k ring-hops upstream
        out = jax.lax.dynamic_update_slice_in_dim(
            out, cur[None], (i - k * stride + chunk_offset) % n, axis=0)
    return out.reshape(-1)


def multiring_all_reduce(x, axis, strides: Sequence[int]):
    """All-reduce (sum) via R independent stride rings — numerically equal
    to ``psum(x, axis)``; emits one collective-permute chain of 2(n-1)
    steps per ring.

    The payload is split R ways; ring r reduce-scatters + all-gathers its
    slice along the ring ``i -> (i + strides[r]) % n``.  Works for any
    dtype with well-defined addition (f32/bf16 gradients, int32 payloads
    of the int8 error-feedback wire).
    """
    strides = tuple(strides)
    if not strides:
        raise ValueError("need at least one stride")
    n = jax.lax.axis_size(axis)
    if n == 1:
        return x
    r = len(strides)
    flat = x.reshape(-1)
    m0 = flat.shape[0]
    per = -(-m0 // (n * r)) * n          # per-ring slice, divisible by n
    if per * r != m0:
        flat = jnp.concatenate(
            [flat, jnp.zeros((per * r - m0,), flat.dtype)])
    # interleave the payload across rings (element e rides ring e % r): all
    # rings carry real data even when padding was needed, so the per-ring
    # link load stays balanced and no ring degenerates to a constant that
    # XLA would fold away.
    parts = flat.reshape(per, r)
    outs = []
    for ri, s in enumerate(strides):
        reduced = ring_reduce_scatter(parts[:, ri], axis, s)
        outs.append(ring_all_gather(reduced, axis, s, chunk_offset=s))
    return jnp.stack(outs, axis=1).reshape(-1)[:m0].reshape(x.shape)


def _ring_index(axis):
    """Linear device index along ``axis`` (row-major over an axis tuple)."""
    if isinstance(axis, (tuple, list)):
        idx = jnp.int32(0)
        for a in axis:
            idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        return idx
    return jax.lax.axis_index(axis)
