"""Spec layer: frozen experiment specs + the string mini-spec grammar.

A *mini-spec* is ``name`` or ``name(k=v,k=v,...)`` — e.g. ``"sf(q=19)"``,
``"fatpaths(n_layers=9,rho=0.6)"``, ``"ecmp(n=8)"``, ``"adversarial"``.
Values are parsed as int, float, bool (``true``/``false``) or bare
string; nested specs are allowed as values (``"jfeq(of=sf(q=5))"``).
:meth:`Spec.format` is the canonical form (keys sorted), and
``Spec.parse(spec.format()) == spec`` always holds.

An :class:`ExperimentSpec` names one cell of the evaluation matrix:
topology x routing scheme x traffic pattern x evaluator (+ seed).  It is
frozen and hashable, so it doubles as a cache / result key.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, List, Tuple, Union

__all__ = ["Spec", "ExperimentSpec", "SpecError", "split_spec_list"]

SpecLike = Union[str, "Spec"]

_NAME_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")


class SpecError(ValueError):
    """Malformed mini-spec string or unknown registry name/parameter."""


def _split_top_level(text: str) -> List[str]:
    """Split on commas not nested inside parentheses."""
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in text:
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
            continue
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                raise SpecError(f"unbalanced ')' in {text!r}")
        cur.append(ch)
    if depth != 0:
        raise SpecError(f"unbalanced '(' in {text!r}")
    parts.append("".join(cur))
    return parts


def split_spec_list(text: str) -> List[str]:
    """Split a comma-separated list of mini-specs, respecting parentheses
    (``"ecmp(n=4),fatpaths(n_layers=9,rho=0.6)"`` -> two items)."""
    return [p.strip() for p in _split_top_level(text) if p.strip()]


def _parse_value(text: str) -> Any:
    s = text.strip()
    if not s:
        raise SpecError("empty value")
    low = s.lower()
    if low == "true":
        return True
    if low == "false":
        return False
    if low == "none":
        return None
    try:
        return int(s)
    except ValueError:
        pass
    try:
        return float(s)
    except ValueError:
        pass
    return s


def _format_value(v: Any) -> str:
    if isinstance(v, Spec):
        return v.format()
    if v is None:
        return "none"
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, float):
        return repr(v)
    return str(v)


@dataclasses.dataclass(frozen=True)
class Spec:
    """One parsed mini-spec: a registry name + keyword overrides.

    ``kwargs`` is a tuple of (key, value) pairs, kept sorted by key so
    that equal specs compare (and hash) equal regardless of the order
    they were written in.
    """

    name: str
    kwargs: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        if not _NAME_RE.match(self.name):
            raise SpecError(f"invalid spec name {self.name!r}")
        object.__setattr__(
            self, "kwargs",
            tuple(sorted(tuple(self.kwargs), key=lambda kv: kv[0])))

    @property
    def kw(self) -> dict:
        return dict(self.kwargs)

    def format(self) -> str:
        """Canonical string form; ``Spec.parse`` round-trips it."""
        if not self.kwargs:
            return self.name
        inner = ",".join(f"{k}={_format_value(v)}" for k, v in self.kwargs)
        return f"{self.name}({inner})"

    def __str__(self) -> str:
        return self.format()

    @classmethod
    def parse(cls, text: str) -> "Spec":
        s = text.strip()
        if "(" not in s:
            if s.endswith(")"):
                raise SpecError(f"unbalanced ')' in {text!r}")
            return cls(name=s)
        if not s.endswith(")"):
            raise SpecError(f"missing closing ')' in {text!r}")
        name, inner = s[:-1].split("(", 1)
        items: List[Tuple[str, Any]] = []
        seen = set()
        if inner.strip():
            for part in _split_top_level(inner):
                if "=" not in part:
                    raise SpecError(
                        f"expected k=v in {text!r}, got {part.strip()!r}")
                k, v = part.split("=", 1)
                k = k.strip()
                if not _NAME_RE.match(k):
                    raise SpecError(f"invalid parameter name {k!r} in {text!r}")
                if k in seen:
                    raise SpecError(f"duplicate parameter {k!r} in {text!r}")
                seen.add(k)
                items.append((k, _parse_value(v)))
        return cls(name=name.strip(), kwargs=tuple(items))

    @classmethod
    def coerce(cls, obj: SpecLike) -> "Spec":
        if isinstance(obj, Spec):
            return obj
        if isinstance(obj, str):
            return cls.parse(obj)
        raise SpecError(f"cannot coerce {type(obj).__name__} to Spec")


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One cell of the evaluation matrix, fully declarative."""

    topo: Spec
    routing: Spec
    pattern: Spec
    evaluator: Spec = Spec("transport")
    seed: int = 0

    @classmethod
    def make(cls, topo: SpecLike, routing: SpecLike, pattern: SpecLike,
             evaluator: SpecLike = "transport", seed: int = 0
             ) -> "ExperimentSpec":
        return cls(topo=Spec.coerce(topo), routing=Spec.coerce(routing),
                   pattern=Spec.coerce(pattern),
                   evaluator=Spec.coerce(evaluator), seed=int(seed))

    @property
    def cell_id(self) -> str:
        return (f"{self.topo.format()}/{self.routing.format()}/"
                f"{self.pattern.format()}/{self.evaluator.format()}"
                f"@s{self.seed}")
