"""repro.experiments — the declarative experiment API.

The only sanctioned way to express an evaluation cell: a topology spec x
a routing-scheme spec x a traffic-pattern spec x an evaluator spec,
executed through a memoizing :class:`Session`:

    from repro.experiments import Session
    s = Session()
    rr = s.run("sf(q=5)", "fatpaths(n_layers=9,rho=0.6)", "adversarial",
               "transport(steps=1200)")
    print(rr.metrics["fct_p99_us"])

Grids go through :meth:`Session.sweep` (``devices=N`` engages the
distributed batch engine, ``checkpoint_dir`` makes them resumable) or
the CLI::

    python -m repro.experiments sweep --topos sf,df,ft \\
        --schemes ecmp,letflow,fatpaths --patterns adversarial,shuffle \\
        --devices 8 --checkpoint /tmp/sweep.ckpt

* :mod:`repro.experiments.specs`      — mini-spec grammar + ExperimentSpec.
* :mod:`repro.experiments.registry`   — decorator registries.
* :mod:`repro.experiments.catalog`    — the registered axes.
* :mod:`repro.experiments.session`    — artifact memoization + grid runner.
* :mod:`repro.experiments.dist_sweep` — bucketed/padded/sharded batch engine.
* :mod:`repro.experiments.results`    — canonical RunResult JSON records.

Exports resolve lazily (PEP 562): ``python -m repro.experiments`` must
be able to parse ``--devices N`` and set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` BEFORE anything
imports jax — an eager ``from .catalog import ...`` here would
initialize the jax backend with the wrong device count.
"""

import importlib

_EXPORTS = {
    # specs (jax-free)
    "ExperimentSpec": ".specs", "Spec": ".specs", "SpecError": ".specs",
    "split_spec_list": ".specs",
    # results (jax-free)
    "RunResult": ".results", "results_to_json": ".results",
    "results_from_json": ".results", "summary_table": ".results",
    "order_results": ".results", "compare_results": ".results",
    "EXECUTION_META_KEYS": ".results",
    # catalog / session / engine (import jax)
    "EVALUATORS": ".catalog", "ROUTINGS": ".catalog",
    "TOPOLOGIES": ".catalog", "TRAFFIC": ".catalog",
    "RoutingBundle": ".catalog", "topo_spec": ".catalog",
    "Session": ".session", "ResolvedCell": ".session",
}

# NOT in _EXPORTS: the dist_sweep FUNCTION.  `repro.experiments.
# dist_sweep` must always name the submodule — exporting the function
# under the same name would make the attribute depend on import order
# (any `import repro.experiments.dist_sweep` rebinds the parent
# package attribute to the module).  Call it as
# `repro.experiments.dist_sweep.dist_sweep(...)` or import it from the
# submodule explicitly.
_SUBMODULES = frozenset({"specs", "registry", "catalog", "session",
                         "results", "dist_sweep"})

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module("." + name, __name__)
    # Resolve the export table BEFORE importing, so an exception raised
    # while the submodule executes propagates as itself instead of being
    # masked as an AttributeError on the package.
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    value = getattr(importlib.import_module(target, __name__), name)
    globals()[name] = value          # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
