"""repro.experiments — the declarative experiment API.

The only sanctioned way to express an evaluation cell: a topology spec x
a routing-scheme spec x a traffic-pattern spec x an evaluator spec,
executed through a memoizing :class:`Session`:

    from repro.experiments import Session
    s = Session()
    rr = s.run("sf(q=5)", "fatpaths(n_layers=9,rho=0.6)", "adversarial",
               "transport(steps=1200)")
    print(rr.metrics["fct_p99_us"])

Grids go through :meth:`Session.sweep` or the CLI::

    python -m repro.experiments sweep --topos sf,df,ft \\
        --schemes ecmp,letflow,fatpaths --patterns adversarial,shuffle

* :mod:`repro.experiments.specs`    — mini-spec grammar + ExperimentSpec.
* :mod:`repro.experiments.registry` — decorator registries.
* :mod:`repro.experiments.catalog`  — the registered axes.
* :mod:`repro.experiments.session`  — artifact memoization + grid runner.
* :mod:`repro.experiments.results`  — canonical RunResult JSON records.
"""

from .catalog import (EVALUATORS, ROUTINGS, TOPOLOGIES, TRAFFIC,  # noqa: F401
                      RoutingBundle, topo_spec)
from .results import (RunResult, results_from_json,  # noqa: F401
                      results_to_json, summary_table)
from .session import ResolvedCell, Session  # noqa: F401
from .specs import ExperimentSpec, Spec, SpecError, split_spec_list  # noqa: F401

__all__ = [
    "Session", "ResolvedCell", "ExperimentSpec", "Spec", "SpecError",
    "RunResult", "RoutingBundle", "results_to_json", "results_from_json",
    "summary_table", "split_spec_list", "topo_spec",
    "TOPOLOGIES", "ROUTINGS", "TRAFFIC", "EVALUATORS",
]
