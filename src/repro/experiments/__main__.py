"""Experiment CLI: run cells or whole grids, emit RunResult JSON.

  python -m repro.experiments sweep --topos sf,df,ft \\
      --schemes ecmp,letflow,fatpaths --patterns adversarial,shuffle \\
      [--evaluators transport] [--seeds 0] [--quick] [--json out.json]

  python -m repro.experiments run --topo "sf(q=5)" --scheme fatpaths \\
      --pattern adversarial [--evaluator "transport(steps=1200)"]

  python -m repro.experiments list          # registered axes + defaults

``--quick`` shortens transport simulations (steps=400) unless a spec
pins ``steps`` explicitly.  One sweep invocation over the defaults
reproduces the paper's Fig 14/15-style topology x scheme x pattern
comparison grid in a single command.
"""

from __future__ import annotations

import argparse
import sys

from .catalog import EVALUATORS, ROUTINGS, TOPOLOGIES, TRAFFIC
from .results import results_to_json, summary_table
from .session import Session
from .specs import Spec, split_spec_list

_QUICK_STEPS = 400


def _quicken(evaluators, quick: bool):
    """Apply --quick: cap transport steps unless the spec pins them."""
    if not quick:
        return evaluators
    out = []
    for e in evaluators:
        spec = Spec.coerce(e)
        if spec.name == "transport" and "steps" not in spec.kw:
            spec = Spec(spec.name, spec.kwargs + (("steps", _QUICK_STEPS),))
        out.append(spec)
    return out


def cmd_sweep(args) -> int:
    session = Session()
    evaluators = _quicken(split_spec_list(args.evaluators), args.quick)
    seeds = [int(s) for s in args.seeds.split(",") if s != ""]
    results = session.sweep(
        topos=split_spec_list(args.topos),
        routings=split_spec_list(args.schemes),
        patterns=split_spec_list(args.patterns),
        evaluators=evaluators, seeds=seeds,
        callback=lambda rr: print(summary_table([rr]), flush=True))
    builds = session.stats["stack_build"]
    hits = session.stats["stack_hit"]
    print(f"# {len(results)} cells; layer/table stacks built {builds}x, "
          f"reused {hits}x", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            f.write(results_to_json(results) + "\n")
        print(f"# wrote {len(results)} RunResults to {args.json}")
    return 0


def cmd_run(args) -> int:
    session = Session()
    (evaluator,) = _quicken([args.evaluator], args.quick)
    rr = session.run(args.topo, args.scheme, args.pattern, evaluator,
                     seed=args.seed)
    print(rr.to_json())
    if args.json:
        with open(args.json, "w") as f:
            f.write(results_to_json([rr]) + "\n")
    return 0


def cmd_list(_args) -> int:
    for title, reg in (("topologies", TOPOLOGIES),
                       ("routing schemes", ROUTINGS),
                       ("traffic patterns", TRAFFIC),
                       ("evaluators", EVALUATORS)):
        print(f"{title}:")
        for name in reg.names():
            defaults = ", ".join(f"{k}={v!r}"
                                 for k, v in sorted(reg.defaults(name).items()))
            print(f"  {name}({defaults})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.experiments",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    sw = sub.add_parser("sweep", help="run a topology x scheme x pattern grid")
    sw.add_argument("--topos", default="sf,df,ft")
    sw.add_argument("--schemes", default="ecmp,letflow,fatpaths")
    sw.add_argument("--patterns", default="adversarial,shuffle")
    sw.add_argument("--evaluators", default="transport")
    sw.add_argument("--seeds", default="0")
    sw.add_argument("--quick", action="store_true")
    sw.add_argument("--json", default="", help="write RunResult list here")
    sw.set_defaults(fn=cmd_sweep)

    rn = sub.add_parser("run", help="run a single cell")
    rn.add_argument("--topo", required=True)
    rn.add_argument("--scheme", required=True)
    rn.add_argument("--pattern", required=True)
    rn.add_argument("--evaluator", default="transport")
    rn.add_argument("--seed", type=int, default=0)
    rn.add_argument("--quick", action="store_true")
    rn.add_argument("--json", default="")
    rn.set_defaults(fn=cmd_run)

    ls = sub.add_parser("list", help="show registered axes and defaults")
    ls.set_defaults(fn=cmd_list)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
