"""Experiment CLI: run cells or whole grids, emit RunResult JSON.

  python -m repro.experiments sweep --topos sf,df,ft \\
      --schemes ecmp,letflow,fatpaths --patterns adversarial,shuffle \\
      [--evaluators transport] [--seeds 0] [--quick] [--json out.json] \\
      [--devices N] [--checkpoint DIR] [--filter SUBSTR] \\
      [--cell-timeout-s N]

  python -m repro.experiments run --topo "sf(q=5)" --scheme fatpaths \\
      --pattern adversarial [--evaluator "transport(steps=1200)"]

  python -m repro.experiments diff a.json b.json [--rtol 0]   # artifacts
  python -m repro.experiments list          # registered axes + defaults

``--quick`` shortens transport simulations (steps=400) unless a spec
pins ``steps`` explicitly.  ``--devices N`` runs the grid through the
distributed batch engine (repro.experiments.dist_sweep): when no device
configuration exists yet, the CLI sets
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (and pins
``JAX_PLATFORMS=cpu``) BEFORE importing jax, so forced host devices
just work; per-cell results are identical for every device count.
``--checkpoint DIR`` makes a sweep resumable: completed cells are
committed per-cell and a re-run skips them.  One sweep invocation over
the defaults reproduces the paper's Fig 14/15-style topology x scheme x
pattern comparison grid in a single command.

Heavy imports happen inside the command handlers — argument parsing and
device-environment setup must run before anything touches jax.
"""

from __future__ import annotations

import argparse
import os
import sys

_QUICK_STEPS = 400
_FORCE_FLAG = "--xla_force_host_platform_device_count"


def _ensure_devices(n) -> None:
    """Arrange for ``n`` visible devices before jax initializes.

    Forced host devices can only be configured via XLA_FLAGS before the
    first jax import; once jax is loaded this is a no-op and
    ``host_device_runtime`` raises its actionable error instead.  A
    pre-existing force flag (e.g. a CI job exporting XLA_FLAGS itself)
    is never second-guessed, and an existing JAX_PLATFORMS choice is
    preserved (it selects the platform, not the device count)."""
    if not n or int(n) <= 1 or "jax" in sys.modules:
        return
    xf = os.environ.get("XLA_FLAGS", "")
    if _FORCE_FLAG not in xf:
        os.environ["XLA_FLAGS"] = (f"{xf} " if xf else "") + \
            f"{_FORCE_FLAG}={int(n)}"
    # Pin the platform even when the caller exported the force flag
    # themselves: forced host devices are a CPU-platform mode, and on a
    # machine whose auto-selected platform is not cpu the flag would be
    # inert (same pin repro.dist.compat / sitecustomize apply).
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def _quicken(evaluators, quick: bool):
    """Apply --quick: cap transport steps unless the spec pins them."""
    from .specs import Spec
    if not quick:
        return evaluators
    out = []
    for e in evaluators:
        spec = Spec.coerce(e)
        if spec.name == "transport" and "steps" not in spec.kw:
            spec = Spec(spec.name, spec.kwargs + (("steps", _QUICK_STEPS),))
        out.append(spec)
    return out


def _watchdog_sweep(session, cells, args, stream) -> int:
    """Sequential sweep with a per-cell wall-clock watchdog
    (``--cell-timeout-s``).  Each cell runs in a worker thread; a cell
    exceeding the budget is recorded as failed-with-timeout (empty
    metrics, structured ``error`` meta) and the sweep moves on.  The
    stuck computation cannot be killed — its executor is abandoned and
    a fresh one started — so a pathological cell costs one zombie
    thread, not the artifact.  Timed-out cells are NEVER checkpointed:
    a checkpoint resume re-attempts exactly them.  Exit code is 0 when
    at least one cell succeeded, 1 when none did."""
    import concurrent.futures as cf
    import dataclasses

    from ..ckpt.sweep import SweepCheckpoint
    from .results import RunResult, results_to_json

    timeout = float(args.cell_timeout_s)
    ckpt = SweepCheckpoint(args.checkpoint) if args.checkpoint else None
    results = []
    n_ok = n_timeout = 0
    ex = cf.ThreadPoolExecutor(max_workers=1)
    for spec in cells:
        if ckpt is not None:
            prev = ckpt.get(spec.cell_id)
            if prev is not None:
                rr = dataclasses.replace(
                    RunResult.from_dict(prev),
                    meta={**RunResult.from_dict(prev).meta,
                          "sweep_resumed": True})
                stream(rr)
                results.append(rr)
                n_ok += 1
                continue
        fut = ex.submit(session.run, spec)
        try:
            rr = fut.result(timeout=timeout)
        except cf.TimeoutError:
            fut.cancel()
            ex.shutdown(wait=False)
            ex = cf.ThreadPoolExecutor(max_workers=1)
            print(f"# cell {spec.cell_id} exceeded --cell-timeout-s "
                  f"{timeout:g}; marked failed-with-timeout", flush=True)
            rr = RunResult(
                topo=spec.topo.format(), routing=spec.routing.format(),
                pattern=spec.pattern.format(),
                evaluator=spec.evaluator.format(), seed=spec.seed,
                metrics={},
                meta={"error": {"type": "timeout",
                                "timeout_s": timeout}},
                wall_s=timeout)
            n_timeout += 1
            results.append(rr)
            continue
        if ckpt is not None:
            ckpt.put(rr.cell_id, rr.to_dict())
        stream(rr)
        results.append(rr)
        n_ok += 1
    ex.shutdown(wait=False)
    print(f"# {len(results)} cells; {n_ok} succeeded, "
          f"{n_timeout} timed out", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            f.write(results_to_json(results) + "\n")
        print(f"# wrote {len(results)} RunResults to {args.json}")
    return 0 if (n_ok > 0 or not cells) else 1


def cmd_sweep(args) -> int:
    _ensure_devices(args.devices)
    from .results import results_to_json, summary_table
    from .session import Session
    from .specs import split_spec_list

    session = Session()
    evaluators = _quicken(split_spec_list(args.evaluators), args.quick)
    seeds = [int(s) for s in args.seeds.split(",") if s != ""]
    cells = session.grid(topos=split_spec_list(args.topos),
                         routings=split_spec_list(args.schemes),
                         patterns=split_spec_list(args.patterns),
                         evaluators=evaluators, seeds=seeds)
    if args.filter:
        kept = [c for c in cells if args.filter in c.cell_id]
        if not kept:
            print(f"error: --filter {args.filter!r} matches none of the "
                  f"{len(cells)} grid cell(s):", file=sys.stderr)
            for c in cells:
                print(f"  {c.cell_id}", file=sys.stderr)
            return 2
        print(f"# --filter {args.filter!r}: {len(kept)} of {len(cells)} "
              "cell(s)", flush=True)
        cells = kept
    stream = lambda rr: print(summary_table([rr]), flush=True)  # noqa: E731
    if args.cell_timeout_s is not None:
        if args.devices is not None:
            print("error: --cell-timeout-s is a sequential-engine "
                  "watchdog; drop --devices", file=sys.stderr)
            return 2
        return _watchdog_sweep(session, cells, args, stream)
    if args.devices is not None or args.checkpoint:
        from .dist_sweep import dist_sweep
        results = dist_sweep(
            session, cells, devices=args.devices,
            checkpoint_dir=args.checkpoint or None, callback=stream,
            log=lambda m: print(m, flush=True))
    else:
        results = []
        for spec in cells:
            rr = session.run(spec)
            stream(rr)
            results.append(rr)
    builds = session.stats["stack_build"]
    hits = session.stats["stack_hit"]
    print(f"# {len(results)} cells; layer/table stacks built {builds}x, "
          f"reused {hits}x", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            f.write(results_to_json(results) + "\n")
        print(f"# wrote {len(results)} RunResults to {args.json}")
    return 0


def cmd_run(args) -> int:
    from .results import results_to_json
    from .session import Session

    session = Session()
    (evaluator,) = _quicken([args.evaluator], args.quick)
    rr = session.run(args.topo, args.scheme, args.pattern, evaluator,
                     seed=args.seed)
    print(rr.to_json())
    if args.json:
        with open(args.json, "w") as f:
            f.write(results_to_json([rr]) + "\n")
    return 0


def cmd_list(_args) -> int:
    from .catalog import EVALUATORS, ROUTINGS, TOPOLOGIES, TRAFFIC

    for title, reg in (("topologies", TOPOLOGIES),
                       ("routing schemes", ROUTINGS),
                       ("traffic patterns", TRAFFIC),
                       ("evaluators", EVALUATORS)):
        print(f"{title}:")
        for name in reg.names():
            defaults = ", ".join(f"{k}={v!r}"
                                 for k, v in sorted(reg.defaults(name).items()))
            print(f"  {name}({defaults})")
            doc = reg.doc(name)
            if doc:
                print(f"      {doc}")
    return 0


def cmd_diff(args) -> int:
    """Cell-for-cell comparison of two sweep artifacts (CI's identity
    check between the sequential and distributed engines)."""
    from .results import compare_results, results_from_json

    sides = []
    for path in (args.a, args.b):
        with open(path) as f:
            sides.append(results_from_json(f.read()))
    diffs = compare_results(sides[0], sides[1], rtol=args.rtol)
    for d in diffs:
        print(d)
    if diffs:
        print(f"# {len(diffs)} difference(s) between {args.a} and {args.b}",
              file=sys.stderr)
        return 1
    print(f"# identical: {len(sides[0])} cells ({args.a} vs {args.b}, "
          f"rtol={args.rtol:g})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.experiments",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    sw = sub.add_parser("sweep", help="run a topology x scheme x pattern grid")
    sw.add_argument("--topos", default="sf,df,ft")
    sw.add_argument("--schemes", default="ecmp,letflow,fatpaths")
    sw.add_argument("--patterns", default="adversarial,shuffle")
    sw.add_argument("--evaluators", default="transport")
    sw.add_argument("--seeds", default="0")
    sw.add_argument("--filter", default="",
                    help="run only cells whose cell id contains this "
                         "substring (rc=2 with the cell list when nothing "
                         "matches)")
    sw.add_argument("--quick", action="store_true")
    sw.add_argument("--json", default="", help="write RunResult list here")
    sw.add_argument("--devices", type=int, default=None,
                    help="run the distributed batch engine over N devices "
                         "(forces N host CPU devices when nothing else "
                         "configures jax)")
    sw.add_argument("--checkpoint", default="",
                    help="resumable sweep: per-cell checkpoint directory")
    sw.add_argument("--cell-timeout-s", type=float, default=None,
                    dest="cell_timeout_s",
                    help="sequential-engine watchdog: a cell exceeding "
                         "this wall-clock budget is marked "
                         "failed-with-timeout (structured error meta) and "
                         "the sweep continues; rc 0 if any cell "
                         "succeeded.  Timed-out cells are not "
                         "checkpointed, so --checkpoint resume "
                         "re-attempts them")
    sw.set_defaults(fn=cmd_sweep)

    rn = sub.add_parser("run", help="run a single cell")
    rn.add_argument("--topo", required=True)
    rn.add_argument("--scheme", required=True)
    rn.add_argument("--pattern", required=True)
    rn.add_argument("--evaluator", default="transport")
    rn.add_argument("--seed", type=int, default=0)
    rn.add_argument("--quick", action="store_true")
    rn.add_argument("--json", default="")
    rn.set_defaults(fn=cmd_run)

    df = sub.add_parser("diff", help="cell-for-cell compare two artifacts")
    df.add_argument("a")
    df.add_argument("b")
    df.add_argument("--rtol", type=float, default=0.0,
                    help="relative tolerance for float metrics (default: "
                         "exact)")
    df.set_defaults(fn=cmd_diff)

    ls = sub.add_parser("list", help="show registered axes and defaults")
    ls.set_defaults(fn=cmd_list)

    args = ap.parse_args(argv)
    from .specs import SpecError
    try:
        return args.fn(args)
    except SpecError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
