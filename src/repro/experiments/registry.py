"""Decorator-based registries for the experiment matrix axes.

Each registry maps a mini-spec name to a builder function plus its
declared defaults.  The defaults double as the parameter whitelist:
a spec naming an unknown entry or an undeclared parameter raises
:class:`~repro.experiments.specs.SpecError` with the valid options, so
typos fail loudly at parse/resolve time, not deep inside a build.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

from .specs import Spec, SpecError, SpecLike

__all__ = ["Registry"]


class Registry:
    """Name -> (builder, defaults) with spec resolution."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, Tuple[Callable, Dict[str, Any]]] = {}

    def register(self, name: str, **defaults) -> Callable:
        """Decorator: register ``fn`` under ``name``; ``defaults`` declare
        every overridable parameter and its default value."""
        if name in self._entries:
            raise ValueError(f"{self.kind} {name!r} registered twice")

        def deco(fn: Callable) -> Callable:
            self._entries[name] = (fn, dict(defaults))
            return fn

        return deco

    def names(self):
        return sorted(self._entries)

    def defaults(self, name: str) -> Dict[str, Any]:
        return dict(self._entries[name][1])

    def doc(self, name: str) -> str:
        """First line of the builder's docstring ('' if undocumented) —
        the one-line description ``experiments list`` prints."""
        d = self._entries[name][0].__doc__ or ""
        return d.strip().splitlines()[0].strip() if d.strip() else ""

    def resolve(self, spec: SpecLike) -> Tuple[Callable, Dict[str, Any]]:
        """Spec -> (builder, merged kwargs); validates name + parameters."""
        spec = Spec.coerce(spec)
        if spec.name not in self._entries:
            raise SpecError(
                f"unknown {self.kind} {spec.name!r}; "
                f"known: {', '.join(self.names())}")
        fn, defaults = self._entries[spec.name]
        kw = dict(defaults)
        for k, v in spec.kwargs:
            if k not in defaults:
                raise SpecError(
                    f"{self.kind} {spec.name!r} has no parameter {k!r} "
                    f"(accepts: {', '.join(sorted(defaults)) or 'none'})")
            kw[k] = v
        return fn, kw

    def build(self, spec: SpecLike, *args, **extra):
        fn, kw = self.resolve(spec)
        return fn(*args, **kw, **extra)

    def canonical(self, spec: SpecLike) -> str:
        """Defaults-filled canonical form: ``"clique"`` and
        ``"clique(k=12)"`` map to the same string, so cache keys built
        from it never double-build equivalent specs."""
        spec = Spec.coerce(spec)
        _, kw = self.resolve(spec)
        return Spec(spec.name, tuple(kw.items())).format()
