"""The registered evaluation-matrix axes: topologies, routing schemes,
traffic patterns, evaluators.

Everything the repo's benchmarks/examples used to assemble by hand is
declared here once:

* ``TOPOLOGIES`` — paper topologies at cost-matched "small" defaults
  (``sf`` == ``sf(q=5)``); compact ``by_name`` forms (``"sf:11"``) are
  accepted too via :func:`topo_spec`.
* ``ROUTINGS``   — ``ecmp`` / ``letflow`` (minimal multi-table) and
  ``fatpaths`` / ``minimal`` (layer stacks, any §5.3 construction
  scheme).  Builders receive a :class:`RoutingCtx` whose ``stack``
  memoizer keys expensive artifacts by ``(topo, scheme, seed)`` so a
  grid never rebuilds a layer stack twice — and ``ecmp``/``letflow``
  share one table stack.
* ``TRAFFIC``    — §2.4 patterns plus ``collide`` (the Fig 5 microcase:
  many flows between one distance-2 router pair).
* ``EVALUATORS`` — ``transport`` (flow simulator, vmap-batched seed
  sweeps), ``mat`` (multicommodity-flow LP), ``fabric`` (link-load /
  collective model over :class:`repro.dist.fabric.ClusterFabric`).

Evaluators return ``(metrics, meta)``: plain-float metrics for the
:class:`~repro.experiments.results.RunResult` record, and bookkeeping
meta (flow counts, forwarding-table sizes, ...).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from ..core import arrivals
from ..core import routing as routing_mod
from ..core import topology as topo_mod
from ..core.layers import LayeredRouting, build_layers
from ..core.throughput import mat_lp, mat_single_layer
from ..core.topology import Topology
from ..core.traffic import FlowWorkload, endpoint_router_map, make_workload
from ..core.transport import SimConfig, ecmp_routing, simulate_seeds
from .registry import Registry
from .specs import Spec, SpecError, SpecLike

__all__ = ["TOPOLOGIES", "ROUTINGS", "TRAFFIC", "EVALUATORS",
           "RoutingBundle", "RoutingCtx", "topo_spec", "stack_rep_key",
           "transport_plan", "transport_meta", "fct_metrics"]

TOPOLOGIES = Registry("topology")
ROUTINGS = Registry("routing scheme")
TRAFFIC = Registry("traffic pattern")
EVALUATORS = Registry("evaluator")


# -----------------------------------------------------------------------------
# Topologies.  Defaults are the repo's "small" cost-matched set.
# -----------------------------------------------------------------------------
@TOPOLOGIES.register("sf", q=5, p=None)
def _sf(q, p) -> Topology:
    return topo_mod.slim_fly(q, concentration=p)


@TOPOLOGIES.register("df", p=3)
def _df(p) -> Topology:
    return topo_mod.dragonfly(p)


@TOPOLOGIES.register("jf", n=50, k=6, p=3, seed=0)
def _jf(n, k, p, seed) -> Topology:
    return topo_mod.jellyfish(n, k, p, seed=seed)


@TOPOLOGIES.register("xp", k=8, lift=None, p=None, seed=0)
def _xp(k, lift, p, seed) -> Topology:
    return topo_mod.xpander(k, lift=lift, concentration=p, seed=seed)


@TOPOLOGIES.register("hx", l=2, s=6, p=None)
def _hx(l, s, p) -> Topology:
    return topo_mod.hyperx(l, s, concentration=p)


@TOPOLOGIES.register("ft", k=8, oversub=1)
def _ft(k, oversub) -> Topology:
    return topo_mod.fat_tree(k, oversubscription=oversub)


@TOPOLOGIES.register("ft2", l=8, s=4, p=4)
def _ft2(l, s, p) -> Topology:
    return topo_mod.two_layer_fat_tree(l, s, p)


@TOPOLOGIES.register("ft2eq", of="sf(q=5)")
def _ft2eq(of) -> Topology:
    """Cost-equalised two-layer fat tree of another registered topology
    (arXiv 1301.6179 construction; endpoint count and cables-per-endpoint
    matched — the paper's FT2 baseline pairing)."""
    return topo_mod.cost_matched_ft2(TOPOLOGIES.build(Spec.coerce(of)))


@TOPOLOGIES.register("clique", k=12, p=None)
def _clique(k, p) -> Topology:
    return topo_mod.clique(k, concentration=p)


@TOPOLOGIES.register("star", n=16)
def _star(n) -> Topology:
    return topo_mod.star(n)


@TOPOLOGIES.register("jfeq", of="sf(q=5)", seed=0)
def _jfeq(of, seed) -> Topology:
    """Equivalent Jellyfish of another registered topology (§2.2.3)."""
    return topo_mod.equivalent_jellyfish(TOPOLOGIES.build(Spec.coerce(of)),
                                         seed=seed)


_COMPACT_KEYS = {"sf": ("q",), "df": ("p",), "ft": ("k",), "xp": ("k",),
                 "clique": ("k",), "star": ("n",), "hx": ("l", "s"),
                 "jf": ("n", "k", "p"), "ft2": ("l", "s", "p")}


def topo_spec(obj: SpecLike) -> Spec:
    """Coerce a topology spec, also accepting the compact
    :func:`repro.core.topology.by_name` form (``"sf:11"``, ``"hx:2x6"``)."""
    if isinstance(obj, str) and ":" in obj:
        fam, _, arg = obj.partition(":")
        keys = _COMPACT_KEYS.get(fam)
        if keys is None:
            raise SpecError(f"unknown compact topology spec {obj!r}; "
                            f"known families: {', '.join(sorted(_COMPACT_KEYS))}")
        vals = arg.split("x")
        if len(vals) != len(keys):
            raise SpecError(f"compact spec {obj!r} needs "
                            f"{len(keys)} 'x'-separated values")
        return Spec(fam, tuple((k, int(v)) for k, v in zip(keys, vals)))
    return Spec.coerce(obj)


# -----------------------------------------------------------------------------
# Routing schemes.
# -----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RoutingBundle:
    """A built routing stack + the load-balancing mode that drives it.

    ``failure_meta`` is set by the ``failures(...)`` axis: a JSON-safe
    summary of the applied damage (dead links/layers, disconnected
    pairs) that :func:`transport_meta` merges into cell meta — computed
    on host once at build time, so both sweep engines report identical
    counts."""

    routing: LayeredRouting
    balancing: str            # ecmp | letflow | fatpaths
    failure_meta: Optional[Dict[str, Any]] = None


@dataclasses.dataclass(frozen=True)
class RoutingCtx:
    """What a routing builder gets from the session: the topology and a
    ``stack(key, thunk)`` memoizer for the expensive artifacts."""

    topo: Topology
    topo_key: str
    seed: int
    stack: Callable[[tuple, Callable[[], LayeredRouting]], LayeredRouting]


def stack_rep_key(topo: Topology) -> tuple:
    """Memo-key suffix for routing artifacts: the resolved path engine and
    table representation at this topology's size.  ``REPRO_PATH_ENGINE``
    can change within one process (tests and CI flip it), and a stack
    built dense must not be served to a caller expecting the compressed
    representation attached — so every stack cache key carries it.
    :meth:`repro.experiments.session.Session.fabric` uses the same suffix
    on its intentionally-colliding keys."""
    from ..core import paths as paths_mod

    n = topo.n_routers
    return (paths_mod.path_engine(n), paths_mod.representation_for(n))


def _minimal_tables(ctx: RoutingCtx, n: int) -> LayeredRouting:
    # ecmp and letflow differ only in balancing — one shared table stack.
    return ctx.stack(
        ("tables", ctx.topo_key, int(n), ctx.seed) + stack_rep_key(ctx.topo),
        lambda: ecmp_routing(ctx.topo, n_tables=int(n), seed=ctx.seed))


def _layer_stack(ctx: RoutingCtx, scheme: str, n_layers: int,
                 rho: float) -> LayeredRouting:
    return ctx.stack(
        ("layers", ctx.topo_key, scheme, int(n_layers), float(rho), ctx.seed)
        + stack_rep_key(ctx.topo),
        lambda: build_layers(ctx.topo, int(n_layers), float(rho),
                             scheme=scheme, seed=ctx.seed))


@ROUTINGS.register("ecmp", n=8)
def _ecmp(ctx: RoutingCtx, n) -> RoutingBundle:
    return RoutingBundle(_minimal_tables(ctx, n), "ecmp")


@ROUTINGS.register("letflow", n=8)
def _letflow(ctx: RoutingCtx, n) -> RoutingBundle:
    return RoutingBundle(_minimal_tables(ctx, n), "letflow")


@ROUTINGS.register("fatpaths", n_layers=9, rho=0.6, scheme="rand")
def _fatpaths(ctx: RoutingCtx, n_layers, rho, scheme) -> RoutingBundle:
    return RoutingBundle(_layer_stack(ctx, scheme, n_layers, rho), "fatpaths")


@ROUTINGS.register("minimal", n_layers=9)
def _minimal(ctx: RoutingCtx, n_layers) -> RoutingBundle:
    """Minimal-only ablation: a rho=1 stack driven by flowlet balancing
    (Fig 11's 'minimal' arm)."""
    return RoutingBundle(_layer_stack(ctx, "rand", n_layers, 1.0), "fatpaths")


@ROUTINGS.register("failures", of="fatpaths", rate=0.05, pattern="bernoulli",
                   mode="repair", down_step=-1, fseed=0)
def _failures(ctx: RoutingCtx, of, rate, pattern, mode, down_step,
              fseed) -> RoutingBundle:
    """Degraded-fabric wrapper: build ``of``'s stack, then kill a seeded
    set of links (``rate`` x ``pattern`` = bernoulli | switch | blast).
    ``down_step < 0`` (default) damages the fabric BEFORE the run, with
    ``mode="repair"`` (next hops re-resolved against the masked
    adjacency) or ``mode="drop"`` (broken table entries invalidated,
    no re-convergence); ``down_step >= 0`` keeps pristine tables and
    kills the links MID-RUN at that scan step (capacity -> 0; flows
    re-pick among surviving layers at their next flowlet boundary).
    The mask key depends on the cell seed and ``fseed`` but NOT the
    scheme, so schemes are compared under identical damage; a realized
    empty mask (e.g. rate=0) reproduces the undamaged cell bit-for-bit.
    """
    from ..core import failures as failures_mod

    inner_spec = Spec.coerce(of)
    if inner_spec.name == "failures":
        raise SpecError("failures(of=...) cannot nest another failures spec")
    fn, kw = ROUTINGS.resolve(inner_spec)
    inner = fn(ctx, **kw)
    rate, down_step = float(rate), int(down_step)
    pattern, mode = str(pattern), str(mode)
    key = failures_mod.scenario_key(ctx.seed, int(fseed))
    dead = failures_mod.failure_mask(key, ctx.topo.adj, rate, pattern)
    ckey = ("failed", ctx.topo_key, ROUTINGS.canonical(inner_spec), rate,
            pattern, mode, down_step, int(fseed), ctx.seed) \
        + stack_rep_key(ctx.topo)
    if down_step >= 0 and dead.any():
        lr = ctx.stack(ckey, lambda: dataclasses.replace(
            inner.routing, build_stats=None,
            link_down_step=failures_mod.link_down_schedule(dead, down_step)))
        report = failures_mod.FailureReport(
            failed_links=int(np.triu(dead, 1).sum()),
            total_links=int(np.triu(np.asarray(ctx.topo.adj, bool), 1).sum()),
            rate=rate, pattern=pattern, mode="midrun",
            dead_layers=0, disconnected_pairs=0, down_step=down_step)
    else:
        lr, report = ctx.stack(ckey, lambda: failures_mod.apply_failures(
            inner.routing, dead, mode=mode, seed=ctx.seed, rate=rate,
            pattern=pattern))
    return RoutingBundle(lr, inner.balancing, failure_meta=report.as_meta())


@ROUTINGS.register("churn", of="fatpaths", rate=0.1, pattern="flap",
                   mtbf=120.0, mttr=40.0, conv=8, events=4, proc="exp",
                   shape=1.5, fseed=0)
def _churn(ctx: RoutingCtx, of, rate, pattern, mtbf, mttr, conv, events,
           proc, shape, fseed) -> RoutingBundle:
    """Link-churn wrapper: build ``of``'s stack, then attach a seeded
    renewal schedule of per-link (down, up) outage intervals (``pattern``
    = flap | rolling | repair; ``mtbf``/``mttr`` mean steps between /
    to repair, ``proc`` = exp | pareto, ``events`` down/up cycles per
    flapping link).  Capacity restores at ``up``; flowlets may re-pick a
    returned link only ``conv`` steps later (control-plane
    re-convergence).  The schedule key depends on the cell seed and
    ``fseed`` but NOT the scheme, so schemes are compared under
    identical churn; an empty realized schedule (e.g. rate=0) reproduces
    the schedule-free cell bit-for-bit.  Composes with ``failures(...)``
    in either order (static damage + churn on the survivors)."""
    from ..core import failures as failures_mod

    inner_spec = Spec.coerce(of)
    if inner_spec.name == "churn":
        raise SpecError("churn(of=...) cannot nest another churn spec")
    fn, kw = ROUTINGS.resolve(inner_spec)
    inner = fn(ctx, **kw)
    rate = float(rate)
    key = failures_mod.scenario_key(ctx.seed, int(fseed))
    sched = failures_mod.churn_schedule(
        key, ctx.topo.adj, rate, pattern=str(pattern), mtbf=float(mtbf),
        mttr=float(mttr), events=int(events), proc=str(proc),
        shape=float(shape))
    summ = failures_mod.churn_summary(sched)
    if summ["churn_events"] == 0:
        # Empty schedule: the inner bundle ITSELF — churn(rate=0) cells
        # compile the schedule-free program, bit-for-bit.
        return inner
    ckey = ("churn", ctx.topo_key, ROUTINGS.canonical(inner_spec), rate,
            str(pattern), float(mtbf), float(mttr), int(conv), int(events),
            str(proc), float(shape), int(fseed), ctx.seed) \
        + stack_rep_key(ctx.topo)
    lr = ctx.stack(ckey, lambda: dataclasses.replace(
        inner.routing, build_stats=None, link_churn=sched,
        churn_conv=int(conv)))
    fm = dict(getattr(inner, "failure_meta", None) or {})
    fm.update(churn_pattern=str(pattern), churn_rate=rate,
              churn_mtbf=float(mtbf), churn_mttr=float(mttr),
              churn_conv=int(conv), **summ)
    return RoutingBundle(lr, inner.balancing, failure_meta=fm)


# -----------------------------------------------------------------------------
# Traffic patterns.
# -----------------------------------------------------------------------------
def _register_workload(name: str, doc: str = "", **overrides):
    defaults = dict(rounds=1, flow_size=float(1 << 20), randomize=True,
                    frac=1.0, spread=0.0, arrival=0.0)
    defaults.update(overrides)

    @TRAFFIC.register(name, **defaults)
    def _build(topo, seed, rounds, flow_size, randomize, frac, spread,
               arrival, _name=name, **kw) -> FlowWorkload:
        return make_workload(topo, _name, flow_size=flow_size,
                             n_rounds=int(rounds), arrival_rate=arrival,
                             randomize=bool(randomize), seed=seed,
                             frac_endpoints=frac, size_spread=spread, **kw)

    if doc:
        _build.__doc__ = doc


_register_workload("uniform", doc="random uniform destinations (§2.4.1)")
_register_workload("permutation", doc="random permutation / derangement "
                                      "(§2.4.2)")
_register_workload("offdiag", doc="off-diagonal shift pattern (§2.4.3)")
_register_workload("shuffle", doc="bit-rotation shuffle pattern (§2.4.4)")
_register_workload("alltoone", acks=0, ack_frac=0.05,
                   doc="incast onto one victim endpoint; acks=1 adds the "
                       "reverse ACK-path flows (TCP outcast)")
# The paper's skew cases run un-randomized (§3.4 is the mitigation):
_register_workload("adversarial", rounds=2, randomize=False,
                   doc="skewed off-diagonal maximising colliding router "
                       "pairs (§2.4.6)")
_register_workload("stencil", randomize=False,
                   doc="4-point stencil as four off-diagonals (§2.4.5)")
_register_workload("worstcase", randomize=False,
                   doc="assignment-maximised path lengths (§2.4.7)")


@TRAFFIC.register("collide", rounds=4, flow_size=float(4 << 20))
def _collide(topo, seed, rounds, flow_size) -> FlowWorkload:
    """Fig 5 microcase: every endpoint of router A sends ``rounds`` flows
    to endpoints of a router B at distance min(2, diameter) — all flows
    share the (often unique) minimal path."""
    import jax.numpy as jnp

    from ..core import paths as paths_mod

    ep2r = endpoint_router_map(topo)
    dist = np.asarray(paths_mod.shortest_path_lengths(
        jnp.asarray(np.asarray(topo.adj, bool)), max_l=8))
    conc = np.asarray(topo.concentration)
    target = 2 if (dist[(dist > 0) & (dist < 10_000)] >= 2).any() else 1
    pair = next(((a, b) for a in range(topo.n_routers)
                 for b in range(topo.n_routers)
                 if dist[a, b] == target and conc[a] > 0 and conc[b] > 0),
                None)
    if pair is None:
        raise SpecError(f"no routable endpoint pair on {topo.name}")
    a_eps = np.where(ep2r == pair[0])[0]
    b_eps = np.where(ep2r == pair[1])[0]
    m = min(len(a_eps), len(b_eps))
    src = np.tile(a_eps[:m], int(rounds))
    dst = np.tile(b_eps[:m], int(rounds))
    return FlowWorkload(
        src=src.astype(np.int32), dst=dst.astype(np.int32),
        size=np.full(len(src), float(flow_size)),
        start=np.zeros(len(src)),
        src_router=ep2r[src].astype(np.int32),
        dst_router=ep2r[dst].astype(np.int32))


# -----------------------------------------------------------------------------
# Open-loop dynamic traffic (PR 6): continuous arrivals, incast waves,
# anycast placement.  All activation steps come from repro.core.arrivals
# (deterministic in (key, flow); prefix-stable — see that module's
# docstring), so both sweep engines derive identical workloads.
# -----------------------------------------------------------------------------
@TRAFFIC.register("load", level=0.5, pattern="uniform",
                  flow_size=float(256 << 10), window=256, process="poisson",
                  shape=1.5, bound=64.0, dt=10e-6, line_rate=12.5e9,
                  samples=32)
def _load(topo, seed, level, pattern, flow_size, window, process, shape,
          bound, dt, line_rate, samples) -> FlowWorkload:
    """Open-loop stream offering ``level`` x bisection bandwidth over a
    ``window``-step arrival window (endpoint pairs drawn from ``pattern``;
    interarrivals from ``process`` = poisson | pareto)."""
    import jax

    level = float(level)
    if not 0.0 < level:
        raise SpecError(f"load level must be > 0 (got {level})")
    bisect = arrivals.bisection_bandwidth(topo, line_rate=float(line_rate),
                                          samples=int(samples),
                                          seed=int(seed))
    rate = level * bisect * float(dt) / float(flow_size)  # flows per step
    n = max(1, int(round(rate * int(window))))
    rounds = max(1, -(-n // max(1, topo.n_endpoints)))
    base = make_workload(topo, str(pattern), flow_size=float(flow_size),
                         n_rounds=rounds, randomize=True, seed=seed)
    idx = np.arange(n) % base.n_flows
    steps = arrivals.activation_steps(
        jax.random.PRNGKey(int(seed)), n, rate=rate, process=str(process),
        shape=float(shape), bound=float(bound))
    return FlowWorkload(
        src=base.src[idx], dst=base.dst[idx], size=base.size[idx],
        start=arrivals.activation_starts(steps, float(dt)),
        src_router=base.src_router[idx], dst_router=base.dst_router[idx],
        active_step=steps)


@TRAFFIC.register("incast", fan_in=8, waves=4, wave_period=64,
                  flow_size=float(256 << 10), acks=1, ack_frac=0.05,
                  dt=10e-6)
def _incast(topo, seed, fan_in, waves, wave_period, flow_size, acks,
            ack_frac, dt) -> FlowWorkload:
    """Synchronized incast waves: ``fan_in`` seeded senders fire at one
    victim every ``wave_period`` steps; acks=1 adds the victim's reverse
    ACK-path flows (the outcast evaluator's workload)."""
    ep2r = endpoint_router_map(topo)
    n = len(ep2r)
    rng = np.random.default_rng(seed)
    victim = int(rng.integers(n))
    others = np.setdiff1d(np.arange(n), [victim])
    fan_in = min(int(fan_in), len(others))
    senders = np.concatenate([
        np.random.default_rng(seed + 7 * w + 1).choice(
            others, size=fan_in, replace=False)
        for w in range(max(1, int(waves)))])
    sched = arrivals.incast_schedule(len(senders), fan_in, int(wave_period))
    src, dst, step = senders, np.full(len(senders), victim), sched
    is_ack = np.zeros(len(senders), dtype=bool)
    if acks:
        src = np.concatenate([src, dst])
        dst = np.concatenate([dst, senders])
        step = np.concatenate([step, sched])
        is_ack = np.concatenate([is_ack, np.ones(len(senders), dtype=bool)])
    size = np.where(is_ack, float(flow_size) * float(ack_frac),
                    float(flow_size))
    step = step.astype(np.int32)
    return FlowWorkload(
        src=src.astype(np.int32), dst=dst.astype(np.int32),
        size=size.astype(np.float64),
        start=arrivals.activation_starts(step, float(dt)),
        src_router=ep2r[src].astype(np.int32),
        dst_router=ep2r[dst].astype(np.int32),
        active_step=step, is_ack=is_ack)


@TRAFFIC.register("anycast", replicas=4, policy="closest",
                  flow_size=float(256 << 10), window=128, process="poisson",
                  shape=1.5, bound=64.0, dt=10e-6)
def _anycast(topo, seed, replicas, policy, flow_size, window, process,
             shape, bound, dt) -> FlowWorkload:
    """Anycast service placement: every client resolves to one of
    ``replicas`` seeded replica endpoints via the batched min-plus router
    distance table (policy = closest | farthest); window > 0 makes the
    request stream open-loop."""
    import jax
    import jax.numpy as jnp

    from ..core import paths as paths_mod

    ep2r = endpoint_router_map(topo)
    n = len(ep2r)
    if n < 2:
        raise SpecError(f"anycast needs >= 2 endpoints on {topo.name}")
    rng = np.random.default_rng(seed)
    reps = np.sort(rng.choice(n, size=min(int(replicas), n - 1),
                              replace=False))
    clients = np.setdiff1d(np.arange(n), reps)
    dist = np.asarray(paths_mod.shortest_path_lengths(
        jnp.asarray(np.asarray(topo.adj, bool)), max_l=16))
    d = dist[ep2r[clients][:, None], ep2r[reps][None, :]]
    if policy == "closest":
        pick = np.argmin(d, axis=1)
    elif policy == "farthest":
        pick = np.argmax(d, axis=1)
    else:
        raise SpecError(f"unknown anycast policy {policy!r}; "
                        "choose 'closest' or 'farthest'")
    src, dst = clients, reps[pick]
    f = len(src)
    if int(window) > 0:
        steps = arrivals.activation_steps(
            jax.random.PRNGKey(int(seed)), f, rate=f / float(int(window)),
            process=str(process), shape=float(shape), bound=float(bound))
    else:
        steps = np.zeros(f, dtype=np.int32)
    return FlowWorkload(
        src=src.astype(np.int32), dst=dst.astype(np.int32),
        size=np.full(f, float(flow_size)),
        start=arrivals.activation_starts(steps, float(dt)),
        src_router=ep2r[src].astype(np.int32),
        dst_router=ep2r[dst].astype(np.int32),
        active_step=steps)


# -----------------------------------------------------------------------------
# Evaluators.  Signature: (session, cell, **kw) -> (metrics, meta).
# -----------------------------------------------------------------------------
def _fct_metrics(sims) -> Dict[str, float]:
    fct = np.concatenate([r.fct[r.finished] for r in sims])
    tput = np.concatenate([r.throughput_per_flow for r in sims])
    finished = float(np.mean([r.finished.mean() for r in sims]))
    util = float(np.mean([r.link_util_mean for r in sims]))
    if len(fct) == 0:
        p50 = p99 = mean = float("nan")
    else:
        p50 = float(np.quantile(fct, 0.50) * 1e6)
        p99 = float(np.quantile(fct, 0.99) * 1e6)
        mean = float(fct.mean() * 1e6)
    if tput.size and not np.all(np.isnan(tput)):
        tput_gbs = float(np.nanmean(tput) / 1e9)
    else:
        tput_gbs = float("nan")
    out = {"fct_p50_us": p50, "fct_p99_us": p99, "fct_mean_us": mean,
           "finished": finished, "tput_gbs": tput_gbs, "link_util": util}
    # Recovery cells additionally report retransmitted bytes.  Computed
    # HERE (host float64 over per-flow accumulators) so the sequential
    # evaluator and dist_sweep — which calls this same function on
    # batch_result sims — emit identical metric dicts.
    rb = [r.retrans_bytes for r in sims if r.retrans_bytes is not None]
    if rb:
        out["retrans_mb"] = float(
            np.mean([np.asarray(b, np.float64).sum() for b in rb]) / 2 ** 20)
    return out


def transport_plan(cell, steps, transport, seeds, dt, flowlet_gap,
                   adaptive=1, chunk=64, recovery="off", rto_base=16,
                   rto_cap=256, ecn_thresh=0.65,
                   record=0) -> Tuple[SimConfig, list]:
    """The transport evaluator's execution plan for one cell:
    ``(SimConfig, sim_seeds)``.  Shared by the in-process evaluator below
    and by :mod:`repro.experiments.dist_sweep`, which runs the same plan
    through padded device-batched programs — both MUST derive config and
    seeds identically or the engines' results diverge.

    ``adaptive`` toggles the early-exit horizon (results are identical
    either way — it only changes how many scan chunks execute), and
    ``REPRO_FULL_HORIZON=1`` force-disables it process-wide WITHOUT
    changing any spec string: the nightly CI job uses that to prove an
    early-exit sweep artifact equals a full-horizon one cell-for-cell.
    ``chunk`` is the scan chunk size; unlike ``adaptive`` it feeds the
    PRNG block layout, so changing it changes the simulated draws.

    ``recovery``/``rto_base``/``rto_cap``/``ecn_thresh``/``record`` are
    the PR 8 loss-recovery lanes (see :class:`SimConfig`); they are part
    of the jit-static config, so recovery cells bucket separately from
    recovery-off cells in the distributed engine automatically."""
    import os
    adaptive_on = bool(int(adaptive)) and \
        os.environ.get("REPRO_FULL_HORIZON", "") != "1"
    cfg = SimConfig(transport=transport, balancing=cell.bundle.balancing,
                    n_steps=int(steps), dt=dt, flowlet_gap=flowlet_gap,
                    horizon_chunk=int(chunk), adaptive_horizon=adaptive_on,
                    recovery=str(recovery), rto_base=int(rto_base),
                    rto_cap=int(rto_cap), ecn_thresh=float(ecn_thresh),
                    record=int(record), seed=cell.seed)
    sim_seeds = [cell.seed + 1000 * i for i in range(max(1, int(seeds)))]
    return cfg, sim_seeds


def transport_meta(cell, cfg, sim_seeds) -> Dict[str, Any]:
    """RunResult meta for a transport-family cell.  Shared by the
    in-process evaluators and :mod:`repro.experiments.dist_sweep` — both
    engines MUST assemble this identically or the engine-identity diff
    fails on meta.  Dynamic (open-loop) workloads additionally record
    their offered byte rate (host float64 — engine-independent)."""
    meta = {"n_seeds": len(sim_seeds), "transport": cfg.transport,
            "balancing": cell.bundle.balancing}
    wl = cell.workload
    if getattr(wl, "active_step", None) is not None:
        meta["offered_gbs"] = arrivals.offered_gbs(wl.size, wl.active_step,
                                                   cfg.dt)
    # Fault-injected cells carry the damage summary (dead links/layers,
    # disconnected pairs) — host ints computed once at build time, so
    # both engines merge identical values.
    fm = getattr(cell.bundle, "failure_meta", None)
    if fm is not None:
        meta.update(fm)
    return meta


@EVALUATORS.register("transport", steps=2000, transport="ndp", seeds=1,
                     dt=10e-6, flowlet_gap=50e-6, adaptive=1, chunk=64,
                     recovery="off", rto_base=16, rto_cap=256,
                     ecn_thresh=0.65)
def _transport(session, cell, steps, transport, seeds, dt, flowlet_gap,
               adaptive, chunk, recovery, rto_base, rto_cap,
               ecn_thresh) -> Tuple[Dict[str, float], Dict[str, Any]]:
    """Flow-level simulation (§7); ``seeds`` > 1 batches a sim-seed sweep
    through one vmapped scan instead of a Python loop.  ``recovery=on``
    arms the loss-recovery lanes (RTO + blackhole escape + lost-in-flight
    accounting); the default compiles the identical recovery-free
    program."""
    cfg, sim_seeds = transport_plan(cell, steps, transport, seeds, dt,
                                    flowlet_gap, adaptive, chunk, recovery,
                                    rto_base, rto_cap, ecn_thresh)
    sims = simulate_seeds(cell.topo, cell.bundle.routing, cell.workload,
                          cfg, sim_seeds)
    return _fct_metrics(sims), transport_meta(cell, cfg, sim_seeds)


@EVALUATORS.register("outcast", steps=2000, transport="ndp", seeds=1,
                     dt=10e-6, flowlet_gap=50e-6, adaptive=1, chunk=64)
def _outcast(session, cell, steps, transport, seeds, dt, flowlet_gap,
             adaptive, chunk) -> Tuple[Dict[str, float], Dict[str, Any]]:
    """Outcast fairness under incast: the standard FCT metrics plus the
    Jain fairness index over per-victim-flow goodput and the p99/p50 FCT
    tail ratio, measured over the data flows into the modal destination
    (ACK-path flows excluded)."""
    cfg, sim_seeds = transport_plan(cell, steps, transport, seeds, dt,
                                    flowlet_gap, adaptive, chunk)
    sims = simulate_seeds(cell.topo, cell.bundle.routing, cell.workload,
                          cfg, sim_seeds)
    wl = cell.workload
    dsts, counts = np.unique(wl.dst, return_counts=True)
    victim = int(dsts[np.argmax(counts)])
    data = wl.dst == victim
    if getattr(wl, "is_ack", None) is not None:
        data &= ~wl.is_ack
    horizon_s = cfg.n_steps * cfg.dt
    goodput, fcts = [], []
    for r in sims:
        elapsed = np.where(r.finished, np.maximum(r.fct, cfg.dt),
                           np.maximum(horizon_s - wl.start, cfg.dt))
        goodput.append((r.delivered / elapsed)[data])
        fcts.append(r.fct[data & r.finished])
    g = np.concatenate(goodput)
    fct = np.concatenate(fcts)
    jain = float(g.sum() ** 2 / (len(g) * (g ** 2).sum())) \
        if g.size and (g ** 2).sum() > 0 else float("nan")
    tail = float(np.quantile(fct, 0.99) / max(np.quantile(fct, 0.50), 1e-12)) \
        if fct.size else float("nan")
    metrics = dict(_fct_metrics(sims), jain_goodput=jain,
                   fct_p99_over_p50=tail, victim_flows=float(data.sum()))
    return metrics, transport_meta(cell, cfg, sim_seeds)


def _trailing_mean(x: np.ndarray, window: int) -> np.ndarray:
    """Trailing ``window``-step moving mean with growing head windows
    (the first k < window entries average what exists).  ONE shared
    implementation for every plateau/band computation — the recovery,
    availability, and degradation evaluators must smooth identically or
    their thresholds drift apart."""
    x = np.asarray(x, np.float64)
    csum = np.concatenate([[0.0], np.cumsum(x)])
    n = np.arange(1, len(x) + 1)
    lo = np.maximum(0, n - window)
    return (csum[n] - csum[lo]) / (n - lo)


def _curve_points_meta(n: int, curve_points: int) -> np.ndarray:
    """Downsampled step indices for trajectory meta (shared by the
    recovery and availability evaluators)."""
    return np.unique(np.linspace(0, max(0, n - 1),
                                 min(int(curve_points), max(1, n)))
                     .round().astype(int))


def _run_alternate(session, cell, rspec, steps, transport, seeds, dt,
                   flowlet_gap, adaptive=1, chunk=64, **plan_kw):
    """Run THIS cell's workload under an alternate routing spec — the
    scenario runner shared by the degradation and availability
    evaluators (baseline / rate-ladder / pristine-control runs).
    Returns ``(sims, bundle, cfg, sim_seeds)``; the alternate bundle is
    memoized in the session like any other routing artifact."""
    import types

    bundle = session.routing(cell.spec.topo, rspec, seed=cell.seed)
    shim = types.SimpleNamespace(bundle=bundle, seed=cell.seed)
    cfg, sim_seeds = transport_plan(shim, steps, transport, seeds, dt,
                                    flowlet_gap, adaptive, chunk, **plan_kw)
    sims = simulate_seeds(cell.topo, bundle.routing, cell.workload,
                          cfg, sim_seeds)
    return sims, bundle, cfg, sim_seeds


@EVALUATORS.register("degradation", rates="0.05:0.15:0.3",
                     patterns="bernoulli:switch", mode="repair", steps=400,
                     transport="ndp", seeds=1, dt=10e-6, flowlet_gap=50e-6,
                     adaptive=1, chunk=64)
def _degradation(session, cell, rates, patterns, mode, steps, transport,
                 seeds, dt, flowlet_gap, adaptive, chunk
                 ) -> Tuple[Dict[str, float], Dict[str, Any]]:
    """Degradation curves: re-run the cell's routing scheme under
    escalating seeded link failures — one scenario per (pattern, rate),
    plus the shared rate-0 baseline — and report absolute and
    baseline-relative throughput/FCT alongside disconnection counts.
    ``rates``/``patterns`` are colon-separated lists.  Failure masks are
    NESTED in rate (see :mod:`repro.core.failures`), so the
    dead-link/disconnected-pair counts are monotone in rate by
    construction, and the throughput curve degrades monotonically up to
    simulation noise."""
    rate_list = sorted({float(r) for r in str(rates).split(":") if r})
    pattern_list = [p for p in str(patterns).split(":") if p]
    if not rate_list or not pattern_list:
        raise SpecError("degradation needs non-empty rates and patterns")

    def run_scenario(fspec: Spec):
        sims, bundle, _, _ = _run_alternate(
            session, cell, fspec, steps, transport, seeds, dt,
            flowlet_gap, adaptive, chunk)
        return _fct_metrics(sims), bundle.failure_meta

    of = cell.spec.routing.format()
    base_m, _ = run_scenario(Spec("failures", (
        ("of", of), ("rate", 0.0), ("mode", str(mode)))))
    metrics = {"tput_base": base_m["tput_gbs"],
               "fct_p99_base": base_m["fct_p99_us"],
               "finished_base": base_m["finished"]}
    meta: Dict[str, Any] = {"failure_mode": str(mode),
                            "failure_rates": rate_list,
                            "failure_patterns": pattern_list,
                            "scenarios": {}}
    base_tput = base_m["tput_gbs"]
    for pat in pattern_list:
        discs = []
        for rate in rate_list:
            m, fm = run_scenario(Spec("failures", (
                ("of", of), ("rate", rate), ("pattern", pat),
                ("mode", str(mode)))))
            tag = f"{pat}_r{rate:g}"
            rel = (m["tput_gbs"] / base_tput
                   if base_tput and base_tput > 0 else float("nan"))
            metrics.update({
                f"tput_{tag}": m["tput_gbs"],
                f"tput_rel_{tag}": rel,
                f"fct_p99_{tag}": m["fct_p99_us"],
                f"finished_{tag}": m["finished"],
                f"disc_{tag}": float(fm["disconnected_pairs"]),
                f"dead_layers_{tag}": float(fm["dead_layers"]),
            })
            discs.append(fm["disconnected_pairs"])
            meta["scenarios"][tag] = fm
        metrics[f"monotone_disc_{pat}"] = float(
            all(a <= b for a, b in zip(discs, discs[1:])))
    return metrics, meta


@EVALUATORS.register("recovery", steps=400, transport="ndp", seeds=1,
                     dt=10e-6, flowlet_gap=50e-6, chunk=64, rto_base=16,
                     rto_cap=256, ecn_thresh=0.65, eps=0.05, window=16,
                     curve_points=64)
def _recovery(session, cell, steps, transport, seeds, dt, flowlet_gap,
              chunk, rto_base, rto_cap, ecn_thresh, eps, window,
              curve_points) -> Tuple[Dict[str, float], Dict[str, Any]]:
    """Time-to-recover under a mid-run fault: run the cell with the
    recovery lanes armed and the per-step record lane on (full horizon —
    the trajectory must be exact), then measure how long aggregate
    goodput takes to climb back within ``eps`` of its pre-fault plateau
    after the ``failures(down_step=...)`` link death.

    Reported metrics: ``ttr_steps`` (steps from the fault until the
    trailing ``window``-step mean goodput re-enters the plateau band;
    NaN if it never does inside the horizon), ``recovered`` (0/1),
    ``dip_frac`` (deepest post-fault goodput dip relative to plateau),
    ``plateau_goodput`` (line-rate units), ``stalled_peak`` (worst
    post-fault stalled-flow count) — plus the standard FCT metrics
    (which include ``retrans_mb``, the retransmitted-byte total).  Meta
    carries the downsampled goodput/stalled trajectories (host float64
    means over seeds, so both sweep engines serialize identical curves).
    Composed without a mid-run fault the cell is trivially recovered
    (``ttr_steps=0``); a layer-pinned scheme (ecmp) over a blackhole
    never re-enters the band — the acceptance control."""
    cfg, sim_seeds = transport_plan(
        cell, steps, transport, seeds, dt, flowlet_gap, adaptive=0,
        chunk=chunk, recovery="on", rto_base=rto_base, rto_cap=rto_cap,
        ecn_thresh=ecn_thresh, record=1)
    sims = simulate_seeds(cell.topo, cell.bundle.routing, cell.workload,
                          cfg, sim_seeds)
    g = np.mean([np.asarray(r.goodput_steps, np.float64) for r in sims],
                axis=0)
    st = np.mean([np.asarray(r.stalled_steps, np.float64) for r in sims],
                 axis=0)
    n = len(g)
    window = max(1, int(window))
    eps = float(eps)
    fm = getattr(cell.bundle, "failure_meta", None) or {}
    down = int(fm.get("link_down_step", -1))
    if down < 0:
        # No one-shot death: fall back to the first churn down-event, so
        # recovery-from-first-outage is measurable on churn cells too.
        down = int(fm.get("churn_first_down", -1))
    if down < 1 or down >= n:
        plateau = float(g[-window:].mean()) if n else float("nan")
        ttr, recovered, dip = 0.0, 1.0, 0.0
    else:
        plateau = float(g[max(0, down - window):down].mean())
        post = g[down:]
        # Trailing moving mean over the POST-fault segment only (early
        # windows are short) — pre-fault steps must not inflate it.
        sm = _trailing_mean(post, window)
        target = (1.0 - eps) * plateau
        hits = np.nonzero(sm >= target)[0]
        recovered = 1.0 if hits.size else 0.0
        ttr = float(hits[0]) if hits.size else float("nan")
        dip = (float((plateau - post.min()) / plateau)
               if plateau > 0 else float("nan"))
    metrics = dict(
        _fct_metrics(sims), ttr_steps=ttr, recovered=recovered,
        dip_frac=dip, plateau_goodput=plateau,
        stalled_peak=float(st[down:].max() if 0 <= down < n else st.max()))
    idx = _curve_points_meta(n, curve_points)
    meta = dict(transport_meta(cell, cfg, sim_seeds),
                recovery_eps=eps, recovery_window=window,
                rto_base=int(rto_base), rto_cap=int(rto_cap),
                curve_steps=[int(i) for i in idx],
                goodput_curve=[float(g[i]) for i in idx],
                stalled_curve=[float(st[i]) for i in idx])
    return metrics, meta


@EVALUATORS.register("availability", slo=0.8, steps=400, transport="ndp",
                     seeds=1, dt=10e-6, flowlet_gap=50e-6, chunk=64,
                     recovery="on", rto_base=16, rto_cap=256,
                     ecn_thresh=0.65, window=16, curve_points=64)
def _availability(session, cell, slo, steps, transport, seeds, dt,
                  flowlet_gap, chunk, recovery, rto_base, rto_cap,
                  ecn_thresh, window, curve_points
                  ) -> Tuple[Dict[str, float], Dict[str, Any]]:
    """Availability-SLO compliance under link churn: run the cell (full
    horizon, per-step record lane on, recovery lanes armed by default)
    and score every post-churn step against the PRISTINE plateau — the
    tail trailing-mean goodput of a control run of the same cell with
    its ``churn(...)`` wrapper stripped, same workload and seeds.

    A step complies when the trailing ``window``-step mean goodput is
    >= ``slo`` x plateau.  Reported metrics: ``availability`` (compliant
    fraction of steps from the first churn down-event), ``violations``
    (number of entries into violation), ``max_outage_steps`` (longest
    violating stretch), ``plateau_goodput`` — plus the standard FCT
    metrics.  Cells without a churn schedule are trivially available
    (1.0).  Meant for saturating workloads (e.g. a huge permutation)
    where pristine goodput holds a plateau; the acceptance pairing is
    ``churn(of=fatpaths...)`` vs the layer-pinned ``churn(of=ecmp...)``
    control on the same flapping fabric."""
    cfg, sim_seeds = transport_plan(
        cell, steps, transport, seeds, dt, flowlet_gap, adaptive=0,
        chunk=chunk, recovery=str(recovery), rto_base=rto_base,
        rto_cap=rto_cap, ecn_thresh=ecn_thresh, record=1)
    sims = simulate_seeds(cell.topo, cell.bundle.routing, cell.workload,
                          cfg, sim_seeds)
    g = np.mean([np.asarray(r.goodput_steps, np.float64) for r in sims],
                axis=0)
    n = len(g)
    window = max(1, int(window))
    slo = float(slo)

    # Pristine control: the same cell with the churn wrapper stripped
    # (shared scenario runner; no-churn cells are their own control).
    rspec = cell.spec.routing
    if rspec.name == "churn":
        _, rkw = ROUTINGS.resolve(rspec)
        pristine_spec = Spec.coerce(rkw["of"])
    else:
        pristine_spec = rspec
    sims0, _, _, _ = _run_alternate(
        session, cell, pristine_spec, steps, transport, seeds, dt,
        flowlet_gap, adaptive=0, chunk=chunk, recovery=str(recovery),
        rto_base=rto_base, rto_cap=rto_cap, ecn_thresh=ecn_thresh,
        record=1)
    g0 = np.mean([np.asarray(r.goodput_steps, np.float64) for r in sims0],
                 axis=0)
    plateau = float(_trailing_mean(g0, window)[-1]) if len(g0) \
        else float("nan")

    fm = getattr(cell.bundle, "failure_meta", None) or {}
    down = int(fm.get("churn_first_down", -1))
    if down < 1 or down >= n or not plateau > 0:
        availability, violations, max_outage = 1.0, 0.0, 0.0
    else:
        sm = _trailing_mean(g[down:], window)
        ok = sm >= slo * plateau
        availability = float(ok.mean())
        bad = np.concatenate([[0], (~ok).astype(np.int64), [0]])
        d = np.diff(bad)
        starts = np.nonzero(d == 1)[0]
        ends = np.nonzero(d == -1)[0]
        violations = float(len(starts))
        max_outage = float((ends - starts).max()) if len(starts) else 0.0
    metrics = dict(
        _fct_metrics(sims), availability=availability,
        violations=violations, max_outage_steps=max_outage,
        plateau_goodput=plateau)
    idx = _curve_points_meta(n, curve_points)
    meta = dict(transport_meta(cell, cfg, sim_seeds),
                availability_slo=slo, availability_window=window,
                pristine_routing=pristine_spec.format(),
                curve_steps=[int(i) for i in idx],
                goodput_curve=[float(g[i]) for i in idx],
                pristine_curve=[float(g0[i]) for i in idx])
    return metrics, meta


#: public alias — dist_sweep assembles the same record from batched sims.
fct_metrics = _fct_metrics


@EVALUATORS.register("mat", max_hops=16, capacity=1.0)
def _mat(session, cell, max_hops, capacity
         ) -> Tuple[Dict[str, float], Dict[str, Any]]:
    """Maximum achievable throughput: LP relaxation + greedy single-layer
    rounding (§6.4)."""
    lp = mat_lp(cell.bundle.routing, cell.workload, max_hops=int(max_hops),
                capacity=capacity)
    single = mat_single_layer(cell.bundle.routing, cell.workload,
                              max_hops=int(max_hops), capacity=capacity)
    metrics = {"mat_T": float(lp.throughput),
               "mat_T_single": float(single.throughput),
               "n_paths": float(lp.n_paths),
               "n_demands": float(lp.n_demands)}
    return metrics, {"lp_status": lp.status}


@EVALUATORS.register("fabric", line_rate=12.5e9, quanta=32)
def _fabric(session, cell, line_rate, quanta
            ) -> Tuple[Dict[str, float], Dict[str, Any]]:
    """Route the workload's flows over a modelled ClusterFabric and report
    link loads (ECMP hash-split for ecmp/letflow cells, greedy flowlets
    for fatpaths/minimal cells).  The fabric's candidate paths are the
    cell's OWN routing stack — a 'minimal' cell is measured over its
    minimal-only layers, not a default FatPaths stack."""
    fb = session.bundle_fabric(cell.spec.topo, cell.spec.routing,
                               seed=cell.seed, line_rate=line_rate,
                               flowlet_quanta=int(quanta))
    scheme = "fatpaths" if cell.bundle.balancing == "fatpaths" else "ecmp"
    wl = cell.workload
    flows = list(zip(wl.src.tolist(), wl.dst.tolist(), wl.size.tolist()))
    rep = fb.evaluate_flows(flows, scheme=scheme,
                            kind=cell.spec.pattern.name,
                            n_ranks=cell.topo.n_endpoints,
                            payload_bytes=float(wl.size.sum()))
    metrics = {"bottleneck_mb": rep.bottleneck_bytes / 2 ** 20,
               "time_ms": rep.time_s * 1e3,
               "util_gini": rep.util_gini,
               "links_used": float(rep.n_links_used),
               "fabric_gb": rep.fabric_bytes / 1e9}
    return metrics, {"fabric_scheme": scheme}


def table_meta(bundle: RoutingBundle) -> Dict[str, int]:
    """§5.5 deployment accounting for a built stack."""
    return {"table_exact": int(routing_mod.table_entries_exact(bundle.routing)),
            "table_prefix": int(routing_mod.table_entries_prefix(bundle.routing))}
