"""Session layer: artifact memoization across experiment cells.

A :class:`Session` is the unit of reuse for a whole evaluation grid:
topologies, forwarding-layer stacks (keyed by ``(topo, scheme, seed)``),
workloads and :class:`~repro.dist.fabric.ClusterFabric` instances are
built at most once, whatever order the cells run in.  ``ecmp`` and
``letflow`` cells share one minimal-table stack; a ``fabric`` evaluator
cell reuses the very same layer stack its ``fatpaths`` transport sibling
built.  ``session.stats`` counts builds vs hits AND accumulates build
wall time — ``build_wall_s`` overall, ``<kind>_build_s`` per artifact
kind, and the device/host split (``build_device_s``/``build_host_s``)
reported by the batched semiring layer builders — so sweeps can expose
their build-vs-simulate split (each ``RunResult.meta`` carries the
per-cell ``build_s`` / ``cache_hits`` / ``cache_builds``).
"""

from __future__ import annotations

import collections
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

from ..core.layers import build_layers
from ..core.topology import Topology
from ..core.traffic import FlowWorkload
from ..core.transport import ecmp_routing
from .catalog import (EVALUATORS, ROUTINGS, TOPOLOGIES, TRAFFIC,
                      RoutingBundle, RoutingCtx, stack_rep_key, table_meta,
                      topo_spec)
from .results import RunResult
from .specs import ExperimentSpec, Spec, SpecLike

__all__ = ["Session", "ResolvedCell"]


class ResolvedCell:
    """An :class:`ExperimentSpec` with its artifacts materialized."""

    def __init__(self, spec: ExperimentSpec, topo: Topology,
                 bundle: RoutingBundle, workload: FlowWorkload):
        self.spec = spec
        self.topo = topo
        self.bundle = bundle
        self.workload = workload
        self.seed = spec.seed


class Session:
    """Memoizing context for running experiment cells."""

    def __init__(self):
        self._cache: Dict[tuple, Any] = {}
        self.stats = collections.Counter()

    # ---- memoization core ----------------------------------------------------
    def _memo(self, key: tuple, build: Callable[[], Any]) -> Any:
        if key in self._cache:
            self.stats[f"{key[0]}_hit"] += 1
            return self._cache[key]
        self.stats[f"{key[0]}_build"] += 1
        t0 = time.perf_counter()
        value = build()
        dt = time.perf_counter() - t0
        # Wall-time split: total per artifact kind, plus the device/host
        # breakdown the batched layer builders report (Counter holds
        # floats fine).
        self.stats[f"{key[0]}_build_s"] += dt
        self.stats["build_wall_s"] += dt
        bs = getattr(value, "build_stats", None)
        if isinstance(bs, dict):
            self.stats["build_device_s"] += bs.get("device_s", 0.0)
            self.stats["build_host_s"] += bs.get("host_s", 0.0)
        self._cache[key] = value
        return value

    def _stack_memo(self, key: tuple, build: Callable[[], Any]) -> Any:
        return self._memo(("stack",) + key, build)

    # ---- artifact builders ---------------------------------------------------
    # Cache keys always use the defaults-filled canonical spec form, so
    # "sf" and "sf(q=5)" (or "sf:5") resolve to the same artifacts.
    def topology(self, spec: SpecLike) -> Topology:
        spec = topo_spec(spec)
        return self._memo(("topo", TOPOLOGIES.canonical(spec)),
                          lambda: TOPOLOGIES.build(spec))

    def routing(self, topo: SpecLike, scheme: SpecLike,
                seed: int = 0) -> RoutingBundle:
        tspec = topo_spec(topo)
        rspec = Spec.coerce(scheme)
        fn, kw = ROUTINGS.resolve(rspec)   # validate before building topo
        ctx = RoutingCtx(topo=self.topology(tspec),
                         topo_key=TOPOLOGIES.canonical(tspec),
                         seed=int(seed), stack=self._stack_memo)
        return fn(ctx, **kw)

    def workload(self, topo: SpecLike, pattern: SpecLike,
                 seed: int = 0) -> FlowWorkload:
        tspec = topo_spec(topo)
        pspec = Spec.coerce(pattern)
        fn, kw = TRAFFIC.resolve(pspec)
        t = self.topology(tspec)
        return self._memo(
            ("workload", TOPOLOGIES.canonical(tspec),
             TRAFFIC.canonical(pspec), int(seed)),
            lambda: fn(t, int(seed), **kw))

    def fabric(self, topo: SpecLike, n_layers: int = 9, rho: float = 0.6,
               seed: int = 0, layer_scheme: str = "rand", n_tables: int = 8,
               line_rate: float = 12.5e9, flowlet_quanta: int = 32):
        """A ClusterFabric sharing this session's cached routing stacks."""
        from ..dist.fabric import ClusterFabric

        tspec = topo_spec(topo)
        t = self.topology(tspec)
        tkey = TOPOLOGIES.canonical(tspec)
        # Same key tuples as catalog._layer_stack/_minimal_tables (incl.
        # the stack_rep_key suffix) so fabric cells share the transport
        # cells' stacks.
        layers = self._stack_memo(
            ("layers", tkey, layer_scheme, int(n_layers), float(rho),
             int(seed)) + stack_rep_key(t),
            lambda: build_layers(t, int(n_layers), float(rho),
                                 scheme=layer_scheme, seed=int(seed)))
        tables = self._stack_memo(
            ("tables", tkey, int(n_tables), int(seed)) + stack_rep_key(t),
            lambda: ecmp_routing(t, n_tables=int(n_tables), seed=int(seed)))
        key = ("fabric", tkey, layer_scheme, int(n_layers), float(rho),
               int(seed), int(n_tables), float(line_rate),
               int(flowlet_quanta))
        return self._memo(key, lambda: ClusterFabric(
            t, n_layers=int(n_layers), rho=float(rho), seed=int(seed),
            layer_scheme=layer_scheme, n_tables=int(n_tables),
            line_rate=float(line_rate), flowlet_quanta=int(flowlet_quanta),
            layers=layers, ecmp=tables))

    def bundle_fabric(self, topo: SpecLike, scheme: SpecLike, seed: int = 0,
                      line_rate: float = 12.5e9, flowlet_quanta: int = 32):
        """A ClusterFabric whose candidate paths are exactly the given
        routing scheme's stack — 'minimal(...)' cells are evaluated over
        their minimal-only layers, not a default FatPaths stack.  Both
        fabric sides point at the bundle's stack; only the side matching
        the scheme's balancing mode is meaningful."""
        from ..dist.fabric import ClusterFabric

        tspec = topo_spec(topo)
        rspec = Spec.coerce(scheme)
        bundle = self.routing(tspec, rspec, seed=seed)
        lr = bundle.routing
        key = ("fabric_cell", TOPOLOGIES.canonical(tspec),
               ROUTINGS.canonical(rspec), int(seed), float(line_rate),
               int(flowlet_quanta))
        return self._memo(key, lambda: ClusterFabric(
            self.topology(tspec), n_layers=lr.n_layers, rho=lr.rho,
            seed=int(seed), line_rate=float(line_rate),
            flowlet_quanta=int(flowlet_quanta), layers=lr, ecmp=lr))

    # ---- cell execution ------------------------------------------------------
    def resolve(self, spec: ExperimentSpec) -> ResolvedCell:
        return ResolvedCell(
            spec=spec,
            topo=self.topology(spec.topo),
            bundle=self.routing(spec.topo, spec.routing, seed=spec.seed),
            workload=self.workload(spec.topo, spec.pattern, seed=spec.seed))

    def run(self, topo, routing: Optional[SpecLike] = None,
            pattern: Optional[SpecLike] = None,
            evaluator: SpecLike = "transport", seed: int = 0) -> RunResult:
        """Evaluate one cell; accepts an ExperimentSpec or the four axes."""
        if isinstance(topo, ExperimentSpec):
            if (routing is not None or pattern is not None
                    or Spec.coerce(evaluator) != Spec("transport")
                    or seed != 0):
                raise ValueError(
                    "run(ExperimentSpec) takes no other arguments; "
                    "dataclasses.replace the spec instead")
            spec = topo
        else:
            spec = ExperimentSpec(topo=topo_spec(topo),
                                  routing=Spec.coerce(routing),
                                  pattern=Spec.coerce(pattern),
                                  evaluator=Spec.coerce(evaluator),
                                  seed=int(seed))
        fn, kw = EVALUATORS.resolve(spec.evaluator)
        t0 = time.perf_counter()
        pre = self.stats_snapshot()
        cell = self.resolve(spec)
        metrics, meta = fn(self, cell, **kw)
        wall = time.perf_counter() - t0
        # One consistent snapshot AFTER the evaluator: builds an evaluator
        # triggers itself (e.g. a fabric cell building via the session)
        # count as build time for this cell, not as simulate time.
        return self.finish_result(spec, cell, metrics, meta, pre, wall)

    # Execution-bookkeeping counters snapshotted around each cell so the
    # per-cell build-vs-simulate split can be attributed (dist_sweep uses
    # the same pair of hooks around its resolve phase).
    _SNAPSHOT_KEYS = ("build_wall_s", "build_device_s", "stack_build",
                      "stack_hit")

    def stats_snapshot(self) -> Dict[str, float]:
        return {k: self.stats[k] for k in self._SNAPSHOT_KEYS}

    def finish_result(self, spec: ExperimentSpec, cell: ResolvedCell,
                      metrics: Dict[str, float], ev_meta: Dict[str, Any],
                      pre: Dict[str, float], wall: float,
                      extra_meta: Optional[Dict[str, Any]] = None,
                      post: Optional[Dict[str, float]] = None) -> RunResult:
        """Assemble the canonical :class:`RunResult` for one evaluated
        cell.  Both execution engines (the sequential loop and the
        distributed batch engine) MUST go through this, so a cell's
        record is identical whichever engine produced it.  ``post``
        bounds the cell's build-accounting window when builds for other
        cells happened since (the batch engine resolves every cell
        before simulating any)."""
        post = post if post is not None else self.stats_snapshot()
        meta = {"n_routers": cell.topo.n_routers,
                "n_endpoints": cell.topo.n_endpoints,
                "n_flows": int(cell.workload.n_flows),
                # build-vs-simulate split for this cell's artifacts
                "build_s": post["build_wall_s"] - pre["build_wall_s"],
                "build_device_s": (post["build_device_s"]
                                   - pre["build_device_s"]),
                "cache_builds": int(post["stack_build"]
                                    - pre["stack_build"]),
                "cache_hits": int(post["stack_hit"]
                                  - pre["stack_hit"]),
                **table_meta(cell.bundle), **ev_meta,
                **(extra_meta or {})}
        return RunResult(
            topo=spec.topo.format(), routing=spec.routing.format(),
            pattern=spec.pattern.format(), evaluator=spec.evaluator.format(),
            seed=spec.seed, metrics=metrics, meta=meta, wall_s=wall)

    def grid(self, topos: Sequence[SpecLike], routings: Sequence[SpecLike],
             patterns: Sequence[SpecLike],
             evaluators: Sequence[SpecLike] = ("transport",),
             seeds: Iterable[int] = (0,)) -> List[ExperimentSpec]:
        """The grid's cells in canonical order (topo-major nesting) —
        the one ordering every sweep artifact is emitted in, whatever
        engine or execution order actually ran the cells."""
        return [ExperimentSpec(topo=topo_spec(t), routing=Spec.coerce(r),
                               pattern=Spec.coerce(p),
                               evaluator=Spec.coerce(e), seed=int(s))
                for t in topos for r in routings for p in patterns
                for e in evaluators for s in seeds]

    def sweep(self, topos: Sequence[SpecLike], routings: Sequence[SpecLike],
              patterns: Sequence[SpecLike],
              evaluators: Sequence[SpecLike] = ("transport",),
              seeds: Iterable[int] = (0,),
              callback: Optional[Callable[[RunResult], None]] = None,
              devices: Optional[int] = None,
              checkpoint_dir: Optional[str] = None) -> List[RunResult]:
        """Run the full grid through this session's caches.

        ``devices`` routes the grid through the distributed batch engine
        (:func:`repro.experiments.dist_sweep.dist_sweep`): cells are
        bucketed by shape signature, vmapped cells x seeds into one
        program per bucket, and sharded over ``devices`` forced host (or
        real) devices.  ``devices=1`` uses the same batched engine on
        one device — per-cell results are identical either way, and
        identical to this sequential path.  ``checkpoint_dir`` makes the
        sweep resumable at cell granularity (completed cells are loaded,
        not re-run)."""
        if devices is not None or checkpoint_dir is not None:
            from .dist_sweep import dist_sweep
            return dist_sweep(
                self, self.grid(topos, routings, patterns, evaluators, seeds),
                devices=devices, checkpoint_dir=checkpoint_dir,
                callback=callback)
        results: List[RunResult] = []
        for spec in self.grid(topos, routings, patterns, evaluators, seeds):
            rr = self.run(spec)
            if callback is not None:
                callback(rr)
            results.append(rr)
        return results
