"""Result layer: the canonical JSON-serializable experiment record.

Every evaluator reduces to one :class:`RunResult` per cell — a flat,
diffable record (cell identity strings, a ``metrics`` dict of plain
floats, a ``meta`` dict of bookkeeping, wall time) that round-trips
through JSON exactly.  The perf trajectory, the CI smoke artifact and
the CLI all speak this one format.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List

__all__ = ["RunResult", "results_to_json", "results_from_json",
           "summary_table"]


@dataclasses.dataclass(frozen=True)
class RunResult:
    """Outcome of one evaluated cell of the experiment matrix."""

    topo: str                  # canonical mini-spec, e.g. "sf(q=5)"
    routing: str               # e.g. "fatpaths(n_layers=9,rho=0.6)"
    pattern: str               # e.g. "adversarial"
    evaluator: str             # e.g. "transport(steps=400)"
    seed: int
    metrics: Dict[str, float]
    meta: Dict[str, Any]
    wall_s: float

    @property
    def cell_id(self) -> str:
        return (f"{self.topo}/{self.routing}/{self.pattern}/"
                f"{self.evaluator}@s{self.seed}")

    def to_dict(self) -> Dict[str, Any]:
        return {"topo": self.topo, "routing": self.routing,
                "pattern": self.pattern, "evaluator": self.evaluator,
                "seed": self.seed, "metrics": dict(self.metrics),
                "meta": dict(self.meta), "wall_s": self.wall_s}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunResult":
        return cls(topo=d["topo"], routing=d["routing"],
                   pattern=d["pattern"], evaluator=d["evaluator"],
                   seed=int(d["seed"]), metrics=dict(d["metrics"]),
                   meta=dict(d["meta"]), wall_s=float(d["wall_s"]))

    @classmethod
    def from_json(cls, text: str) -> "RunResult":
        return cls.from_dict(json.loads(text))


def results_to_json(results: Iterable[RunResult], indent: int = 1) -> str:
    return json.dumps([r.to_dict() for r in results], indent=indent,
                      sort_keys=True)


def results_from_json(text: str) -> List[RunResult]:
    return [RunResult.from_dict(d) for d in json.loads(text)]


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        if v != v:                       # nan
            return "nan"
        if abs(v) >= 1000 or (0 < abs(v) < 0.01):
            return f"{v:.3g}"
        return f"{v:.2f}"
    return str(v)


def summary_table(results: Iterable[RunResult]) -> str:
    """Aligned text table: one row per cell, metrics as k=v."""
    rows = []
    for r in results:
        cell = f"{r.topo} {r.routing} {r.pattern} {r.evaluator} s{r.seed}"
        mets = " ".join(f"{k}={_fmt(v)}" for k, v in sorted(r.metrics.items()))
        rows.append((cell, mets, r.wall_s))
    if not rows:
        return "(no results)"
    w = max(len(c) for c, _, _ in rows)
    return "\n".join(f"{c:<{w}}  [{t:6.2f}s]  {m}" for c, m, t in rows)
