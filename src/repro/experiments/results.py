"""Result layer: the canonical JSON-serializable experiment record.

Every evaluator reduces to one :class:`RunResult` per cell — a flat,
diffable record (cell identity strings, a ``metrics`` dict of plain
floats, a ``meta`` dict of bookkeeping, wall time) that round-trips
through JSON exactly.  The perf trajectory, the CI smoke artifact and
the CLI all speak this one format.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List

__all__ = ["RunResult", "results_to_json", "results_from_json",
           "summary_table", "order_results", "compare_results",
           "EXECUTION_META_KEYS"]


@dataclasses.dataclass(frozen=True)
class RunResult:
    """Outcome of one evaluated cell of the experiment matrix."""

    topo: str                  # canonical mini-spec, e.g. "sf(q=5)"
    routing: str               # e.g. "fatpaths(n_layers=9,rho=0.6)"
    pattern: str               # e.g. "adversarial"
    evaluator: str             # e.g. "transport(steps=400)"
    seed: int
    metrics: Dict[str, float]
    meta: Dict[str, Any]
    wall_s: float

    @property
    def cell_id(self) -> str:
        return (f"{self.topo}/{self.routing}/{self.pattern}/"
                f"{self.evaluator}@s{self.seed}")

    def to_dict(self) -> Dict[str, Any]:
        return {"topo": self.topo, "routing": self.routing,
                "pattern": self.pattern, "evaluator": self.evaluator,
                "seed": self.seed, "metrics": dict(self.metrics),
                "meta": dict(self.meta), "wall_s": self.wall_s}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunResult":
        return cls(topo=d["topo"], routing=d["routing"],
                   pattern=d["pattern"], evaluator=d["evaluator"],
                   seed=int(d["seed"]), metrics=dict(d["metrics"]),
                   meta=dict(d["meta"]), wall_s=float(d["wall_s"]))

    @classmethod
    def from_json(cls, text: str) -> "RunResult":
        return cls.from_dict(json.loads(text))


def results_to_json(results: Iterable[RunResult], indent: int = 1) -> str:
    return json.dumps([r.to_dict() for r in results], indent=indent,
                      sort_keys=True)


def results_from_json(text: str) -> List[RunResult]:
    return [RunResult.from_dict(d) for d in json.loads(text)]


# Meta keys that describe HOW a cell was executed (timings, cache
# hit/miss counters, batch bookkeeping), not WHAT it computed.  They
# legitimately differ between a sequential sweep and a distributed one
# (artifact builds land on different cells, walls differ), so the
# cell-identity comparison below ignores them.
EXECUTION_META_KEYS = frozenset({
    "build_s", "build_device_s", "cache_builds", "cache_hits",
    "sweep_bucket", "sweep_resumed", "sweep_chunks",
})


def order_results(results: Iterable[RunResult],
                  cell_ids: Iterable[str]) -> List[RunResult]:
    """Reorder ``results`` to match the canonical ``cell_ids`` sequence.

    The distributed sweep engine executes cells bucket-by-bucket (grouped
    by shape signature), so completion order depends on bucketing and
    device count; the emitted artifact must not.  Unknown ids raise —
    a sweep must account for every planned cell."""
    by_id: Dict[str, List[RunResult]] = {}
    for r in results:
        by_id.setdefault(r.cell_id, []).append(r)
    out: List[RunResult] = []
    for cid in cell_ids:
        bucket = by_id.get(cid)
        if not bucket:
            raise KeyError(f"no result for planned cell {cid!r}")
        out.append(bucket.pop(0))
    leftover = [cid for cid, rs in by_id.items() if rs]
    if leftover:
        raise KeyError(f"results for unplanned cells: {leftover[:3]!r}...")
    return out


def _close(a: float, b: float, rtol: float) -> bool:
    if a == b:                            # covers ints, exact floats, strings
        return True
    if isinstance(a, float) and isinstance(b, float):
        if a != a and b != b:             # NaN == NaN for identity purposes
            return True
        return rtol > 0 and abs(a - b) <= rtol * max(abs(a), abs(b))
    return False


def compare_results(a: Iterable[RunResult], b: Iterable[RunResult],
                    rtol: float = 0.0) -> List[str]:
    """Cell-for-cell identity check: returns a list of human-readable
    mismatch descriptions (empty == identical).

    Cells are matched by ``cell_id``; ``metrics`` and ``meta`` must agree
    exactly (``rtol`` > 0 allows a relative tolerance on float values,
    for cross-machine artifact comparison), except ``wall_s`` and the
    :data:`EXECUTION_META_KEYS` which describe execution, not results."""
    a, b = list(a), list(b)
    diffs: List[str] = []
    bi = {r.cell_id: r for r in b}
    if len(bi) != len(b):
        diffs.append("duplicate cell_ids in right-hand results")
    ai_ids = [r.cell_id for r in a]
    if sorted(ai_ids) != sorted(bi):
        only_a = set(ai_ids) - set(bi)
        only_b = set(bi) - set(ai_ids)
        diffs.append(f"cell sets differ: only-left={sorted(only_a)[:3]} "
                     f"only-right={sorted(only_b)[:3]}")
        return diffs
    for ra in a:
        rb = bi[ra.cell_id]
        for field, da, db in (("metrics", ra.metrics, rb.metrics),
                              ("meta", ra.meta, rb.meta)):
            ka = set(da) - EXECUTION_META_KEYS
            kb = set(db) - EXECUTION_META_KEYS
            if ka != kb:
                diffs.append(f"{ra.cell_id}: {field} keys differ "
                             f"{sorted(ka ^ kb)}")
                continue
            for k in sorted(ka):
                if not _close(da[k], db[k], rtol):
                    diffs.append(f"{ra.cell_id}: {field}[{k}] "
                                 f"{da[k]!r} != {db[k]!r}")
    return diffs


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        if v != v:                       # nan
            return "nan"
        if abs(v) >= 1000 or (0 < abs(v) < 0.01):
            return f"{v:.3g}"
        return f"{v:.2f}"
    return str(v)


def summary_table(results: Iterable[RunResult]) -> str:
    """Aligned text table: one row per cell, metrics as k=v."""
    rows = []
    for r in results:
        cell = f"{r.topo} {r.routing} {r.pattern} {r.evaluator} s{r.seed}"
        mets = " ".join(f"{k}={_fmt(v)}" for k, v in sorted(r.metrics.items()))
        rows.append((cell, mets, r.wall_s))
    if not rows:
        return "(no results)"
    w = max(len(c) for c, _, _ in rows)
    return "\n".join(f"{c:<{w}}  [{t:6.2f}s]  {m}" for c, m, t in rows)
