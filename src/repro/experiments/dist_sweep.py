"""Distributed sweep engine: the whole evaluation matrix as device-
parallel batched programs.

The sequential :meth:`Session.sweep` runs one cell at a time — one
``lax.scan`` dispatch per (cell, sim-seed), wall-clock-bound long before
the paper-scale grid (six topologies x four schemes x many patterns and
seeds, §7).  This engine converts the grid into a handful of batched
device programs:

1. **bucket** — transport cells are grouped by padded shape signature:
   identical :class:`~repro.core.transport.SimConfig` (scheme/transport/
   steps/...), identical layer count L, and the same power-of-two size
   class of flow / virtual-link counts (cells in a bucket pay each
   other's padding, so size classes bound the waste at 2x);
2. **pad** — each cell's prepared scan operands are padded to the bucket
   maxima with *exactness-preserving* padding
   (:func:`repro.core.transport.pad_prepared`): padded flows never
   start, padded hop slots map to the write-only trash link, padded link
   slots are never indexed.  Per-flow randomness is ``fold_in``-keyed by
   flow index, so padding changes no real flow's draws;
3. **vmap** — all of a bucket's (cell, sim-seed) elements run as ONE
   program, ``jax.vmap`` over the stacked operands;
4. **shard_map** — the element axis is sharded over a
   :class:`repro.dist.Runtime` mesh (``--devices N`` forced host devices
   or real accelerators), so an 8-device host advances ~8 cells per
   dispatch.

Because steps 2-4 are all bit-exact transformations of the standalone
simulation, per-cell results are IDENTICAL to the sequential engine and
independent of device count — CI asserts sequential == ``--devices 8``
cell-for-cell (see :func:`repro.experiments.results.compare_results`).

The transport scan's adaptive horizon (PR 5) composes with all of the
above: a batched ``lax.while_loop`` stops each element's chunked scan
as soon as its flows are done or provably stuck, which jax's batching
rule applies per element (finished elements' carries are frozen by
``select``), so early exit stays bit-identical under vmap/shard_map
too.  The executed chunk count is surfaced as ``sweep_chunks`` in each
cell's meta — execution bookkeeping (like ``sweep_bucket``), never part
of the results, and ignored by :func:`compare_results`.

Sweeps are resumable: with a checkpoint directory every finished cell is
committed (atomic per-cell JSON, :class:`repro.ckpt.SweepCheckpoint`)
and a re-run loads completed cells instead of re-simulating them.
Non-transport evaluators (``mat``, ``fabric``) fall back to the
sequential path within the same sweep and share its checkpointing.

Graceful degradation (PR 8): a bucket whose compile or execution fails
— a Pallas lowering/runtime error on an exotic shape, say — is retried
ONCE with every cell forced onto the ``ref`` kernel backend; if the
retry fails too, the bucket's cells are emitted with empty metrics and
a structured ``error`` meta field instead of poisoning the whole
artifact.  Cells whose simulation state comes back non-finite (inf/NaN
delivered bytes) are quarantined the same way.  Error cells are NEVER
checkpointed, so a later resume re-attempts exactly them.

Emission is streamed (``callback`` fires as each cell completes,
bucket-by-bucket) but the returned list — and therefore every sweep
artifact — is in canonical grid order, independent of execution order
(:func:`repro.experiments.results.order_results`).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.sweep import SweepCheckpoint
from ..core import transport as transport_mod
from ..dist.sharding import P, Runtime, host_device_runtime
from .catalog import EVALUATORS, fct_metrics, transport_meta, transport_plan
from .results import RunResult, order_results
from .session import ResolvedCell, Session
from .specs import ExperimentSpec

__all__ = ["dist_sweep", "bucket_signature"]


@dataclasses.dataclass
class _Work:
    """One transport cell planned for batched execution.  Only the
    cheap shape signature is computed up front; the heavy scan operands
    (the (L, F, H+2) path tensor) are built per-bucket at dispatch time
    so peak memory scales with one bucket, not the whole grid."""

    spec: ExperimentSpec
    cell: ResolvedCell
    cfg: Any                     # SimConfig (seed = the cell's seed)
    sim_seeds: List[int]
    n_flows: int
    e_tot: int
    n_layers: int
    ev_meta: Dict[str, Any]
    pre: Dict[str, float]
    post: Dict[str, float]
    resolve_s: float
    size: Any = None             # (F,) float32, filled at dispatch
    start: Any = None            # (F,) float32 flow start times, ditto


def _ceil_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def bucket_signature(cfg, static) -> tuple:
    """The batch-compatibility key for a prepared transport cell: the
    full SimConfig with the seed normalized away (the PRNG key is a scan
    *operand*, not part of the program) plus the layer count L.  L is a
    hard key — padding the layer axis would change layer-choice draws —
    while flows / links / hop depth pad exactly and stay out of the
    key."""
    return (dataclasses.replace(cfg, seed=0), static[1])


def padded_signature(cfg, n_layers: int, n_flows: int, e_tot: int,
                     link_down: bool = False, churn_k: int = 0) -> tuple:
    """The bucketing key actually used to group cells: the compatibility
    key plus the power-of-two size class of the flow count and the
    virtual-link count.  Cells in one bucket batch into one program and
    pay each other's padding, so a 100-flow cell must not share a bucket
    with a 10k-flow cell — size classes bound the waste at 2x while
    still merging near-same-size cells across topologies.  Computed from
    the cheap :func:`repro.core.transport.shape_signature` probe, no
    scan operands needed.  ``link_down`` flags cells with a mid-run
    link-death schedule: their prepared operand tree carries one extra
    leaf (and the scan compiles an extra capacity select), so they must
    not stack with pristine cells.  ``churn_k`` is the churn schedule's
    per-link event-slot count (0 = no schedule): churn cells carry two
    extra (e, K, ...) operands and extra scan lanes, so they never share
    a bucket with pristine cells, and K is an exact stacking dimension —
    not a pow2 class — because event slots are never padded."""
    return (dataclasses.replace(cfg, seed=0), n_layers,
            _ceil_pow2(n_flows), _ceil_pow2(e_tot), bool(link_down),
            int(churn_k))


# The compiled bucket programs live at module scope: a fresh
# ``jax.jit(closure)`` per call would recompile every bucket on every
# sweep (jit caches key on function identity).  ``cfg``/``static`` are
# hashable static args; the ``_sharded_*`` variants additionally
# memoize per Runtime so the shard_map wrapping is built once per mesh.
#
# Two program shapes: ``_*_scan`` batches independent (cell, seed)
# elements — operands per element; ``_*_scan_seeds`` batches cells with
# a NESTED vmap over each cell's sim-seed keys, so a seed sweep shares
# one copy of the cell's operand tensors instead of shipping
# ``n_seeds`` duplicates to the device.
@functools.partial(jax.jit, static_argnames=("cfg", "static"))
def _vmapped_scan(stacked, keys, cfg, static):
    return jax.vmap(
        lambda a, k: transport_mod._run_scan_impl(a, k, cfg, static)
    )(stacked, keys)


@functools.partial(jax.jit, static_argnames=("cfg", "static"))
def _vmapped_scan_seeds(stacked, keys, cfg, static):
    return jax.vmap(lambda a, ks: jax.vmap(
        lambda k: transport_mod._run_scan_impl(a, k, cfg, static))(ks)
    )(stacked, keys)


@functools.lru_cache(maxsize=128)
def _sharded_scan(rt: Runtime, cfg, static):
    axis = rt.data_axes[0]

    def body(stacked, keys):
        return _vmapped_scan(stacked, keys, cfg, static)

    return jax.jit(rt.shard_map(body, in_specs=(P(axis), P(axis)),
                                out_specs=P(axis)))


@functools.lru_cache(maxsize=128)
def _sharded_scan_seeds(rt: Runtime, cfg, static):
    axis = rt.data_axes[0]

    def body(stacked, keys):
        return _vmapped_scan_seeds(stacked, keys, cfg, static)

    return jax.jit(rt.shard_map(body, in_specs=(P(axis), P(axis)),
                                out_specs=P(axis)))


def _dispatch_bucket(works: List[_Work], rt: Runtime, bucket_index: int):
    """Asynchronously launch one bucket's batched program.

    Scheduling policy over the mesh:

    * no mesh                    -> plain vmapped program;
    * elements >= mesh size      -> ``shard_map`` the element axis over
      the whole mesh (intra-bucket data parallelism);
    * elements <  mesh size      -> run the whole (small) bucket on ONE
      device, round-robin by bucket index — different buckets then
      execute concurrently on different devices (inter-bucket
      parallelism), instead of padding a 2-element bucket out to an
      8-device mesh.

    Seed sweeps share operands: when every cell in the bucket has the
    same seed count S > 1 — and the cell axis alone still has enough
    units to feed the mesh — the program is a NESTED vmap: outer over
    cells (operands stacked once), inner over each cell's S PRNG keys,
    so the device sees one copy of each cell's tensors, not S
    duplicates.  Mixed seed counts, or fewer cells than devices, fall
    back to the flat one-element-per-(cell, seed) layout (duplicated
    operands, but every element shardable).

    Returns ``(finals, elements, mode, pads)`` where ``finals`` are
    device arrays still computing — jax dispatch is async, so callers
    may launch further buckets before blocking on this one
    (:func:`_finalize_bucket`) — ``elements`` is the flat (work_idx,
    sim_seed) order matching the flattened batch axis/axes of
    ``finals``, and ``pads`` the realized (F, E, H) pad targets.

    The heavy scan operands are built HERE, bucket by bucket: preparing
    the whole grid up front would hold every cell's (L, F, H+2) path
    tensor live at once.
    """
    cfg0 = dataclasses.replace(works[0].cfg, seed=0)
    prepared = []
    for w in works:
        arrs, static = transport_mod.prepare(
            w.cell.topo, w.cell.bundle.routing, w.cell.workload, w.cfg)
        w.size = np.asarray(arrs["size"])
        w.start = np.asarray(arrs["start"])
        prepared.append((arrs, static))
    n_flows = max(w.n_flows for w in works)
    n_edges = max(w.e_tot for w in works)
    hop_slots = max(a["path_edges"].shape[2] for a, _ in prepared)
    static_pad = None
    padded_cells = []
    for arrs, static in prepared:
        padded, static_pad = transport_mod.pad_prepared(
            arrs, static, n_flows=n_flows, n_edges=n_edges,
            hop_slots=hop_slots)
        padded_cells.append(padded)
    del prepared

    n_dev = 1 if rt.mesh is None else rt.fsdp_size
    seed_counts = {len(w.sim_seeds) for w in works}
    # Nest only when the OUTER (cell) axis can still feed the mesh:
    # sharding happens over whatever axis the program batches, so a
    # 6-cell x 8-seed bucket on an 8-device mesh must use the flat
    # 48-element layout (duplicated operands, full parallelism), not 6
    # nested units serialized onto one device.
    nest_seeds = (seed_counts == {max(seed_counts)}
                  and max(seed_counts) > 1
                  and (n_dev == 1 or len(works) >= n_dev))
    if nest_seeds:
        # units = cells; keys (C, S, 2); operands one copy per cell.
        units = list(padded_cells)
        key_rows = [[jax.random.PRNGKey(s) for s in w.sim_seeds]
                    for w in works]
        scan, sharded = _vmapped_scan_seeds, _sharded_scan_seeds
    else:
        # units = (cell, seed) elements; operands duplicated per seed.
        units, key_rows = [], []
        for w, padded in zip(works, padded_cells):
            for s in w.sim_seeds:
                units.append(padded)
                key_rows.append(jax.random.PRNGKey(s))
        scan, sharded = _vmapped_scan, _sharded_scan
    elements = [(wi, s) for wi, w in enumerate(works) for s in w.sim_seeds]

    n_real = len(units)
    use_shard_map = rt.mesh is not None and n_real >= n_dev
    if use_shard_map:
        while len(units) % n_dev:       # pad the unit axis to the mesh size
            units.append(units[0])
            key_rows.append(key_rows[0])

    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    keys = jnp.asarray(np.stack([np.asarray(k) for k in key_rows]))

    if rt.mesh is None:
        finals = scan(stacked, keys, cfg0, static_pad)
        mode = "vmap"
    elif use_shard_map:
        finals = sharded(rt, cfg0, static_pad)(stacked, keys)
        mode = f"shard_map[{n_dev}]"
    else:
        dev = rt.mesh.devices.flat[bucket_index % n_dev]
        stacked = jax.device_put(stacked, dev)
        keys = jax.device_put(keys, dev)
        finals = scan(stacked, keys, cfg0, static_pad)
        mode = f"device[{bucket_index % n_dev}]"
    mode += "+seednest" if nest_seeds else ""
    return finals, (elements, nest_seeds), mode, (n_flows, n_edges,
                                                  hop_slots)


def _finalize_bucket(works: List[_Work], finals, elements
                     ) -> Tuple[Dict[int, list], Dict[int, int]]:
    """Block on one bucket's device results and split them back into
    per-cell, per-seed :class:`SimResult`s (padding stripped).  Nested
    seed batches come back as (C, S, ...) leaves; flattening them
    cell-major matches the flat ``elements`` order exactly.

    Also returns each cell's executed chunk count (the adaptive
    horizon's early-exit depth, max over its sim seeds) — execution
    bookkeeping for the sweep meta, never part of the results."""
    elements, nested = elements
    n_elem = len(elements)

    def flat(v):
        v = np.asarray(v)
        if nested:                                    # (C, S, ...) leaves
            v = v.reshape((v.shape[0] * v.shape[1],) + v.shape[2:])
        return v[:n_elem]

    finals = {k: flat(v)
              for k, v in jax.block_until_ready(finals).items()}
    sims: Dict[int, list] = {wi: [] for wi in range(len(works))}
    chunks: Dict[int, int] = {wi: 0 for wi in range(len(works))}
    for i, (wi, s) in enumerate(elements):
        w = works[wi]
        sims[wi].append(transport_mod.batch_result(
            w.size, {k: v[i] for k, v in finals.items()},
            dataclasses.replace(w.cfg, seed=s), n_flows=w.n_flows,
            start=w.start))
        chunks[wi] = max(chunks[wi], int(finals["horizon_chunks"][i]))
    return sims, chunks


def dist_sweep(session: Session, cells: List[ExperimentSpec], *,
               devices: Optional[int] = None,
               runtime: Optional[Runtime] = None,
               checkpoint_dir: Optional[str] = None,
               callback: Optional[Callable[[RunResult], None]] = None,
               log: Optional[Callable[[str], None]] = None
               ) -> List[RunResult]:
    """Run ``cells`` through the batched engine (module docstring).

    ``devices=None`` or ``1`` runs the same bucketed/padded programs on
    one device; results are identical for every device count.  The
    returned list is in the order of ``cells`` (canonical grid order).
    """
    rt = runtime if runtime is not None else host_device_runtime(
        devices if devices is not None else 1)
    ckpt = SweepCheckpoint(checkpoint_dir) if checkpoint_dir else None
    say = log if log is not None else (lambda _msg: None)

    def emit(rr: RunResult, done_via_ckpt: bool = False,
             persist: bool = True) -> RunResult:
        # Error/quarantined cells pass persist=False: they must NOT be
        # checkpointed, so a checkpoint resume re-attempts them.
        if ckpt is not None and not done_via_ckpt and persist:
            ckpt.put(rr.cell_id, rr.to_dict())
        if callback is not None:
            callback(rr)
        return rr

    results: List[RunResult] = []
    batched: List[_Work] = []
    n_resumed = 0
    for spec in cells:
        if ckpt is not None:
            prev = ckpt.get(spec.cell_id)
            if prev is not None:
                rr = RunResult.from_dict(prev)
                rr = dataclasses.replace(
                    rr, meta={**rr.meta, "sweep_resumed": True})
                results.append(emit(rr, done_via_ckpt=True))
                n_resumed += 1
                continue
        _, kw = EVALUATORS.resolve(spec.evaluator)
        if spec.evaluator.name != "transport":
            # mat / fabric / custom evaluators: sequential fallback.
            results.append(emit(session.run(spec)))
            continue
        t0 = time.perf_counter()
        pre = session.stats_snapshot()
        cell = session.resolve(spec)
        cfg, sim_seeds = transport_plan(cell, **kw)
        n_flows, e_tot, n_layers = transport_mod.shape_signature(
            cell.topo, cell.bundle.routing, cell.workload)
        batched.append(_Work(
            spec=spec, cell=cell, cfg=cfg, sim_seeds=sim_seeds,
            n_flows=n_flows, e_tot=e_tot, n_layers=n_layers,
            ev_meta=transport_meta(cell, cfg, sim_seeds),
            pre=pre, post=session.stats_snapshot(),
            resolve_s=time.perf_counter() - t0))
    if n_resumed:
        say(f"# resumed {n_resumed} completed cell(s) from checkpoint")

    buckets: Dict[tuple, List[_Work]] = {}
    for w in batched:
        has_lds = getattr(w.cell.bundle.routing, "link_down_step",
                          None) is not None
        lc = getattr(w.cell.bundle.routing, "link_churn", None)
        buckets.setdefault(
            padded_signature(w.cfg, w.n_layers, w.n_flows, w.e_tot,
                             link_down=has_lds,
                             churn_k=0 if lc is None else int(lc.shape[2])),
            []).append(w)

    # Dispatch ahead of finalize: jax dispatch is async, so small
    # buckets placed on different devices (and shard_mapped big ones)
    # execute concurrently while the host pads/stacks the next buckets.
    # The dispatch window is BOUNDED (a few buckets beyond the mesh
    # size): an unbounded launch-everything-first loop would hold every
    # bucket's stacked device operands live at once, scaling peak
    # memory with the whole grid instead of the window.
    t_sim = time.perf_counter()
    n_dev = max(1, rt.fsdp_size)
    max_in_flight = max(4, 2 * n_dev)
    in_flight: List[tuple] = []
    n_buckets = n_elems = 0

    def emit_error(bi: int, w: _Work, error: Dict[str, Any]):
        # Structured quarantine record: empty metrics, the failure in
        # meta, never checkpointed (a resume re-attempts the cell).
        results.append(emit(session.finish_result(
            w.spec, w.cell, {}, w.ev_meta, w.pre, w.resolve_s,
            extra_meta={"sweep_bucket": bi, "error": error},
            post=w.post), persist=False))

    def finalize(bi, works, finals, desc, t_disp, retried: bool):
        # One-shot graceful degradation: a failed compile/execute is
        # retried with every cell forced onto the ref kernel backend
        # (a fresh bucket program — the SimConfig is jit-static); a
        # second failure quarantines the bucket's cells.
        try:
            sims, chunks = _finalize_bucket(works, finals, desc)
        except Exception as e:                      # noqa: BLE001
            if retried:
                say(f"# bucket {bi}: ref-backend retry failed too "
                    f"({type(e).__name__}); quarantining "
                    f"{len(works)} cell(s)")
                for w in works:
                    emit_error(bi, w, {
                        "type": "bucket_failure", "retried_ref": True,
                        "exception": type(e).__name__,
                        "message": str(e)[:500]})
                return
            say(f"# bucket {bi}: batched execution failed "
                f"({type(e).__name__}); retrying once on the "
                "ref kernel backend")
            for w in works:
                w.cfg = dataclasses.replace(w.cfg, kernel_backend="ref")
            t2 = time.perf_counter()
            try:
                finals2, desc2, _mode, _pads = _dispatch_bucket(
                    works, rt, bi)
            except Exception as e2:                 # noqa: BLE001
                say(f"# bucket {bi}: ref-backend retry failed too "
                    f"({type(e2).__name__}); quarantining "
                    f"{len(works)} cell(s)")
                for w in works:
                    emit_error(bi, w, {
                        "type": "bucket_failure", "retried_ref": True,
                        "exception": type(e2).__name__,
                        "message": str(e2)[:500]})
                return
            finalize(bi, works, finals2, desc2, t2, retried=True)
            return
        bucket_wall = time.perf_counter() - t_disp
        for wi, w in enumerate(works):
            bad = [r for r in sims[wi]
                   if not (np.all(np.isfinite(r.delivered))
                           and np.isfinite(r.link_util_mean))]
            if bad:
                say(f"# bucket {bi}: non-finite simulation state for "
                    f"{w.spec.cell_id}; quarantining")
                emit_error(bi, w, {"type": "nonfinite",
                                   "seeds_bad": len(bad)})
                continue
            metrics = fct_metrics(sims[wi])
            wall = w.resolve_s + bucket_wall * (len(w.sim_seeds)
                                                / max(1, len(desc[0])))
            results.append(emit(session.finish_result(
                w.spec, w.cell, metrics, w.ev_meta, w.pre, wall,
                extra_meta={"sweep_bucket": bi,
                            # adaptive-horizon early-exit depth: how many
                            # full scan chunks ran (execution meta — the
                            # sequential engine legitimately omits it).
                            "sweep_chunks": chunks[wi]}, post=w.post)))

    def finalize_oldest():
        bi, works, finals, desc, t_disp = in_flight.pop(0)
        finalize(bi, works, finals, desc, t_disp, retried=False)

    for bi, works in enumerate(buckets.values()):
        t_disp = time.perf_counter()
        try:
            finals, desc, mode, (nf, ne, nh) = _dispatch_bucket(works, rt,
                                                                bi)
        except Exception as e:                      # noqa: BLE001
            say(f"# bucket {bi}: dispatch failed ({type(e).__name__}); "
                "retrying once on the ref kernel backend")
            for w in works:
                w.cfg = dataclasses.replace(w.cfg, kernel_backend="ref")
            try:
                finals, desc, mode, (nf, ne, nh) = _dispatch_bucket(
                    works, rt, bi)
            except Exception as e2:                 # noqa: BLE001
                say(f"# bucket {bi}: ref-backend retry failed too "
                    f"({type(e2).__name__}); quarantining "
                    f"{len(works)} cell(s)")
                for w in works:
                    emit_error(bi, w, {
                        "type": "bucket_failure", "retried_ref": True,
                        "exception": type(e2).__name__,
                        "message": str(e2)[:500]})
                n_buckets += 1
                continue
        say(f"# bucket {bi}: {len(works)} cells x seeds = {len(desc[0])} "
            f"programs via {mode}, padded to F={nf} E={ne} H={nh}")
        in_flight.append((bi, works, finals, desc, t_disp))
        n_buckets += 1
        n_elems += len(desc[0])
        while len(in_flight) > max_in_flight:
            finalize_oldest()
    while in_flight:
        finalize_oldest()
    if n_buckets:
        say(f"# {n_buckets} bucket(s), {n_elems} batched programs, "
            f"simulate wall {time.perf_counter() - t_sim:.2f}s "
            f"on {n_dev} device(s)")

    return order_results(results, [c.cell_id for c in cells])
