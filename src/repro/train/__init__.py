from .optimizer import AdamWConfig, adamw_init, adamw_update, opt_specs  # noqa: F401
from .train_step import TrainConfig, make_train_step, make_train_state  # noqa: F401
