"""jit-able train step: loss -> grad -> (compressed) reduce -> AdamW.

Gradient flow per step (the distributed-optimization story):

  * microbatching (``grad_accum > 1``) runs as a ``lax.scan`` over
    microbatches — activation memory is one microbatch, gradients accumulate
    in the wire dtype;
  * under pjit the DP gradient reduction is emitted by XLA as
    reduce-scatter/all-gather against the fsdp-sharded parameters; casting
    grads to ``rt.collective_dtype`` (bf16) before accumulation halves the
    wire bytes (recorded in the dry-run);
  * optional int8 error-feedback compression (``AdamWConfig.compress``)
    quantises the gradient contribution per microbatch and carries the
    quantisation residual in optimizer state;
  * the FatPaths-layered multi-ring collective schedule lives in
    ``dist.collectives`` (shard_map + collective_permute); it is exercised
    standalone (correctness vs psum) and through ``benchmarks/bench_fabric``
    — under pjit the DP reduction is emitted by XLA, so the layered
    schedule is wired in at the mesh/device-order level (launch.mesh) and
    evaluated against the fabric model, not spliced into already-reduced
    pjit gradients.

``make_train_step`` returns a pure function
``(params, opt_state, batch, rng) -> (params, opt_state, metrics)`` which
the launcher jits with in/out shardings from ``make_train_state``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import P, Runtime
from ..models import model as model_mod
from ..models.common import dtype_of
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_init, adamw_update, ef_init, opt_specs

__all__ = ["TrainConfig", "make_train_state", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    grad_accum: int = 1


def make_train_state(cfg: ModelConfig, rt: Runtime, key,
                     tc: Optional[TrainConfig] = None):
    """(params, opt_state) + their PartitionSpec trees."""
    tc = tc or TrainConfig()
    params = model_mod.init_params(cfg, rt, key)
    opt = adamw_init(params)
    if tc.opt.compress == "int8_ef":
        opt["ef"] = ef_init(params)
    pspecs = model_mod.param_specs(cfg, rt)
    ospecs = opt_specs(pspecs, with_ef=tc.opt.compress == "int8_ef")
    return params, opt, pspecs, ospecs


def _quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def make_train_step(cfg: ModelConfig, rt: Runtime,
                    tc: Optional[TrainConfig] = None):
    tc = tc or TrainConfig()
    wire_dt = dtype_of(rt.collective_dtype)
    pspecs = model_mod.param_specs(cfg, rt)

    def _constrain(grads):
        """Pin gradient shardings to the parameter layout — otherwise XLA
        may materialise e.g. the (vocab, d) embedding gradient replicated
        (a 4 GiB scatter + all-reduce for a 256k vocab)."""
        if rt.mesh is None:
            return grads
        return jax.tree.map(lambda g, s: rt.shard_spec(g, s), grads, pspecs)

    def micro_loss(params, micro) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        return model_mod.loss_fn(params, cfg, rt, micro)

    grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

    def train_step(params, opt_state, batch, step_rng):
        del step_rng  # deterministic substrate; kept for API stability

        if tc.grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = _constrain(grads)
            grads = jax.tree.map(lambda g: g.astype(wire_dt), grads)
        else:
            def split(x):
                b = x.shape[0]
                mb = b // tc.grad_accum
                return x.reshape((tc.grad_accum, mb) + x.shape[1:])

            micro_batches = jax.tree.map(split, batch)
            # accumulate in f32 (bf16 accumulation loses ~1e-2 relative);
            # the wire cast happens once, after the scan, so the DP reduce
            # XLA emits at the optimizer boundary still moves wire_dt bytes.
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, micro):
                g_acc, loss_acc = acc
                (loss, _), g = grad_fn(params, micro)
                g = _constrain(g)
                if tc.opt.compress == "int8_ef":
                    def q(gi):
                        qi, s = _quantize_int8(gi.astype(jnp.float32))
                        return qi.astype(jnp.float32) * s
                    g = jax.tree.map(q, g)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (zero_g, jnp.float32(0.0)), micro_batches)
            grads = jax.tree.map(
                lambda g: (g / tc.grad_accum).astype(wire_dt), grads)
            loss = loss_sum / tc.grad_accum
            metrics = {"ce": loss, "aux": jnp.float32(0.0)}

        new_params, new_opt, opt_metrics = adamw_update(
            tc.opt, params, grads, opt_state)
        out_metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_opt, out_metrics

    return train_step
