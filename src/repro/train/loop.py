"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler accounting.

The loop is deliberately boring — that is the point of restart-safety:

  state  = (params, opt)           # sharded pytrees
  data   = deterministic (seed, step) pipeline  -> same batches after restart
  ckpt   = atomic + async (ckpt.CheckpointManager)

Failure handling at scale (documented contract, exercised by tests):
  * ``inject_failure_at``: raises mid-run; a fresh ``run()`` on the same
    directory restores the latest committed step and reproduces the exact
    same loss trajectory (tests/test_train.py::test_failure_injection_and_restart_reproduces_trajectory).
  * elastic restart: the restore path re-shards to the *current* mesh, so a
    job restarted on a different pod count continues
    (tests/test_ckpt_elastic.py).
  * stragglers: in synchronous SPMD the slowest device gates the step; the
    loop records per-step wall time and flags outliers (> straggler_factor
    × rolling median). On a real cluster the flagged hosts are the
    candidates for replacement; here the hook is unit-tested with a fake
    clock. Collective-level mitigation (layer re-routing) lives in
    dist.fabric / the transport simulator.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..ckpt.checkpoint import CheckpointManager
from ..data.pipeline import DataConfig, SyntheticDataset
from ..dist.sharding import Runtime
from ..models.config import ModelConfig
from .train_step import TrainConfig, make_train_state, make_train_step

__all__ = ["LoopConfig", "TrainLoop"]


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    keep: int = 3
    straggler_factor: float = 3.0
    inject_failure_at: Optional[int] = None   # raise to simulate a node loss


class TrainLoop:
    def __init__(self, cfg: ModelConfig, rt: Runtime, data: DataConfig,
                 tc: Optional[TrainConfig] = None,
                 lc: Optional[LoopConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg, self.rt = cfg, rt
        self.tc = tc or TrainConfig()
        self.lc = lc or LoopConfig(total_steps=100)
        self.data = SyntheticDataset(cfg, data, rt)
        self.clock = clock
        self.step_fn = None
        self.mgr = (CheckpointManager(self.lc.ckpt_dir, self.lc.keep)
                    if self.lc.ckpt_dir else None)
        self.history: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []

    # -- state ------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params, opt, pspecs, ospecs = make_train_state(
            self.cfg, self.rt, jax.random.PRNGKey(seed), self.tc)
        if self.rt.mesh is not None:
            params = jax.tree.map(
                lambda p, s: jax.device_put(p, jax.NamedSharding(self.rt.mesh, s)),
                params, pspecs, is_leaf=lambda x: hasattr(x, "shape"))
        return {"params": params, "opt": opt}

    def _maybe_restore(self, state):
        start = 0
        if self.mgr is not None:
            try:
                state, extra = self.mgr.restore_latest(state)
                start = int(extra.get("next_step", 0))
            except FileNotFoundError:
                pass
        return state, start

    # -- run --------------------------------------------------------------
    def run(self, seed: int = 0) -> Dict[str, Any]:
        state = self.init_state(seed)
        state, start = self._maybe_restore(state)
        if self.step_fn is None:
            self.step_fn = jax.jit(make_train_step(self.cfg, self.rt, self.tc),
                                   donate_argnums=(0, 1))
        times: List[float] = []
        for step in range(start, self.lc.total_steps):
            if self.lc.inject_failure_at is not None and \
                    step == self.lc.inject_failure_at:
                raise RuntimeError(f"injected failure at step {step}")
            batch = self.data.batch(step)
            t0 = self.clock()
            params, opt, metrics = self.step_fn(
                state["params"], state["opt"], batch, jax.random.PRNGKey(step))
            state = {"params": params, "opt": opt}
            jax.block_until_ready(metrics["loss"])
            dt = self.clock() - t0
            times.append(dt)
            med = float(np.median(times[-32:]))
            if len(times) > 4 and dt > self.lc.straggler_factor * med:
                self.straggler_steps.append(step)
            if step % self.lc.log_every == 0 or step == self.lc.total_steps - 1:
                self.history.append(
                    {"step": step, "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"]),
                     "wall_s": dt})
            if self.mgr is not None and (step + 1) % self.lc.ckpt_every == 0:
                self.mgr.save(step + 1, state, {"next_step": step + 1})
        if self.mgr is not None:
            self.mgr.save(self.lc.total_steps, state,
                          {"next_step": self.lc.total_steps})
            self.mgr.wait()
        return {"state": state, "history": self.history,
                "stragglers": self.straggler_steps}
