"""AdamW with ZeRO-style sharded state (no optax dependency).

Optimizer moments inherit the parameter PartitionSpecs, which are already
fsdp×tp sharded (``models.model.param_specs``): the m/v state for a P-param
model occupies P/n_devices per device — ZeRO-1/3 equivalent in the pjit
world.  The master copy is f32 regardless of ``param_dtype``.

Distributed-optimization tricks, in the order they appear on the wire:
  1. gradients leave the backward pass in ``rt.collective_dtype``
     (bf16 by default — 2× wire-byte reduction; the psum/reduce-scatter XLA
     emits is bf16, visible in the dry-run collective-bytes term);
  2. optional int8 error-feedback compression for the DP reduce
     (``compress="int8_ef"``) — 4× wire reduction, residual carried in the
     optimizer state (beyond-paper knob, off by default);
  3. global-norm clipping happens *after* the reduce on the sharded grads
     (norm is one scalar all-reduce).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_specs",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    compress: str = "none"        # none | int8_ef


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def ef_init(params) -> Dict[str, Any]:
    """Error-feedback residual state for int8 compressed reductions."""
    return {"resid": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)}


def opt_specs(param_spec_tree, with_ef: bool = False):
    from ..dist.sharding import P
    leaf = lambda s: isinstance(s, P)
    out = {"m": param_spec_tree, "v": param_spec_tree, "step": P()}
    if with_ef:
        out["ef"] = {"resid": param_spec_tree}
    return out


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def _decay_mask(path) -> bool:
    """Weight decay on matrices only (no norms/embedding bias conventions)."""
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    names = [str(k) for k in keys]
    return not any(("norm" in n) or n in ("ln1", "ln2", "ln1_post", "ln2_post",
                                          "scale", "bias", "a_log", "d_skip",
                                          "dt_bias") for n in names)


def adamw_update(cfg: AdamWConfig, params, grads, state,
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step. grads may be bf16 (wire dtype); math is f32."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat = jax.tree_util.tree_map_with_path(upd, params, grads,
                                            state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    if "ef" in state:
        new_state["ef"] = state["ef"]
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics
