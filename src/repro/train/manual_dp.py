"""Manual data-parallel training with FatPaths-layered gradient sync.

Under GSPMD-managed DP the gradient reduction happens inside autodiff, in
the accumulation dtype, with XLA choosing the algorithm (measured in
EXPERIMENTS.md §Perf: the `collective_dtype` knob is a no-op there).
This module is the explicit alternative: the whole step runs in shard_map
over the data axis — params replicated, batch sharded — and the gradient
all-reduce is OURS:

  * ``dist.collectives.multiring_all_reduce`` with ``n_rings`` stride
    rings == the paper's layers (near-disjoint fabric paths);
  * the wire dtype is under OUR control at the JAX level.  Measured
    caveat (EXPERIMENTS.md §Perf): XLA:CPU hoists converts across
    ppermute and runs bf16 rings in f32 — on TPU bf16 collective-permutes
    are native, so the halving is real there; the int8+EF path as written
    sums ring payloads in int32 (overflow-safe) — true sub-f32 wire for
    it needs per-hop dequantisation schedules (future work);
  * straggler/fault semantics: each ring is an independent ppermute
    chain, so a slow link delays only its own flowlets (the fabric-model
    measurements in bench_fabric quantify the spread).

Intended for replicated-parameter (data-parallel-only) regimes — exactly
where gradient wire compression matters most (small/medium models on many
nodes).  Equivalence to the pjit step is tested on 8 host devices
(tests/test_manual_dp.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.collectives import layer_strides, multiring_all_reduce
from ..dist.sharding import P, Runtime
from ..models import model as model_mod
from ..models.common import dtype_of
from ..models.config import ModelConfig
from .optimizer import AdamWConfig, adamw_update

__all__ = ["ManualDPConfig", "make_manual_dp_step"]


@dataclasses.dataclass(frozen=True)
class ManualDPConfig:
    opt: AdamWConfig = AdamWConfig()
    n_rings: int = 4                 # FatPaths layers for the gradient AR
    wire: str = "bfloat16"           # float32 | bfloat16 | int8_ef


def make_manual_dp_step(cfg: ModelConfig, rt: Runtime,
                        mc: Optional[ManualDPConfig] = None):
    """(params, opt_state, ef, batch) -> (params, opt_state, ef, metrics).

    ``ef`` is the error-feedback residual tree (zeros_like(params) f32);
    pass it even for non-int8 wire (ignored).  rt.data_axes must span the
    whole mesh (replicated params).
    """
    mc = mc or ManualDPConfig()
    axis = rt.data_axes if len(rt.data_axes) > 1 else rt.data_axes[0]
    # inside the manual region every array is device-local: the model's
    # sharding constraints must no-op (mesh axes are 'manual' in here)
    rt_local = Runtime(mesh=None)

    def local_loss(params, micro):
        loss, _ = model_mod.loss_fn(params, cfg, rt_local, micro)
        return loss

    def step(params, opt_state, ef, batch):
        n = jax.lax.axis_size(axis)
        strides = layer_strides(n, mc.n_rings)
        loss, grads = jax.value_and_grad(local_loss)(params, batch)

        def sync(g, r):
            gf = g.astype(jnp.float32)
            if mc.wire == "int8_ef":
                gf = gf + r                      # carry-in residual
                scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
                q = jnp.clip(jnp.round(gf / scale), -127, 127)
                new_r = gf - q * scale           # local quantisation error
                wire_val = q.astype(jnp.int8)
                # rings sum int8 payloads in int32 to avoid overflow
                summed = multiring_all_reduce(
                    wire_val.astype(jnp.int32), axis, strides)
                out = summed.astype(jnp.float32) * scale / n
                return out, new_r
            wire_dt = dtype_of(mc.wire) if mc.wire != "float32" \
                else jnp.float32
            summed = multiring_all_reduce(gf.astype(wire_dt), axis, strides)
            return summed.astype(jnp.float32) / n, r

        pairs = jax.tree.map(sync, grads, ef)
        grads_g = jax.tree.map(lambda t: t[0], pairs,
                               is_leaf=lambda t: isinstance(t, tuple))
        new_ef = jax.tree.map(lambda t: t[1], pairs,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_params, new_opt, om = adamw_update(mc.opt, params, grads_g,
                                               opt_state)
        loss_g = jax.lax.pmean(loss, axis)
        return new_params, new_opt, new_ef, {"loss": loss_g, **om}

    if rt.mesh is None:
        raise ValueError("manual DP needs a mesh")

    rep = None  # replicated spec entry

    def specs_like(tree):
        return jax.tree.map(lambda x: P(*((rep,) * x.ndim)), tree)

    def wrapped(params, opt_state, ef, batch):
        in_specs = (specs_like(params), specs_like(opt_state),
                    specs_like(ef),
                    jax.tree.map(lambda x: P(rt.fsdp, *((None,) * (x.ndim - 1))),
                                 batch))
        out_specs = (specs_like(params), specs_like(opt_state),
                     specs_like(ef), {"loss": P(), "lr": P(),
                                      "grad_norm": P()})
        return jax.shard_map(step, mesh=rt.mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)(
            params, opt_state, ef, batch)

    return wrapped
