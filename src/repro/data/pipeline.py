"""Deterministic, sharded synthetic token pipeline.

The pipeline is deterministic in ``(seed, step)`` — restart-safe: resuming
from a checkpoint at step `s` regenerates exactly the batches the crashed
run would have seen.  Data are generated *per data-shard on the host that
owns it* via ``jax.make_array_from_callback``, so no host ever materialises
the global batch (the property that matters at 1000+ nodes).

Two generators:
  * ``lm``    — Zipf-ish token stream with induced bigram structure so a
                100M model trained for a few hundred steps shows a clearly
                falling loss (used by examples/train_e2e.py).
  * ``bytes`` — uniform tokens (throughput benchmarking; zero host compute).

For the modality-frontend architectures (hubert, qwen2-vl) the "tokens" are
precomputed frame/patch embeddings; ``make_global_batch`` produces the
matching ``embeds`` entry per the config's ``frontend``/``frontend_dim``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import P, Runtime
from ..models.config import ModelConfig

__all__ = ["DataConfig", "SyntheticDataset", "make_global_batch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    kind: str = "lm"              # lm | bytes
    zipf_a: float = 1.2           # lm: token frequency skew


def _lm_tokens(rng: np.random.Generator, b: int, s: int, vocab: int,
               zipf_a: float) -> np.ndarray:
    """Zipf unigram draw + deterministic bigram transition (t -> (a*t+c)%V
    with prob 1/2) — enough structure that CE falls quickly below ln(V)."""
    base = rng.zipf(zipf_a, size=(b, s)).astype(np.int64)
    base = (base - 1) % vocab
    follow = (base[:, :-1] * 31 + 17) % vocab
    mask = rng.random((b, s - 1)) < 0.5
    out = base.copy()
    out[:, 1:] = np.where(mask, follow, base[:, 1:])
    return out.astype(np.int32)


class SyntheticDataset:
    """Deterministic (seed, step) -> per-shard batch generator."""

    def __init__(self, cfg: ModelConfig, data: DataConfig, rt: Runtime):
        self.cfg = cfg
        self.data = data
        self.rt = rt
        assert data.global_batch % max(rt.fsdp_size, 1) == 0, (
            data.global_batch, rt.fsdp_size)

    # -- host-side generation for one data shard ------------------------------
    def _shard_tokens(self, step: int, shard: int, rows: int) -> np.ndarray:
        d = self.data
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, step, shard]))
        if d.kind == "bytes":
            return rng.integers(0, self.cfg.vocab,
                                size=(rows, d.seq_len), dtype=np.int32)
        return _lm_tokens(rng, rows, d.seq_len, self.cfg.vocab, d.zipf_a)

    def _shard_embeds(self, step: int, shard: int, rows: int) -> np.ndarray:
        d = self.data
        rng = np.random.default_rng(
            np.random.SeedSequence([d.seed, step, shard, 7]))
        return rng.standard_normal(
            (rows, d.seq_len, self.cfg.frontend_dim)).astype(np.float32)

    # -- global batch ----------------------------------------------------------
    def batch(self, step: int) -> Dict[str, jax.Array]:
        """Global batch assembled shard-by-shard (never a full host copy)."""
        cfg, d, rt = self.cfg, self.data, self.rt
        gshape = (d.global_batch, d.seq_len)
        if rt.mesh is None:
            tok = self._shard_tokens(step, 0, d.global_batch)
            out: Dict[str, jax.Array] = {"tokens": jnp.asarray(tok),
                                         "labels": jnp.asarray(tok)}
            if cfg.frontend is not None:
                out["embeds"] = jnp.asarray(
                    self._shard_embeds(step, 0, d.global_batch))
                out.pop("tokens")
            return out

        sharding = jax.NamedSharding(rt.mesh, rt.spec("fsdp", None))
        rows_per = d.global_batch // rt.fsdp_size

        def cb(index):
            # index is a tuple of slices into the global shape
            start = index[0].start or 0
            shard = start // rows_per
            return self._shard_tokens(step, shard, rows_per)

        tok = jax.make_array_from_callback(gshape, sharding, cb)
        out = {"tokens": tok, "labels": tok}
        if cfg.frontend is not None:
            esh = jax.NamedSharding(rt.mesh, rt.spec("fsdp", None, None))

            def cb_e(index):
                start = index[0].start or 0
                return self._shard_embeds(step, start // rows_per, rows_per)

            out["embeds"] = jax.make_array_from_callback(
                (d.global_batch, d.seq_len, cfg.frontend_dim), esh, cb_e)
            out.pop("tokens")
        return out


def make_global_batch(cfg: ModelConfig, rt: Runtime, global_batch: int,
                      seq_len: int, step: int = 0, seed: int = 0,
                      kind: str = "lm") -> Dict[str, jax.Array]:
    ds = SyntheticDataset(cfg, DataConfig(global_batch, seq_len, seed, kind), rt)
    return ds.batch(step)
