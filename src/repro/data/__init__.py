from .pipeline import DataConfig, SyntheticDataset, make_global_batch  # noqa: F401
