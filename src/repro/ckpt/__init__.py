from .checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint  # noqa: F401
