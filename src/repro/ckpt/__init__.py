from .checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint  # noqa: F401
from .sweep import SweepCheckpoint  # noqa: F401
