"""Sharded, restart-safe checkpoints with elastic re-shard restore.

Layout of one checkpoint (directory = one step):

    <dir>/step_000400/
        manifest.json       # tree structure, shapes, dtypes, shard map,
                            # data-pipeline cursor, mesh shape, checksums
        shard_00000.npz     # this process's param/opt leaves (flat names)
        ...
        COMMIT              # written last: a checkpoint without COMMIT is
                            # ignored by restore (crash-consistency)

Fault-tolerance properties (the large-scale story):
  * **atomic**: writers target ``.tmp-`` then rename; COMMIT is the final
    rename, so a node failure mid-save never corrupts the latest good step;
  * **async**: ``CheckpointManager.save`` snapshots leaves to host memory
    and writes on a background thread — the train loop blocks only for the
    device->host copy;
  * **elastic**: restore re-shards to whatever mesh the new job has
    (shapes are global; each process slices what it owns), so a restart on
    fewer/more pods works — checked by tests/test_ckpt.py;
  * **self-validating**: per-leaf crc32 in the manifest.

Single-process semantics here (the container), but the format is
process-sharded: every process writes ``shard_<proc>.npz`` of the leaves it
owns, and the manifest records the (process -> leaves) map.
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "CheckpointManager",
           "latest_step"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[name] = np.asarray(leaf)
    return flat


def _unflatten_into(tree, flat: Dict[str, np.ndarray]):
    names = []
    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        names.append("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                              for k in path))
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    new_leaves = []
    for name, ref in zip(names, leaves):
        arr = flat[name]
        assert tuple(arr.shape) == tuple(ref.shape), (name, arr.shape, ref.shape)
        new_leaves.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:08d}")


def latest_step(base: str) -> Optional[int]:
    if not os.path.isdir(base):
        return None
    steps = []
    for d in os.listdir(base):
        if d.startswith("step_") and \
                os.path.exists(os.path.join(base, d, "COMMIT")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def save_checkpoint(base: str, step: int, state: Dict[str, Any],
                    extra: Optional[Dict[str, Any]] = None,
                    process_index: int = 0) -> str:
    """Write one atomic checkpoint; returns its directory."""
    flat = _flatten(state)
    final = _step_dir(base, step)
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "extra": extra or {},
        "leaves": {
            name: {"shape": list(a.shape), "dtype": str(a.dtype),
                   "crc32": zlib.crc32(np.ascontiguousarray(a).tobytes()),
                   "proc": process_index}
            for name, a in flat.items()
        },
    }
    np.savez(os.path.join(tmp, f"shard_{process_index:05d}.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_checkpoint(base: str, like: Dict[str, Any],
                       step: Optional[int] = None,
                       ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Restore into the structure/shardings of ``like`` (elastic: ``like``
    may target a different mesh; leaves are re-sharded on device_put)."""
    if step is None:
        step = latest_step(base)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {base}")
    d = _step_dir(base, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(d)):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(d, fn)) as z:
                for k in z.files:
                    flat[k] = z[k]
    for name, meta in manifest["leaves"].items():
        crc = zlib.crc32(np.ascontiguousarray(flat[name]).tobytes())
        if crc != meta["crc32"]:
            raise IOError(f"checksum mismatch for {name} at step {step}")
    restored = _unflatten_into(like, flat)
    # re-shard onto the target's shardings (elastic restore)
    restored = jax.tree.map(
        lambda new, ref: (jax.device_put(new, ref.sharding)
                          if hasattr(ref, "sharding") else new),
        restored, like)
    return restored, manifest["extra"]


class CheckpointManager:
    """Async writer + retention policy + restart cursor."""

    def __init__(self, base: str, keep: int = 3):
        self.base = base
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(base, exist_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, state: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Device->host copy now; disk write on a background thread."""
        self.wait()
        host_state = jax.tree.map(np.asarray, state)   # blocking D2H snapshot

        def work():
            save_checkpoint(self.base, step, host_state, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def restore_latest(self, like, step: Optional[int] = None):
        return restore_checkpoint(self.base, like, step)

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.base)
            if d.startswith("step_") and not d.endswith(".tmp")
            and os.path.exists(os.path.join(self.base, d, "COMMIT")))
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(_step_dir(self.base, s), ignore_errors=True)
