"""Resumable-sweep checkpoint store: one JSON file per completed cell.

A sweep over a big evaluation grid can die hours in (preemption, OOM on
one pathological cell, Ctrl-C).  :class:`SweepCheckpoint` makes the grid
restart-safe at cell granularity with the same crash-consistency idiom
as the training checkpoints (:mod:`repro.ckpt.checkpoint`): each
completed cell's :class:`~repro.experiments.results.RunResult` is
written to ``<dir>/cell_<sha1(cell_id)>.json`` via a ``.tmp-`` +
``os.replace`` rename, so a file either holds a complete record or does
not exist.  A re-run loads the directory, skips every finished cell and
only executes the remainder — the cell id (canonical topo/routing/
pattern/evaluator specs + seed) keys the record, so a *different* grid
sharing some cells reuses exactly the overlap and nothing else.

The store is deliberately schema-light (flat JSON per cell, no
manifest): concurrent sweeps over disjoint cells may share a directory,
and a partially-written directory is always safe to resume from.  Each
record does carry a ``schema`` version (:data:`SCHEMA`): resuming from
a directory written by an incompatible repo version raises instead of
silently reusing records whose metric/meta layout has since changed —
torn or foreign files are still skipped, only files that parse as
complete records with the wrong version reject the resume.
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, Iterator, Optional

__all__ = ["SweepCheckpoint", "SchemaMismatch", "SCHEMA"]

#: Per-cell record layout version.  Bump when RunResult serialization
#: changes incompatibly (metrics/meta structure, cell-id derivation).
SCHEMA = 1


class SchemaMismatch(RuntimeError):
    """A checkpoint directory holds records from another schema version."""


def _cell_path(base: str, cell_id: str) -> str:
    h = hashlib.sha1(cell_id.encode()).hexdigest()[:20]
    return os.path.join(base, f"cell_{h}.json")


class SweepCheckpoint:
    """Cell-granular sweep persistence (see module docstring)."""

    def __init__(self, directory: str):
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)
        self._cache: Optional[Dict[str, dict]] = None

    # ---- read side -----------------------------------------------------------
    def load(self) -> Dict[str, dict]:
        """cell_id -> RunResult dict for every committed cell on disk.

        Raises :class:`SchemaMismatch` if any complete record carries a
        ``schema`` other than :data:`SCHEMA` — a stale directory from an
        incompatible repo version must not be silently resumed."""
        out: Dict[str, dict] = {}
        for name in sorted(os.listdir(self.directory)):
            if not (name.startswith("cell_") and name.endswith(".json")):
                continue
            try:
                with open(os.path.join(self.directory, name)) as f:
                    d = json.load(f)
                cell_id, result = d["cell_id"], d["result"]
            except (json.JSONDecodeError, KeyError, OSError):
                continue          # torn/foreign file: treat as not done
            if d.get("schema") != SCHEMA:
                raise SchemaMismatch(
                    f"checkpoint directory {self.directory!r} holds record "
                    f"{name} with schema {d.get('schema')!r} (this version "
                    f"writes schema {SCHEMA}); delete or move the stale "
                    "directory to resume")
            out[cell_id] = result
        self._cache = out
        return dict(out)

    def _loaded(self) -> Dict[str, dict]:
        if self._cache is None:
            self.load()
        return self._cache

    def __contains__(self, cell_id: str) -> bool:
        return cell_id in self._loaded()

    def __len__(self) -> int:
        return len(self._loaded())

    def __iter__(self) -> Iterator[str]:
        return iter(self._loaded())

    def get(self, cell_id: str) -> Optional[dict]:
        """The stored RunResult dict for ``cell_id``, or None."""
        return self._loaded().get(cell_id)

    # ---- write side ----------------------------------------------------------
    def put(self, cell_id: str, result_dict: dict) -> None:
        """Atomically commit one completed cell (write tmp, rename)."""
        path = _cell_path(self.directory, cell_id)
        tmp = path + ".tmp-" + str(os.getpid())
        with open(tmp, "w") as f:
            json.dump({"cell_id": cell_id, "schema": SCHEMA,
                       "result": result_dict}, f, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        if self._cache is not None:
            self._cache[cell_id] = result_dict
