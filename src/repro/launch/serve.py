"""Serving launcher: batched generation with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch olmoe-1b-7b --smoke \\
      --batch 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import numpy as np
    import jax

    from repro import configs
    from repro.dist.sharding import Runtime
    from repro.models import model as model_mod
    from repro.serve.engine import ServeConfig, ServingEngine

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    rt = Runtime(mesh=None)
    params = model_mod.init_params(cfg, rt, jax.random.PRNGKey(args.seed))
    eng = ServingEngine(cfg, rt, params,
                        ServeConfig(batch=args.batch, max_len=args.max_len))

    rng = np.random.default_rng(args.seed)
    done = 0
    t0 = time.monotonic()
    while done < args.n_requests:
        nbatch = min(args.batch, args.n_requests - done)
        prompts = [rng.integers(1, cfg.vocab, size=rng.integers(2, 9))
                   for _ in range(nbatch)]
        outs = eng.run(prompts, max_new=args.max_new)
        for i, o in enumerate(outs):
            print(f"req {done + i}: prompt {len(prompts[i])} toks -> "
                  f"{o[:8]}{'...' if len(o) > 8 else ''}")
        done += nbatch
    dt = time.monotonic() - t0
    toks = args.n_requests * (args.max_new + 1)
    print(f"{args.n_requests} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
