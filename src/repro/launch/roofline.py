"""Roofline report: aggregate dry-run JSONs into the §Roofline table.

Per (arch × shape × mesh) cell:
  compute / memory / collective terms (s), dominant term, MODEL_FLOPS,
  useful-flops ratio, live bytes per device vs HBM, and — via the fabric
  model — the collective term re-evaluated on a modelled cluster topology
  under ECMP vs FatPaths routing (the paper's contribution applied to this
  system's own traffic).

Usage:
  python -m repro.launch.roofline --dir experiments/dryrun [--fabric sf:11]
  python -m repro.launch.roofline --dir experiments/dryrun --markdown
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from .hlo_analysis import HW


def load_cells(dir_: str, tag: str = "") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("_")
        is_tagged = parts[-1] not in ("single", "multi")
        if tag:
            if not base.endswith("-" + tag) and not base.endswith("_" + tag):
                continue
        elif is_tagged:
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


# One experiments Session caches fabrics (and their layer stacks) across
# every cell of a roofline report.
_SESSION = None


def _session():
    global _SESSION
    if _SESSION is None:
        from ..experiments import Session
        _SESSION = Session()
    return _SESSION


def _advice(cell: Dict) -> str:
    """One sentence: what moves this cell's dominant term down."""
    dom = cell["roofline"]["dominant"]
    kind = cell["kind"]
    fam = cell["arch"].split("-")[0]
    if dom == "collective":
        if cell["arch"] in ("deepseek-v2-236b", "olmoe-1b-7b"):
            return ("EP a2a + param AGs dominate: larger per-device batch "
                    "or FatPaths-routed fabric (1.9x on a2a)")
        if kind == "train":
            return ("TP activation all-reduces: pure-FSDP relayout "
                    "(gemma2: 4.1x) or fewer TP ways")
        return "SP boundary gathers: longer seq chunks amortise"
    if dom == "memory":
        if kind == "decode":
            return "KV/state reads are the floor; quantise cache below bf16"
        return "attention/expert HBM traffic: larger fused blocks (Pallas)"
    return "compute-bound: already near MXU roofline; check useful-flops"


def fabric_collective_term(cell: Dict, fabric_spec: str = "sf:11",
                           n_rings: int = 1) -> Dict[str, float]:
    """Re-evaluate the cell's collective traffic on a modelled fabric.

    ``fabric_spec`` is an experiments topology mini-spec — canonical
    (``"sf(q=11)"``) or compact (``"sf:11"``) form."""
    fb = _session().fabric(fabric_spec, n_layers=9, rho=0.6)
    topo = fb.topo
    n = cell["n_devices"]
    out = {}
    for scheme in ("ecmp", "fatpaths"):
        t = 0.0
        for kind, wire in cell.get("collectives", {}).items():
            if kind == "total" or wire <= 0:
                continue
            # wire bytes/device -> payload/device for the fabric flows
            rep = fb.collective_time(kind, min(n, topo.n_endpoints), wire,
                                     scheme=scheme)
            t += rep.time_s
        out[scheme] = t
    return out


def row(cell: Dict) -> Dict:
    r = cell["roofline"]
    hw = HW()
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    bound = max(terms.values())
    # roofline fraction: useful model compute time / bound step time
    t_model = (r["model_flops_global"] / cell["n_devices"]) / hw.peak_flops
    frac = t_model / bound if bound > 0 else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "kind": cell["kind"],
        "compute_ms": r["compute_s"] * 1e3,
        "memory_ms": r["memory_s"] * 1e3,
        "collective_ms": r["collective_s"] * 1e3,
        "dominant": r["dominant"],
        "model_tflops_global": r["model_flops_global"] / 1e12,
        "useful_flops_ratio": r["useful_flops_ratio"],
        "roofline_frac": frac,
        "live_GiB": cell["live_bytes_per_device"] / 2 ** 30,
        "fits_hbm": cell["live_bytes_per_device"] <= hw.hbm_bytes,
        "compile_s": cell.get("compile_s", 0.0),
        "advice": _advice(cell),
    }


def markdown_table(rows: List[Dict]) -> str:
    fab = any("fabric_ecmp_ms" in r for r in rows)
    hdr = ("| arch | shape | mesh | dom | compute ms | memory ms | "
           "coll ms | roofline frac | useful flops | live GiB | fits |"
           + (" fabric ecmp/fp ms |" if fab else "")
           + " next lever |")
    sep = "|" + "---|" * (12 + (1 if fab else 0))
    lines = [hdr, sep]
    for r in rows:
        fabcol = (f" {r.get('fabric_ecmp_ms', 0):.0f}/"
                  f"{r.get('fabric_fatpaths_ms', 0):.0f} |" if fab else "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['dominant'][:4]}"
            f" | {r['compute_ms']:.1f} | {r['memory_ms']:.1f}"
            f" | {r['collective_ms']:.1f} | {r['roofline_frac']:.2f}"
            f" | {r['useful_flops_ratio']:.2f} | {r['live_GiB']:.2f}"
            f" | {'Y' if r['fits_hbm'] else 'N'} |{fabcol}"
            f" {r['advice']} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--fabric", default="")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cells = load_cells(args.dir, args.tag)
    rows = [row(c) for c in cells]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if args.fabric:
        for c, r in zip(sorted(cells, key=lambda c: (c["arch"], c["shape"],
                                                     c["mesh"])), rows):
            fc = fabric_collective_term(c, args.fabric)
            r["fabric_ecmp_ms"] = fc["ecmp"] * 1e3
            r["fabric_fatpaths_ms"] = fc["fatpaths"] * 1e3
    text = markdown_table(rows) if args.markdown else json.dumps(rows, indent=1)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
