"""Training launcher.

Runs a real training job on whatever devices exist (CPU here; the same
code drives a TPU pod — the mesh shape is the only difference):

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \\
      --steps 100 --global-batch 8 --seq 128 --ckpt-dir /tmp/ck

``--smoke`` selects the reduced config (full configs need the pod).
Fault-tolerance drills: ``--inject-failure-at N`` crashes mid-run; simply
re-running the same command resumes from the last committed checkpoint and
reproduces the exact trajectory (deterministic pipeline).
"""

from __future__ import annotations

import argparse
import dataclasses
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="", help="e.g. 2x4 => (data, model)")
    args = ap.parse_args()

    import jax

    from repro import configs
    from repro.data.pipeline import DataConfig
    from repro.dist.sharding import Runtime
    from repro.launch.mesh import make_mesh
    from repro.train.loop import LoopConfig, TrainLoop
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import TrainConfig

    cfg = configs.get_smoke(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    mesh = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        mesh = make_mesh(shape, ("data", "model")[:len(shape)])
    rt = Runtime(mesh=mesh)

    loop = TrainLoop(
        cfg, rt,
        DataConfig(global_batch=args.global_batch, seq_len=args.seq,
                   seed=args.seed),
        TrainConfig(opt=AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                                    total_steps=args.steps),
                    grad_accum=args.grad_accum),
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                   log_every=args.log_every,
                   ckpt_dir=args.ckpt_dir or None,
                   inject_failure_at=args.inject_failure_at))
    out = loop.run(seed=args.seed)
    for h in out["history"]:
        print(json.dumps(h))
    if out["stragglers"]:
        print("straggler steps:", out["stragglers"])


if __name__ == "__main__":
    main()
