"""Parse compiled HLO for collective traffic + roofline terms.

``collective_bytes`` scans post-optimization HLO text for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops, extracts output shapes and replica-group sizes, and converts to *wire
bytes per device* with the standard ring-algorithm factors:

  all-reduce       2 (g-1)/g * payload        (payload = full operand)
  all-gather       (g-1)/g   * output
  reduce-scatter   (g-1)     * output         (= (g-1)/g * input)
  all-to-all       (g-1)/g   * payload
  collective-permute         * payload

The flat collective roofline term is wire_bytes / link_bw; dist.fabric
refines it with the modelled cluster topology (per-link bottleneck under
ECMP vs FatPaths routing).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["CollectiveOp", "parse_collectives", "collective_bytes",
           "roofline_terms", "HW"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g.  %all-reduce.5 = bf16[1024,512]{1,0} all-reduce(...), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRCTGT_RE = re.compile(r"source_target_pairs=\{\{")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    out_bytes: int          # bytes of the (tuple-summed) output shape
    group_size: int

    @property
    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        if self.kind == "all-reduce":
            return 2.0 * (g - 1) / g * self.out_bytes
        if self.kind == "all-gather":
            return (g - 1) / g * self.out_bytes
        if self.kind == "reduce-scatter":
            return float(g - 1) * self.out_bytes
        if self.kind == "all-to-all":
            return (g - 1) / g * self.out_bytes
        return float(self.out_bytes)          # collective-permute


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            out_b = sum(_shape_bytes(t, d)
                        for t, d in _TUPLE_ELEM_RE.findall(tuple_body))
        else:
            out_b = _shape_bytes(dtype, dims)
        g = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))
            elif kind == "collective-permute":
                g = 2
        ops.append(CollectiveOp(kind=kind, out_bytes=out_b, group_size=g))
    return ops


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Aggregate wire bytes (per device) by collective kind."""
    agg: Dict[str, float] = {}
    for op in parse_collectives(hlo_text):
        agg[op.kind] = agg.get(op.kind, 0.0) + op.wire_bytes
        agg["total"] = agg.get("total", 0.0) + op.wire_bytes
    return agg


# TPU v5e-class hardware constants (task spec)
@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 / chip
    hbm_bw: float = 819e9            # bytes/s / chip
    link_bw: float = 50e9            # bytes/s / ICI link
    hbm_bytes: float = 16e9          # capacity (context)


def roofline_terms(cost: Dict[str, float], coll: Dict[str, float],
                   hw: HW = HW()) -> Dict[str, float]:
    """Three roofline terms in seconds from per-device cost analysis."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    wire = float(coll.get("total", 0.0))
    t_c = flops / hw.peak_flops
    t_m = bytes_hbm / hw.hbm_bw
    t_n = wire / hw.link_bw
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "dominant": dom,
            "flops": flops, "hbm_bytes": bytes_hbm, "wire_bytes": wire}
