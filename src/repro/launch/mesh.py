"""Production mesh construction (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls it.

FatPaths integration: ``fatpaths_device_order`` reorders devices so that
mesh neighbours (ring-collective peers) land on fabric-adjacent endpoints
of the modelled cluster topology — the paper's "routing-aware" placement
applied to collective scheduling (see repro.dist.fabric).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              device_order: Optional[np.ndarray] = None):
    """General mesh over the first prod(shape) local devices; optional
    explicit device permutation (fabric-aware placement)."""
    import jax
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    devs = np.array(jax.devices()[:n])
    if device_order is not None:
        devs = devs[np.asarray(device_order)[:n]]
    return Mesh(devs.reshape(tuple(shape)), tuple(axes))


def fatpaths_device_order(n_devices: int, topo=None) -> np.ndarray:
    """Order devices so consecutive mesh coordinates sit on fabric-adjacent
    endpoints: BFS order over the cluster topology's routers (endpoints of a
    router stay contiguous).  Deterministic; identity when no topology is
    given."""
    if topo is None:
        return np.arange(n_devices)
    from collections import deque

    adj = topo.adj
    n_r = adj.shape[0]
    # BFS from router 0 for a locality-preserving linearisation.
    order = []
    seen = np.zeros(n_r, dtype=bool)
    queue = deque([0])
    seen[0] = True
    while queue:
        v = queue.popleft()
        order.append(v)
        for u in np.nonzero(adj[v])[0]:
            if not seen[u]:
                seen[u] = True
                queue.append(u)
    order += [i for i in range(n_r) if not seen[i]]
    ep_order = []
    conc = topo.concentration
    base = np.concatenate([[0], np.cumsum(conc)[:-1]])
    for r in order:
        ep_order.extend(range(int(base[r]), int(base[r] + conc[r])))
    ep_order = np.array(ep_order)
    # Restrict to a permutation of range(n_devices): keep the BFS order of
    # the endpoints that map to devices, then append any device ids beyond
    # the modelled endpoint count in natural order.
    ep_order = ep_order[ep_order < n_devices]
    if len(ep_order) < n_devices:
        present = np.zeros(n_devices, dtype=bool)
        present[ep_order] = True
        ep_order = np.concatenate([ep_order, np.nonzero(~present)[0]])
    return ep_order
