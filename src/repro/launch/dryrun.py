import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY other import (jax locks the device
count at first init).  For every runnable cell this driver:

  1. builds the production mesh — (16, 16) ("data", "model") single-pod or
     (2, 16, 16) ("pod", "data", "model") multi-pod;
  2. assembles the step the shape dictates (train_step / prefill / decode)
     with in_shardings from the model's PartitionSpec trees;
  3. ``jit(...).lower(**ShapeDtypeStructs).compile()`` — no allocation;
  4. records ``memory_analysis()``, ``cost_analysis()`` and the parsed
     collective wire bytes to JSON under --out (resumable: done cells are
     skipped unless --force).

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --tag ep --moe-mode ep  # hillclimb
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp


def _build_runtime(multi_pod: bool, args):
    from repro.dist.sharding import Runtime
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    if getattr(args, "fsdp_only", False):
        # same physical mesh; the 'model' axis is logically a data axis
        data_axes = data_axes + ("model",)
        return Runtime(
            mesh=mesh, data_axes=data_axes, model_axis="model",
            tp_disabled=True,
            sequence_parallel=False,
            moe_mode="tp",
            seq_sharded_decode=False,
            collective_dtype=args.collective_dtype,
        )
    return Runtime(
        mesh=mesh,
        data_axes=data_axes,
        model_axis="model",
        sequence_parallel=args.sequence_parallel,
        moe_mode=args.moe_mode,
        seq_sharded_decode=not args.no_seq_sharded_decode,
        collective_dtype=args.collective_dtype,
    )


def _sds_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def lower_cell(arch: str, shape: str, multi_pod: bool, args):
    """Returns (lowered, compiled, meta) for one cell."""
    from repro import configs
    from repro.models import model as model_mod
    from repro.serve.engine import ServeConfig, make_decode_step, \
        make_prefill_step
    from repro.train.optimizer import adamw_init
    from repro.train.train_step import TrainConfig, make_train_step

    cfg = configs.get_config(arch)
    if args.remat:
        cfg = dataclasses.replace(cfg, remat=args.remat)
    sh = configs.SHAPES[shape]
    rt = _build_runtime(multi_pod, args)
    mesh = rt.mesh

    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_sds = _sds_tree(functools.partial(model_mod.init_params, cfg, rt),
                           key_sds)
    pspecs = model_mod.param_specs(cfg, rt)
    p_shardings = rt.tree_sharding(pspecs)
    batch_sds = configs.input_specs(cfg, shape, rt)
    b_specs = configs.batch_specs(cfg, shape, rt)
    b_shardings = {k: jax.NamedSharding(mesh, v) for k, v in b_specs.items()}

    with mesh:
        if sh.kind == "train":
            tc = TrainConfig(grad_accum=args.grad_accum)
            step = make_train_step(cfg, rt, tc)
            opt_sds = _sds_tree(adamw_init, params_sds)
            from repro.train.optimizer import opt_specs
            o_shardings = rt.tree_sharding(opt_specs(pspecs))
            jitted = jax.jit(step,
                             in_shardings=(p_shardings, o_shardings,
                                           b_shardings, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds, key_sds)
        elif sh.kind == "prefill":
            sc = ServeConfig(batch=sh.global_batch, max_len=sh.seq_len)
            step = make_prefill_step(cfg, rt, sc)
            jitted = jax.jit(step, in_shardings=(p_shardings, b_shardings))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            sc = ServeConfig(batch=sh.global_batch, max_len=sh.seq_len)
            step = make_decode_step(cfg, rt, sc)
            cache_sds = _sds_tree(
                functools.partial(model_mod.init_cache, cfg, rt,
                                  sh.global_batch, sh.seq_len))
            c_shardings = rt.tree_sharding(
                model_mod.cache_specs(cfg, rt, sh.global_batch, sh.seq_len))
            tok_sds = batch_sds[next(iter(batch_sds))]
            tok_sharding = b_shardings[next(iter(b_shardings))]
            jitted = jax.jit(step,
                             in_shardings=(p_shardings, c_shardings,
                                           tok_sharding),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_sds, cache_sds, tok_sds)
        compiled = lowered.compile()
    tokens = sh.global_batch * (sh.seq_len if sh.kind in ("train", "prefill")
                                else 1)
    meta = {"arch": arch, "shape": shape,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_devices": 512 if multi_pod else 256,
            "kind": sh.kind,
            "tokens_global": tokens,
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count()}
    return lowered, compiled, meta


def analyse(lowered, compiled, meta) -> Dict[str, Any]:
    from repro.launch.hlo_analysis import HW
    from repro.launch.hlo_cost import module_cost

    raw_cost = compiled.cost_analysis()
    if isinstance(raw_cost, (list, tuple)):
        raw_cost = raw_cost[0]
    raw_cost = {k: float(v) for k, v in raw_cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals")}
    mem = compiled.memory_analysis()
    mem_d = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "host_argument_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_d[attr] = int(v)
    hlo = compiled.as_text()
    mc = module_cost(hlo)                      # loop-aware, per device
    hw = HW()
    t_c = mc.flops / hw.peak_flops
    t_m = mc.bytes_ideal / hw.hbm_bw          # TPU-projected HBM traffic
    t_m_raw = mc.bytes_accessed / hw.hbm_bw   # as-compiled (XLA:CPU fusion)
    t_n = mc.coll_bytes.get("total", 0.0) / hw.link_bw
    dominant = {t_c: "compute", t_m: "memory", t_n: "collective"}[
        max(t_c, t_m, t_n)]
    # MODEL_FLOPS (6·N_active·D train, 2·N_active·D forward) vs HLO flops
    tokens = meta["tokens_global"]
    n_act = meta["active_param_count"]
    mf = (6.0 if meta["kind"] == "train" else 2.0) * n_act * tokens
    hlo_global = mc.flops * meta["n_devices"]
    roof = {
        "compute_s": t_c, "memory_s": t_m, "memory_s_ascompiled": t_m_raw,
        "collective_s": t_n,
        "dominant": dominant,
        "flops_per_device": mc.flops,
        "hbm_bytes_per_device": mc.bytes_ideal,
        "hbm_bytes_ascompiled": mc.bytes_accessed,
        "wire_bytes_per_device": mc.coll_bytes.get("total", 0.0),
        "model_flops_global": mf,
        "useful_flops_ratio": mf / hlo_global if hlo_global else 0.0,
        "unknown_trip_counts": mc.unknown_trip_counts,
    }
    live = mem_d.get("argument_size_in_bytes", 0) + \
        mem_d.get("temp_size_in_bytes", 0)
    return {**meta, "xla_cost_analysis": raw_cost, "memory": mem_d,
            "collectives": mc.coll_bytes, "roofline": roof,
            "live_bytes_per_device": live,
            "hlo_len": len(hlo)}


def run_cell(arch: str, shape: str, multi_pod: bool, args,
             out_dir: str) -> Dict[str, Any]:
    tag = f"-{args.tag}" if args.tag else ""
    name = f"{arch}_{shape}_{'multi' if multi_pod else 'single'}{tag}.json"
    path = os.path.join(out_dir, name)
    if os.path.exists(path) and not args.force:
        print(f"[skip] {name}")
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    print(f"[cell] {arch} × {shape} × {'2x16x16' if multi_pod else '16x16'} "
          f"…", flush=True)
    lowered, compiled, meta = lower_cell(arch, shape, multi_pod, args)
    rec = analyse(lowered, compiled, meta)
    rec["compile_s"] = time.time() - t0
    r = rec["roofline"]
    print(f"   compute={r['compute_s']*1e3:8.2f}ms memory="
          f"{r['memory_s']*1e3:8.2f}ms collective={r['collective_s']*1e3:8.2f}ms"
          f" dominant={r['dominant']}"
          f" live={rec['live_bytes_per_device']/2**30:.2f}GiB"
          f" ({rec['compile_s']:.0f}s)", flush=True)
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    # hillclimb knobs
    ap.add_argument("--moe-mode", default="tp", choices=["tp", "ep"])
    ap.add_argument("--collective-dtype", default="bfloat16")
    ap.add_argument("--remat", default="")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--fsdp-only", action="store_true",
                    help="pure-FSDP layout: 'model' axis becomes data")
    ap.add_argument("--sequence-parallel", action="store_true")
    ap.add_argument("--no-seq-sharded-decode", action="store_true")
    args = ap.parse_args()

    from repro import configs

    failures = []
    if args.all:
        meshes = [False, True]
        if args.multi_pod_only:
            meshes = [True]
        if args.single_pod_only:
            meshes = [False]
        for arch in configs.ARCHS:
            for shape in configs.SHAPES:
                ok, why = configs.applicable(arch, shape)
                if not ok:
                    print(f"[n/a ] {arch} × {shape}: {why}")
                    continue
                for mp in meshes:
                    try:
                        run_cell(arch, shape, mp, args, args.out)
                    except Exception as e:
                        failures.append((arch, shape, mp, repr(e)))
                        print(f"[FAIL] {arch} × {shape} × "
                              f"{'multi' if mp else 'single'}: {e!r}",
                              flush=True)
                        traceback.print_exc()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        run_cell(args.arch, args.shape, args.multi_pod, args, args.out)

    if failures:
        print(f"\n{len(failures)} FAILED CELLS:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
