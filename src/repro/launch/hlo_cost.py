"""Loop-aware cost model over post-optimization HLO text.

``jax.stages.Compiled.cost_analysis()`` counts each while-loop *body once*
— a model scanning 48 layers under-reports FLOPs ~48×, and collectives
inside the layer loop (MoE psum, TP all-reduce) vanish from the wire-byte
count.  This module re-derives per-device cost from ``compiled.as_text()``
with loop multiplication:

  * every computation is parsed into instructions with shapes;
  * per-computation cost = Σ instruction costs (+ called computations);
  * ``while`` sites multiply body+cond cost by ``known_trip_count`` from
    XLA's backend_config (fallback: 1, flagged);
  * FLOPs: dot = 2·|out|·K (K = contraction extent); elementwise/reduce =
    |shape|; transcendentals counted separately too.
  * bytes: operand + output bytes at fusion/op boundaries (fusion internals
    excluded — they live in registers/VMEM), the standard HBM-traffic
    proxy;
  * collectives: ring-model wire bytes per device (see hlo_analysis), each
    multiplied by its enclosing trip counts.

Validated against hand-counted matmul/scan cases in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["module_cost", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "compare", "select", "and", "or", "xor", "not",
    "clamp", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "remainder", "power", "atan2", "shift-left", "shift-right-logical",
    "shift-right-arithmetic",
}
_TRANSCENDENTAL = {"exponential", "exp", "log", "tanh", "rsqrt", "sqrt",
                   "logistic", "sine", "cosine", "tan", "expm1", "log1p",
                   "erf", "cbrt"}
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "bitcast-convert",
         "opt-barrier", "custom-call", "rng-bit-generator", "domain",
         "get-dimension-size"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def _shape_info(type_str: str) -> Tuple[int, int, List[int]]:
    """(total_bytes, n_elems_of_first_array, dims_of_first_array)."""
    total = 0
    first_n: Optional[int] = None
    first_dims: List[int] = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dtype, 4)
        n = 1
        ds = []
        if dims:
            for d in dims.split(","):
                n *= int(d)
                ds.append(int(d))
        total += n * b
        if first_n is None:
            first_n, first_dims = n, ds
    return total, (first_n or 0), first_dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str           # args + attrs (may span the rest of the line)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0       # as-compiled fusion boundaries (XLA:CPU)
    bytes_ideal: float = 0.0          # TPU-projected: dot/collective/slice/
                                      # reduce traffic only (elementwise fused)
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_counts: int = 0

    def add(self, other: "HloCost", times: float = 1.0) -> None:
        self.flops += times * other.flops
        self.transcendentals += times * other.transcendentals
        self.bytes_accessed += times * other.bytes_accessed
        self.bytes_ideal += times * other.bytes_ideal
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + times * v
        self.unknown_trip_counts += other.unknown_trip_counts


def _parse_computations(text: str) -> Tuple[Dict[str, List[Instr]], str]:
    comps: Dict[str, List[Instr]] = {}
    entry = ""
    cur: Optional[str] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = mc.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, tstr, opcode, rest = mi.groups()
            comps[cur].append(Instr(name, tstr, opcode, rest))
    return comps, entry


def _ring_wire(kind: str, payload_bytes: float, g: int) -> float:
    g = max(g, 1)
    if kind.startswith("all-reduce"):
        return 2.0 * (g - 1) / g * payload_bytes
    if kind.startswith("all-gather"):
        return (g - 1) / g * payload_bytes      # payload = gathered output
    if kind == "reduce-scatter":
        return float(g - 1) * payload_bytes     # payload = scattered output
    if kind == "all-to-all":
        return (g - 1) / g * payload_bytes
    return float(payload_bytes)                 # collective-permute


def _nth_operand_bytes(ins: Instr, shape_map: Dict[str, str],
                       n: int) -> Optional[int]:
    names = _OPERAND_RE.findall(ins.rest.split(", calls=")[0]
                                .split(", to_apply=")[0])
    if len(names) > n and names[n] in shape_map:
        return _shape_info(shape_map[names[n]])[0]
    return None


def _root_dus_update_bytes(called, comps, shapes) -> Optional[int]:
    """If a fused computation's ROOT is dynamic-update-slice, return the
    update-operand bytes (the true write volume of the in-place fusion)."""
    for cn in called:
        instrs = comps.get(cn, [])
        if not instrs:
            continue
        root = instrs[-1]
        if root.opcode == "dynamic-update-slice":
            upd = _nth_operand_bytes(root, shapes.get(cn, {}), 1)
            if upd is not None:
                return upd
    return None


def module_cost(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    shapes: Dict[str, Dict[str, str]] = {
        c: {i.name: i.type_str for i in instrs}
        for c, instrs in comps.items()
    }
    memo: Dict[Tuple[str, bool], HloCost] = {}

    def comp_cost(cname: str, fused: bool = False) -> HloCost:
        """fused=True: compute-only accounting (fusion internals never touch
        HBM; their boundary bytes are charged at the fusion op site)."""
        key = (cname, fused)
        if key in memo:
            return memo[key]
        memo[key] = HloCost()            # guard vs. accidental recursion
        total = HloCost()
        for ins in comps.get(cname, []):
            out_bytes, out_n, out_dims = _shape_info(ins.type_str)
            op = ins.opcode
            if op in _FREE or op.startswith("constant"):
                continue
            if fused:
                out_bytes = 0
            called = _CALL_ATTR_RE.findall(ins.rest)
            # operand bytes (resolved within this computation)
            opnd_bytes = 0
            if not fused:
                for nm in _OPERAND_RE.findall(ins.rest.split(", calls=")[0]
                                              .split(", to_apply=")[0]):
                    t = shapes[cname].get(nm)
                    if t:
                        opnd_bytes += _shape_info(t)[0]
            if op == "while":
                body = cond = None
                mb = re.search(r"body=%([\w\.\-]+)", ins.rest)
                mcnd = re.search(r"condition=%([\w\.\-]+)", ins.rest)
                body = mb.group(1) if mb else None
                cond = mcnd.group(1) if mcnd else None
                mt = _TRIP_RE.search(ins.rest)
                trips = int(mt.group(1)) if mt else 1
                if not mt:
                    total.unknown_trip_counts += 1
                if body:
                    total.add(comp_cost(body, fused), trips)
                if cond:
                    total.add(comp_cost(cond, fused), trips)
                continue
            if op == "conditional":
                mb = _COND_BRANCH_RE.search(ins.rest)
                branches = (re.findall(r"%([\w\.\-]+)", mb.group(1))
                            if mb else called)
                if branches:   # charge the mean branch
                    sub = HloCost()
                    for bname in branches:
                        sub.add(comp_cost(bname, fused))
                    total.add(sub, 1.0 / len(branches))
                total.bytes_accessed += out_bytes + opnd_bytes
                continue
            if op == "fusion":
                has_dot = False
                for cn in called:
                    sub = comp_cost(cn, True)
                    total.add(sub)
                    if any(i.opcode in ("dot", "dot-general", "convolution")
                           for i in comps.get(cn, [])):
                        has_dot = True
                # in-place update fusions: charge the slice, not the buffer
                dus_slice = _root_dus_update_bytes(called, comps, shapes)
                if dus_slice is not None and not fused:
                    b = max(opnd_bytes - out_bytes, 0) + 2 * dus_slice
                    total.bytes_accessed += b
                    total.bytes_ideal += b
                else:
                    total.bytes_accessed += out_bytes + opnd_bytes
                    if has_dot:
                        total.bytes_ideal += out_bytes + opnd_bytes
                continue
            if op in ("call", "async-start"):
                for cn in called:
                    total.add(comp_cost(cn, fused))
                total.bytes_accessed += out_bytes + opnd_bytes
                continue
            if op in _COLLECTIVES:
                total.bytes_ideal += out_bytes + opnd_bytes
                g = 1
                mg = _GROUPS_RE.search(ins.rest)
                if mg:
                    g = len(mg.group(1).split(","))
                else:
                    mi2 = _GROUPS_IOTA_RE.search(ins.rest)
                    if mi2:
                        g = int(mi2.group(2))
                    elif op.startswith("collective-permute"):
                        g = 2
                kind = op.replace("-start", "")
                wire = _ring_wire(kind, out_bytes, g)
                total.coll_bytes[kind] = total.coll_bytes.get(kind, 0.0) + wire
                total.coll_bytes["total"] = \
                    total.coll_bytes.get("total", 0.0) + wire
                total.bytes_accessed += out_bytes + opnd_bytes
                continue
            # compute ops ----------------------------------------------------
            if op in ("dot", "dot-general"):
                if not fused:
                    total.bytes_ideal += out_bytes + opnd_bytes
                k = 1
                mlc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
                lhs_nm = _OPERAND_RE.search(ins.rest)
                if mlc and lhs_nm:
                    lhs_t = shapes[cname].get(lhs_nm.group(1))
                    if lhs_t:
                        _, _, lhs_dims = _shape_info(lhs_t)
                        for d in (mlc.group(1).split(",")
                                  if mlc.group(1) else []):
                            di = int(d)
                            if di < len(lhs_dims):
                                k *= lhs_dims[di]
                total.flops += 2.0 * out_n * k
            elif op == "convolution":
                total.flops += 2.0 * out_n  # stub frontends only; negligible
            elif op in ("reduce", "reduce-window"):
                # ~1 flop per input element of the first (data) operand
                nm = _OPERAND_RE.search(ins.rest)
                in_n = out_n
                if nm is not None:
                    t = shapes[cname].get(nm.group(1))
                    if t:
                        in_n = _shape_info(t)[1]
                total.flops += float(max(in_n, out_n))
                if not fused:
                    total.bytes_ideal += out_bytes + opnd_bytes
            elif op in _TRANSCENDENTAL:
                total.flops += out_n
                total.transcendentals += out_n
            elif op in _ELEMENTWISE or op == "map":
                total.flops += out_n
            elif op in ("sort",):
                for cn in called:
                    total.add(comp_cost(cn, True), max(out_n, 1))
            elif op in ("dynamic-slice", "gather"):
                total.bytes_accessed += 2 * out_bytes  # read slice, write out
                total.bytes_ideal += 2 * out_bytes
                continue
            elif op in ("dynamic-update-slice", "scatter"):
                upd = _nth_operand_bytes(ins, shapes.get(cname, {}), 1)
                if upd is not None and not fused:
                    total.bytes_accessed += 2 * upd
                    total.bytes_ideal += 2 * upd
                    continue
            # everything else (reshape/transpose/convert/copy/pad/slice/
            # concatenate/broadcast/rng...): bytes only
            total.bytes_accessed += out_bytes + opnd_bytes
        memo[key] = total
        return total

    return comp_cost(entry) if entry else HloCost()
