"""FatPaths core: topologies, path diversity, layered routing, transport.

The paper's primary contribution as a composable JAX library:

* :mod:`repro.core.topology`   — SF / DF / JF / XP / HX / FT3 generators.
* :mod:`repro.core.paths`      — adjacency-algebra path analysis (Appendix B.1).
* :mod:`repro.core.diversity`  — CDP / PI / TNL metrics (§4.2, Appendix B.3).
* :mod:`repro.core.layers`     — FatPaths layered routing (§5.2–5.4).
* :mod:`repro.core.routing`    — forwarding functions + table accounting (§5.1, §5.5).
* :mod:`repro.core.traffic`    — traffic patterns (§2.4).
* :mod:`repro.core.arrivals`   — open-loop arrival processes (PR 6).
* :mod:`repro.core.transport`  — flow-level purified-transport simulator (§7).
* :mod:`repro.core.throughput` — MAT multicommodity-flow LP (§6.4).
"""

from . import (arrivals, diversity, layers, paths, routing, throughput,  # noqa: F401
               topology, traffic, transport)
from .layers import LayeredRouting, build_layers  # noqa: F401
from .routing import ForwardingFunction  # noqa: F401
from .topology import Topology, by_name  # noqa: F401
from .traffic import FlowWorkload, make_workload  # noqa: F401
from .transport import (SimConfig, SimResult, ecmp_routing,  # noqa: F401
                        simulate, simulate_seeds)
