"""Forwarding-function layer (paper §5.1, §5.4, §5.5).

Wraps a :class:`~repro.core.layers.LayeredRouting` into the paper's routing
model: a per-layer destination-based forwarding function
``sigma_i(s, t) -> (port j, next hop s')`` plus deployment accounting —
exact-match vs prefix-compressed table sizes (§5.5.2: endpoint tables are
O(N); compressing "all endpoints on one router share routes" gives O(N_r)).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from .layers import LayeredRouting

__all__ = ["ForwardingFunction", "table_entries_exact", "table_entries_prefix",
           "vlan_layers_required"]


@dataclasses.dataclass
class ForwardingFunction:
    """sigma_i as a callable over (s, t) with port resolution."""

    routing: LayeredRouting
    layer: int
    _ports: np.ndarray = dataclasses.field(init=False, repr=False)

    def __post_init__(self):
        adj = self.routing.topo.adj
        n = adj.shape[0]
        # port[s, u] = index of u among s's neighbours (k'-bounded), -1 else.
        ports = np.full((n, n), -1, dtype=np.int32)
        for s in range(n):
            nbrs = np.nonzero(adj[s])[0]
            ports[s, nbrs] = np.arange(len(nbrs), dtype=np.int32)
        object.__setattr__(self, "_ports", ports)

    def __call__(self, s: int, t: int) -> Tuple[int, int]:
        nxt = int(self.routing.nh[self.layer, s, t])
        if nxt < 0 or nxt == s:
            return -1, nxt
        return int(self._ports[s, nxt]), nxt

    def route(self, s: int, t: int, max_hops: int = 64):
        """Full router path s..t; raises on loops (loop-freedom check)."""
        path = [s]
        cur = s
        while cur != t:
            port, nxt = self(cur, t)
            if nxt < 0:
                raise LookupError(f"layer {self.layer} cannot route {s}->{t}")
            cur = nxt
            path.append(cur)
            if len(path) > max_hops:
                raise RuntimeError(f"loop detected on layer {self.layer} "
                                   f"({s}->{t}): {path[:8]}...")
        return path


def table_entries_exact(routing: LayeredRouting) -> int:
    """Exact-match entries: one per (router, layer, destination endpoint)."""
    n_ep = routing.topo.n_endpoints
    return routing.topo.n_routers * routing.n_layers * n_ep


def table_entries_prefix(routing: LayeredRouting) -> int:
    """Prefix-compressed entries (§5.5.2): one per (router, layer,
    destination *router*) — the O(N) -> O(N_r) saving."""
    n_r = routing.topo.n_routers
    return n_r * routing.n_layers * n_r


def vlan_layers_required(routing: LayeredRouting) -> int:
    """Number of VLAN tags needed to deploy the layers (§5.5.1): one per
    layer; FatPaths keeps this O(1) vs SPAIN's O(k') / PAST's O(N) (§6.3)."""
    return routing.n_layers
