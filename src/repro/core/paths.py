"""Path analysis via adjacency-matrix algebra (paper Appendix B.1).

All heavy routines are JAX programs expressed as *semiring* matrix
products through :mod:`repro.kernels.semiring` — boolean OR/AND for
reachability, saturating f32 counting for walk multiplicities, (min, +)
for weighted distances.  On TPU the products route through the tiled
Pallas kernel; on CPU they lower to XLA's native (batched) matmul via
the jnp oracle in ``kernels/ref.py``.

The batched entry points (``apsp_batched``, ``forwarding_batched``,
``layer_tables_batched``, ``minplus_apsp_batched``, ``edge_usage_batched``)
operate on an (L, N, N) stack of layer adjacencies in ONE device program
— this is what lets :func:`repro.core.layers.build_layers` construct a
whole FatPaths layer stack without a per-layer host loop.  Random
tie-breaks use per-layer PRNG keys on device (uniform choice among
equal-cost next hops, distribution-identical to the historical
host-side ``rng.random`` scoring).

Counts are held in f32 and *saturate*: they are exact below 2**24, which
is far beyond every threshold the paper's diversity metrics use (the
paper cares about counts in the range 1..k' ~ tens).

Two *engines* implement the batched builders (PR 9):

* ``dense``   — the original (L, N, N) semiring products; simplest, and
                the fastest below ~500 routers where every intermediate
                fits in cache.
* ``blocked`` — the scale engine: frontier/wavefront APSP that relaxes
                through the (N, Dmax) neighbor table instead of a full
                matmul (O(N^2 * Dmax) per sweep, and low-diameter
                topologies converge in <= diameter sweeps — <= 4 on
                paper-scale Slim Fly), plus destination-chunked
                forwarding construction so no (N, Dmax, N) intermediate
                ever materialises.  Bit-identical to ``dense`` — both
                compute exact BFS levels and consume the same per-entry
                uniforms — which CI asserts on every scheme.

``REPRO_PATH_ENGINE=dense|blocked|auto`` selects (default ``auto``:
``blocked`` from 512 routers up).  :class:`CompressedTables` is the
matching forwarding-table representation: per-router ``(dst-block,
next-hop set)`` instead of a dense int32 row — ~4x smaller, exact.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.semiring import semiring_matmul

__all__ = [
    "shortest_path_lengths",
    "apsp_batched",
    "forwarding_batched",
    "layer_tables_batched",
    "minplus_apsp_batched",
    "edge_usage_batched",
    "diameter",
    "average_path_length",
    "path_counts_exact_length",
    "min_path_stats",
    "next_hop_options",
    "build_forwarding",
    "table_validity_batched",
    "walk_paths",
    "walk_paths_layers",
    "path_engine",
    "representation_for",
    "CompressedTables",
]

PATH_ENGINES = ("dense", "blocked", "auto")

# auto threshold: below this router count the dense engine's single
# matmul program wins; above it the frontier gathers do (and the dense
# (N, Dmax, N) forwarding intermediate starts to dominate memory).
_BLOCKED_MIN_N = 512

# Destination-axis chunk for the blocked engine's gathers: bounds every
# intermediate at O(N * Dmax * _CHUNK) regardless of N.
_CHUNK = 256


def path_engine(n: Optional[int] = None, override: Optional[str] = None) -> str:
    """Resolve the path-engine choice: explicit ``override`` wins, then
    ``REPRO_PATH_ENGINE=dense|blocked|auto``, else ``auto`` — which picks
    ``blocked`` from ``_BLOCKED_MIN_N`` routers up (``n=None`` means the
    caller has no size in hand and auto resolves to ``dense``)."""
    eng = override or os.environ.get("REPRO_PATH_ENGINE", "") or "auto"
    if eng not in PATH_ENGINES:
        raise ValueError(f"unknown path engine {eng!r}; "
                         f"choose from {PATH_ENGINES}")
    if eng == "auto":
        return "blocked" if (n is not None and n >= _BLOCKED_MIN_N) else "dense"
    return eng


def representation_for(n: Optional[int] = None,
                       override: Optional[str] = None) -> str:
    """Resolve the forwarding-table representation (``dense`` |
    ``compressed``): explicit override wins, else it follows the engine —
    the blocked engine carries compressed tables, the dense one plain
    (L, N, N) arrays."""
    if override in ("dense", "compressed"):
        return override
    if override not in (None, "", "auto"):
        raise ValueError(f"unknown table representation {override!r}; "
                         "choose 'dense', 'compressed' or 'auto'")
    return "compressed" if path_engine(n) == "blocked" else "dense"


# -----------------------------------------------------------------------------
# Batched cores (traceable; shared by the jitted entry points below and by
# the single-program layer builders in repro.core.layers).
# -----------------------------------------------------------------------------
def _apsp_core(adj: jnp.ndarray, max_l: int) -> jnp.ndarray:
    """(L, N, N) bool adjacency stack -> (L, N, N) int32 distances via
    boolean-semiring frontier products; unreachable pairs get max_l + 1."""
    _, n, _ = adj.shape
    eye = jnp.eye(n, dtype=bool)
    dist0 = jnp.where(eye[None], 0,
                      jnp.where(adj, 1, max_l + 1)).astype(jnp.int32)
    reach0 = adj | eye[None]

    def body(state):
        dist, reach, l, _ = state
        nreach = semiring_matmul(reach, adj, "bool")
        newly = nreach & ~reach
        dist = jnp.where(newly & (dist > l + 1), l + 1, dist)
        return dist, reach | nreach, l + 1, newly.any()

    def cond(state):
        return jnp.logical_and(state[3], state[2] < max_l)

    dist, _, _, _ = jax.lax.while_loop(
        cond, body, (dist0, reach0, jnp.int32(1), jnp.bool_(True)))
    return dist


def neighbor_table(adj_union: np.ndarray) -> np.ndarray:
    """(N, Dmax) int32 padded neighbor-index table for a (union)
    adjacency.  Entry ``nbr[s, j]`` is the j-th neighbor of s; pad slots
    hold non-neighbor ids and are masked out by the per-layer adjacency
    gather.  This is what keeps forwarding construction at
    O(N * Dmax * N) instead of O(N^3): next-hop candidates are always
    neighbors, and Dmax = k' << N."""
    a = np.asarray(adj_union, dtype=bool)
    dmax = max(1, int(a.sum(axis=1).max()))
    # stable argsort puts neighbors (True) first in ascending-id order
    return np.argsort(~a, axis=1, kind="stable")[:, :dmax].astype(np.int32)


def _apsp_blocked_core(adj: jnp.ndarray, nbr_in: jnp.ndarray,
                       max_l: int) -> jnp.ndarray:
    """Frontier/wavefront APSP: the blocked engine's replacement for the
    boolean-semiring products of :func:`_apsp_core`.

    The dense relaxation ``nreach[s, t] = OR_u reach[s, u] & adj[u, t]``
    only has candidates u that are *in-neighbors* of t, so it is gathered
    through the (N, Dmax) in-neighbor table instead of multiplied:
    O(N^2 * Dmax) per sweep, chunked over the destination axis so no
    intermediate exceeds O(N * Dmax * _CHUNK).  Both engines compute
    exact BFS levels sweep-by-sweep, so the int32 distances are
    bit-identical; convergence takes exactly ``diameter`` sweeps (<= 4 on
    paper-scale Slim Fly)."""
    _, n, _ = adj.shape
    d = nbr_in.shape[1]
    nc = -(-n // _CHUNK)
    npad = nc * _CHUNK
    # pad the destination axis; pad rows gather dummy candidates that the
    # all-False edge_ok mask discards.
    nbr_p = jnp.zeros((npad, d), jnp.int32).at[:n].set(nbr_in)
    nbr_p = nbr_p.reshape(nc, _CHUNK, d)
    eye = jnp.eye(n, dtype=bool)

    def one_layer(adj_l):
        # edge_ok[t, j] — the directed edge nbr_in[t, j] -> t exists here.
        edge_ok = jnp.take_along_axis(adj_l.T, nbr_in, axis=1)   # (N, D)
        edge_ok = jnp.zeros((npad, d), bool).at[:n].set(edge_ok)
        edge_ok = edge_ok.reshape(nc, _CHUNK, d)
        dist0 = jnp.where(eye, 0,
                          jnp.where(adj_l, 1, max_l + 1)).astype(jnp.int32)
        reach0 = adj_l | eye

        def relax(reach):
            def one_chunk(args):
                nbr_c, ok_c = args                     # (C, D) each
                cand = reach[:, nbr_c]                 # (N, C, D)
                return (cand & ok_c[None]).any(axis=2)  # (N, C)

            out = jax.lax.map(one_chunk, (nbr_p, edge_ok))   # (nc, N, C)
            return jnp.moveaxis(out, 0, 1).reshape(n, npad)[:, :n]

        def body(state):
            dist, reach, l, _ = state
            nreach = relax(reach)
            newly = nreach & ~reach
            dist = jnp.where(newly & (dist > l + 1), l + 1, dist)
            return dist, reach | nreach, l + 1, newly.any()

        def cond(state):
            return jnp.logical_and(state[3], state[2] < max_l)

        dist, _, _, _ = jax.lax.while_loop(
            cond, body, (dist0, reach0, jnp.int32(1), jnp.bool_(True)))
        return dist

    return jax.lax.map(one_layer, adj)


def _forwarding_core(adj: jnp.ndarray, dist: jnp.ndarray, nbr: jnp.ndarray,
                     key: jnp.ndarray) -> jnp.ndarray:
    """Single-next-hop tables for an (L, N, N) stack, on device.

    For each (layer, s, t) the next hop is chosen *uniformly at random*
    among the equal-cost candidates ``{u in nbr[s] : adj[s, u],
    dist[u, t] == dist[s, t] - 1}`` by picking the r-th valid candidate,
    with r drawn from one per-(s, t) uniform — one random number per
    table entry, one PRNG stream per layer stack.
    """
    L, n, _ = adj.shape
    u01 = jax.random.uniform(key, (L, n, n))
    rows = jnp.arange(n)[:, None]

    def one_layer(args):
        adj_l, dist_l, u_l = args
        has_edge = jnp.take_along_axis(adj_l, nbr, axis=1)   # (N, D)
        dist_nbr = dist_l[nbr]                               # (N, D, N)
        # ok[s, j, t]: edge s->nbr[s,j] in this layer, one hop closer to t.
        ok = has_edge[:, :, None] & (dist_nbr + 1 == dist_l[:, None, :])
        cnt = ok.sum(axis=1)                                 # (N, N)
        r = jnp.clip((u_l * cnt).astype(jnp.int32), 0,
                     jnp.maximum(cnt - 1, 0))
        csum = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        pick = ok & (csum == (r + 1)[:, None, :])
        j = jnp.argmax(pick, axis=1)                         # (N, N)
        nh = nbr[rows, j].astype(jnp.int32)
        return jnp.where(cnt > 0, nh, -1)

    nh = jax.lax.map(one_layer, (adj, dist, u01))
    idx = jnp.arange(n)
    return nh.at[:, idx, idx].set(idx)


def _forwarding_blocked_core(adj: jnp.ndarray, dist: jnp.ndarray,
                             nbr: jnp.ndarray, key: jnp.ndarray) -> jnp.ndarray:
    """Destination-chunked :func:`_forwarding_core`: the dense version
    gathers a (N, Dmax, N) candidate-distance cube per layer (~0.5 GB at
    sf(q=29)); here each chunk holds (N, Dmax, _CHUNK).  The per-entry
    uniforms come from the SAME (L, N, N) draw, sliced per chunk, and
    every per-column computation (candidate mask, count, r-th-valid pick)
    is column-independent — so the tables are bit-identical to the dense
    engine's."""
    L, n, _ = adj.shape
    d = nbr.shape[1]
    u01 = jax.random.uniform(key, (L, n, n))
    rows = jnp.arange(n)[:, None]
    nc = -(-n // _CHUNK)
    npad = nc * _CHUNK

    def one_layer(args):
        adj_l, dist_l, u_l = args
        has_edge = jnp.take_along_axis(adj_l, nbr, axis=1)       # (N, D)
        # pad the dest axis with a distance no candidate test matches
        # (x + 1 == x is never true), so pad columns yield cnt=0 / nh=-1
        # and are sliced away.
        dist_p = jnp.full((n, npad), jnp.int32(-10)).at[:, :n].set(dist_l)
        u_p = jnp.zeros((n, npad), u_l.dtype).at[:, :n].set(u_l)
        dist_cs = jnp.moveaxis(dist_p.reshape(n, nc, _CHUNK), 1, 0)
        u_cs = jnp.moveaxis(u_p.reshape(n, nc, _CHUNK), 1, 0)

        def one_chunk(args2):
            dist_c, u_c = args2                                  # (N, C)
            dist_nbr = dist_c[nbr]                               # (N, D, C)
            ok = has_edge[:, :, None] & (dist_nbr + 1 == dist_c[:, None, :])
            cnt = ok.sum(axis=1)                                 # (N, C)
            r = jnp.clip((u_c * cnt).astype(jnp.int32), 0,
                         jnp.maximum(cnt - 1, 0))
            csum = jnp.cumsum(ok.astype(jnp.int32), axis=1)
            pick = ok & (csum == (r + 1)[:, None, :])
            j = jnp.argmax(pick, axis=1)                         # (N, C)
            nh_c = nbr[rows, j].astype(jnp.int32)
            return jnp.where(cnt > 0, nh_c, -1)

        out = jax.lax.map(one_chunk, (dist_cs, u_cs))            # (nc, N, C)
        return jnp.moveaxis(out, 0, 1).reshape(n, npad)[:, :n]

    nh = jax.lax.map(one_layer, (adj, dist, u01))
    idx = jnp.arange(n)
    return nh.at[:, idx, idx].set(idx)


def _minplus_apsp_core(w: jnp.ndarray, max_l: int) -> jnp.ndarray:
    """All-pairs weighted distances for a (K, N, N) weight stack (+inf
    non-edges, 0 diagonal) by repeated (min, +) squaring: after i
    squarings paths of up to 2**i hops are covered, and with unit-ish
    weights (>= 1) no shortest path uses more than ~1.25 * max_l hops."""
    iters = max(1, int(np.ceil(np.log2(1.25 * max_l + 1))))
    d = w
    for _ in range(iters):
        d = semiring_matmul(d, d, "minplus")
    return d


def _edge_usage_core(nh: jnp.ndarray, reach: jnp.ndarray,
                     max_hops: int) -> jnp.ndarray:
    """Per-edge count of (s, t) pairs routed over each directed edge.

    Counting-semiring fixpoint instead of a host-side table walk: for a
    destination t the forwarding column is a tree, and the number of
    sources crossing edge (u, nh[u, t]) is the subtree size
    ``c[u, t] = r[u, t] + sum_{v : nh[v, t] = u} c[v, t]`` with
    ``r = reach & off-diagonal``.  ``max_hops`` iterations of the linear
    map converge because no source sits deeper than the longest path.
    """
    n = nh.shape[0]
    eye = jnp.eye(n, dtype=bool)
    valid = (nh >= 0) & reach & ~eye
    r = (reach & ~eye).astype(jnp.float32)
    tgt = jnp.clip(nh, 0)
    tcols = jnp.broadcast_to(jnp.arange(n)[None, :], (n, n))

    def body(_, c):
        contrib = jnp.where(valid, c, 0.0)
        return r + jnp.zeros_like(c).at[tgt, tcols].add(contrib)

    c = jax.lax.fori_loop(0, max_hops, body, jnp.zeros((n, n), jnp.float32))
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], (n, n))
    return jnp.zeros((n, n), jnp.float32).at[rows, tgt].add(
        jnp.where(valid, c, 0.0))


def _layer_tables_core(adj: jnp.ndarray, nbr: jnp.ndarray, key: jnp.ndarray,
                       max_l: int, engine: str = "dense",
                       nbr_in: Optional[jnp.ndarray] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """APSP + forwarding through either engine.  ``nbr_in`` is the
    in-neighbor table the frontier APSP relaxes through; ``None`` reuses
    ``nbr`` — correct whenever ``nbr`` was built from a symmetric
    superset adjacency (the topology base graph), which is every builder
    in :mod:`repro.core.layers`."""
    if engine == "blocked":
        dist = _apsp_blocked_core(adj, nbr if nbr_in is None else nbr_in,
                                  max_l)
        nh = _forwarding_blocked_core(adj, dist, nbr, key)
    else:
        dist = _apsp_core(adj, max_l)
        nh = _forwarding_core(adj, dist, nbr, key)
    reach = dist <= max_l
    return nh, reach, dist


# -----------------------------------------------------------------------------
# Jitted batched entry points.
# -----------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("max_l",))
def _apsp_dense_program(adj, max_l):
    return _apsp_core(adj.astype(jnp.bool_), max_l)


@functools.partial(jax.jit, static_argnames=("max_l",))
def _apsp_blocked_program(adj, nbr_in, max_l):
    return _apsp_blocked_core(adj.astype(jnp.bool_), nbr_in, max_l)


def apsp_batched(adj: jnp.ndarray, max_l: int = 64,
                 engine: Optional[str] = None) -> jnp.ndarray:
    """All-pairs shortest path lengths for an (L, N, N) adjacency stack in
    one device program; unreachable pairs get ``max_l + 1``.  ``engine``
    overrides the ``REPRO_PATH_ENGINE`` resolution; both engines return
    bit-identical distances."""
    if path_engine(adj.shape[-1], engine) == "blocked":
        adj_np = np.asarray(adj, dtype=bool)
        nbr_in = jnp.asarray(neighbor_table(adj_np.any(axis=0).T))
        return _apsp_blocked_program(jnp.asarray(adj_np), nbr_in, max_l)
    return _apsp_dense_program(jnp.asarray(adj), max_l)


@functools.partial(jax.jit, static_argnames=("engine",))
def _forwarding_program(adj, dist, nbr, key, engine="dense"):
    if engine == "blocked":
        return _forwarding_blocked_core(adj.astype(jnp.bool_), dist, nbr, key)
    return _forwarding_core(adj.astype(jnp.bool_), dist, nbr, key)


def forwarding_batched(adj: jnp.ndarray, dist: jnp.ndarray,
                       key: jnp.ndarray,
                       engine: Optional[str] = None) -> jnp.ndarray:
    """Random-tie-break forwarding tables for an (L, N, N) stack; ``key``
    seeds the per-entry uniform choice (one PRNG stream for the stack)."""
    nbr = jnp.asarray(neighbor_table(np.asarray(adj).any(axis=0)))
    return _forwarding_program(jnp.asarray(adj), jnp.asarray(dist), nbr, key,
                               path_engine(adj.shape[-1], engine))


@functools.partial(jax.jit, static_argnames=("max_l", "engine"))
def _layer_tables_program(adj, nbr, key, max_l, engine="dense", nbr_in=None):
    return _layer_tables_core(adj.astype(jnp.bool_), nbr, key, max_l,
                              engine, nbr_in)


def layer_tables_batched(adj: jnp.ndarray, key: jnp.ndarray, max_l: int,
                         engine: Optional[str] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """APSP + forwarding for a whole layer stack: ONE device program.

    Returns ``(nh, reach, dist)`` each (L, N, N).  The host's only job is
    the (N, Dmax) union neighbor table; APSP and every table entry are
    computed in a single jitted call.  ``engine`` overrides the
    ``REPRO_PATH_ENGINE`` resolution; the blocked engine additionally
    gets the union's in-neighbor table for the frontier relaxation (the
    stack union need not be symmetric — failure-masked stacks).
    """
    adj_np = np.asarray(adj, dtype=bool)
    union = adj_np.any(axis=0)
    nbr = jnp.asarray(neighbor_table(union))
    eng = path_engine(adj_np.shape[-1], engine)
    nbr_in = jnp.asarray(neighbor_table(union.T)) if eng == "blocked" else None
    return _layer_tables_program(jnp.asarray(adj_np), nbr, key, max_l,
                                 eng, nbr_in)


@functools.partial(jax.jit, static_argnames=("max_l",))
def minplus_apsp_batched(w: jnp.ndarray, max_l: int) -> jnp.ndarray:
    """(min, +) all-pairs distances for a (K, N, N) weight stack.

    Precondition: edge weights are >= 1 (+inf for non-edges, 0 diagonal)
    and every hop-distance is <= ``max_l`` — the squaring count is sized
    for shortest weighted paths of at most ~1.25 * max_l hops, which is
    what the ``ksp`` scheme's 1 + 0.25*U(0,1) perturbed unit weights
    guarantee.  Sub-unit weights would admit longer optimal paths than
    the iteration covers and silently overestimate distances.
    """
    return _minplus_apsp_core(w.astype(jnp.float32), max_l)


@functools.partial(jax.jit, static_argnames=("max_hops",))
def edge_usage_batched(nh: jnp.ndarray, reach: jnp.ndarray,
                       max_hops: int) -> jnp.ndarray:
    """Directed-edge usage counts for an (L, N, N) table stack (f32,
    exact below 2**24)."""
    return jax.vmap(lambda a, b: _edge_usage_core(a, b, max_hops))(nh, reach)


@functools.partial(jax.jit, static_argnames=("max_hops",))
def table_validity_batched(nh: jnp.ndarray, alive: jnp.ndarray,
                           max_hops: int) -> jnp.ndarray:
    """``valid[l, s, t]`` — the (layer, s, t) forwarding entry still
    delivers: every hop of the walk from s to t traverses an alive
    directed edge (``alive[u, nh[u, t]]``) and terminates at t within
    ``max_hops``.  The fixpoint grows from the diagonal
    (``valid = eye | (edge alive & valid at next hop)``), so loops and
    dead-edge walks never validate.  Used by the fault-injection engine
    (:mod:`repro.core.failures`, ``mode="drop"``) to strip broken
    entries from pristine tables without re-converging routes.
    """
    _, n, _ = nh.shape
    eye = jnp.eye(n, dtype=bool)
    idx = jnp.arange(n)
    alive = alive.astype(jnp.bool_)

    def one_layer(nh_l):
        nxt = jnp.clip(nh_l, 0).astype(jnp.int32)
        edge_ok = (nh_l >= 0) & alive[idx[:, None], nxt]

        def body(_, valid):
            return eye | (edge_ok & jnp.take_along_axis(valid, nxt, axis=0))

        return jax.lax.fori_loop(0, max_hops, body, eye)

    return jax.vmap(one_layer)(nh)


# -----------------------------------------------------------------------------
# Compressed forwarding tables: per-router (dst-block, next-hop set).
# -----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CompressedTables:
    """Forwarding tables as per-router next-hop *sets* per destination
    block, instead of a dense (L, N, N) int32 array.

    A shortest-path table row has at most ``Dmax`` distinct next hops
    (they are neighbors of the router), and consecutive destinations
    overwhelmingly share them — so each (layer, router, dst-block) keeps
    the sorted set of next hops appearing in that block
    (``nh_sets[l, s, b, :]``, -1 padded) and the dense entry shrinks to a
    uint8 index into it (``sel``).  Reconstruction is exact:
    ``nh[l, s, t] == nh_sets[l, s, t // block, sel[l, s, t]]`` bitwise,
    which is what lets :func:`repro.core.transport._prepare` walk paths
    straight off the compressed form.

    The ratio vs dense is ~``0.25 + K/block`` (uint8 selector plus the
    set arrays), so larger blocks compress better — but ``K`` (the worst
    per-block distinct-next-hop count) must fit the uint8 selector, and
    a very-high-radix router can reach every destination in a block via
    a distinct next hop (e.g. an FT2 spine).  ``block=None`` (the
    default) therefore starts at 512 and halves until ``K <= 255``; at
    sf(q=29) that lands on 512 directly for ~2.8x less memory than the
    dense stack (36 MB vs 102 MB for 9 layers).
    """

    nh_sets: np.ndarray   # (L, N, nb, K) int32, -1 padded
    sel: np.ndarray       # (L, N, N) uint8 index into nh_sets' last axis
    block: int
    n: int

    _AUTO_BLOCK = 512

    @classmethod
    def from_dense(cls, nh: np.ndarray,
                   block: Optional[int] = None) -> "CompressedTables":
        nh = np.asarray(nh, dtype=np.int32)
        L, n, _ = nh.shape
        auto = block is None
        block = cls._AUTO_BLOCK if auto else int(block)
        while True:
            nb = -(-n // block)
            npad = nb * block
            v = np.full((L, n, npad), -1, np.int32)
            v[:, :, :n] = nh
            v = v.reshape(L, n, nb, block)
            order = np.argsort(v, axis=-1, kind="stable")
            sv = np.take_along_axis(v, order, axis=-1)
            new = np.ones(sv.shape, dtype=bool)
            new[..., 1:] = sv[..., 1:] != sv[..., :-1]
            rank_sorted = np.cumsum(new, axis=-1, dtype=np.int32) - 1
            k = int(rank_sorted[..., -1].max()) + 1
            if k <= 255:
                break
            if not auto or block <= 2:
                raise ValueError(
                    f"next-hop set size {k} exceeds uint8 selector "
                    f"at block={block}")
            block //= 2
        nh_sets = np.full((L, n, nb, k), -1, np.int32)
        np.put_along_axis(nh_sets, rank_sorted, sv, axis=-1)
        sel = np.empty(v.shape, np.uint8)
        np.put_along_axis(sel, order, rank_sorted.astype(np.uint8), axis=-1)
        sel = sel.reshape(L, n, npad)[:, :, :n]
        return cls(nh_sets=nh_sets, sel=np.ascontiguousarray(sel),
                   block=block, n=n)

    def dense(self) -> np.ndarray:
        """The exact dense (L, N, N) int32 stack this was built from."""
        L, n = self.sel.shape[0], self.n
        nb = self.nh_sets.shape[2]
        t = np.arange(n)
        out = np.empty((L, n, n), np.int32)
        for l in range(L):
            out[l] = self.nh_sets[l, np.arange(n)[:, None], t[None, :]
                                  // self.block, self.sel[l]]
        return out

    def lookup(self, layer: np.ndarray, cur: np.ndarray,
               t: np.ndarray) -> np.ndarray:
        """Vectorised next-hop lookup ``nh[layer, cur, t]`` off the
        compressed form (numpy, the host-side walk path)."""
        layer = np.asarray(layer)
        cur = np.asarray(cur)
        t = np.asarray(t)
        k = self.sel[layer, cur, t]
        return self.nh_sets[layer, cur, t // self.block, k]

    @property
    def nbytes(self) -> int:
        return self.nh_sets.nbytes + self.sel.nbytes


@functools.partial(jax.jit, static_argnames=("max_l",))
def shortest_path_lengths(adj: jnp.ndarray, max_l: int = 64) -> jnp.ndarray:
    """All-pairs shortest path lengths via boolean adjacency powers.

    Args:
      adj: (N, N) bool adjacency.
      max_l: iteration cap (>= diameter).

    Returns:
      (N, N) int32 distance matrix; unreachable pairs get ``max_l + 1``;
      diagonal is 0.
    """
    return _apsp_core(adj.astype(jnp.bool_)[None], max_l)[0]


def diameter(adj: np.ndarray, max_l: int = 64) -> int:
    d = np.asarray(shortest_path_lengths(jnp.asarray(adj), max_l=max_l))
    finite = d[d <= max_l]
    return int(finite.max())


def average_path_length(adj: np.ndarray, max_l: int = 64) -> float:
    n = adj.shape[0]
    d = np.asarray(shortest_path_lengths(jnp.asarray(adj), max_l=max_l)).astype(np.float64)
    off = ~np.eye(n, dtype=bool)
    return float(d[off].mean())


@functools.partial(jax.jit, static_argnames=("l",))
def path_counts_exact_length(adj: jnp.ndarray, l: int) -> jnp.ndarray:
    """Number of length-``l`` walks between every pair (Theorem 1),
    saturating-count semiring powers."""
    a = adj.astype(jnp.float32)
    out = a
    for _ in range(l - 1):
        out = semiring_matmul(out, a, "count")
    return out


@functools.partial(jax.jit, static_argnames=("max_l",))
def _min_path_stats_jit(adj: jnp.ndarray, max_l: int
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(dist, counts-of-shortest-walks) with the masked select done on
    device — one fetch for the whole result instead of one (N, N)
    transfer per candidate length."""
    dist = _apsp_core(adj.astype(jnp.bool_)[None], max_l)[0]
    a = adj.astype(jnp.float32)
    counts = jnp.where(dist == 1, a, 0.0)
    cur = a
    for l in range(2, max_l + 1):
        cur = semiring_matmul(cur, a, "count")
        counts = jnp.where(dist == l, cur, counts)
    return dist, counts


@functools.partial(jax.jit, static_argnames=("max_l",))
def _min_path_counts_rows_jit(adj: jnp.ndarray, dist: jnp.ndarray,
                              max_l: int) -> jnp.ndarray:
    """Row-blocked shortest-walk counts: the power sequence advances per
    source-row block ((_CHUNK, N) at a time), so the only (N, N) f32
    arrays alive are the adjacency and the output — the dense variant
    additionally holds every running power."""
    n = adj.shape[0]
    a = adj.astype(jnp.float32)
    nc = -(-n // _CHUNK)
    npad = nc * _CHUNK
    a_rows = jnp.zeros((npad, n), jnp.float32).at[:n].set(a)
    d_rows = jnp.zeros((npad, n), jnp.int32).at[:n].set(dist)
    a_rows = a_rows.reshape(nc, _CHUNK, n)
    d_rows = d_rows.reshape(nc, _CHUNK, n)

    def one_block(args):
        cur, d_r = args
        counts = jnp.where(d_r == 1, cur, 0.0)
        for l in range(2, max_l + 1):
            cur = semiring_matmul(cur, a, "count")
            counts = jnp.where(d_r == l, cur, counts)
        return counts

    out = jax.lax.map(one_block, (a_rows, d_rows))
    return out.reshape(npad, n)[:n]


def min_path_stats(adj: np.ndarray, max_l: int = 8,
                   engine: Optional[str] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-pair (l_min, c_min): shortest-path length and multiplicity (§4.2.1).

    c_min counts *shortest walks*, which for the minimal length equal
    shortest paths (no repeated vertex fits in a minimal walk).  Under
    the blocked engine the distances come from the frontier APSP and the
    counts from row-blocked powers, so peak memory stays O(_CHUNK * N)
    per intermediate instead of several (N, N) f32 matrices.
    """
    if path_engine(adj.shape[-1], engine) == "blocked":
        a_np = np.asarray(adj, dtype=bool)
        nbr_in = jnp.asarray(neighbor_table(a_np.T))
        dist = _apsp_blocked_program(jnp.asarray(a_np)[None], nbr_in,
                                     max_l)[0]
        counts = _min_path_counts_rows_jit(jnp.asarray(a_np), dist, max_l)
    else:
        dist, counts = _min_path_stats_jit(jnp.asarray(adj), max_l)
    return np.asarray(dist), np.asarray(counts, dtype=np.float64)


def next_hop_options(adj: np.ndarray, dist: Optional[np.ndarray] = None,
                     max_l: int = 64) -> np.ndarray:
    """(N, N, N) bool: ``opt[s, t, u]`` — u is a valid shortest-path next hop
    from s towards t.  This is the set-semiring routing-table construction of
    Appendix B.1.1, expressed as a distance test:
    u is a next hop iff adj[s, u] and dist[u, t] == dist[s, t] - 1.

    Memory is O(N^3) bits; callers with large N should use
    :func:`build_forwarding` which keeps one random choice per (s, t).
    """
    if dist is None:
        dist = np.asarray(shortest_path_lengths(jnp.asarray(adj), max_l=max_l))
    a = np.asarray(adj, dtype=bool)
    n = a.shape[0]
    out = np.zeros((n, n, n), dtype=bool)
    for s in range(n):
        # valid u: a[s, u] and dist[u, t] == dist[s, t] - 1
        ok = a[s][:, None] & (dist == dist[s][None, :] - 1)  # (u, t)
        out[s] = ok.T  # (t, u)
    return out


def build_forwarding(adj: np.ndarray, dist: Optional[np.ndarray] = None,
                     seed: int = 0, max_l: int = 64) -> np.ndarray:
    """Single-next-hop forwarding table for shortest-path routing (§5.4).

    Returns (N, N) int32 ``nh[s, t]`` = next router from s towards t
    (``nh[t, t] = t``); a random choice among equal-cost options, matching
    the paper's "choose a random first step port if there are multiple".
    Unreachable pairs get -1.  The L=1 case of :func:`forwarding_batched`.
    """
    a = np.asarray(adj, dtype=bool)
    if dist is None:
        dist_j = shortest_path_lengths(jnp.asarray(a), max_l=max_l)
    else:
        dist_j = jnp.asarray(dist, dtype=jnp.int32)
    nh = np.asarray(forwarding_batched(a[None], dist_j[None],
                                       jax.random.PRNGKey(seed))[0]).copy()
    reach = np.asarray(dist_j) <= max_l
    nh[~reach] = -1
    np.fill_diagonal(nh, np.arange(a.shape[0]))
    return nh


def walk_paths(nh: np.ndarray, s: np.ndarray, t: np.ndarray, max_hops: int) -> np.ndarray:
    """Materialise router sequences by iterating a forwarding table.

    Args:
      nh: (N, N) next-hop table.
      s, t: (F,) endpoints.
      max_hops: path length cap.

    Returns:
      (F, max_hops + 1) int32 router ids; after reaching t the sequence
      repeats t.  A -1 appears if the table cannot route.
    """
    return walk_paths_layers(np.asarray(nh)[None],
                             np.zeros(len(np.atleast_1d(s)), dtype=np.int32),
                             s, t, max_hops)


def walk_paths_layers(nh_stack: Union[np.ndarray, CompressedTables],
                      layer: np.ndarray, s: np.ndarray,
                      t: np.ndarray, max_hops: int) -> np.ndarray:
    """Walk per-sample forwarding tables: sample i follows layer
    ``layer[i]`` of ``nh_stack``.  One vectorised walk for the whole
    (sample, layer) batch — no per-sample Python loop.  ``nh_stack`` may
    be the dense (L, N, N) array or a :class:`CompressedTables` (the
    walk then never touches a dense table; lookups are exact, so the
    sequences are identical).

    Returns (F, max_hops + 1) int32 router sequences (semantics of
    :func:`walk_paths`).
    """
    layer = np.asarray(layer, dtype=np.int32)
    s = np.asarray(s, dtype=np.int32)
    t = np.asarray(t, dtype=np.int32)
    compressed = isinstance(nh_stack, CompressedTables)
    out = np.zeros((len(s), max_hops + 1), dtype=np.int32)
    cur = s.copy()
    out[:, 0] = cur
    for h in range(1, max_hops + 1):
        if compressed:
            nxt = nh_stack.lookup(layer, np.maximum(cur, 0), t)
        else:
            nxt = nh_stack[layer, np.maximum(cur, 0), t]
        dead = (nxt < 0) | (cur < 0)
        cur = np.where(dead, -1, np.where(cur == t, t, nxt)).astype(np.int32)
        out[:, h] = cur
    return out
