"""Path analysis via adjacency-matrix algebra (paper Appendix B.1).

All heavy routines are JAX programs (vectorised boolean / counting matrix
multiplication); on TPU the counting products route through the Pallas
``pathcount`` kernel (see ``repro.kernels.pathcount``); the jnp expressions
here are its oracle semantics.

Counts are held in f32 and *saturate*: they are exact below 2**24, which is
far beyond every threshold the paper's diversity metrics use (the paper
cares about counts in the range 1..k' ~ tens).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "shortest_path_lengths",
    "diameter",
    "average_path_length",
    "path_counts_exact_length",
    "min_path_stats",
    "next_hop_options",
    "build_forwarding",
    "walk_paths",
]

_SAT = jnp.float32(3.0e38)


@functools.partial(jax.jit, static_argnames=("max_l",))
def shortest_path_lengths(adj: jnp.ndarray, max_l: int = 64) -> jnp.ndarray:
    """All-pairs shortest path lengths via boolean adjacency powers.

    Args:
      adj: (N, N) bool adjacency.
      max_l: iteration cap (>= diameter).

    Returns:
      (N, N) int32 distance matrix; unreachable pairs get ``max_l + 1``;
      diagonal is 0.
    """
    n = adj.shape[0]
    a = adj.astype(jnp.bool_)
    dist0 = jnp.where(jnp.eye(n, dtype=bool), 0, jnp.where(a, 1, max_l + 1))

    def body(state):
        dist, reach, l, changed = state
        nreach = (reach.astype(jnp.float32) @ a.astype(jnp.float32)) > 0
        newly = nreach & ~reach
        dist = jnp.where(newly & (dist > l + 1), l + 1, dist)
        return dist, reach | nreach, l + 1, newly.any()

    def cond(state):
        _, _, l, changed = state
        return jnp.logical_and(changed, l < max_l)

    reach0 = a | jnp.eye(n, dtype=bool)
    dist, _, _, _ = jax.lax.while_loop(cond, body, (dist0.astype(jnp.int32), reach0, jnp.int32(1), jnp.bool_(True)))
    return dist


def diameter(adj: np.ndarray, max_l: int = 64) -> int:
    d = np.asarray(shortest_path_lengths(jnp.asarray(adj), max_l=max_l))
    finite = d[d <= max_l]
    return int(finite.max())


def average_path_length(adj: np.ndarray, max_l: int = 64) -> float:
    n = adj.shape[0]
    d = np.asarray(shortest_path_lengths(jnp.asarray(adj), max_l=max_l)).astype(np.float64)
    off = ~np.eye(n, dtype=bool)
    return float(d[off].mean())


@functools.partial(jax.jit, static_argnames=("l",))
def path_counts_exact_length(adj: jnp.ndarray, l: int) -> jnp.ndarray:
    """Number of length-``l`` walks between every pair (Theorem 1), saturating f32."""
    a = adj.astype(jnp.float32)
    out = a
    for _ in range(l - 1):
        out = jnp.minimum(out @ a, _SAT)
    return out


def min_path_stats(adj: np.ndarray, max_l: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Per-pair (l_min, c_min): shortest-path length and multiplicity (§4.2.1).

    c_min counts *shortest walks*, which for the minimal length equal
    shortest paths (no repeated vertex fits in a minimal walk).
    """
    adj_j = jnp.asarray(adj)
    dist = np.asarray(shortest_path_lengths(adj_j, max_l=max_l))
    n = adj.shape[0]
    counts = np.zeros((n, n), dtype=np.float64)
    power = jnp.asarray(adj, dtype=jnp.float32)
    a = jnp.asarray(adj, dtype=jnp.float32)
    cur = power
    for l in range(1, max_l + 1):
        mask = dist == l
        if mask.any():
            counts[mask] = np.asarray(cur)[mask]
        if l < max_l:
            cur = jnp.minimum(cur @ a, _SAT)
    return dist, counts


def next_hop_options(adj: np.ndarray, dist: Optional[np.ndarray] = None,
                     max_l: int = 64) -> np.ndarray:
    """(N, N, N) bool: ``opt[s, t, u]`` — u is a valid shortest-path next hop
    from s towards t.  This is the set-semiring routing-table construction of
    Appendix B.1.1, expressed as a distance test:
    u is a next hop iff adj[s, u] and dist[u, t] == dist[s, t] - 1.

    Memory is O(N^3) bits; callers with large N should use
    :func:`build_forwarding` which keeps one random choice per (s, t).
    """
    if dist is None:
        dist = np.asarray(shortest_path_lengths(jnp.asarray(adj), max_l=max_l))
    a = np.asarray(adj, dtype=bool)
    n = a.shape[0]
    out = np.zeros((n, n, n), dtype=bool)
    for s in range(n):
        # valid u: a[s, u] and dist[u, t] == dist[s, t] - 1
        ok = a[s][:, None] & (dist == dist[s][None, :] - 1)  # (u, t)
        out[s] = ok.T  # (t, u)
    return out


def build_forwarding(adj: np.ndarray, dist: Optional[np.ndarray] = None,
                     seed: int = 0, max_l: int = 64) -> np.ndarray:
    """Single-next-hop forwarding table for shortest-path routing (§5.4).

    Returns (N, N) int32 ``nh[s, t]`` = next router from s towards t
    (``nh[t, t] = t``); a random choice among equal-cost options, matching
    the paper's "choose a random first step port if there are multiple".
    Unreachable pairs get -1.
    """
    a = np.asarray(adj, dtype=bool)
    n = a.shape[0]
    if dist is None:
        dist = np.asarray(shortest_path_lengths(jnp.asarray(a), max_l=max_l))
    rng = np.random.default_rng(seed)
    nh = np.full((n, n), -1, dtype=np.int32)
    for s in range(n):
        # (u, t): u neighbor of s on a shortest path to t; random tie-break.
        ok = a[s][:, None] & (dist == dist[s][None, :] - 1)
        score = np.where(ok, rng.random((n, n)), -1.0)
        best = score.argmax(axis=0)
        has = ok.any(axis=0)
        nh[s] = np.where(has, best, -1)
        nh[s, s] = s
    reach = dist <= max_l
    nh[~reach] = -1
    np.fill_diagonal(nh, np.arange(n))
    return nh


def walk_paths(nh: np.ndarray, s: np.ndarray, t: np.ndarray, max_hops: int) -> np.ndarray:
    """Materialise router sequences by iterating a forwarding table.

    Args:
      nh: (N, N) next-hop table.
      s, t: (F,) endpoints.
      max_hops: path length cap.

    Returns:
      (F, max_hops + 1) int32 router ids; after reaching t the sequence
      repeats t.  A -1 appears if the table cannot route.
    """
    s = np.asarray(s, dtype=np.int32)
    t = np.asarray(t, dtype=np.int32)
    out = np.zeros((len(s), max_hops + 1), dtype=np.int32)
    cur = s.copy()
    out[:, 0] = cur
    for h in range(1, max_hops + 1):
        nxt = nh[cur, t]
        dead = (nxt < 0) | (cur < 0)
        cur = np.where(dead, -1, np.where(cur == t, t, nxt)).astype(np.int32)
        out[:, h] = cur
    return out
