"""Path analysis via adjacency-matrix algebra (paper Appendix B.1).

All heavy routines are JAX programs expressed as *semiring* matrix
products through :mod:`repro.kernels.semiring` — boolean OR/AND for
reachability, saturating f32 counting for walk multiplicities, (min, +)
for weighted distances.  On TPU the products route through the tiled
Pallas kernel; on CPU they lower to XLA's native (batched) matmul via
the jnp oracle in ``kernels/ref.py``.

The batched entry points (``apsp_batched``, ``forwarding_batched``,
``layer_tables_batched``, ``minplus_apsp_batched``, ``edge_usage_batched``)
operate on an (L, N, N) stack of layer adjacencies in ONE device program
— this is what lets :func:`repro.core.layers.build_layers` construct a
whole FatPaths layer stack without a per-layer host loop.  Random
tie-breaks use per-layer PRNG keys on device (uniform choice among
equal-cost next hops, distribution-identical to the historical
host-side ``rng.random`` scoring).

Counts are held in f32 and *saturate*: they are exact below 2**24, which
is far beyond every threshold the paper's diversity metrics use (the
paper cares about counts in the range 1..k' ~ tens).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.semiring import semiring_matmul

__all__ = [
    "shortest_path_lengths",
    "apsp_batched",
    "forwarding_batched",
    "layer_tables_batched",
    "minplus_apsp_batched",
    "edge_usage_batched",
    "diameter",
    "average_path_length",
    "path_counts_exact_length",
    "min_path_stats",
    "next_hop_options",
    "build_forwarding",
    "table_validity_batched",
    "walk_paths",
    "walk_paths_layers",
]


# -----------------------------------------------------------------------------
# Batched cores (traceable; shared by the jitted entry points below and by
# the single-program layer builders in repro.core.layers).
# -----------------------------------------------------------------------------
def _apsp_core(adj: jnp.ndarray, max_l: int) -> jnp.ndarray:
    """(L, N, N) bool adjacency stack -> (L, N, N) int32 distances via
    boolean-semiring frontier products; unreachable pairs get max_l + 1."""
    _, n, _ = adj.shape
    eye = jnp.eye(n, dtype=bool)
    dist0 = jnp.where(eye[None], 0,
                      jnp.where(adj, 1, max_l + 1)).astype(jnp.int32)
    reach0 = adj | eye[None]

    def body(state):
        dist, reach, l, _ = state
        nreach = semiring_matmul(reach, adj, "bool")
        newly = nreach & ~reach
        dist = jnp.where(newly & (dist > l + 1), l + 1, dist)
        return dist, reach | nreach, l + 1, newly.any()

    def cond(state):
        return jnp.logical_and(state[3], state[2] < max_l)

    dist, _, _, _ = jax.lax.while_loop(
        cond, body, (dist0, reach0, jnp.int32(1), jnp.bool_(True)))
    return dist


def neighbor_table(adj_union: np.ndarray) -> np.ndarray:
    """(N, Dmax) int32 padded neighbor-index table for a (union)
    adjacency.  Entry ``nbr[s, j]`` is the j-th neighbor of s; pad slots
    hold non-neighbor ids and are masked out by the per-layer adjacency
    gather.  This is what keeps forwarding construction at
    O(N * Dmax * N) instead of O(N^3): next-hop candidates are always
    neighbors, and Dmax = k' << N."""
    a = np.asarray(adj_union, dtype=bool)
    dmax = max(1, int(a.sum(axis=1).max()))
    # stable argsort puts neighbors (True) first in ascending-id order
    return np.argsort(~a, axis=1, kind="stable")[:, :dmax].astype(np.int32)


def _forwarding_core(adj: jnp.ndarray, dist: jnp.ndarray, nbr: jnp.ndarray,
                     key: jnp.ndarray) -> jnp.ndarray:
    """Single-next-hop tables for an (L, N, N) stack, on device.

    For each (layer, s, t) the next hop is chosen *uniformly at random*
    among the equal-cost candidates ``{u in nbr[s] : adj[s, u],
    dist[u, t] == dist[s, t] - 1}`` by picking the r-th valid candidate,
    with r drawn from one per-(s, t) uniform — one random number per
    table entry, one PRNG stream per layer stack.
    """
    L, n, _ = adj.shape
    u01 = jax.random.uniform(key, (L, n, n))
    rows = jnp.arange(n)[:, None]

    def one_layer(args):
        adj_l, dist_l, u_l = args
        has_edge = jnp.take_along_axis(adj_l, nbr, axis=1)   # (N, D)
        dist_nbr = dist_l[nbr]                               # (N, D, N)
        # ok[s, j, t]: edge s->nbr[s,j] in this layer, one hop closer to t.
        ok = has_edge[:, :, None] & (dist_nbr + 1 == dist_l[:, None, :])
        cnt = ok.sum(axis=1)                                 # (N, N)
        r = jnp.clip((u_l * cnt).astype(jnp.int32), 0,
                     jnp.maximum(cnt - 1, 0))
        csum = jnp.cumsum(ok.astype(jnp.int32), axis=1)
        pick = ok & (csum == (r + 1)[:, None, :])
        j = jnp.argmax(pick, axis=1)                         # (N, N)
        nh = nbr[rows, j].astype(jnp.int32)
        return jnp.where(cnt > 0, nh, -1)

    nh = jax.lax.map(one_layer, (adj, dist, u01))
    idx = jnp.arange(n)
    return nh.at[:, idx, idx].set(idx)


def _minplus_apsp_core(w: jnp.ndarray, max_l: int) -> jnp.ndarray:
    """All-pairs weighted distances for a (K, N, N) weight stack (+inf
    non-edges, 0 diagonal) by repeated (min, +) squaring: after i
    squarings paths of up to 2**i hops are covered, and with unit-ish
    weights (>= 1) no shortest path uses more than ~1.25 * max_l hops."""
    iters = max(1, int(np.ceil(np.log2(1.25 * max_l + 1))))
    d = w
    for _ in range(iters):
        d = semiring_matmul(d, d, "minplus")
    return d


def _edge_usage_core(nh: jnp.ndarray, reach: jnp.ndarray,
                     max_hops: int) -> jnp.ndarray:
    """Per-edge count of (s, t) pairs routed over each directed edge.

    Counting-semiring fixpoint instead of a host-side table walk: for a
    destination t the forwarding column is a tree, and the number of
    sources crossing edge (u, nh[u, t]) is the subtree size
    ``c[u, t] = r[u, t] + sum_{v : nh[v, t] = u} c[v, t]`` with
    ``r = reach & off-diagonal``.  ``max_hops`` iterations of the linear
    map converge because no source sits deeper than the longest path.
    """
    n = nh.shape[0]
    eye = jnp.eye(n, dtype=bool)
    valid = (nh >= 0) & reach & ~eye
    r = (reach & ~eye).astype(jnp.float32)
    tgt = jnp.clip(nh, 0)
    tcols = jnp.broadcast_to(jnp.arange(n)[None, :], (n, n))

    def body(_, c):
        contrib = jnp.where(valid, c, 0.0)
        return r + jnp.zeros_like(c).at[tgt, tcols].add(contrib)

    c = jax.lax.fori_loop(0, max_hops, body, jnp.zeros((n, n), jnp.float32))
    rows = jnp.broadcast_to(jnp.arange(n)[:, None], (n, n))
    return jnp.zeros((n, n), jnp.float32).at[rows, tgt].add(
        jnp.where(valid, c, 0.0))


def _layer_tables_core(adj: jnp.ndarray, nbr: jnp.ndarray, key: jnp.ndarray,
                       max_l: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    dist = _apsp_core(adj, max_l)
    nh = _forwarding_core(adj, dist, nbr, key)
    reach = dist <= max_l
    return nh, reach, dist


# -----------------------------------------------------------------------------
# Jitted batched entry points.
# -----------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("max_l",))
def apsp_batched(adj: jnp.ndarray, max_l: int = 64) -> jnp.ndarray:
    """All-pairs shortest path lengths for an (L, N, N) adjacency stack in
    one device program; unreachable pairs get ``max_l + 1``."""
    return _apsp_core(adj.astype(jnp.bool_), max_l)


@jax.jit
def _forwarding_program(adj, dist, nbr, key):
    return _forwarding_core(adj.astype(jnp.bool_), dist, nbr, key)


def forwarding_batched(adj: jnp.ndarray, dist: jnp.ndarray,
                       key: jnp.ndarray) -> jnp.ndarray:
    """Random-tie-break forwarding tables for an (L, N, N) stack; ``key``
    seeds the per-entry uniform choice (one PRNG stream for the stack)."""
    nbr = jnp.asarray(neighbor_table(np.asarray(adj).any(axis=0)))
    return _forwarding_program(jnp.asarray(adj), jnp.asarray(dist), nbr, key)


@functools.partial(jax.jit, static_argnames=("max_l",))
def _layer_tables_program(adj, nbr, key, max_l):
    return _layer_tables_core(adj.astype(jnp.bool_), nbr, key, max_l)


def layer_tables_batched(adj: jnp.ndarray, key: jnp.ndarray, max_l: int
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """APSP + forwarding for a whole layer stack: ONE device program.

    Returns ``(nh, reach, dist)`` each (L, N, N).  The host's only job is
    the (N, Dmax) union neighbor table; APSP and every table entry are
    computed in a single jitted call.
    """
    adj_np = np.asarray(adj, dtype=bool)
    nbr = jnp.asarray(neighbor_table(adj_np.any(axis=0)))
    return _layer_tables_program(jnp.asarray(adj_np), nbr, key, max_l)


@functools.partial(jax.jit, static_argnames=("max_l",))
def minplus_apsp_batched(w: jnp.ndarray, max_l: int) -> jnp.ndarray:
    """(min, +) all-pairs distances for a (K, N, N) weight stack.

    Precondition: edge weights are >= 1 (+inf for non-edges, 0 diagonal)
    and every hop-distance is <= ``max_l`` — the squaring count is sized
    for shortest weighted paths of at most ~1.25 * max_l hops, which is
    what the ``ksp`` scheme's 1 + 0.25*U(0,1) perturbed unit weights
    guarantee.  Sub-unit weights would admit longer optimal paths than
    the iteration covers and silently overestimate distances.
    """
    return _minplus_apsp_core(w.astype(jnp.float32), max_l)


@functools.partial(jax.jit, static_argnames=("max_hops",))
def edge_usage_batched(nh: jnp.ndarray, reach: jnp.ndarray,
                       max_hops: int) -> jnp.ndarray:
    """Directed-edge usage counts for an (L, N, N) table stack (f32,
    exact below 2**24)."""
    return jax.vmap(lambda a, b: _edge_usage_core(a, b, max_hops))(nh, reach)


@functools.partial(jax.jit, static_argnames=("max_hops",))
def table_validity_batched(nh: jnp.ndarray, alive: jnp.ndarray,
                           max_hops: int) -> jnp.ndarray:
    """``valid[l, s, t]`` — the (layer, s, t) forwarding entry still
    delivers: every hop of the walk from s to t traverses an alive
    directed edge (``alive[u, nh[u, t]]``) and terminates at t within
    ``max_hops``.  The fixpoint grows from the diagonal
    (``valid = eye | (edge alive & valid at next hop)``), so loops and
    dead-edge walks never validate.  Used by the fault-injection engine
    (:mod:`repro.core.failures`, ``mode="drop"``) to strip broken
    entries from pristine tables without re-converging routes.
    """
    _, n, _ = nh.shape
    eye = jnp.eye(n, dtype=bool)
    idx = jnp.arange(n)
    alive = alive.astype(jnp.bool_)

    def one_layer(nh_l):
        nxt = jnp.clip(nh_l, 0).astype(jnp.int32)
        edge_ok = (nh_l >= 0) & alive[idx[:, None], nxt]

        def body(_, valid):
            return eye | (edge_ok & jnp.take_along_axis(valid, nxt, axis=0))

        return jax.lax.fori_loop(0, max_hops, body, eye)

    return jax.vmap(one_layer)(nh)


@functools.partial(jax.jit, static_argnames=("max_l",))
def shortest_path_lengths(adj: jnp.ndarray, max_l: int = 64) -> jnp.ndarray:
    """All-pairs shortest path lengths via boolean adjacency powers.

    Args:
      adj: (N, N) bool adjacency.
      max_l: iteration cap (>= diameter).

    Returns:
      (N, N) int32 distance matrix; unreachable pairs get ``max_l + 1``;
      diagonal is 0.
    """
    return _apsp_core(adj.astype(jnp.bool_)[None], max_l)[0]


def diameter(adj: np.ndarray, max_l: int = 64) -> int:
    d = np.asarray(shortest_path_lengths(jnp.asarray(adj), max_l=max_l))
    finite = d[d <= max_l]
    return int(finite.max())


def average_path_length(adj: np.ndarray, max_l: int = 64) -> float:
    n = adj.shape[0]
    d = np.asarray(shortest_path_lengths(jnp.asarray(adj), max_l=max_l)).astype(np.float64)
    off = ~np.eye(n, dtype=bool)
    return float(d[off].mean())


@functools.partial(jax.jit, static_argnames=("l",))
def path_counts_exact_length(adj: jnp.ndarray, l: int) -> jnp.ndarray:
    """Number of length-``l`` walks between every pair (Theorem 1),
    saturating-count semiring powers."""
    a = adj.astype(jnp.float32)
    out = a
    for _ in range(l - 1):
        out = semiring_matmul(out, a, "count")
    return out


@functools.partial(jax.jit, static_argnames=("max_l",))
def _min_path_stats_jit(adj: jnp.ndarray, max_l: int
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(dist, counts-of-shortest-walks) with the masked select done on
    device — one fetch for the whole result instead of one (N, N)
    transfer per candidate length."""
    dist = _apsp_core(adj.astype(jnp.bool_)[None], max_l)[0]
    a = adj.astype(jnp.float32)
    counts = jnp.where(dist == 1, a, 0.0)
    cur = a
    for l in range(2, max_l + 1):
        cur = semiring_matmul(cur, a, "count")
        counts = jnp.where(dist == l, cur, counts)
    return dist, counts


def min_path_stats(adj: np.ndarray, max_l: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Per-pair (l_min, c_min): shortest-path length and multiplicity (§4.2.1).

    c_min counts *shortest walks*, which for the minimal length equal
    shortest paths (no repeated vertex fits in a minimal walk).
    """
    dist, counts = _min_path_stats_jit(jnp.asarray(adj), max_l)
    return np.asarray(dist), np.asarray(counts, dtype=np.float64)


def next_hop_options(adj: np.ndarray, dist: Optional[np.ndarray] = None,
                     max_l: int = 64) -> np.ndarray:
    """(N, N, N) bool: ``opt[s, t, u]`` — u is a valid shortest-path next hop
    from s towards t.  This is the set-semiring routing-table construction of
    Appendix B.1.1, expressed as a distance test:
    u is a next hop iff adj[s, u] and dist[u, t] == dist[s, t] - 1.

    Memory is O(N^3) bits; callers with large N should use
    :func:`build_forwarding` which keeps one random choice per (s, t).
    """
    if dist is None:
        dist = np.asarray(shortest_path_lengths(jnp.asarray(adj), max_l=max_l))
    a = np.asarray(adj, dtype=bool)
    n = a.shape[0]
    out = np.zeros((n, n, n), dtype=bool)
    for s in range(n):
        # valid u: a[s, u] and dist[u, t] == dist[s, t] - 1
        ok = a[s][:, None] & (dist == dist[s][None, :] - 1)  # (u, t)
        out[s] = ok.T  # (t, u)
    return out


def build_forwarding(adj: np.ndarray, dist: Optional[np.ndarray] = None,
                     seed: int = 0, max_l: int = 64) -> np.ndarray:
    """Single-next-hop forwarding table for shortest-path routing (§5.4).

    Returns (N, N) int32 ``nh[s, t]`` = next router from s towards t
    (``nh[t, t] = t``); a random choice among equal-cost options, matching
    the paper's "choose a random first step port if there are multiple".
    Unreachable pairs get -1.  The L=1 case of :func:`forwarding_batched`.
    """
    a = np.asarray(adj, dtype=bool)
    if dist is None:
        dist_j = shortest_path_lengths(jnp.asarray(a), max_l=max_l)
    else:
        dist_j = jnp.asarray(dist, dtype=jnp.int32)
    nh = np.asarray(forwarding_batched(a[None], dist_j[None],
                                       jax.random.PRNGKey(seed))[0]).copy()
    reach = np.asarray(dist_j) <= max_l
    nh[~reach] = -1
    np.fill_diagonal(nh, np.arange(a.shape[0]))
    return nh


def walk_paths(nh: np.ndarray, s: np.ndarray, t: np.ndarray, max_hops: int) -> np.ndarray:
    """Materialise router sequences by iterating a forwarding table.

    Args:
      nh: (N, N) next-hop table.
      s, t: (F,) endpoints.
      max_hops: path length cap.

    Returns:
      (F, max_hops + 1) int32 router ids; after reaching t the sequence
      repeats t.  A -1 appears if the table cannot route.
    """
    return walk_paths_layers(np.asarray(nh)[None],
                             np.zeros(len(np.atleast_1d(s)), dtype=np.int32),
                             s, t, max_hops)


def walk_paths_layers(nh_stack: np.ndarray, layer: np.ndarray, s: np.ndarray,
                      t: np.ndarray, max_hops: int) -> np.ndarray:
    """Walk per-sample forwarding tables: sample i follows layer
    ``layer[i]`` of ``nh_stack``.  One vectorised walk for the whole
    (sample, layer) batch — no per-sample Python loop.

    Returns (F, max_hops + 1) int32 router sequences (semantics of
    :func:`walk_paths`).
    """
    layer = np.asarray(layer, dtype=np.int32)
    s = np.asarray(s, dtype=np.int32)
    t = np.asarray(t, dtype=np.int32)
    out = np.zeros((len(s), max_hops + 1), dtype=np.int32)
    cur = s.copy()
    out[:, 0] = cur
    for h in range(1, max_hops + 1):
        nxt = nh_stack[layer, np.maximum(cur, 0), t]
        dead = (nxt < 0) | (cur < 0)
        cur = np.where(dead, -1, np.where(cur == t, t, nxt)).astype(np.int32)
        out[:, h] = cur
    return out
