"""Fault injection: seeded failure masks + degraded layer stacks.

FatPaths' central claim is that "fat" path diversity buys robustness;
this module is the machinery that tests it.  Three pieces:

1. **Failure masks** — seeded, deterministic sets of dead links drawn
   per-link from ``fold_in(key, link_id)`` (the same per-index keying
   contract as :mod:`repro.core.arrivals`): a draw depends only on the
   scenario key and the link's canonical id, never on array shapes,
   padding, or which other links exist.  Patterns:

   * ``bernoulli`` — each undirected link fails independently iff its
     uniform is below ``rate``;
   * ``switch``    — correlated switch-kill: each *router* fails iff its
     uniform is below ``rate``; every incident link dies with it;
   * ``blast``     — an incident with a blast radius: the epicenter
     router is the argmin of the router uniforms, and the
     ``ceil(rate * n_links)`` links nearest to it (by hop distance of
     their nearer endpoint, ties broken by link id) die together.

   All three are *nested* in ``rate``: the dead set at a lower rate is a
   subset of the dead set at any higher rate (one uniform per entity,
   compared against a moving threshold — or a fixed kill ordering for
   ``blast``).  Degradation curves over a rate sweep are therefore
   monotone in the failure *set*, not just in expectation.

2. **Static degradation** (:func:`apply_failures`) — applies a mask to a
   built :class:`~repro.core.layers.LayeredRouting` stack *before* the
   run.  ``mode="repair"`` re-resolves every layer's next hops against
   the masked adjacency through the batched semiring engine (modelling
   routing re-convergence; repaired tables are shortest-path tables of
   the surviving graph, hence loop-free by construction).
   ``mode="drop"`` keeps the pristine tables and invalidates every
   (layer, s, t) entry whose walk crosses a dead link (modelling
   no-reconvergence: traffic on broken entries is simply lost, so the
   balancer must avoid them); surviving entries are a sub-table of a
   shortest-path table and stay loop-free.  Layers left with no usable
   off-diagonal pair are counted in ``dead_layers``.

3. **Mid-run link death** (:func:`link_down_schedule`) — a per-link
   death step threaded through the fused waterfill scan as a capacity
   mask (the PR-6 activation-lane pattern): at step >= death the link's
   capacity is 0, flows on it stall, and the flowlet-gap timer re-picks
   among the surviving usable layers at the next flowlet boundary.

An *empty* mask short-circuits: :func:`apply_failures` returns the input
stack object unchanged, so ``failures(rate=0)`` cells reproduce the
pristine cell bit-for-bit (a repair rebuild, even of an unmasked graph,
could re-draw tie-breaks and change results).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import paths as paths_mod
from .layers import LayeredRouting, _UNREACH

__all__ = ["PATTERNS", "scenario_key", "link_uniforms", "failure_mask",
           "apply_failures", "link_down_schedule", "FailureReport"]

PATTERNS = ("bernoulli", "switch", "blast")

_INT32_MAX = np.iinfo(np.int32).max


def scenario_key(seed: int, fseed: int = 0) -> jnp.ndarray:
    """PRNG key for one failure scenario.

    ``seed`` is the experiment seed (so seed sweeps sample scenarios) and
    ``fseed`` an extra scenario index for batching thousands of scenarios
    under one experiment seed.  The key deliberately does NOT depend on
    the routing scheme: within a cell seed, every scheme faces the SAME
    dead links, so scheme curves are comparable under identical damage.
    """
    base = jax.random.fold_in(jax.random.PRNGKey(0xFA1), int(seed))
    return jax.random.fold_in(base, int(fseed))


@jax.jit
def _uniforms_by_id(key, ids):
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)


def link_uniforms(key, ids) -> np.ndarray:
    """One U(0,1) per integer id, drawn from ``fold_in(key, id)`` — the
    draw for an id is independent of every other id present (vmappable,
    padding/shape independent)."""
    ids = np.asarray(ids, dtype=np.uint32)
    if ids.size == 0:
        return np.zeros(0, dtype=np.float64)
    return np.asarray(_uniforms_by_id(key, jnp.asarray(ids)),
                      dtype=np.float64)


def _undirected_links(adj: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    a = np.asarray(adj, dtype=bool)
    return np.nonzero(np.triu(a, 1))


def failure_mask(key, adj: np.ndarray, rate: float,
                 pattern: str = "bernoulli") -> np.ndarray:
    """(N, N) bool symmetric mask of DEAD links for one scenario.

    Link ids are canonical (``u * N + v`` with u < v); router draws live
    in the disjoint id space ``N*N + r``.  Masks are nested in ``rate``
    (see module docstring).
    """
    if pattern not in PATTERNS:
        raise ValueError(f"unknown failure pattern {pattern!r}; "
                         f"choose from {PATTERNS}")
    a = np.asarray(adj, dtype=bool)
    n = a.shape[0]
    iu, ju = _undirected_links(a)
    dead = np.zeros((n, n), dtype=bool)
    rate = float(rate)
    if len(iu) == 0 or rate <= 0.0:
        return dead
    if pattern == "bernoulli":
        u = link_uniforms(key, iu.astype(np.int64) * n + ju)
        kill = u < rate
    elif pattern == "switch":
        ur = link_uniforms(key, n * n + np.arange(n))
        down = ur < rate
        kill = down[iu] | down[ju]
    elif pattern == "blast":
        ur = link_uniforms(key, n * n + np.arange(n))
        epi = int(np.argmin(ur))
        hops = np.asarray(paths_mod.shortest_path_lengths(
            jnp.asarray(a), max_l=64))[epi].astype(np.int64)
        k = int(np.ceil(rate * len(iu)))
        order = np.lexsort((iu.astype(np.int64) * n + ju,
                            np.minimum(hops[iu], hops[ju])))
        kill = np.zeros(len(iu), dtype=bool)
        kill[order[:k]] = True
    dead[iu[kill], ju[kill]] = True
    dead[ju[kill], iu[kill]] = True
    return dead


@dataclasses.dataclass(frozen=True)
class FailureReport:
    """Host-side summary of one applied failure scenario."""

    failed_links: int          # undirected links killed
    total_links: int
    rate: float
    pattern: str
    mode: str
    dead_layers: int           # layers left with no usable off-diag pair
    disconnected_pairs: int    # router pairs reachable before, by no layer now
    down_step: int = -1        # mid-run death step (-1 = static/pre-run)

    def as_meta(self) -> Dict[str, object]:
        """JSON-safe dict merged into cell meta by both sweep engines."""
        return {
            "failed_links": int(self.failed_links),
            "total_links": int(self.total_links),
            "failure_rate": float(self.rate),
            "failure_pattern": str(self.pattern),
            "failure_mode": str(self.mode),
            "dead_layers": int(self.dead_layers),
            "disconnected_pairs": int(self.disconnected_pairs),
            "link_down_step": int(self.down_step),
        }


def _off_diag(n: int) -> np.ndarray:
    return ~np.eye(n, dtype=bool)


def _count_report(lr: LayeredRouting, reach_before: np.ndarray,
                  reach_after: np.ndarray, dead: np.ndarray, rate: float,
                  pattern: str, mode: str, down_step: int = -1
                  ) -> FailureReport:
    n = reach_before.shape[1]
    off = _off_diag(n)
    before_l = (reach_before & off[None]).any(axis=(1, 2))
    after_l = (reach_after & off[None]).any(axis=(1, 2))
    pair_before = reach_before.any(axis=0) & off
    pair_after = reach_after.any(axis=0) & off
    iu, ju = _undirected_links(lr.topo.adj)
    return FailureReport(
        failed_links=int(np.triu(dead, 1).sum()),
        total_links=int(len(iu)),
        rate=float(rate),
        pattern=pattern,
        mode=mode,
        dead_layers=int((before_l & ~after_l).sum()),
        disconnected_pairs=int((pair_before & ~pair_after).sum()),
        down_step=int(down_step),
    )


def apply_failures(lr: LayeredRouting, dead: np.ndarray,
                   mode: str = "repair", seed: int = 0,
                   rate: float = 0.0, pattern: str = "bernoulli",
                   max_len: Optional[int] = None
                   ) -> Tuple[LayeredRouting, FailureReport]:
    """Degraded copy of ``lr`` under the dead-link mask (pre-run damage).

    ``mode="repair"``: every layer's next hops are re-resolved against
    its masked adjacency via the batched semiring engine (ONE device
    program for the whole stack) — routing has re-converged around the
    failures, so paths may lengthen but every surviving pair stays
    routable within the layer.  ``mode="drop"``: the pristine tables are
    kept and every (layer, s, t) entry whose walk crosses a dead link is
    invalidated on device (no re-convergence; the load balancer simply
    avoids broken entries).  Both modes are loop-free: repaired tables
    are shortest-path tables, dropped tables are sub-tables of one.

    An empty mask returns ``lr`` ITSELF (not a copy): rate-0 scenarios
    are bit-for-bit the pristine cell.
    """
    dead = np.asarray(dead, dtype=bool)
    if not dead.any():
        report = _count_report(lr, lr.reach, lr.reach, dead, rate, pattern,
                               mode)
        return lr, report
    if mode not in ("repair", "drop"):
        raise ValueError(f"unknown failure mode {mode!r}")

    masked_la = lr.layer_adj & ~dead[None]
    n = dead.shape[0]
    idx = np.arange(n)

    if mode == "repair":
        if max_len is None:
            # Re-converged paths detour around failures: build slack + 2.
            max_len = max(6, lr.topo.diameter_nominal + 6)
        union = masked_la.any(axis=0)
        nbr = jnp.asarray(paths_mod.neighbor_table(union))
        key = jax.random.fold_in(jax.random.PRNGKey(int(seed)), 0xF1)
        eng = paths_mod.path_engine(n)
        nbr_in = (jnp.asarray(paths_mod.neighbor_table(union.T))
                  if eng == "blocked" else None)
        nh_j, reach_j, dist_j = paths_mod._layer_tables_program(
            jnp.asarray(masked_la), nbr, key, max_len, eng, nbr_in)
        reach = np.asarray(reach_j)
        nh = np.asarray(nh_j)
        pathlen = np.where(reach, np.asarray(dist_j),
                           _UNREACH).astype(np.int16)
    else:
        # Walks take exactly pathlen hops (shortest-path forwarding), so
        # the stack's longest reachable path bounds the fixpoint depth.
        max_hops = int(lr.pathlen[lr.reach].max(initial=1)) + 1
        valid = np.asarray(paths_mod.table_validity_batched(
            jnp.asarray(lr.nh), jnp.asarray(~dead), max_hops))
        reach = lr.reach & valid
        off = _off_diag(n)
        layer_dead = ~(reach & off[None]).any(axis=(1, 2))
        reach = reach & ~layer_dead[:, None, None]
        nh = np.where(reach, lr.nh, -1).astype(np.int32)
        nh[:, idx, idx] = idx
        pathlen = np.where(reach, lr.pathlen, _UNREACH).astype(np.int16)

    report = _count_report(lr, lr.reach, reach, dead, rate, pattern, mode)
    # The tables changed, so any compressed form on the pristine stack is
    # stale; re-attach one iff the input carried one.
    compressed = None
    if lr.compressed is not None:
        # Auto block, not the input's: repair redistributes next hops,
        # so the old block size may no longer fit the uint8 selector.
        compressed = paths_mod.CompressedTables.from_dense(nh)
    degraded = dataclasses.replace(
        lr, nh=nh, reach=reach, pathlen=pathlen, layer_adj=masked_la,
        build_stats=None, link_down_step=None, compressed=compressed)
    return degraded, report


def link_down_schedule(dead: np.ndarray, step: int) -> np.ndarray:
    """(N, N) int32 per-directed-link death step for mid-run failures.

    Masked links die (capacity -> 0) at scan step ``step``; surviving
    links carry INT32_MAX (never die).  Fed to the transport scan via
    ``LayeredRouting.link_down_step``.
    """
    dead = np.asarray(dead, dtype=bool)
    sym = dead | dead.T
    return np.where(sym, np.int32(step),
                    np.int32(_INT32_MAX)).astype(np.int32)
