"""Fault injection: seeded failure masks + degraded layer stacks.

FatPaths' central claim is that "fat" path diversity buys robustness;
this module is the machinery that tests it.  Three pieces:

1. **Failure masks** — seeded, deterministic sets of dead links drawn
   per-link from ``fold_in(key, link_id)`` (the same per-index keying
   contract as :mod:`repro.core.arrivals`): a draw depends only on the
   scenario key and the link's canonical id, never on array shapes,
   padding, or which other links exist.  Patterns:

   * ``bernoulli`` — each undirected link fails independently iff its
     uniform is below ``rate``;
   * ``switch``    — correlated switch-kill: each *router* fails iff its
     uniform is below ``rate``; every incident link dies with it;
   * ``blast``     — an incident with a blast radius: the epicenter
     router is the argmin of the router uniforms, and the
     ``ceil(rate * n_links)`` links nearest to it (by hop distance of
     their nearer endpoint, ties broken by link id) die together.

   All three are *nested* in ``rate``: the dead set at a lower rate is a
   subset of the dead set at any higher rate (one uniform per entity,
   compared against a moving threshold — or a fixed kill ordering for
   ``blast``).  Degradation curves over a rate sweep are therefore
   monotone in the failure *set*, not just in expectation.

2. **Static degradation** (:func:`apply_failures`) — applies a mask to a
   built :class:`~repro.core.layers.LayeredRouting` stack *before* the
   run.  ``mode="repair"`` re-resolves every layer's next hops against
   the masked adjacency through the batched semiring engine (modelling
   routing re-convergence; repaired tables are shortest-path tables of
   the surviving graph, hence loop-free by construction).
   ``mode="drop"`` keeps the pristine tables and invalidates every
   (layer, s, t) entry whose walk crosses a dead link (modelling
   no-reconvergence: traffic on broken entries is simply lost, so the
   balancer must avoid them); surviving entries are a sub-table of a
   shortest-path table and stay loop-free.  Layers left with no usable
   off-diagonal pair are counted in ``dead_layers``.

3. **Mid-run link death** (:func:`link_down_schedule`) — a per-link
   death step threaded through the fused waterfill scan as a capacity
   mask (the PR-6 activation-lane pattern): at step >= death the link's
   capacity is 0, flows on it stall, and the flowlet-gap timer re-picks
   among the surviving usable layers at the next flowlet boundary.

4. **Link churn** (:func:`churn_schedule`) — links that die AND come
   back: per-link sorted, non-overlapping ``(down, up)`` step intervals
   in an ``(N, N, K, 2)`` int32 tensor (``INT32_MAX`` rows = never),
   drawn as seeded renewal processes.  Patterns:

   * ``flap``    — the flapping set is selected by the SAME per-link
     uniforms as ``bernoulli`` (so it is nested in ``rate`` and a rate-r
     flap set equals the rate-r bernoulli dead set); each flapping
     link's alive/repair durations are exponential (or Pareto-II, see
     ``proc``) renewals with means ``mtbf``/``mttr``, drawn from
     ``fold_in(key, 2*N*N + link_id)`` — padding/shape independent;
   * ``rolling`` — sequential maintenance windows over switch groups of
     ``round(rate * N)`` routers: group g's incident links go down for
     ``mttr`` steps starting at ``mtbf + g * (mtbf + mttr)``;
   * ``repair``  — the PR 7 ``bernoulli`` dead set dies at step 1 and
     returns after a per-link exponential repair time (mean ``mttr``).

   Capacity restores at ``up``; flowlets may RE-PICK a returned link
   only at ``up + conv_steps`` (control-plane re-convergence, gated in
   the transport scan via ``LayeredRouting.churn_conv``).

An *empty* mask short-circuits: :func:`apply_failures` returns the input
stack object unchanged, so ``failures(rate=0)`` cells reproduce the
pristine cell bit-for-bit (a repair rebuild, even of an unmasked graph,
could re-draw tie-breaks and change results).  An all-sentinel churn
schedule is likewise dropped by the ``churn(...)`` axis, so
``churn(rate=0)`` cells are the pristine program, not a gated one.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import paths as paths_mod
from .layers import LayeredRouting, _UNREACH

__all__ = ["PATTERNS", "CHURN_PATTERNS", "scenario_key", "link_uniforms",
           "failure_mask", "apply_failures", "link_down_schedule",
           "churn_schedule", "churn_summary", "FailureReport"]

PATTERNS = ("bernoulli", "switch", "blast")
CHURN_PATTERNS = ("flap", "rolling", "repair")

_INT32_MAX = np.iinfo(np.int32).max


def scenario_key(seed: int, fseed: int = 0) -> jnp.ndarray:
    """PRNG key for one failure scenario.

    ``seed`` is the experiment seed (so seed sweeps sample scenarios) and
    ``fseed`` an extra scenario index for batching thousands of scenarios
    under one experiment seed.  The key deliberately does NOT depend on
    the routing scheme: within a cell seed, every scheme faces the SAME
    dead links, so scheme curves are comparable under identical damage.
    """
    base = jax.random.fold_in(jax.random.PRNGKey(0xFA1), int(seed))
    return jax.random.fold_in(base, int(fseed))


@jax.jit
def _uniforms_by_id(key, ids):
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)


def link_uniforms(key, ids) -> np.ndarray:
    """One U(0,1) per integer id, drawn from ``fold_in(key, id)`` — the
    draw for an id is independent of every other id present (vmappable,
    padding/shape independent)."""
    ids = np.asarray(ids, dtype=np.uint32)
    if ids.size == 0:
        return np.zeros(0, dtype=np.float64)
    return np.asarray(_uniforms_by_id(key, jnp.asarray(ids)),
                      dtype=np.float64)


def _undirected_links(adj: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    a = np.asarray(adj, dtype=bool)
    return np.nonzero(np.triu(a, 1))


def failure_mask(key, adj: np.ndarray, rate: float,
                 pattern: str = "bernoulli") -> np.ndarray:
    """(N, N) bool symmetric mask of DEAD links for one scenario.

    Link ids are canonical (``u * N + v`` with u < v); router draws live
    in the disjoint id space ``N*N + r``.  Masks are nested in ``rate``
    (see module docstring).
    """
    if pattern not in PATTERNS:
        raise ValueError(f"unknown failure pattern {pattern!r}; "
                         f"choose from {PATTERNS}")
    a = np.asarray(adj, dtype=bool)
    n = a.shape[0]
    iu, ju = _undirected_links(a)
    dead = np.zeros((n, n), dtype=bool)
    rate = float(rate)
    if len(iu) == 0 or rate <= 0.0:
        return dead
    if pattern == "bernoulli":
        u = link_uniforms(key, iu.astype(np.int64) * n + ju)
        kill = u < rate
    elif pattern == "switch":
        ur = link_uniforms(key, n * n + np.arange(n))
        down = ur < rate
        kill = down[iu] | down[ju]
    elif pattern == "blast":
        ur = link_uniforms(key, n * n + np.arange(n))
        epi = int(np.argmin(ur))
        hops = np.asarray(paths_mod.shortest_path_lengths(
            jnp.asarray(a), max_l=64))[epi].astype(np.int64)
        k = int(np.ceil(rate * len(iu)))
        order = np.lexsort((iu.astype(np.int64) * n + ju,
                            np.minimum(hops[iu], hops[ju])))
        kill = np.zeros(len(iu), dtype=bool)
        kill[order[:k]] = True
    dead[iu[kill], ju[kill]] = True
    dead[ju[kill], iu[kill]] = True
    return dead


@dataclasses.dataclass(frozen=True)
class FailureReport:
    """Host-side summary of one applied failure scenario."""

    failed_links: int          # undirected links killed
    total_links: int
    rate: float
    pattern: str
    mode: str
    dead_layers: int           # layers left with no usable off-diag pair
    disconnected_pairs: int    # router pairs reachable before, by no layer now
    down_step: int = -1        # mid-run death step (-1 = static/pre-run)

    def as_meta(self) -> Dict[str, object]:
        """JSON-safe dict merged into cell meta by both sweep engines."""
        return {
            "failed_links": int(self.failed_links),
            "total_links": int(self.total_links),
            "failure_rate": float(self.rate),
            "failure_pattern": str(self.pattern),
            "failure_mode": str(self.mode),
            "dead_layers": int(self.dead_layers),
            "disconnected_pairs": int(self.disconnected_pairs),
            "link_down_step": int(self.down_step),
        }


def _off_diag(n: int) -> np.ndarray:
    return ~np.eye(n, dtype=bool)


def _count_report(lr: LayeredRouting, reach_before: np.ndarray,
                  reach_after: np.ndarray, dead: np.ndarray, rate: float,
                  pattern: str, mode: str, down_step: int = -1
                  ) -> FailureReport:
    n = reach_before.shape[1]
    off = _off_diag(n)
    before_l = (reach_before & off[None]).any(axis=(1, 2))
    after_l = (reach_after & off[None]).any(axis=(1, 2))
    pair_before = reach_before.any(axis=0) & off
    pair_after = reach_after.any(axis=0) & off
    iu, ju = _undirected_links(lr.topo.adj)
    return FailureReport(
        failed_links=int(np.triu(dead, 1).sum()),
        total_links=int(len(iu)),
        rate=float(rate),
        pattern=pattern,
        mode=mode,
        dead_layers=int((before_l & ~after_l).sum()),
        disconnected_pairs=int((pair_before & ~pair_after).sum()),
        down_step=int(down_step),
    )


def apply_failures(lr: LayeredRouting, dead: np.ndarray,
                   mode: str = "repair", seed: int = 0,
                   rate: float = 0.0, pattern: str = "bernoulli",
                   max_len: Optional[int] = None
                   ) -> Tuple[LayeredRouting, FailureReport]:
    """Degraded copy of ``lr`` under the dead-link mask (pre-run damage).

    ``mode="repair"``: every layer's next hops are re-resolved against
    its masked adjacency via the batched semiring engine (ONE device
    program for the whole stack) — routing has re-converged around the
    failures, so paths may lengthen but every surviving pair stays
    routable within the layer.  ``mode="drop"``: the pristine tables are
    kept and every (layer, s, t) entry whose walk crosses a dead link is
    invalidated on device (no re-convergence; the load balancer simply
    avoids broken entries).  Both modes are loop-free: repaired tables
    are shortest-path tables, dropped tables are sub-tables of one.

    An empty mask returns ``lr`` ITSELF (not a copy): rate-0 scenarios
    are bit-for-bit the pristine cell.
    """
    dead = np.asarray(dead, dtype=bool)
    if not dead.any():
        report = _count_report(lr, lr.reach, lr.reach, dead, rate, pattern,
                               mode)
        return lr, report
    if mode not in ("repair", "drop"):
        raise ValueError(f"unknown failure mode {mode!r}")

    masked_la = lr.layer_adj & ~dead[None]
    n = dead.shape[0]
    idx = np.arange(n)

    if mode == "repair":
        if max_len is None:
            # Re-converged paths detour around failures: build slack + 2.
            max_len = max(6, lr.topo.diameter_nominal + 6)
        union = masked_la.any(axis=0)
        nbr = jnp.asarray(paths_mod.neighbor_table(union))
        key = jax.random.fold_in(jax.random.PRNGKey(int(seed)), 0xF1)
        eng = paths_mod.path_engine(n)
        nbr_in = (jnp.asarray(paths_mod.neighbor_table(union.T))
                  if eng == "blocked" else None)
        nh_j, reach_j, dist_j = paths_mod._layer_tables_program(
            jnp.asarray(masked_la), nbr, key, max_len, eng, nbr_in)
        reach = np.asarray(reach_j)
        nh = np.asarray(nh_j)
        pathlen = np.where(reach, np.asarray(dist_j),
                           _UNREACH).astype(np.int16)
    else:
        # Walks take exactly pathlen hops (shortest-path forwarding), so
        # the stack's longest reachable path bounds the fixpoint depth.
        max_hops = int(lr.pathlen[lr.reach].max(initial=1)) + 1
        valid = np.asarray(paths_mod.table_validity_batched(
            jnp.asarray(lr.nh), jnp.asarray(~dead), max_hops))
        reach = lr.reach & valid
        off = _off_diag(n)
        layer_dead = ~(reach & off[None]).any(axis=(1, 2))
        reach = reach & ~layer_dead[:, None, None]
        nh = np.where(reach, lr.nh, -1).astype(np.int32)
        nh[:, idx, idx] = idx
        pathlen = np.where(reach, lr.pathlen, _UNREACH).astype(np.int16)

    report = _count_report(lr, lr.reach, reach, dead, rate, pattern, mode)
    # The tables changed, so any compressed form on the pristine stack is
    # stale; re-attach one iff the input carried one.
    compressed = None
    if lr.compressed is not None:
        # Auto block, not the input's: repair redistributes next hops,
        # so the old block size may no longer fit the uint8 selector.
        compressed = paths_mod.CompressedTables.from_dense(nh)
    degraded = dataclasses.replace(
        lr, nh=nh, reach=reach, pathlen=pathlen, layer_adj=masked_la,
        build_stats=None, link_down_step=None, link_churn=None,
        compressed=compressed)
    return degraded, report


def link_down_schedule(dead: np.ndarray, step: int) -> np.ndarray:
    """(N, N) int32 per-directed-link death step for mid-run failures.

    Masked links die (capacity -> 0) at scan step ``step``; surviving
    links carry INT32_MAX (never die).  Fed to the transport scan via
    ``LayeredRouting.link_down_step``.
    """
    dead = np.asarray(dead, dtype=bool)
    sym = dead | dead.T
    return np.where(sym, np.int32(step),
                    np.int32(_INT32_MAX)).astype(np.int32)


@functools.partial(jax.jit, static_argnames=("m",))
def _uniforms_by_id_m(key, ids, m):
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)
    return jax.vmap(lambda k: jax.random.uniform(k, (m,)))(keys)


def link_uniforms_m(key, ids, m: int) -> np.ndarray:
    """``(len(ids), m)`` U(0,1) draws; row ``i`` depends only on
    ``(key, ids[i])`` and the fixed per-id shape ``m`` — like
    :func:`link_uniforms`, but m draws per id (renewal sequences)."""
    ids = np.asarray(ids, dtype=np.uint32)
    if ids.size == 0:
        return np.zeros((0, m), dtype=np.float64)
    return np.asarray(_uniforms_by_id_m(key, jnp.asarray(ids), int(m)),
                      dtype=np.float64)


def _duration_steps(u: np.ndarray, mean: float, proc: str,
                    shape: float) -> np.ndarray:
    """Uniforms -> integer durations (>= 1 step) with the given mean:
    ``proc="exp"`` inverse-CDF exponential, ``proc="pareto"`` a
    Pareto-II/Lomax with tail index ``shape`` (> 1 so the mean exists) —
    the heavy-tailed MTBF/MTTR regime of deployment studies."""
    mean = max(float(mean), 1.0)
    if proc == "exp":
        d = -mean * np.log1p(-u)
    elif proc == "pareto":
        if shape <= 1.0:
            raise ValueError(f"pareto churn needs shape > 1, got {shape}")
        d = mean * (shape - 1.0) * ((1.0 - u) ** (-1.0 / shape) - 1.0)
    else:
        raise ValueError(f"unknown churn process {proc!r}; "
                         f"choose from ('exp', 'pareto')")
    return np.maximum(1, np.rint(d)).astype(np.int64)


def churn_schedule(key, adj: np.ndarray, rate: float,
                   pattern: str = "flap", mtbf: float = 120.0,
                   mttr: float = 40.0, events: int = 4,
                   proc: str = "exp", shape: float = 1.5) -> np.ndarray:
    """(N, N, K, 2) int32 symmetric per-link ``(down, up)`` churn
    intervals for one scenario (see module docstring for the patterns).

    Invariants (property-tested):

    * per-link intervals are sorted and non-overlapping:
      ``1 <= down_0 < up_0 < down_1 < ...`` for real events, with
      ``(INT32_MAX, INT32_MAX)`` sentinel padding after the last one;
    * the churned-link set is nested in ``rate`` for ``flap``/``repair``
      (same selection uniforms as the ``bernoulli`` mask), and a link's
      event stream is identical at every rate that includes it;
    * every draw is keyed by ``fold_in(key, 2*N*N + link_id)`` (disjoint
      from the link/router mask id spaces), so schedules are invariant
      under padding and under the presence of other links.

    ``down >= 1`` always: step 0's initial layer picks are never gated,
    so a schedule-free prefix is common to every churn cell.
    """
    if pattern not in CHURN_PATTERNS:
        raise ValueError(f"unknown churn pattern {pattern!r}; "
                         f"choose from {CHURN_PATTERNS}")
    a = np.asarray(adj, dtype=bool)
    n = a.shape[0]
    iu, ju = _undirected_links(a)
    rate = float(rate)
    k_ev = 2 if pattern == "rolling" else (1 if pattern == "repair"
                                           else max(1, int(events)))
    sched = np.full((n, n, k_ev, 2), _INT32_MAX, dtype=np.int32)
    if len(iu) == 0 or rate <= 0.0:
        return sched
    lid = iu.astype(np.int64) * n + ju
    ev_ids = 2 * n * n + lid               # disjoint from mask id spaces

    if pattern == "flap":
        churning = link_uniforms(key, lid) < rate      # == bernoulli set
        if not churning.any():
            return sched
        cid = ev_ids[churning]
        u = link_uniforms_m(key, cid, 2 * k_ev)
        alive = _duration_steps(u[:, 0::2], mtbf, proc, shape)
        rep = _duration_steps(u[:, 1::2], mttr, proc, shape)
        # Alternate alive/repair and cumsum: down_k = end of the k-th
        # alive stretch, up_k = down_k + repair_k.  int64 then clipped —
        # events pushed past INT32_MAX degenerate to empty sentinels.
        inter = np.empty((len(cid), 2 * k_ev), dtype=np.int64)
        inter[:, 0::2] = alive
        inter[:, 1::2] = rep
        c = np.minimum(np.cumsum(inter, axis=1), _INT32_MAX)
        ev = np.stack([c[:, 0::2], c[:, 1::2]], axis=2).astype(np.int32)
        ev[ev[..., 0] >= _INT32_MAX] = _INT32_MAX
        sched[iu[churning], ju[churning]] = ev
    elif pattern == "repair":
        churning = link_uniforms(key, lid) < rate      # == bernoulli set
        if not churning.any():
            return sched
        u = link_uniforms_m(key, ev_ids[churning], 1)[:, 0]
        rep = _duration_steps(u, mttr, proc, shape)
        ev = np.stack([np.ones_like(rep), 1 + rep], axis=1)
        sched[iu[churning], ju[churning], 0] = \
            np.minimum(ev, _INT32_MAX).astype(np.int32)
    else:  # rolling maintenance windows over switch groups
        gsize = max(1, int(round(rate * n)))
        group = np.arange(n) // gsize
        w = max(1, int(round(mttr)))       # window length
        gap = max(1, int(round(mtbf)))     # quiet time before/between
        n_groups = int(group.max()) + 1
        down_g = gap + np.arange(n_groups, dtype=np.int64) * (w + gap)
        up_g = down_g + w
        ga, gb = group[iu], group[ju]
        first, second = np.minimum(ga, gb), np.maximum(ga, gb)
        ev = np.full((len(iu), k_ev, 2), _INT32_MAX, dtype=np.int64)
        ev[:, 0, 0] = down_g[first]
        ev[:, 0, 1] = up_g[first]
        both = second != first             # endpoint groups differ: 2 events
        ev[both, 1, 0] = down_g[second][both]
        ev[both, 1, 1] = up_g[second][both]
        sched[iu, ju] = np.minimum(ev, _INT32_MAX).astype(np.int32)
    sched = np.minimum(sched, np.swapaxes(sched, 0, 1))
    return sched


def churn_summary(sched: np.ndarray) -> Dict[str, int]:
    """Host-side accounting for one churn schedule: churned undirected
    links, total real events, and the first down step (-1 when the
    schedule is empty) — JSON-safe, merged into cell meta."""
    downs = np.asarray(sched)[..., 0]
    tri = np.triu(np.ones(downs.shape[:2], dtype=bool), 1)
    ev = (downs < _INT32_MAX) & tri[..., None]
    n_events = int(ev.sum())
    first = int(downs[ev].min()) if n_events else -1
    return {"churn_links": int(ev.any(axis=-1).sum()),
            "churn_events": n_events, "churn_first_down": first}
