"""Maximum achievable throughput (MAT) via multicommodity flow (paper §6.4).

Path-based LP, TopoBench-style, extended with FatPaths layers: the candidate
paths of a demand are the realised routes of each usable layer, so the LP
measures exactly what the layered routing can deliver.

  maximise    T
  subject to  sum_p x[d, p]          = demand_d * T      (all demands d)
              sum_{(d,p) using e} x  <= capacity_e       (all edges e)
              x >= 0

The paper adds an integer constraint (a flow may not split across layers);
we solve the LP relaxation and additionally report a greedy single-layer
rounding (`mat_single_layer`), which lower-bounds the integral optimum.
Solved with scipy's HiGHS.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np
import scipy.optimize
import scipy.sparse as sp

from . import paths as paths_mod
from .layers import LayeredRouting
from .topology import Topology
from .traffic import FlowWorkload

__all__ = ["MATResult", "router_demands", "mat_lp", "mat_single_layer"]


@dataclasses.dataclass
class MATResult:
    throughput: float          # T (flow units per unit capacity)
    n_demands: int
    n_paths: int
    status: str


def router_demands(wl: FlowWorkload, n_routers: int) -> Dict[Tuple[int, int], float]:
    """Aggregate endpoint flows into router-pair demands T(s, t)."""
    d: Dict[Tuple[int, int], float] = {}
    for s, t in zip(wl.src_router, wl.dst_router):
        if s == t:
            continue
        d[(int(s), int(t))] = d.get((int(s), int(t)), 0.0) + 1.0
    return d


def _candidate_paths(routing: LayeredRouting,
                     demands: Dict[Tuple[int, int], float],
                     max_hops: int) -> List[List[List[int]]]:
    """Per demand: deduplicated list of edge-id paths, one per usable layer."""
    eix = routing.topo.edge_index_matrix()
    out: List[List[List[int]]] = []
    for (s, t) in demands:
        seen = set()
        plist: List[List[int]] = []
        for i in range(routing.n_layers):
            if not routing.reach[i, s, t]:
                continue
            seq = paths_mod.walk_paths(routing.nh[i], np.array([s]),
                                       np.array([t]), max_hops)[0]
            edges = []
            ok = True
            for a, b in zip(seq[:-1], seq[1:]):
                if a == t or b < 0:
                    break
                e = int(eix[a, b])
                if e < 0:
                    ok = False
                    break
                edges.append(e)
            reached = t in set(int(x) for x in seq)
            if ok and edges and reached:
                key = tuple(edges)
                if key not in seen:
                    seen.add(key)
                    plist.append(edges)
        out.append(plist)
    return out


def mat_lp(routing: LayeredRouting, wl: FlowWorkload,
           max_hops: int = 16, capacity: float = 1.0) -> MATResult:
    """LP-relaxed MAT for a layered routing and a workload."""
    topo = routing.topo
    demands = router_demands(wl, topo.n_routers)
    if not demands:
        return MATResult(float("inf"), 0, 0, "empty")
    dkeys = list(demands)
    paths = _candidate_paths(routing, demands, max_hops)
    n_edges = int(topo.adj.sum())  # directed edges

    # Variables: one per (demand, path), then T last.
    var_of: List[Tuple[int, List[int]]] = []
    for di, plist in enumerate(paths):
        for p in plist:
            var_of.append((di, p))
    nv = len(var_of) + 1
    if not var_of:
        return MATResult(0.0, len(dkeys), 0, "no-paths")

    # Equality: per demand, sum of its path vars - demand*T = 0.
    eq_r, eq_c, eq_v = [], [], []
    for vi, (di, _) in enumerate(var_of):
        eq_r.append(di)
        eq_c.append(vi)
        eq_v.append(1.0)
    for di, k in enumerate(dkeys):
        eq_r.append(di)
        eq_c.append(nv - 1)
        eq_v.append(-demands[k])
    A_eq = sp.coo_matrix((eq_v, (eq_r, eq_c)), shape=(len(dkeys), nv)).tocsr()
    b_eq = np.zeros(len(dkeys))

    # Capacity: per directed edge.
    ub_r, ub_c, ub_v = [], [], []
    for vi, (_, p) in enumerate(var_of):
        for e in p:
            ub_r.append(e)
            ub_c.append(vi)
            ub_v.append(1.0)
    A_ub = sp.coo_matrix((ub_v, (ub_r, ub_c)), shape=(n_edges, nv)).tocsr()
    b_ub = np.full(n_edges, capacity)

    c = np.zeros(nv)
    c[-1] = -1.0
    res = scipy.optimize.linprog(
        c, A_ub=A_ub, b_ub=b_ub, A_eq=A_eq, b_eq=b_eq,
        bounds=[(0, None)] * nv, method="highs")
    t = float(res.x[-1]) if res.status == 0 else 0.0
    return MATResult(t, len(dkeys), len(var_of), res.message if res.status else "optimal")


def mat_single_layer(routing: LayeredRouting, wl: FlowWorkload,
                     max_hops: int = 16, capacity: float = 1.0) -> MATResult:
    """Greedy integral variant: each demand picks ONE path (its shortest,
    then least-loaded); T = min over edges of capacity / load (max-min)."""
    topo = routing.topo
    demands = router_demands(wl, topo.n_routers)
    if not demands:
        return MATResult(float("inf"), 0, 0, "empty")
    paths = _candidate_paths(routing, demands, max_hops)
    n_edges = int(topo.adj.sum())
    load = np.zeros(n_edges)
    n_paths = 0
    for (key, plist) in zip(demands, paths):
        if not plist:
            continue
        n_paths += len(plist)
        best, best_cost = None, None
        for p in plist:
            cost = (len(p), float(load[p].max()) if p else 0.0)
            if best is None or cost < best_cost:
                best, best_cost = p, cost
        load[best] += demands[key]
    mx = load.max()
    t = float(capacity / mx) if mx > 0 else float("inf")
    return MATResult(t, len(demands), n_paths, "greedy")
