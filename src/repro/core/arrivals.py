"""Open-loop arrival processes: continuous traffic for the flow simulator.

The paper's headline claims (§7: throughput/latency *under load*) are
about fabrics serving a continuous stream of flows, not a one-shot batch
that decays to idle.  This module is the arrival-process subsystem that
feeds the transport scan's dynamic-traffic lane
(:mod:`repro.core.transport`, the ``active_at`` operand and
``depart_step`` state channel): per-flow *activation steps* for Poisson
and bounded-Pareto interarrival processes, synchronized incast wave
schedules, offered-load accounting, and a bisection-bandwidth estimate
that load levels are expressed against.

Determinism contract (the property every batch engine rests on):

* every random draw depends only on ``(key, flow)`` — flow ``i``'s
  uniform comes from ``jax.random.fold_in(key, i)``, exactly like the
  transport scan's per-flow step draws depend only on
  ``(key, flow, step)`` — so growing the flow count (batch padding, or
  just building a longer stream) never changes an earlier flow's draw;
* the interarrival cumsum runs on the host in float64 (``np.cumsum`` is
  a strictly sequential accumulation), so activation steps are
  *prefix-stable*: ``activation_steps(key, n2)[:n1] ==
  activation_steps(key, n1)`` bit for bit for any ``n2 >= n1``.

Conceptually the simulator's flow axis is a ring buffer of flow slots:
a "slot" is occupied from its activation step (``active_at``) until the
flow departs (``depart_step``).  Because the batched scan needs a static
flow axis, the ring is unrolled — every arrival gets its own row up
front and the activation/departure lanes gate when the row participates
in the water-filling step.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["flow_uniforms", "interarrival_gaps", "activation_steps",
           "incast_schedule", "offered_load", "offered_gbs",
           "bisection_bandwidth", "activation_starts"]


def flow_uniforms(key, n: int) -> np.ndarray:
    """(n,) float64 U[0,1) draws where draw ``i`` depends ONLY on
    ``(key, i)`` — the padding-safe derivation (see module docstring).
    Returned as a host array: everything downstream is float64 host
    math, keeping activation steps independent of device/backend."""
    import jax

    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(np.arange(n))
    u = jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)
    return np.asarray(u, dtype=np.float64)


def _bounded_pareto(u: np.ndarray, shape: float, bound: float) -> np.ndarray:
    """Inverse-CDF bounded Pareto on [1, bound] with tail index ``shape``,
    normalized to mean 1 (so a gap stream keeps its configured rate
    while individual gaps stay heavy-tailed => bursty arrival clumps)."""
    a, h = float(shape), float(bound)
    if a <= 0 or h <= 1:
        raise ValueError(f"bounded Pareto needs shape > 0, bound > 1 "
                         f"(got shape={a}, bound={h})")
    x = (1.0 - u * (1.0 - h ** -a)) ** (-1.0 / a)
    if abs(a - 1.0) < 1e-9:
        mean = np.log(h) / (1.0 - 1.0 / h)
    else:
        mean = (a / (a - 1.0)) * (1.0 - h ** (1.0 - a)) / (1.0 - h ** -a)
    return x / mean


def interarrival_gaps(key, n: int, mean_steps: float,
                      process: str = "poisson", shape: float = 1.5,
                      bound: float = 64.0) -> np.ndarray:
    """(n,) interarrival gaps in (fractional) steps, mean ``mean_steps``.

    ``poisson`` draws exponential gaps (a Poisson arrival process);
    ``pareto`` draws bounded-Pareto gaps (heavy-tailed interarrivals —
    the bursty/wave regime).  Gap ``i`` is a pure function of
    ``(key, i)``; see the module docstring's determinism contract."""
    u = np.clip(flow_uniforms(key, n), 1e-12, 1.0 - 1e-12)
    if process == "poisson":
        gaps = -np.log1p(-u)
    elif process == "pareto":
        gaps = _bounded_pareto(u, shape, bound)
    else:
        raise ValueError(f"unknown arrival process {process!r}; "
                         "choose 'poisson' or 'pareto'")
    return gaps * float(mean_steps)


def activation_steps(key, n: int, *, rate: float, process: str = "poisson",
                     shape: float = 1.5, bound: float = 64.0) -> np.ndarray:
    """(n,) int32 activation step per flow for an open-loop stream of
    ``rate`` flow arrivals per simulation step (flow 0 arrives at step
    0; flow i at the floor of the gap cumsum).  Prefix-stable in ``n``
    and deterministic in ``(key, flow)`` — the contract the distributed
    sweep engine's bit-identity guarantee extends over."""
    if n <= 0:
        return np.zeros(0, dtype=np.int32)
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0 (got {rate})")
    gaps = interarrival_gaps(key, n, 1.0 / float(rate), process=process,
                             shape=shape, bound=bound)
    t = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    return np.floor(t).astype(np.int32)


def incast_schedule(n_flows: int, fan_in: int, wave_period: int
                    ) -> np.ndarray:
    """(n_flows,) int32 synchronized incast wave schedule: flows arrive
    in waves of ``fan_in``, wave ``w`` activating at step
    ``w * wave_period`` (all senders of a wave fire simultaneously —
    the TCP-incast/outcast stressor)."""
    if fan_in <= 0 or wave_period < 0:
        raise ValueError("incast needs fan_in > 0 and wave_period >= 0")
    return ((np.arange(n_flows) // int(fan_in))
            * int(wave_period)).astype(np.int32)


def offered_load(sizes: np.ndarray, steps: np.ndarray, dt: float,
                 capacity: float) -> float:
    """Realized offered load of an arrival stream as a fraction of
    ``capacity`` (bytes/s): total bytes over the realized arrival window
    ``(max step + 1) * dt``.  For a stream built by
    :func:`activation_steps` at rate ``level * capacity * dt / size``
    this converges to ``level`` as the flow count grows."""
    sizes = np.asarray(sizes, dtype=np.float64)
    if sizes.size == 0 or capacity <= 0:
        return 0.0
    window_s = (float(np.max(steps)) + 1.0) * float(dt)
    return float(sizes.sum() / window_s / float(capacity))


def offered_gbs(sizes: np.ndarray, steps: np.ndarray, dt: float) -> float:
    """Offered byte rate of a dynamic workload in GB/s (host float64 —
    identical whichever engine computes it, so it is safe in RunResult
    meta that the engine-identity diff compares exactly)."""
    sizes = np.asarray(sizes, dtype=np.float64)
    if sizes.size == 0:
        return 0.0
    window_s = (float(np.max(steps)) + 1.0) * float(dt)
    return float(sizes.sum() / window_s / 1e9)


def bisection_bandwidth(topo, line_rate: float = 12.5e9, samples: int = 32,
                        seed: int = 0) -> float:
    """Estimated bisection bandwidth in bytes/s: the minimum, over
    ``samples`` seeded balanced router bipartitions, of the directed
    link count crossing the cut, times ``line_rate``.  An upper-bound
    sampling estimate (true bisection minimizes over ALL balanced cuts),
    deterministic in ``seed`` — good enough as the normalizer that
    ``load(level=...)`` sweeps express offered load against, and exact
    on symmetric topologies where every balanced cut is minimal.

    Each bipartition is drawn from its own ``default_rng((seed, i))``
    stream: sample i depends only on ``(seed, i)``, never on how many
    samples ran before it, so the estimate is stable across processes
    and across ``samples`` prefixes (the per-index keying contract the
    rest of the repo's PRNG draws follow)."""
    adj = np.asarray(topo.adj, dtype=bool)
    n = adj.shape[0]
    if n < 2:
        return float(line_rate)
    best = None
    for i in range(max(1, int(samples))):
        rng = np.random.default_rng((int(seed), i))
        side = np.zeros(n, dtype=bool)
        side[rng.permutation(n)[:n // 2]] = True
        cut = int(adj[side][:, ~side].sum() + adj[~side][:, side].sum())
        best = cut if best is None else min(best, cut)
    return float(max(best, 1)) * float(line_rate)


def activation_starts(steps: np.ndarray, dt: float) -> np.ndarray:
    """(F,) float64 start seconds matching the transport scan's own step
    clock: the scan compares ``start <= i * float32(dt)``, so starts are
    computed through the same float32 product — activation by the
    ``active_at`` lane and by the ``start`` lane then agree exactly on
    the activation step (no one-ulp disagreement)."""
    return (np.asarray(steps).astype(np.float32)
            * np.float32(dt)).astype(np.float64)
