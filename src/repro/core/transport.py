"""Flow-level transport + load-balancing simulator (paper §7, htsim analogue).

A vectorised discrete-time simulator written as a single ``jax.lax.scan``:
all flows advance simultaneously in Δt steps; link sharing is an iterative
max-min water-filling approximation that never oversubscribes a link.

Modelled per paper §3 / §7.1.3:

* **Transport** —
  - ``ndp``  ("purified"): senders start at line rate; per-step rate equals
    the receiver-driven fair share (trimming => no timeouts, headers always
    arrive).
  - ``tcp``: slow start from a small window, AIMD (halve on congestion),
    additive increase otherwise.
  - ``dctcp``: like tcp but gentle multiplicative decrease (ECN-style).
* **Load balancing** —
  - ``ecmp``: flow hashes onto one of ``n_ecmp`` minimal-path forwarding
    tables at start; never re-routes.
  - ``letflow``: flowlet re-routing among the minimal tables only.
  - ``fatpaths``: flowlet re-routing across FatPaths layers (minimal +
    non-minimal); layer choice uniform among layers that can route (s, t)
    (fallback guarantees layer 0 always can).
* **Flowlet elasticity** — the probability that a flowlet gap occurs in a
  step grows as the flow's achieved rate falls:
  ``p_gap = dt/gap * (1 - rate/line + eps)`` — slow (congested) flows
  re-roll paths often, fast flows stick (paper §3.2).

Endpoint NICs are modelled as virtual links (injection + ejection), so
incast (all-to-one) and concentration effects are captured.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import LayeredRouting
from .topology import Topology
from .traffic import FlowWorkload

__all__ = ["SimConfig", "SimResult", "simulate", "simulate_seeds",
           "ecmp_routing"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    transport: str = "ndp"          # ndp | tcp | dctcp
    balancing: str = "fatpaths"     # ecmp | letflow | fatpaths
    dt: float = 10e-6               # seconds per step
    n_steps: int = 2000
    line_rate: float = 12.5e9       # bytes/s (100 GbE)
    link_latency: float = 1e-6      # per hop (INET-matched fixed delay)
    sw_latency: float = 10e-6       # endpoint software stack latency
    flowlet_gap: float = 50e-6      # LetFlow-style gap timescale
    gap_eps: float = 0.05           # baseline re-roll probability factor
    max_hops: int = 12
    fair_iters: int = 2             # water-filling refinement iterations
    tcp_init: float = 0.05          # initial rate fraction (slow start)
    tcp_ai: float = 0.02            # additive increase per step (frac of line)
    tcp_md: float = 0.5             # multiplicative decrease (tcp)
    dctcp_md: float = 0.85          # gentle decrease (dctcp)
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    fct: np.ndarray            # (F,) seconds; NaN if unfinished
    delivered: np.ndarray      # (F,) bytes delivered
    size: np.ndarray           # (F,) flow sizes
    finished: np.ndarray       # (F,) bool
    link_util_mean: float
    config: SimConfig

    @property
    def throughput_per_flow(self) -> np.ndarray:
        return np.where(self.finished, self.size / np.maximum(self.fct, 1e-12),
                        np.nan)

    def fct_stats(self) -> Dict[str, float]:
        ok = self.finished
        f = self.fct[ok]
        if len(f) == 0:
            return {"mean": float("nan"), "p50": float("nan"),
                    "p99": float("nan"), "finished": 0.0}
        return {
            "mean": float(f.mean()),
            "p50": float(np.quantile(f, 0.50)),
            "p99": float(np.quantile(f, 0.99)),
            "finished": float(ok.mean()),
        }


def ecmp_routing(topo: Topology, n_tables: int = 8, seed: int = 0,
                 max_len: Optional[int] = None) -> LayeredRouting:
    """Minimal-path-only multi-table routing: n differently tie-broken
    shortest-path tables (flow-hash ECMP / LetFlow substrate).  All n
    tables come out of one batched forwarding program (APSP is shared:
    every table sees the same full-graph distances)."""
    import time

    from . import paths as paths_mod

    adj = np.asarray(topo.adj, dtype=bool)
    if max_len is None:
        max_len = max(6, topo.diameter_nominal + 2)
    t0 = time.perf_counter()
    nbr = jnp.asarray(paths_mod.neighbor_table(adj))
    stack = jnp.asarray(np.broadcast_to(adj[None], (n_tables,) + adj.shape))
    t_dev = time.perf_counter()
    dist_j = paths_mod.shortest_path_lengths(jnp.asarray(adj), max_l=max_len)
    nh = paths_mod._forwarding_program(
        stack, jnp.broadcast_to(dist_j[None], stack.shape), nbr,
        jax.random.PRNGKey(seed))
    nh = np.asarray(jax.block_until_ready(nh)).copy()
    t1 = time.perf_counter()
    dist = np.asarray(dist_j)
    reach = dist <= max_len
    nh[:, ~reach] = -1
    idx = np.arange(adj.shape[0])
    nh[:, idx, idx] = idx
    plen = np.where(reach, dist, 10_000).astype(np.int16)
    t2 = time.perf_counter()
    return LayeredRouting(
        topo=topo, scheme="ecmp", rho=1.0,
        nh=nh, reach=np.stack([reach] * n_tables),
        pathlen=np.stack([plen] * n_tables),
        layer_adj=np.stack([adj] * n_tables),
        build_stats={"total_s": t2 - t0, "device_s": t1 - t_dev,
                     "host_s": (t_dev - t0) + (t2 - t1)},
    )


@functools.partial(jax.jit, static_argnames=("max_hops",))
def _path_edge_tensor(nh, eix, src_r, dst_r, max_hops):
    """Walk every layer's table once, ahead of the scan: (L, F, max_hops)
    directed fabric edge ids along each flow's path in each layer (-1
    padding once the destination router is reached) plus an (L, F)
    routed-ok mask.  The per-step scan work then collapses from
    ``max_hops`` sequential gathers to ONE gather by current layer."""

    def one_layer(nh_l):
        def hop(cur, _):
            nxt = nh_l[cur, dst_r]
            at_dst = cur == dst_r
            hole = nxt < 0
            e = jnp.where(at_dst | hole, -1,
                          eix[cur, jnp.where(hole, cur, nxt)])
            return jnp.where(at_dst | hole, cur, nxt), e
        cur, es = jax.lax.scan(hop, src_r, None, length=max_hops)
        return es.T, cur == dst_r                      # (F, H), (F,)

    return jax.vmap(one_layer)(nh)


def _prepare(topo: Topology, routing: LayeredRouting, wl: FlowWorkload,
             cfg: SimConfig):
    """Static arrays for the scan — including the per-layer path-edge
    tensor, so the scan body never re-derives flow paths."""
    eix = topo.edge_index_matrix()              # (N, N) -> directed edge id
    n_edges = int((eix >= 0).sum())
    n_ep = wl.src.max() + 1 if len(wl.src) else 1
    n_ep = int(max(n_ep, wl.dst.max() + 1))
    # virtual links: [0, E) fabric, [E, E+n_ep) injection, [E+n_ep, ..) eject,
    # final slot = trash for -1 scatter.
    e_inj = n_edges
    e_ej = n_edges + n_ep
    e_tot = n_edges + 2 * n_ep + 1
    src_r = jnp.asarray(wl.src_router)
    dst_r = jnp.asarray(wl.dst_router)
    edges, routed = _path_edge_tensor(jnp.asarray(routing.nh),
                                      jnp.asarray(eix), src_r, dst_r,
                                      cfg.max_hops)
    # Trim the hop axis to the longest realised path: the per-step cost
    # then tracks actual path lengths, not the cfg.max_hops cap (padding
    # is all -1 beyond the longest path by construction).
    hmax = max(1, int((edges >= 0).sum(axis=2).max())) if edges.size else 1
    edges = edges[:, :, :hmax]
    n_flows = len(wl.src)
    src_e = jnp.asarray(wl.src + e_inj)
    dst_e = jnp.asarray(wl.dst + e_ej)
    n_layers = routing.nh.shape[0]
    # (L, F, H+2): fabric hops + injection + ejection NIC per layer.
    path_edges = jnp.concatenate(
        [edges,
         jnp.broadcast_to(src_e[None, :, None], (n_layers, n_flows, 1)),
         jnp.broadcast_to(dst_e[None, :, None], (n_layers, n_flows, 1))],
        axis=2)
    usable = jnp.asarray(routing.reach)[:, src_r, dst_r].T   # (F, L)
    return dict(
        path_edges=path_edges,                         # (L, F, H+2)
        routed=routed,                                 # (L, F)
        path_hops=(edges >= 0).sum(axis=2).astype(jnp.float32),  # (L, F)
        usable=usable,
        size=jnp.asarray(wl.size, dtype=jnp.float32),
        start=jnp.asarray(wl.start, dtype=jnp.float32),
        e_tot=e_tot,
        n_layers=n_layers,
    )


def _pick_layers(key, usable, minimal_only_mask):
    """Uniform choice among usable layers per flow (layer 0 fallback)."""
    usable = usable & minimal_only_mask[None, :]       # (F, L)
    g = jax.random.gumbel(key, usable.shape)
    g = jnp.where(usable, g, -jnp.inf)
    pick = jnp.argmax(g, axis=1).astype(jnp.int32)
    any_ok = usable.any(axis=1)
    return jnp.where(any_ok, pick, 0)


def _run_scan_impl(arrs, key0, cfg: SimConfig, static: Tuple[int, int, int]):
    e_tot, n_layers, n_steps = static
    f = arrs["size"].shape[0]
    line_bytes = jnp.float32(cfg.line_rate * cfg.dt)   # bytes per step at line

    minimal_only = jnp.ones(n_layers, dtype=bool)
    is_fatpaths = cfg.balancing == "fatpaths"
    reroute = cfg.balancing in ("letflow", "fatpaths")

    k_init, k_scan = jax.random.split(key0)
    layer0 = _pick_layers(k_init, arrs["usable"], minimal_only)

    if cfg.transport == "ndp":
        rate0 = jnp.ones(f, dtype=jnp.float32)         # line rate start
    else:
        rate0 = jnp.full(f, cfg.tcp_init, dtype=jnp.float32)

    init = dict(
        remaining=arrs["size"],
        layer=layer0,
        rate=rate0,
        fct=jnp.full(f, jnp.nan, dtype=jnp.float32),
        hops=jnp.zeros(f, dtype=jnp.float32),
        key=k_scan,
        util_acc=jnp.float32(0.0),
    )

    cap = jnp.ones(e_tot, dtype=jnp.float32)           # capacities in line units

    def step(state, i):
        t = i.astype(jnp.float32) * cfg.dt
        key, k_gap, k_pick = jax.random.split(state["key"], 3)
        started = arrs["start"] <= t
        done = state["remaining"] <= 0
        active = started & ~done

        # One gather by current layer replaces the per-step table walk:
        # paths were materialised once in _prepare.
        frows = jnp.arange(f)
        all_edges = arrs["path_edges"][state["layer"], frows]   # (F, H+2)
        routed = arrs["routed"][state["layer"], frows]
        n_hops = arrs["path_hops"][state["layer"], frows]
        all_edges = jnp.where(active[:, None] & routed[:, None],
                              jnp.where(all_edges < 0, e_tot - 1, all_edges),
                              e_tot - 1)

        # --- iterative max-min approximation (feasible by construction) ----
        w = active.astype(jnp.float32) * routed.astype(jnp.float32)
        desired = jnp.minimum(state["rate"], 1.0) * w
        onehot_count = jnp.zeros(e_tot).at[all_edges.reshape(-1)].add(
            jnp.repeat(w, all_edges.shape[1]))
        fair = cap / jnp.maximum(onehot_count, 1e-9)
        adv = jnp.min(jnp.where(all_edges < e_tot - 1,
                                fair[all_edges], jnp.inf), axis=1)
        d = jnp.minimum(desired, adv)
        for _ in range(cfg.fair_iters):
            load = jnp.zeros(e_tot).at[all_edges.reshape(-1)].add(
                jnp.repeat(d, all_edges.shape[1]))
            scale = jnp.minimum(1.0, cap / jnp.maximum(load, 1e-9))
            s = jnp.min(jnp.where(all_edges < e_tot - 1,
                                  scale[all_edges], jnp.inf), axis=1)
            s = jnp.where(jnp.isfinite(s), s, 0.0)
            d = d * s
        sent = d  # fraction of line rate actually achieved this step
        share = adv  # the fair share signal (congestion feedback)

        delivered = sent * line_bytes
        new_remaining = jnp.maximum(state["remaining"] - delivered * w, 0.0)
        newly_done = (new_remaining <= 0) & ~done & started
        # FCT includes propagation + software latency along the path taken.
        fct_now = (t + cfg.dt - arrs["start"]
                   + n_hops * cfg.link_latency + cfg.sw_latency)
        fct = jnp.where(newly_done, fct_now, state["fct"])
        hops = jnp.where(newly_done, n_hops, state["hops"])

        # --- transport rate dynamics --------------------------------------
        if cfg.transport == "ndp":
            rate = jnp.ones(f, dtype=jnp.float32)
        else:
            congested = share < state["rate"] * 0.98
            md = cfg.tcp_md if cfg.transport == "tcp" else cfg.dctcp_md
            slow_start = state["rate"] < 0.5
            up = jnp.where(slow_start, state["rate"] * 2.0,
                           state["rate"] + cfg.tcp_ai)
            rate = jnp.where(congested, jnp.maximum(share * md, cfg.tcp_init),
                             jnp.minimum(up, 1.0))

        # --- flowlet elasticity + layer re-roll -----------------------------
        if reroute:
            slack = 1.0 - jnp.clip(sent, 0.0, 1.0)
            p_gap = jnp.clip(cfg.dt / cfg.flowlet_gap
                             * (slack + cfg.gap_eps), 0.0, 1.0)
            roll = jax.random.uniform(k_gap, (f,)) < p_gap
            newpick = _pick_layers(k_pick, arrs["usable"], minimal_only)
            layer = jnp.where(roll & active, newpick, state["layer"])
        else:
            layer = state["layer"]

        util = sent.sum() / jnp.maximum(w.sum(), 1.0)
        out = dict(remaining=new_remaining, layer=layer, rate=rate, fct=fct,
                   hops=hops, key=key, util_acc=state["util_acc"] + util)
        return out, None

    final, _ = jax.lax.scan(step, init, jnp.arange(n_steps))
    return final


_run_scan = functools.partial(jax.jit,
                              static_argnames=("cfg", "static"))(_run_scan_impl)


@functools.partial(jax.jit, static_argnames=("cfg", "static"))
def _run_scan_batch(arrs, keys, cfg: SimConfig,
                    static: Tuple[int, int, int]):
    """One vmapped scan over a batch of PRNG keys (seed sweep)."""
    return jax.vmap(lambda k: _run_scan_impl(arrs, k, cfg, static))(keys)


def _to_result(size: np.ndarray, final, cfg: SimConfig) -> SimResult:
    remaining = np.asarray(final["remaining"])
    return SimResult(
        fct=np.asarray(final["fct"]),
        delivered=size - remaining,
        size=size,
        finished=remaining <= 0,
        link_util_mean=float(final["util_acc"]) / cfg.n_steps,
        config=cfg,
    )


def simulate(topo: Topology, routing: LayeredRouting, wl: FlowWorkload,
             cfg: SimConfig) -> SimResult:
    """Run the flow simulator; returns per-flow FCTs and aggregates."""
    arrs = _prepare(topo, routing, wl, cfg)
    static = (int(arrs["e_tot"]), int(arrs["n_layers"]), int(cfg.n_steps))
    jarrs = {k: v for k, v in arrs.items() if k not in ("e_tot", "n_layers")}
    final = _run_scan(jarrs, jax.random.PRNGKey(cfg.seed), cfg, static)
    return _to_result(np.asarray(arrs["size"]), final, cfg)


def simulate_seeds(topo: Topology, routing: LayeredRouting, wl: FlowWorkload,
                   cfg: SimConfig, seeds) -> list:
    """Seed sweep batched through ONE vmapped scan (no Python loop over
    simulations): same topology/routing/workload, one PRNG stream per
    seed.  Returns a list of :class:`SimResult`, one per seed, identical
    to looping :func:`simulate` with ``cfg.seed`` set to each value."""
    seeds = [int(s) for s in seeds]
    if not seeds:
        return []
    arrs = _prepare(topo, routing, wl, cfg)
    static = (int(arrs["e_tot"]), int(arrs["n_layers"]), int(cfg.n_steps))
    jarrs = {k: v for k, v in arrs.items() if k not in ("e_tot", "n_layers")}
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    finals = _run_scan_batch(jarrs, keys, cfg, static)
    size = np.asarray(arrs["size"])
    return [
        _to_result(size, {k: v[i] for k, v in finals.items()},
                   dataclasses.replace(cfg, seed=s))
        for i, s in enumerate(seeds)
    ]
