"""Flow-level transport + load-balancing simulator (paper §7, htsim analogue).

A vectorised discrete-time simulator written as a single ``jax.lax.scan``:
all flows advance simultaneously in Δt steps; link sharing is an iterative
max-min water-filling approximation that never oversubscribes a link.

Modelled per paper §3 / §7.1.3:

* **Transport** —
  - ``ndp``  ("purified"): senders start at line rate; per-step rate equals
    the receiver-driven fair share (trimming => no timeouts, headers always
    arrive).
  - ``tcp``: slow start from a small window, AIMD (halve on congestion),
    additive increase otherwise.
  - ``dctcp``: like tcp but gentle multiplicative decrease (ECN-style).
* **Load balancing** —
  - ``ecmp``: flow hashes onto one of ``n_ecmp`` minimal-path forwarding
    tables at start; never re-routes.
  - ``letflow``: flowlet re-routing among the minimal tables only.
  - ``fatpaths``: flowlet re-routing across FatPaths layers (minimal +
    non-minimal); layer choice uniform among layers that can route (s, t)
    (fallback guarantees layer 0 always can).
* **Flowlet elasticity** — the probability that a flowlet gap occurs in a
  step grows as the flow's achieved rate falls:
  ``p_gap = dt/gap * (1 - rate/line + eps)`` — slow (congested) flows
  re-roll paths often, fast flows stick (paper §3.2).

Endpoint NICs are modelled as virtual links (injection + ejection), so
incast (all-to-one) and concentration effects are captured.

Execution structure (PR 5):

* **Fused water-filling step** — the per-step scatter/gather/min inner
  loop is one :func:`repro.kernels.waterfill.waterfill_step` call: a
  single fused Pallas kernel on TPU, the jnp oracle on CPU
  (``SimConfig.kernel_backend`` / ``REPRO_KERNEL_BACKEND`` override).
* **PRNG derivation** — per-flow keys ``fold_in(key, flow)`` are hoisted
  out of the step body; step draws come from
  ``uniform(fold_in(flow_key, chunk), (horizon_chunk, 2))[step_in_chunk]``
  so one bulk generation per chunk replaces the per-step vmapped
  ``fold_in`` pair.  Row ``i``'s draws still depend only on
  ``(key, i, step)`` — the padding-safety property the distributed sweep
  engine's bit-identity guarantee rests on — but the draws themselves
  differ from the pre-PR5 stream (and change if ``horizon_chunk``
  changes), so any seed-sensitive baseline re-baselines with this PR.
* **Adaptive horizon** — the scan runs as a ``lax.while_loop`` over
  fixed-size chunks of ``horizon_chunk`` steps that stops as soon as
  every flow is finished or provably stuck (weight 0 forever: no layer
  it can ever pick routes it).  Skipped steps are exact no-ops on every
  result-bearing state component, so early exit returns results
  bit-identical to the full-horizon run; cells whose flows stay active
  (slow but routable) run the full ``n_steps``.

Dynamic traffic (PR 6) — the open-loop lane:

* ``arrs["active_at"]`` is a per-flow activation *step* (int32 operand,
  from :attr:`FlowWorkload.active_step`, built by
  :mod:`repro.core.arrivals`): a flow participates only once
  ``step >= active_at`` AND ``start <= t`` — with ``active_at = 0``
  (the default for every static workload) the predicate reduces bitwise
  to the old closed-loop one, so a dynamic cell whose activations are
  all zero reproduces the static-batch result exactly;
* ``state["depart_step"]`` records the step at which each flow finished
  (-1 while in flight) — the departure half of the unrolled
  flow-slot ring buffer (see :mod:`repro.core.arrivals`);
* the adaptive horizon needs no extra predicate for pending arrivals: a
  not-yet-active flow keeps ``remaining > 0``, and it is counted stuck
  only if no pickable layer can EVER route it — in which case it sends
  nothing after arriving either, so skipping it stays an exact no-op;
* the masking of inactive flows' edges to the trash link moved INTO the
  fused water-filling kernel (the ``active`` lane of
  :func:`repro.kernels.waterfill.waterfill_step`), value-identical to
  the host-side select it replaces — inactive flows still see share
  = +inf (an uncongested network), which the tcp/dctcp ramp relies on.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.waterfill import waterfill_step
from .layers import LayeredRouting
from .topology import Topology
from .traffic import FlowWorkload

__all__ = ["SimConfig", "SimResult", "simulate", "simulate_seeds",
           "ecmp_routing", "prepare", "pad_prepared", "batch_result",
           "shape_signature"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    transport: str = "ndp"          # ndp | tcp | dctcp
    balancing: str = "fatpaths"     # ecmp | letflow | fatpaths
    dt: float = 10e-6               # seconds per step
    n_steps: int = 2000
    line_rate: float = 12.5e9       # bytes/s (100 GbE)
    link_latency: float = 1e-6      # per hop (INET-matched fixed delay)
    sw_latency: float = 10e-6       # endpoint software stack latency
    flowlet_gap: float = 50e-6      # LetFlow-style gap timescale
    gap_eps: float = 0.05           # baseline re-roll probability factor
    max_hops: int = 12
    fair_iters: int = 2             # water-filling refinement iterations
    tcp_init: float = 0.05          # initial rate fraction (slow start)
    tcp_ai: float = 0.02            # additive increase per step (frac of line)
    tcp_md: float = 0.5             # multiplicative decrease (tcp)
    dctcp_md: float = 0.85          # gentle decrease (dctcp)
    horizon_chunk: int = 64         # scan chunk size (also the PRNG block)
    adaptive_horizon: bool = True   # stop once all flows are done/stuck
    kernel_backend: str = ""        # "" = auto | "pallas" | "ref"
    # --- loss-recovery lanes (PR 8) -------------------------------------
    # recovery="off" (default) compiles the exact pre-PR-8 program —
    # every recovery lane is trace-time gated, so legacy cells reproduce
    # their results bit-for-bit.  recovery="on" adds: a per-flow stall
    # timer, a retransmission-timeout state machine with exponential
    # backoff (deterministic blackhole escape onto the next usable
    # surviving layer), lost-in-flight rollback on mid-run link death
    # (ndp pays one trimmed-RTT, tcp a full RTO stall + slow-start
    # re-entry, dctcp in between), and link-load ECN marking as the
    # dctcp congestion signal.
    recovery: str = "off"           # off | on
    rto_base: int = 16              # initial retransmission timeout (steps)
    rto_cap: int = 256              # exponential-backoff ceiling (steps)
    ecn_thresh: float = 0.65        # link claim-utilization ECN mark point
    # record=1 additionally materialises per-step aggregate lanes
    # (goodput, stalled-flow count) for the recovery evaluator's
    # time-to-recover curves; off for every batched sweep cell.
    record: int = 0
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    fct: np.ndarray            # (F,) seconds; NaN if unfinished
    delivered: np.ndarray      # (F,) bytes delivered
    size: np.ndarray           # (F,) flow sizes
    finished: np.ndarray       # (F,) bool
    link_util_mean: float
    config: SimConfig
    # (F,) step index at which each flow completed; -1 = still in flight
    # at the horizon (the departure lane of the dynamic-traffic ring).
    depart_step: Optional[np.ndarray] = None
    # Recovery lanes (PR 8; None unless cfg.recovery/record enabled):
    # per-flow retransmitted bytes, and the per-step aggregate goodput
    # (line units) / stalled-flow-count curves the recovery evaluator
    # turns into time-to-recover metrics.
    retrans_bytes: Optional[np.ndarray] = None
    goodput_steps: Optional[np.ndarray] = None
    stalled_steps: Optional[np.ndarray] = None

    @property
    def throughput_per_flow(self) -> np.ndarray:
        return np.where(self.finished, self.size / np.maximum(self.fct, 1e-12),
                        np.nan)

    def fct_stats(self) -> Dict[str, float]:
        ok = self.finished
        f = self.fct[ok]
        if len(f) == 0:
            return {"mean": float("nan"), "p50": float("nan"),
                    "p99": float("nan"), "finished": 0.0}
        return {
            "mean": float(f.mean()),
            "p50": float(np.quantile(f, 0.50)),
            "p99": float(np.quantile(f, 0.99)),
            "finished": float(ok.mean()),
        }


def ecmp_routing(topo: Topology, n_tables: int = 8, seed: int = 0,
                 max_len: Optional[int] = None) -> LayeredRouting:
    """Minimal-path-only multi-table routing: n differently tie-broken
    shortest-path tables (flow-hash ECMP / LetFlow substrate).  All n
    tables come out of one batched forwarding program (APSP is shared:
    every table sees the same full-graph distances)."""
    import time

    from . import paths as paths_mod

    adj = np.asarray(topo.adj, dtype=bool)
    if max_len is None:
        max_len = max(6, topo.diameter_nominal + 2)
    t0 = time.perf_counter()
    engine = paths_mod.path_engine(adj.shape[0])
    nbr = jnp.asarray(paths_mod.neighbor_table(adj))
    stack = jnp.asarray(np.broadcast_to(adj[None], (n_tables,) + adj.shape))
    t_dev = time.perf_counter()
    dist_j = paths_mod.apsp_batched(jnp.asarray(adj)[None],
                                    max_l=max_len)[0]
    nh = paths_mod._forwarding_program(
        stack, jnp.broadcast_to(dist_j[None], stack.shape), nbr,
        jax.random.PRNGKey(seed), engine)
    nh = np.asarray(jax.block_until_ready(nh)).copy()
    t1 = time.perf_counter()
    dist = np.asarray(dist_j)
    reach = dist <= max_len
    nh[:, ~reach] = -1
    idx = np.arange(adj.shape[0])
    nh[:, idx, idx] = idx
    plen = np.where(reach, dist, 10_000).astype(np.int16)
    compressed = None
    if paths_mod.representation_for(adj.shape[0]) == "compressed":
        compressed = paths_mod.CompressedTables.from_dense(nh)
    t2 = time.perf_counter()
    return LayeredRouting(
        topo=topo, scheme="ecmp", rho=1.0,
        nh=nh, reach=np.stack([reach] * n_tables),
        pathlen=np.stack([plen] * n_tables),
        layer_adj=np.stack([adj] * n_tables),
        build_stats={"total_s": t2 - t0, "device_s": t1 - t_dev,
                     "host_s": (t_dev - t0) + (t2 - t1)},
        compressed=compressed,
    )


@functools.partial(jax.jit, static_argnames=("max_hops",))
def _path_edge_tensor(nh, eix, src_r, dst_r, max_hops):
    """Walk every layer's table once, ahead of the scan: (L, F, max_hops)
    directed fabric edge ids along each flow's path in each layer (-1
    padding once the destination router is reached) plus an (L, F)
    routed-ok mask.  The per-step scan work then collapses from
    ``max_hops`` sequential gathers to ONE gather by current layer."""

    def one_layer(nh_l):
        def hop(cur, _):
            nxt = nh_l[cur, dst_r]
            at_dst = cur == dst_r
            hole = nxt < 0
            e = jnp.where(at_dst | hole, -1,
                          eix[cur, jnp.where(hole, cur, nxt)])
            return jnp.where(at_dst | hole, cur, nxt), e
        cur, es = jax.lax.scan(hop, src_r, None, length=max_hops)
        return es.T, cur == dst_r                      # (F, H), (F,)

    return jax.vmap(one_layer)(nh)


@functools.partial(jax.jit, static_argnames=("max_hops", "block"))
def _path_edge_tensor_compressed(nh_sets, sel, eix, src_r, dst_r, max_hops,
                                 block):
    """:func:`_path_edge_tensor` off compressed tables: the per-hop
    next-hop gather becomes selector + set-member lookups
    (``nh_sets[l, cur, dst_r // block, sel[l, cur, dst_r]]``) and never
    touches a dense (N, N) table row.  Lookups reconstruct the dense
    entry exactly, so edges/routed are bit-identical to the dense walk."""
    dst_blk = dst_r // block

    def one_layer(args):
        nh_sets_l, sel_l = args

        def hop(cur, _):
            k = sel_l[cur, dst_r].astype(jnp.int32)
            nxt = nh_sets_l[cur, dst_blk, k]
            at_dst = cur == dst_r
            hole = nxt < 0
            e = jnp.where(at_dst | hole, -1,
                          eix[cur, jnp.where(hole, cur, nxt)])
            return jnp.where(at_dst | hole, cur, nxt), e
        cur, es = jax.lax.scan(hop, src_r, None, length=max_hops)
        return es.T, cur == dst_r                      # (F, H), (F,)

    return jax.vmap(one_layer)((nh_sets, sel))


def _virtual_links(topo: Topology, wl: FlowWorkload):
    """(edge-index matrix, fabric edge count, endpoint count) — the
    virtual-link layout shared by :func:`_prepare` and the cheap
    :func:`shape_signature` probe."""
    eix = topo.edge_index_matrix()              # (N, N) -> directed edge id
    n_edges = int((eix >= 0).sum())
    # Empty workloads get one (unused) endpoint slot: max() on an empty
    # array raises, and every downstream shape stays well-formed with
    # n_ep = 1 (a zero-flow cell simulates to an all-empty SimResult).
    if len(wl.src):
        n_ep = int(max(wl.src.max(), wl.dst.max()) + 1)
    else:
        n_ep = 1
    return eix, n_edges, n_ep


def shape_signature(topo: Topology, routing: LayeredRouting,
                    wl: FlowWorkload) -> Tuple[int, int, int]:
    """(n_flows, e_tot, n_layers) for a cell WITHOUT building the scan
    operands — what batch engines bucket on.  Matches the shapes
    :func:`prepare` will realize (the hop depth is the one axis only
    the path walk can determine)."""
    _, n_edges, n_ep = _virtual_links(topo, wl)
    return (len(wl.src), n_edges + 2 * n_ep + 1, int(routing.nh.shape[0]))


def _prepare(topo: Topology, routing: LayeredRouting, wl: FlowWorkload,
             cfg: SimConfig):
    """Static arrays for the scan — including the per-layer path-edge
    tensor, so the scan body never re-derives flow paths."""
    eix, n_edges, n_ep = _virtual_links(topo, wl)
    # virtual links: [0, E) fabric, [E, E+n_ep) injection, [E+n_ep, ..) eject,
    # final slot = trash for -1 scatter.
    e_inj = n_edges
    e_ej = n_edges + n_ep
    e_tot = n_edges + 2 * n_ep + 1
    src_r = jnp.asarray(wl.src_router)
    dst_r = jnp.asarray(wl.dst_router)
    ct = getattr(routing, "compressed", None)
    if ct is not None:
        edges, routed = _path_edge_tensor_compressed(
            jnp.asarray(ct.nh_sets), jnp.asarray(ct.sel), jnp.asarray(eix),
            src_r, dst_r, cfg.max_hops, ct.block)
    else:
        edges, routed = _path_edge_tensor(jnp.asarray(routing.nh),
                                          jnp.asarray(eix), src_r, dst_r,
                                          cfg.max_hops)
    # Trim the hop axis to the longest realised path: the per-step cost
    # then tracks actual path lengths, not the cfg.max_hops cap (padding
    # is all -1 beyond the longest path by construction).
    hmax = max(1, int((edges >= 0).sum(axis=2).max())) if edges.size else 1
    edges = edges[:, :, :hmax]
    n_flows = len(wl.src)
    src_e = jnp.asarray(wl.src + e_inj)
    dst_e = jnp.asarray(wl.dst + e_ej)
    n_layers = routing.nh.shape[0]
    # (L, F, H+2): fabric hops + injection + ejection NIC per layer.
    path_edges = jnp.concatenate(
        [edges,
         jnp.broadcast_to(src_e[None, :, None], (n_layers, n_flows, 1)),
         jnp.broadcast_to(dst_e[None, :, None], (n_layers, n_flows, 1))],
        axis=2)
    usable = jnp.asarray(routing.reach)[:, src_r, dst_r].T   # (F, L)
    # Dynamic-traffic activation lane: step index before which the flow
    # does not exist.  Static workloads (active_step=None) get zeros —
    # the activation predicate then reduces bitwise to the closed-loop
    # ``start <= t`` one.
    active_step = getattr(wl, "active_step", None)
    if active_step is None:
        active_at = jnp.zeros(n_flows, dtype=jnp.int32)
    else:
        active_at = jnp.asarray(active_step, dtype=jnp.int32)
    out = dict(
        path_edges=path_edges,                         # (L, F, H+2)
        routed=routed,                                 # (L, F)
        path_hops=(edges >= 0).sum(axis=2).astype(jnp.float32),  # (L, F)
        usable=usable,
        size=jnp.asarray(wl.size, dtype=jnp.float32),
        start=jnp.asarray(wl.start, dtype=jnp.float32),
        active_at=active_at,                           # (F,) int32
        e_tot=e_tot,
        n_layers=n_layers,
    )
    # Mid-run link-death lane (fault injection): per-virtual-link death
    # step, INT32_MAX = never dies.  The key is ABSENT for pristine
    # fabrics — the scan's capacity select is gated at trace time, so a
    # fabric without scheduled failures compiles to a program bitwise
    # identical to one built before this lane existed.
    lds_r = getattr(routing, "link_down_step", None)
    if lds_r is not None:
        lds = np.full(e_tot, np.iinfo(np.int32).max, dtype=np.int32)
        fabric = np.asarray(eix) >= 0
        lds[np.asarray(eix)[fabric]] = np.asarray(lds_r,
                                                  dtype=np.int32)[fabric]
        out["link_down_step"] = jnp.asarray(lds)       # (e_tot,) int32
    # Link-churn lane (PR 10): per-virtual-link sorted (down, up) event
    # intervals plus the re-pick step (up + churn_conv, saturating).
    # Same trace-time contract as link_down_step: the keys are ABSENT
    # for schedule-free fabrics, so those compile the pre-churn program.
    lc_r = getattr(routing, "link_churn", None)
    if lc_r is not None:
        imax = np.iinfo(np.int32).max
        lc_r = np.asarray(lc_r, dtype=np.int32)
        lc = np.full((e_tot,) + lc_r.shape[2:], imax, dtype=np.int32)
        fabric = np.asarray(eix) >= 0
        lc[np.asarray(eix)[fabric]] = lc_r[fabric]
        conv = int(getattr(routing, "churn_conv", 0) or 0)
        pick_at = np.minimum(lc[..., 1].astype(np.int64) + conv, imax)
        out["link_churn"] = jnp.asarray(lc)            # (e_tot, K, 2)
        out["churn_pick_at"] = jnp.asarray(            # (e_tot, K)
            pick_at.astype(np.int32))
    return out


def _flow_uniforms(key, f):
    """(F, 2) U[0,1) draws where row ``i`` depends ONLY on ``(key, i)``.

    A plain ``jax.random.uniform(key, (f,))`` is NOT padding-safe:
    threefry pairs the flat counter array across its two halves, so
    growing ``f`` (batch padding) changes every flow's draw.  Deriving a
    per-flow key via ``fold_in`` makes each row's bits a function of the
    flow index alone — a cell simulated standalone and the same cell
    padded into a larger batch consume identical randomness, which is
    what lets the distributed sweep engine promise bit-identical
    per-cell results (see repro.experiments.dist_sweep)."""
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(f))
    return jax.vmap(lambda k: jax.random.uniform(k, (2,)))(keys)


def _chunk_uniforms(flow_keys, c, chunk: int):
    """(chunk, F, 2) U[0,1) draws for one scan chunk, generated in one
    bulk pass instead of two vmapped ``fold_in`` sweeps per step.

    Draw ``[s, i]`` depends only on ``(flow_keys[i], c, s)`` — per-flow
    keys keep the padding-safety property of :func:`_flow_uniforms`, and
    the counter offset inside the fixed-size ``(chunk, 2)`` block pins
    each step's bits regardless of how many steps of the chunk actually
    execute (the tail chunk slices this same block)."""
    cks = jax.vmap(jax.random.fold_in, in_axes=(0, None))(flow_keys, c)
    u = jax.vmap(lambda k: jax.random.uniform(k, (chunk, 2)))(cks)
    return jnp.moveaxis(u, 0, 1)


def _pick_layers(u, usable, minimal_only_mask):
    """Uniform choice among usable layers per flow, driven by one
    per-flow uniform ``u`` (layer 0 fallback): pick the r-th usable
    layer with r ~ U{0..n_usable-1}."""
    usable = usable & minimal_only_mask[None, :]       # (F, L)
    c = jnp.cumsum(usable.astype(jnp.int32), axis=1)   # (F, L)
    n = c[:, -1]
    r = jnp.minimum((u * n).astype(jnp.int32), jnp.maximum(n - 1, 0))
    pick = jnp.argmax(c > r[:, None], axis=1).astype(jnp.int32)
    return jnp.where(n > 0, pick, 0)


def _rto_next(rto, delivered, backoff, rto_base: int, rto_cap: int):
    """One step of the retransmission-timeout state machine (vectorised
    over flows): ``backoff`` events (stall-timer expiry, loss on link
    death) double the RTO up to ``rto_cap``; a successful delivery
    resets it to ``rto_base`` and WINS over a same-step backoff.  Pure —
    the scan body and the property tests share this exact function, so
    'backoff is monotone until delivery and resets on delivery' is
    asserted on the code that runs."""
    bumped = jnp.where(backoff, jnp.minimum(rto * 2, rto_cap), rto)
    return jnp.where(delivered, jnp.asarray(rto_base, rto.dtype), bumped)


def _escape_layers(layer, esc_ok):
    """Deterministic blackhole escape: the next layer (cyclically after
    the current one) that is pickable AND routes the flow.  ``esc_ok``
    is the static (F, L) surviving-usable-layer mask; flows with no such
    layer return their current layer (valid=False).  No PRNG draws —
    escape is timeout-driven and independent of the flowlet hazard."""
    n_layers = esc_ok.shape[1]
    order = (layer[:, None] + 1
             + jnp.arange(n_layers, dtype=jnp.int32)[None, :]) % n_layers
    ok = jnp.take_along_axis(esc_ok, order, axis=1)          # (F, L)
    first = jnp.argmax(ok, axis=1)
    esc = jnp.take_along_axis(order, first[:, None], axis=1)[:, 0]
    valid = ok.any(axis=1)
    return jnp.where(valid, esc, layer).astype(jnp.int32), valid


def _churn_state(i, sched, pick_at):
    """Per-link churn predicates at step ``i``: ``dead`` — inside a
    ``(down, up)`` outage interval (capacity 0); ``unpickable`` — inside
    the wider ``(down, up + conv)`` window during which flowlets may not
    (re-)pick the link.  Capacity restores at ``up``, USABILITY at
    ``up + conv`` — the control-plane re-convergence delay.  ``sched``
    is ``(..., K, 2)`` int32 with INT32_MAX sentinels, ``pick_at`` the
    precomputed saturating ``up + conv`` (``(..., K)``).  Pure — the
    scan body and the unit tests share this exact function."""
    down = sched[..., 0]
    dead = jnp.any((down <= i) & (i < sched[..., 1]), axis=-1)
    unpickable = jnp.any((down <= i) & (i < pick_at), axis=-1)
    return dead, unpickable


def _run_scan_impl(arrs, key0, cfg: SimConfig, static: Tuple[int, int, int]):
    e_tot, n_layers, n_steps = static
    f = arrs["size"].shape[0]
    line_bytes = jnp.float32(cfg.line_rate * cfg.dt)   # bytes per step at line

    minimal_only = jnp.ones(n_layers, dtype=bool)
    reroute = cfg.balancing in ("letflow", "fatpaths")
    chunk = max(1, int(cfg.horizon_chunk))
    n_full, rem = divmod(n_steps, chunk)
    # Loss-recovery lanes (PR 8) — ALL trace-time gated: with
    # recovery="off" and record=0 every branch below compiles away and
    # the program is identical to the pre-PR-8 scan (test-asserted
    # bitwise per transport mode).
    recovery_on = str(cfg.recovery).lower() in ("on", "1", "true")
    record_on = bool(int(cfg.record))
    has_lds = "link_down_step" in arrs
    # Churn lanes (PR 10), gated exactly like link_down_step: absent
    # operands compile the identical pre-churn program.  has_death arms
    # the loss-accounting lanes for EITHER kind of mid-run link death.
    has_churn = "link_churn" in arrs
    has_death = has_lds or has_churn
    # Link-load ECN marking replaces the pure share-vs-rate congested
    # bool as the dctcp signal only under recovery (tcp keeps the
    # legacy signal in both modes).
    want_util = recovery_on and cfg.transport == "dctcp"

    k_init, k_scan = jax.random.split(key0)
    layer0 = _pick_layers(_flow_uniforms(k_init, f)[:, 0], arrs["usable"],
                          minimal_only)
    # Per-flow key table, hoisted out of the step body: step randomness
    # is (flow key, chunk, step-in-chunk) — see _chunk_uniforms.
    flow_keys = jax.vmap(lambda i: jax.random.fold_in(k_scan, i))(
        jnp.arange(f))

    if cfg.transport == "ndp":
        rate0 = jnp.ones(f, dtype=jnp.float32)         # line rate start
    else:
        rate0 = jnp.full(f, cfg.tcp_init, dtype=jnp.float32)

    init = dict(
        remaining=arrs["size"],
        layer=layer0,
        rate=rate0,
        hops=jnp.zeros(f, dtype=jnp.float32),
        # Per-flow accumulators (elementwise, exact under flow padding);
        # the utilization ratio is taken on host AFTER stripping padding,
        # so batched and standalone runs report bit-identical metrics.
        sent_acc=jnp.zeros(f, dtype=jnp.float32),
        w_acc=jnp.zeros(f, dtype=jnp.float32),
        # Departure lane: the step at which the flow finished (-1 = in
        # flight).  Result-bearing AND exact under early exit: once all
        # flows are done/stuck no step produces a newly_done, so skipped
        # chunks cannot have written it.
        depart_step=jnp.full(f, -1, dtype=jnp.int32),
    )
    if recovery_on:
        # stall: consecutive ~zero-share steps; rto: current timeout
        # (steps, doubles on backoff up to rto_cap); blocked_until: the
        # step before which a loss-penalised flow may not send;
        # retrans_acc: lost-in-flight line-units that had to be resent.
        init.update(
            stall=jnp.zeros(f, dtype=jnp.int32),
            rto=jnp.full(f, int(cfg.rto_base), dtype=jnp.int32),
            blocked_until=jnp.zeros(f, dtype=jnp.int32),
            retrans_acc=jnp.zeros(f, dtype=jnp.float32),
        )

    cap = jnp.ones(e_tot, dtype=jnp.float32)           # capacities in line units
    frows = jnp.arange(f)
    # One packed (L, F, H+4) record — path edges | routed | hop count —
    # so the step body gathers by current layer ONCE, not three times.
    n_slots = arrs["path_edges"].shape[2]
    packed = jnp.concatenate(
        [arrs["path_edges"].astype(jnp.int32),
         arrs["routed"].astype(jnp.int32)[..., None],
         arrs["path_hops"].astype(jnp.int32)[..., None]], axis=2)

    # Provably-stuck support for the adaptive horizon: a flow whose
    # current layer cannot route it AND that can never re-roll onto a
    # routing layer (re-rolls pick among `usable` layers, falling back
    # to layer 0) has weight 0 on every future step.  Without re-routing
    # the layer is pinned, so the current layer alone decides.
    if reroute:
        pickable = arrs["usable"] & minimal_only[None, :]
        pickable = jnp.where(pickable.any(axis=1, keepdims=True), pickable,
                             (jnp.arange(n_layers) == 0)[None, :])
        pick_routable = jnp.any(pickable & arrs["routed"].T, axis=1)  # (F,)
        # Static escape-candidate mask for the RTO blackhole escape:
        # layers a flow may pick that actually route it.
        esc_ok = pickable & arrs["routed"].T                           # (F, L)
    else:
        pick_routable = jnp.zeros(f, dtype=bool)
        esc_ok = None

    def step(state, xs):
        if reroute:
            i, u = xs
        else:
            i = xs
        t = i.astype(jnp.float32) * cfg.dt
        # Open-loop activation: a flow exists once its activation step
        # has been reached AND its start time has passed.  Static cells
        # have active_at == 0 everywhere, reducing this bitwise to the
        # closed-loop ``start <= t`` predicate.
        started = (arrs["start"] <= t) & (i >= arrs["active_at"])
        done = state["remaining"] <= 0
        active = started & ~done

        # One gather by current layer replaces the per-step table walk:
        # paths were materialised once in _prepare, packed once above.
        g = packed[state["layer"], frows]                       # (F, H+4)
        edges = g[:, :n_slots]
        routed = g[:, n_slots] > 0
        n_hops = g[:, n_slots + 1].astype(jnp.float32)
        if recovery_on:
            # Loss penalties stall the sender: a flow blocked by its
            # transport's loss response (RTO stall for tcp, a fraction
            # of it for dctcp, one trimmed-RTT for ndp) sends nothing
            # until its blocked_until step.
            unblocked = i >= state["blocked_until"]
            send = active & routed & unblocked
        else:
            send = active & routed

        # --- fused max-min water-filling (feasible by construction) -------
        # The active lane masks non-sending rows to the trash link inside
        # the kernel (value-identical to the host-side select it replaced).
        w = send.astype(jnp.float32)
        desired = jnp.minimum(state["rate"], 1.0) * w
        # Mid-run link death: a link's capacity drops to 0 at its
        # scheduled step (fair share 0 in both waterfill backends), so
        # flows on it stall, their slack maxes the flowlet-gap hazard,
        # and the next re-roll lands on a surviving usable layer.  The
        # branch is trace-time: pristine fabrics (no "link_down_step"
        # operand) compile the exact pre-fault program.
        if "link_down_step" in arrs:
            cap_t = jnp.where(i < arrs["link_down_step"], cap, 0.0)
        else:
            cap_t = cap
        # Link churn: capacity 0 inside every (down, up) outage window —
        # and back to line rate at `up` (unlike the one-shot lane, links
        # RETURN).  Re-pick usability is gated separately below.
        if has_churn:
            churn_dead, link_unpick = _churn_state(
                i, arrs["link_churn"], arrs["churn_pick_at"])
            cap_t = jnp.where(churn_dead, 0.0, cap_t)
        wf = waterfill_step(edges, w, desired, cap_t, active=send,
                            fair_iters=cfg.fair_iters,
                            backend=cfg.kernel_backend or None,
                            want_util=want_util)
        if want_util:
            sent, share, util = wf
        else:
            sent, share = wf

        # Lost-in-flight accounting on mid-run link death: at the step a
        # path edge dies, a bandwidth-delay-product estimate of the
        # bytes in the pipe (rate x path latency in steps, capped by
        # what was actually sent) is rolled back from sent_acc into
        # remaining — those bytes MUST be retransmitted.  The dying
        # link's capacity is already 0 this step, so the hit flow
        # delivered nothing concurrently.
        if recovery_on and has_death:
            safe_e = jnp.where(edges >= 0, edges, e_tot - 1)     # (F, S)
            died_now = None
            if has_lds:
                lds_g = arrs["link_down_step"][safe_e]
                died_now = jnp.any(lds_g == i, axis=1)
            if has_churn:
                # A churn down-event on the current path this step: the
                # same in-flight loss as a one-shot death (events repeat,
                # so a flapping link charges the pipe on EVERY down).
                ch_d = arrs["link_churn"][..., 0][safe_e]        # (F, S, K)
                c_hit = jnp.any(ch_d == i, axis=(1, 2))
                died_now = c_hit if died_now is None else died_now | c_hit
            hit = active & routed & died_now
            pipe_steps = (n_hops * jnp.float32(cfg.link_latency)
                          + jnp.float32(cfg.sw_latency)) / jnp.float32(cfg.dt)
            lost = jnp.where(
                hit, jnp.minimum(state["sent_acc"],
                                 state["rate"] * pipe_steps), 0.0)
        else:
            hit = None
            lost = 0.0

        delivered = sent * line_bytes
        new_remaining = jnp.maximum(state["remaining"] - delivered * w, 0.0)
        if recovery_on and has_death:
            new_remaining = new_remaining + lost * line_bytes
        newly_done = (new_remaining <= 0) & ~done & started
        # FCT is NOT accumulated in-scan: it is derived on the host from
        # the integer depart/hops lanes (:func:`_to_result`).  A float
        # chain like ``t + dt - start + ...`` is fair game for XLA to
        # regroup, and batched vs standalone compilations regrouped it
        # DIFFERENTLY once ``start`` was nonzero (dynamic traffic) —
        # a 1-ulp engine divergence.  Integer lanes can't regroup.
        hops = jnp.where(newly_done, n_hops, state["hops"])
        depart = jnp.where(newly_done, i.astype(jnp.int32),
                           state["depart_step"])

        # --- transport rate dynamics --------------------------------------
        if cfg.transport == "ndp":
            rate = jnp.ones(f, dtype=jnp.float32)
        elif cfg.transport == "dctcp" and recovery_on:
            # ECN: mark proportionally to the worst link claim
            # utilization on the path — a DCTCP-style graded decrease
            # (full dctcp_md multiplicative decrease at saturation)
            # instead of the binary share-vs-rate signal.  A dead link
            # reports huge utilization, so blackholed flows mark at
            # full strength.
            denom = max(1.0 - float(cfg.ecn_thresh), 1e-6)
            frac = jnp.clip((util - cfg.ecn_thresh) / denom, 0.0, 1.0)
            slow_start = state["rate"] < 0.5
            up = jnp.where(slow_start, state["rate"] * 2.0,
                           state["rate"] + cfg.tcp_ai)
            down = state["rate"] * (1.0 - (1.0 - cfg.dctcp_md) * frac)
            rate = jnp.where(frac > 0, jnp.maximum(down, cfg.tcp_init),
                             jnp.minimum(up, 1.0))
        else:
            congested = share < state["rate"] * 0.98
            md = cfg.tcp_md if cfg.transport == "tcp" else cfg.dctcp_md
            slow_start = state["rate"] < 0.5
            up = jnp.where(slow_start, state["rate"] * 2.0,
                           state["rate"] + cfg.tcp_ai)
            rate = jnp.where(congested, jnp.maximum(share * md, cfg.tcp_init),
                             jnp.minimum(up, 1.0))

        # --- RTO state machine + loss penalties (recovery lanes) ----------
        if recovery_on:
            progress = sent > 1e-6
            # Stall timer: consecutive steps an unblocked, wanting flow
            # got ~zero share (blackholed on a dead edge, starved, or
            # unrouted on its current layer).
            stalled = active & unblocked & ~progress
            stall_new = jnp.where(stalled, state["stall"] + 1, 0)
            expire = stalled & (stall_new >= state["rto"])
            backoff = expire
            blocked = state["blocked_until"]
            if has_death:
                i32 = i.astype(jnp.int32)
                if cfg.transport == "ndp":
                    # Trimming: loss detected in one trimmed-RTT, no
                    # timeout and no backoff (headers always arrive).
                    pen = jnp.int32(1)
                elif cfg.transport == "tcp":
                    # Full RTO stall + slow-start re-entry.
                    pen = state["rto"]
                    rate = jnp.where(hit, jnp.float32(cfg.tcp_init), rate)
                else:
                    # dctcp: a fraction of the RTO + gentle decrease.
                    pen = jnp.maximum(state["rto"] // 4, 1)
                    rate = jnp.where(
                        hit, jnp.maximum(state["rate"] * cfg.dctcp_md,
                                         cfg.tcp_init), rate)
                blocked = jnp.where(hit, i32 + pen, blocked)
                if cfg.transport != "ndp":
                    backoff = backoff | hit
            rto = _rto_next(state["rto"], progress, backoff,
                            int(cfg.rto_base), int(cfg.rto_cap))
            stall_out = jnp.where(expire, 0, stall_new)
            retrans = state["retrans_acc"] + (lost if has_death else 0.0)

        # --- flowlet elasticity + layer re-roll -----------------------------
        if reroute:
            slack = 1.0 - jnp.clip(sent, 0.0, 1.0)
            p_gap = jnp.clip(cfg.dt / cfg.flowlet_gap
                             * (slack + cfg.gap_eps), 0.0, 1.0)
            roll = u[:, 0] < p_gap
            if has_churn:
                # Re-convergence gating: a layer whose path crosses a
                # link inside its (down, up + conv) window is not
                # re-pickable this step — flows already placed on it
                # keep sending once capacity returns at `up`, but new
                # flowlet picks wait out the control-plane delay.  With
                # every candidate gated the flow keeps its layer (no
                # forced fallback onto a dead layer 0).
                pe_safe = jnp.where(arrs["path_edges"] >= 0,
                                    arrs["path_edges"], e_tot - 1)
                layer_live = ~jnp.any(link_unpick[pe_safe], axis=2).T  # (F, L)
                cand = arrs["usable"] & layer_live
                newpick = _pick_layers(u[:, 1], cand, minimal_only)
                roll = roll & cand.any(axis=1)
            else:
                newpick = _pick_layers(u[:, 1], arrs["usable"], minimal_only)
            layer = jnp.where(roll & active, newpick, state["layer"])
        else:
            layer = state["layer"]
        if recovery_on and reroute:
            # Blackhole escape: when the stall timer crosses the RTO the
            # flow DETERMINISTICALLY re-picks the next usable layer that
            # routes it — timeout-driven, independent of the stochastic
            # flowlet hazard, and consuming no PRNG draws (so the
            # hazard's (key, flow, step) stream is untouched).  Without
            # re-routing (ecmp) the layer stays pinned: the
            # never-recovers control.
            esc_layer, esc_valid = _escape_layers(
                state["layer"], esc_ok & layer_live if has_churn else esc_ok)
            layer = jnp.where(expire & esc_valid, esc_layer, layer)

        out = dict(remaining=new_remaining, layer=layer, rate=rate,
                   hops=hops, depart_step=depart, w_acc=state["w_acc"] + w)
        if recovery_on:
            out.update(
                sent_acc=state["sent_acc"] + sent
                - (lost if has_death else 0.0),
                stall=stall_out, rto=rto, blocked_until=blocked,
                retrans_acc=retrans)
        else:
            out["sent_acc"] = state["sent_acc"] + sent
        if record_on:
            # Per-step aggregates for the recovery evaluator's curves.
            # f32 device sums are fine HERE: the record lane only runs
            # on the sequential evaluator path (both engines execute
            # this same unpadded program), never in padded batches.
            ys = dict(
                goodput=jnp.sum(sent * w),
                stalled=jnp.sum((active & (sent <= 1e-6))
                                .astype(jnp.float32)))
        else:
            ys = None
        return out, ys

    # Record buffers ride the while-loop carry OUTSIDE the per-step scan
    # carry (they are written chunk-at-a-time via dynamic_update_slice).
    # bufs0 is None when record=0 — an empty pytree node, so the carry
    # structure (and the compiled program) is unchanged from pre-PR-8.
    if record_on:
        bufs0 = dict(goodput_t=jnp.zeros(n_steps, dtype=jnp.float32),
                     stalled_t=jnp.zeros(n_steps, dtype=jnp.float32))
    else:
        bufs0 = None

    def run_chunk(state, bufs, c, length: int):
        steps_i = c * chunk + jnp.arange(length)
        if reroute:
            # Full (chunk, F, 2) block even for the tail: a step's draws
            # must not depend on how many steps of its chunk execute.
            u = _chunk_uniforms(flow_keys, c, chunk)[:length]
            xs = (steps_i, u)
        else:
            xs = steps_i
        state, ys = jax.lax.scan(step, state, xs)
        if record_on:
            bufs = {
                "goodput_t": jax.lax.dynamic_update_slice(
                    bufs["goodput_t"], ys["goodput"], (c * chunk,)),
                "stalled_t": jax.lax.dynamic_update_slice(
                    bufs["stalled_t"], ys["stalled"], (c * chunk,)),
            }
        return state, bufs

    def exhausted(state):
        # Pending arrivals block early exit for free: a flow whose
        # active_at lies ahead still has remaining > 0, and it only
        # counts as stuck if NO pickable layer can ever route it — in
        # which case it would send nothing after activating either.
        routed_cur = arrs["routed"][state["layer"], frows]
        stuck = ~routed_cur & ~pick_routable
        return jnp.all((state["remaining"] <= 0.0) | stuck)

    # Adaptive horizon: fixed-size chunks under a while_loop.  Once every
    # flow is done or provably stuck, each further step is an exact no-op
    # on every result-bearing state component (remaining/fct/hops/accs;
    # weight-0 flows send nothing and accumulate nothing), so stopping
    # early is bit-identical to running all n_steps.  Only result-inert
    # components keep evolving full-horizon (a done tcp flow's rate ramp,
    # a stuck flow's layer re-rolls) — none of them feed SimResult.
    if n_full:
        def w_cond(carry):
            state, _bufs, c = carry
            go = c < n_full
            if cfg.adaptive_horizon:
                go = go & ~exhausted(state)
            return go

        def w_body(carry):
            state, bufs, c = carry
            state, bufs = run_chunk(state, bufs, c, chunk)
            return state, bufs, c + 1

        state, bufs, c_run = jax.lax.while_loop(w_cond, w_body,
                                                (init, bufs0, jnp.int32(0)))
    else:
        state, bufs, c_run = init, bufs0, jnp.int32(0)
    if rem:
        # The tail rides chunk index n_full unconditionally (running it
        # after an early exit is the same no-op as the skipped chunks).
        state, bufs = run_chunk(state, bufs, n_full, rem)
    # horizon_chunks is execution bookkeeping (how far the while_loop
    # ran), never a result: downstream result assembly ignores it and
    # the sweep engines report it as execution meta only.
    out = dict(state, horizon_chunks=c_run)
    if record_on:
        out.update(bufs)
    return out


_run_scan = functools.partial(jax.jit,
                              static_argnames=("cfg", "static"))(_run_scan_impl)


@functools.partial(jax.jit, static_argnames=("cfg", "static"))
def _run_scan_batch(arrs, keys, cfg: SimConfig,
                    static: Tuple[int, int, int]):
    """One vmapped scan over a batch of PRNG keys (seed sweep)."""
    return jax.vmap(lambda k: _run_scan_impl(arrs, k, cfg, static))(keys)


def _to_result(size: np.ndarray, final, cfg: SimConfig,
               start: Optional[np.ndarray] = None) -> SimResult:
    remaining = np.asarray(final["remaining"])
    # Flow-time-weighted achieved-rate fraction: total line-rate fraction
    # actually sent over total demanded.  Host-side float64 over the
    # (padding-stripped) per-flow accumulators — identical whether the
    # cell ran standalone or inside a padded batch.
    sent = float(np.asarray(final["sent_acc"], dtype=np.float64).sum())
    want = float(np.asarray(final["w_acc"], dtype=np.float64).sum())
    # FCT from the integer depart lane, on host with a FIXED numpy op
    # order (left-to-right, no FMA): completion time (the step after the
    # departing step's clock tick) minus start, plus propagation and
    # software latency over the path taken at completion.  Deriving this
    # from integer state is what makes dynamic cells' FCTs bit-identical
    # between the sequential and distributed engines — see the step
    # body's comment.
    dep = np.asarray(final["depart_step"])
    hops = np.asarray(final["hops"])
    f32 = np.float32
    start32 = (np.zeros(dep.shape, np.float32) if start is None
               else np.asarray(start, np.float32))
    fct = ((dep.astype(np.float32) + f32(1.0)) * f32(cfg.dt) - start32
           + hops * f32(cfg.link_latency) + f32(cfg.sw_latency))
    fct = np.where(dep >= 0, fct, np.float32(np.nan))
    # Recovery/record lanes are optional scan outputs (absent = None).
    line_bytes = f32(cfg.line_rate * cfg.dt)
    ret = final.get("retrans_acc")
    return SimResult(
        fct=fct,
        delivered=size - remaining,
        size=size,
        finished=remaining <= 0,
        link_util_mean=sent / max(want, 1.0),
        config=cfg,
        depart_step=dep,
        retrans_bytes=(None if ret is None
                       else np.asarray(ret) * line_bytes),
        goodput_steps=(None if "goodput_t" not in final
                       else np.asarray(final["goodput_t"])),
        stalled_steps=(None if "stalled_t" not in final
                       else np.asarray(final["stalled_t"])),
    )


def prepare(topo: Topology, routing: LayeredRouting, wl: FlowWorkload,
            cfg: SimConfig):
    """Public prepare step for external batch engines: returns
    ``(arrs, static)`` where ``arrs`` is the dict of scan operands and
    ``static = (e_tot, n_layers, n_steps)`` the static shape triple
    consumed by the scan program.  ``repro.experiments.dist_sweep`` pads
    and stacks many cells' ``arrs`` into one vmapped program."""
    arrs = _prepare(topo, routing, wl, cfg)
    static = (int(arrs["e_tot"]), int(arrs["n_layers"]), int(cfg.n_steps))
    jarrs = {k: v for k, v in arrs.items() if k not in ("e_tot", "n_layers")}
    return jarrs, static


def pad_prepared(arrs, static, *, n_flows: int, n_edges: int,
                 hop_slots: int):
    """Pad one cell's prepared scan operands to a bucket-wide shape so
    heterogeneous cells stack into one batched program, WITHOUT changing
    the simulation of the real flows.

    Exactness argument (each padding axis):

    * flows (F): padded flows have ``start=inf`` and ``active_at`` =
      INT32_MAX (never started, never activated), size 0,
      ``usable``/``routed`` False — their water-filling weight is 0.0, an
      exact no-op on every shared-link sum, and the per-flow randomness
      is ``fold_in``-keyed by flow index so real flows' draws are
      unchanged (:func:`_flow_uniforms`);
    * hop slots (H): pad columns are -1, which the scan maps to the trash
      link and excludes from every min/fair-share reduction;
    * virtual links (e_tot): extra slots have capacity 1 and no flow ever
      indexes them (edge ids are cell-local); only the trash slot moves,
      and it is write-only.

    The layer count L and step count are bucket keys, never padded —
    padding L would change layer-choice draws, padding steps would change
    the dynamics.
    """
    e_tot, n_layers, n_steps = static
    F, H = arrs["size"].shape[0], arrs["path_edges"].shape[2]
    if n_flows < F or n_edges < e_tot or hop_slots < H:
        raise ValueError(f"pad target ({n_flows},{n_edges},{hop_slots}) "
                         f"smaller than cell ({F},{e_tot},{H})")

    def padf(x, fill, axis):
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, n_flows - x.shape[axis])
        return jnp.pad(x, pads, constant_values=fill)

    pe = jnp.pad(arrs["path_edges"], ((0, 0), (0, 0), (0, hop_slots - H)),
                 constant_values=-1)
    out = dict(
        path_edges=padf(pe, -1, 1),
        routed=padf(arrs["routed"], False, 1),
        path_hops=padf(arrs["path_hops"], 0.0, 1),
        usable=padf(arrs["usable"], False, 0),
        size=padf(arrs["size"], 0.0, 0),
        start=padf(arrs["start"], jnp.inf, 0),
        active_at=padf(arrs["active_at"], np.iinfo(np.int32).max, 0),
    )
    if "link_down_step" in arrs:
        # Pad link slots with INT32_MAX (never die); no flow indexes
        # them, so the value only has to keep the select a no-op.
        out["link_down_step"] = jnp.pad(
            arrs["link_down_step"], (0, n_edges - e_tot),
            constant_values=np.iinfo(np.int32).max)
    if "link_churn" in arrs:
        # Churn events pad the same way: sentinel intervals never open,
        # so padded link slots are never dead nor pick-gated.  The event
        # axis K is a bucket key (padded_signature), never padded.
        imax = np.iinfo(np.int32).max
        out["link_churn"] = jnp.pad(
            arrs["link_churn"], ((0, n_edges - e_tot), (0, 0), (0, 0)),
            constant_values=imax)
        out["churn_pick_at"] = jnp.pad(
            arrs["churn_pick_at"], ((0, n_edges - e_tot), (0, 0)),
            constant_values=imax)
    return out, (int(n_edges), n_layers, n_steps)


def batch_result(size: np.ndarray, final, cfg: SimConfig,
                 n_flows: Optional[int] = None,
                 start: Optional[np.ndarray] = None) -> SimResult:
    """One element of a batched scan output -> :class:`SimResult`,
    stripping flow padding (``n_flows`` = the cell's real flow count).
    ``start`` is the cell's (unpadded) flow start times; omit for
    all-start-at-zero workloads."""
    per_flow = ("remaining", "layer", "rate", "hops",
                "sent_acc", "w_acc", "depart_step",
                # recovery lanes (present only when cfg.recovery is on)
                "stall", "rto", "blocked_until", "retrans_acc")
    if n_flows is not None:
        final = {k: (v[:n_flows] if k in per_flow else v)
                 for k, v in final.items()}
        size = size[:n_flows]
        if start is not None:
            start = np.asarray(start)[:n_flows]
    return _to_result(np.asarray(size), final, cfg, start=start)


def simulate(topo: Topology, routing: LayeredRouting, wl: FlowWorkload,
             cfg: SimConfig) -> SimResult:
    """Run the flow simulator; returns per-flow FCTs and aggregates."""
    jarrs, static = prepare(topo, routing, wl, cfg)
    # The PRNG key is a scan operand; cfg.seed is NOT read inside the
    # program, so normalize it out of the jit-static config — otherwise
    # every sweep seed recompiles a byte-identical scan.
    cfg0 = dataclasses.replace(cfg, seed=0)
    final = _run_scan(jarrs, jax.random.PRNGKey(cfg.seed), cfg0, static)
    return _to_result(np.asarray(jarrs["size"]), final, cfg,
                      start=np.asarray(jarrs["start"]))


def simulate_seeds(topo: Topology, routing: LayeredRouting, wl: FlowWorkload,
                   cfg: SimConfig, seeds) -> list:
    """Seed sweep batched through ONE vmapped scan (no Python loop over
    simulations): same topology/routing/workload, one PRNG stream per
    seed.  Returns a list of :class:`SimResult`, one per seed, identical
    to looping :func:`simulate` with ``cfg.seed`` set to each value."""
    seeds = [int(s) for s in seeds]
    if not seeds:
        return []
    jarrs, static = prepare(topo, routing, wl, cfg)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    # seed normalized out of the static config — see simulate().
    finals = _run_scan_batch(jarrs, keys, dataclasses.replace(cfg, seed=0),
                             static)
    size = np.asarray(jarrs["size"])
    start = np.asarray(jarrs["start"])
    return [
        _to_result(size, {k: v[i] for k, v in finals.items()},
                   dataclasses.replace(cfg, seed=s), start=start)
        for i, s in enumerate(seeds)
    ]
