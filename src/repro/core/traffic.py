"""Traffic patterns (paper §2.4) and flow workloads.

A pattern is a mapping from source endpoint ids to destination endpoint
ids over ``N`` endpoints.  Endpoint e lives on router ``e // p`` (uniform
concentration) or per-router offsets for non-uniform concentration.

Workloads add flow sizes and Poisson arrival times (paper §2.3).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .topology import Topology

__all__ = [
    "endpoint_router_map",
    "random_uniform",
    "random_permutation",
    "off_diagonal",
    "shuffle",
    "stencil2d",
    "all_to_one",
    "adversarial",
    "worst_case",
    "randomized_mapping",
    "FlowWorkload",
    "make_workload",
    "PATTERNS",
]


def endpoint_router_map(topo: Topology) -> np.ndarray:
    """(N,) router id of each endpoint."""
    return np.repeat(np.arange(topo.n_routers), topo.concentration)


# ---- §2.4 patterns: src endpoint id -> dst endpoint id ----------------------
def random_uniform(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    t = rng.integers(0, n, size=n)
    # avoid self-talk
    self_hit = t == np.arange(n)
    t[self_hit] = (t[self_hit] + 1) % n
    return t


def random_permutation(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    while True:
        t = rng.permutation(n)
        if not (t == np.arange(n)).any():
            return t
        # derangement retry is cheap; expected < e attempts


def off_diagonal(n: int, c: int = 1) -> np.ndarray:
    return (np.arange(n) + c) % n


def shuffle(n: int) -> np.ndarray:
    """Bit-rotation ("shuffle") pattern: t(s) = rotl_i(s), 2^i <= n < 2^(i+1)."""
    i = max(1, int(np.floor(np.log2(max(2, n)))))
    s = np.arange(n)
    rot = ((s << 1) | (s >> (i - 1))) & ((1 << i) - 1)
    return rot % n


def stencil2d(n: int, offsets: Tuple[int, ...] = (1, -1, 42, -42)) -> np.ndarray:
    """4-point stencil as four off-diagonals; returns (4, N) destinations
    (4x oversubscribed — each endpoint talks to four peers)."""
    return np.stack([(np.arange(n) + c) % n for c in offsets])


def all_to_one(n: int, seed: int = 0, acks: bool = False):
    """Many-to-one incast onto a seeded victim endpoint.

    ``acks=False`` (the PATTERNS-compatible default) returns the (n,)
    destination map: everyone sends to the victim (the victim itself
    sends to its neighbour so the map stays self-talk-free).

    ``acks=True`` returns ``(src, dst, is_ack)`` arrays: the data flows
    ``i -> victim`` for every ``i != victim`` PLUS the reverse ACK-path
    flows ``victim -> i`` — the TCP-outcast scenario, where the victim's
    ACK/response traffic shares the congested last hop in reverse and
    per-sender fairness collapses.  ``is_ack`` marks the reverse flows.
    """
    rng = np.random.default_rng(seed)
    tgt = int(rng.integers(n))
    if not acks:
        t = np.full(n, tgt)
        t[tgt] = (tgt + 1) % n
        return t
    senders = np.setdiff1d(np.arange(n), [tgt])
    src = np.concatenate([senders, np.full(len(senders), tgt)])
    dst = np.concatenate([np.full(len(senders), tgt), senders])
    is_ack = np.concatenate([np.zeros(len(senders), bool),
                             np.ones(len(senders), bool)])
    return src, dst, is_ack


def adversarial(n: int, seed: int = 0) -> np.ndarray:
    """Skewed off-diagonal with a large offset chosen to maximise colliding
    router pairs (§2.4.6): offset ~ N/2 + small prime jitter."""
    rng = np.random.default_rng(seed)
    c = n // 2 + int(rng.integers(1, 7)) * 13
    return (np.arange(n) + c) % n


def worst_case(topo: Topology, seed: int = 0,
               sample_cap: int = 4096) -> np.ndarray:
    """Jyothi et al. style worst-case: pair endpoints to maximise total
    path length via linear-sum assignment on router distances (§2.4.7)."""
    from scipy.optimize import linear_sum_assignment

    from . import paths as paths_mod
    import jax.numpy as jnp

    ep2r = endpoint_router_map(topo)
    n = len(ep2r)
    rng = np.random.default_rng(seed)
    if n > sample_cap:
        # Assignment on a subsample; remaining endpoints get the adversarial
        # off-diagonal (keeps O(n^3) Hungarian tractable).
        idx = rng.choice(n, size=sample_cap, replace=False)
    else:
        idx = np.arange(n)
    dist = np.asarray(paths_mod.shortest_path_lengths(jnp.asarray(topo.adj)))
    d = dist[np.ix_(ep2r[idx], ep2r[idx])].astype(np.float64)
    np.fill_diagonal(d, -1e6)  # forbid self-pairing
    rows, cols = linear_sum_assignment(-d)  # maximise distance
    t = adversarial(n, seed)
    t[idx[rows]] = idx[cols]
    self_hit = t == np.arange(n)
    t[self_hit] = (t[self_hit] + 1) % n
    return t


def randomized_mapping(t: np.ndarray, seed: int = 0) -> np.ndarray:
    """Randomised workload mapping (§3.4): relabel endpoints u.a.r. so
    logical neighbours land on random routers."""
    rng = np.random.default_rng(seed)
    n = len(t)
    relabel = rng.permutation(n)
    out = np.empty(n, dtype=t.dtype)
    out[relabel] = relabel[t]
    return out


PATTERNS = {
    "uniform": random_uniform,
    "permutation": random_permutation,
    "offdiag": off_diagonal,
    "shuffle": shuffle,
    "alltoone": all_to_one,
    "adversarial": adversarial,
}


# ---- Flow workloads ----------------------------------------------------------
@dataclasses.dataclass
class FlowWorkload:
    """A set of flows over endpoints: arrays indexed by flow id.

    ``active_step``/``is_ack`` are the open-loop dynamic-traffic lanes
    (PR 6): when ``active_step`` is set, flow ``i`` only participates in
    the transport scan from step ``active_step[i]`` on (arrivals built by
    :mod:`repro.core.arrivals`); ``None`` keeps the closed-loop batch
    semantics (everyone active from step 0).  ``is_ack`` marks reverse
    ACK-path flows (see :func:`all_to_one` with ``acks=True``) so
    evaluators can separate data goodput from ACK traffic.
    """

    src: np.ndarray         # (F,) endpoint ids
    dst: np.ndarray         # (F,) endpoint ids
    size: np.ndarray        # (F,) bytes
    start: np.ndarray       # (F,) seconds
    src_router: np.ndarray  # (F,)
    dst_router: np.ndarray  # (F,)
    active_step: Optional[np.ndarray] = None  # (F,) int32 activation steps
    is_ack: Optional[np.ndarray] = None       # (F,) bool reverse-ACK marker

    @property
    def n_flows(self) -> int:
        return len(self.src)


def make_workload(topo: Topology, pattern: str = "permutation",
                  flow_size: float = 1 << 20, n_rounds: int = 1,
                  arrival_rate: float = 0.0, randomize: bool = True,
                  seed: int = 0, frac_endpoints: float = 1.0,
                  size_spread: float = 0.0, acks: bool = False,
                  ack_frac: float = 0.05) -> FlowWorkload:
    """Build a flow workload from a named pattern.

    Args:
      pattern: key of PATTERNS, or ``stencil`` / ``worstcase``.
      flow_size: mean flow size in bytes (a flow == a message, §7.1.4).
      n_rounds: independent pattern instances (e.g. 4 permutations in
        parallel => 4x oversubscription as in Fig 4).
      arrival_rate: flows per endpoint per second for Poisson starts
        (0 => all flows start at t=0).
      randomize: apply §3.4 randomised endpoint mapping.
      frac_endpoints: fraction of communicating endpoints (§7.1.10).
      size_spread: lognormal sigma for flow sizes (0 => fixed size).
      acks: ``alltoone`` only — also emit the victim's reverse ACK-path
        flows (TCP-outcast scenario); marked in ``is_ack`` and sized at
        ``ack_frac * flow_size``.
      ack_frac: ACK flow size as a fraction of ``flow_size``.
    """
    rng = np.random.default_rng(seed)
    ep2r = endpoint_router_map(topo)
    n = len(ep2r)
    srcs, dsts, ack_rows = [], [], []
    for r in range(n_rounds):
        if pattern == "stencil":
            st = stencil2d(n, offsets=(1, -1, 42 if n <= 10_000 else 1337,
                                       -(42 if n <= 10_000 else 1337)))
            for row in st:
                srcs.append(np.arange(n))
                dsts.append(row)
                ack_rows.append(np.zeros(n, dtype=bool))
            continue
        if pattern == "alltoone" and acks:
            s, d, a = all_to_one(n, seed=seed + r, acks=True)
            if randomize:
                relabel = np.random.default_rng(seed + 101 + r).permutation(n)
                s, d = relabel[s], relabel[d]
            srcs.append(s)
            dsts.append(d)
            ack_rows.append(a)
            continue
        if pattern == "worstcase":
            t = worst_case(topo, seed=seed + r)
        else:
            fn = PATTERNS[pattern]
            if pattern in ("uniform", "permutation", "alltoone", "adversarial"):
                t = fn(n, seed=seed + r)
            elif pattern == "offdiag":
                t = fn(n, c=1 + r)
            else:
                t = fn(n)
        if randomize:
            t = randomized_mapping(t, seed=seed + 101 + r)
        srcs.append(np.arange(n))
        dsts.append(t)
        ack_rows.append(np.zeros(n, dtype=bool))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    is_ack = np.concatenate(ack_rows)
    keep = src != dst
    src, dst, is_ack = src[keep], dst[keep], is_ack[keep]
    if frac_endpoints < 1.0:
        mask = rng.random(len(src)) < frac_endpoints
        src, dst, is_ack = src[mask], dst[mask], is_ack[mask]
    f = len(src)
    if size_spread > 0:
        size = flow_size * rng.lognormal(0.0, size_spread, size=f)
    else:
        size = np.full(f, float(flow_size))
    if is_ack.any():
        size = np.where(is_ack, size * float(ack_frac), size)
    if arrival_rate > 0:
        start = rng.exponential(1.0 / arrival_rate, size=f).cumsum()
        start = start * (f / max(start[-1], 1e-9)) / arrival_rate / f  # window
        start = rng.uniform(0, f / (arrival_rate * n), size=f)
    else:
        start = np.zeros(f)
    return FlowWorkload(
        src=src.astype(np.int32), dst=dst.astype(np.int32),
        size=size.astype(np.float64), start=start.astype(np.float64),
        src_router=ep2r[src].astype(np.int32),
        dst_router=ep2r[dst].astype(np.int32),
        is_ack=is_ack if is_ack.any() else None,
    )
