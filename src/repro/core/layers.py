"""FatPaths layered routing (paper §5.2–§5.4).

A *layer* is a subset of links with its own shortest-path forwarding
function sigma_i.  Layer 0 always contains every link (minimal paths);
layers 1..n-1 are rho-sparsified and oriented into DAGs by random vertex
permutations (Listing 1), so their "shortest paths" are non-minimal paths
of the full network — the "fat" path diversity.

Construction schemes (§5.3):
  * ``rand``    — Listing 1 verbatim: keep directed edge (u, v) with
                  pi(u) < pi(v) and probability rho.
  * ``pi_min``  — overlap-minimising variant (§5.3.2): edge inclusion
                  probability is biased *against* edges already heavily used
                  by the shortest paths of previously built layers.
  * ``undir``   — ablation: sparsify without DAG orientation (layer graphs
                  stay undirected; forwarding remains loop-free because it
                  follows intra-layer shortest paths).
  * ``spain``   — SPAIN adaptation: each layer is a BFS spanning tree from a
                  random root (tree paths, resilience-style multipathing).
  * ``past``    — PAST adaptation: per-layer re-randomised shortest-path
                  trees on the full graph (one address-tree per layer).
  * ``ksp``     — k-shortest-paths adaptation: per-layer randomly perturbed
                  edge weights spread traffic over near-minimal paths.

Forwarding is destination-based: ``nh[i, s, t]`` = next hop at router s for
a packet tagged layer i, destination t.  Unreachable (layer, s, t) entries
are -1; the load balancer (transport sim) only assigns flowlets to layers
whose reach mask is set, and falls back to layer 0 otherwise (§C.3).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from . import paths as paths_mod
from .topology import Topology

__all__ = ["LayeredRouting", "build_layers", "layer_disjoint_paths"]

_UNREACH = 10_000


@dataclasses.dataclass
class LayeredRouting:
    """Stacked forwarding state for n layers over one topology."""

    topo: Topology
    scheme: str
    rho: float
    nh: np.ndarray          # (L, N, N) int32 next hop, -1 unreachable
    reach: np.ndarray       # (L, N, N) bool
    pathlen: np.ndarray     # (L, N, N) int16 intra-layer shortest-path length
    layer_adj: np.ndarray   # (L, N, N) bool directed layer adjacency

    @property
    def n_layers(self) -> int:
        return int(self.nh.shape[0])

    def usable_layers(self, s: int, t: int) -> np.ndarray:
        return np.nonzero(self.reach[:, s, t])[0]

    def validate_loop_free(self, n_samples: int = 200, seed: int = 0,
                           max_hops: int = 64) -> None:
        """Walk the tables for random (layer, s, t); every reachable entry
        must hit t within max_hops (shortest-path forwarding => loop-free)."""
        rng = np.random.default_rng(seed)
        L, N, _ = self.nh.shape
        for _ in range(n_samples):
            i = rng.integers(L)
            s, t = rng.choice(N, size=2, replace=False)
            if not self.reach[i, s, t]:
                continue
            cur, hops = s, 0
            while cur != t:
                nxt = self.nh[i, cur, t]
                assert nxt >= 0, f"hole in layer {i} at ({cur}->{t})"
                cur = int(nxt)
                hops += 1
                assert hops <= max_hops, f"loop in layer {i} ({s}->{t})"


def _forwarding_from_dist(adj_dir: np.ndarray, dist: np.ndarray,
                          seed: int, chunk: int = 64) -> np.ndarray:
    """Vectorised single-next-hop table for a (possibly directed) graph."""
    n = adj_dir.shape[0]
    rng = np.random.default_rng(seed)
    nh = np.full((n, n), -1, dtype=np.int32)
    for s0 in range(0, n, chunk):
        s1 = min(n, s0 + chunk)
        # ok[s, u, t]: edge s->u exists and dist[u, t] == dist[s, t] - 1
        ok = adj_dir[s0:s1, :, None] & (dist[None, :, :] == dist[s0:s1, None, :] - 1)
        score = np.where(ok, rng.random(ok.shape, dtype=np.float32), -1.0)
        best = score.argmax(axis=1).astype(np.int32)      # (chunk, t)
        has = ok.any(axis=1)
        nh[s0:s1] = np.where(has, best, -1)
    idx = np.arange(n)
    nh[idx, idx] = idx
    return nh


def _layer_tables(adj_dir: np.ndarray, seed: int, max_len: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    dist = np.asarray(
        paths_mod.shortest_path_lengths(jnp.asarray(adj_dir), max_l=max_len))
    reach = dist <= max_len
    nh = _forwarding_from_dist(adj_dir, dist, seed)
    pathlen = np.where(reach, dist, _UNREACH).astype(np.int16)
    return nh, reach, pathlen


def _rand_layer(adj: np.ndarray, rho: float, rng: np.random.Generator,
                oriented: bool = True) -> np.ndarray:
    """One Listing-1 layer: directed DAG (or undirected if not oriented)."""
    n = adj.shape[0]
    pi = rng.permutation(n)
    iu, ju = np.nonzero(np.triu(adj, 1))
    keep = rng.random(len(iu)) < rho
    out = np.zeros((n, n), dtype=bool)
    u, v = iu[keep], ju[keep]
    if oriented:
        fwd = pi[u] < pi[v]
        uu = np.where(fwd, u, v)
        vv = np.where(fwd, v, u)
        out[uu, vv] = True
    else:
        out[u, v] = True
        out[v, u] = True
    return out


def _edge_usage(nh: np.ndarray, reach: np.ndarray, max_hops: int) -> np.ndarray:
    """Count how many (s, t) pairs route over each directed edge."""
    n = nh.shape[0]
    s_idx, t_idx = np.nonzero(reach & ~np.eye(n, dtype=bool))
    usage = np.zeros((n, n), dtype=np.int64)
    cur = s_idx.astype(np.int64).copy()
    tgt = t_idx.astype(np.int64)
    for _ in range(max_hops):
        active = cur != tgt
        if not active.any():
            break
        nxt = nh[cur[active], tgt[active]].astype(np.int64)
        good = nxt >= 0
        np.add.at(usage, (cur[active][good], nxt[good]), 1)
        new_cur = cur.copy()
        upd = np.where(good, nxt, tgt[active])
        new_cur[np.nonzero(active)[0]] = upd
        cur = new_cur
    return usage


def build_layers(topo: Topology, n_layers: int, rho: float,
                 scheme: str = "rand", seed: int = 0,
                 max_len: Optional[int] = None) -> LayeredRouting:
    """Construct the FatPaths layer stack (layer 0 = all links, minimal)."""
    adj = np.asarray(topo.adj, dtype=bool)
    n = adj.shape[0]
    if max_len is None:
        # Allow "almost minimal" detours: nominal diameter + slack.
        max_len = max(6, topo.diameter_nominal + 4)
    rng = np.random.default_rng(seed)

    layer_adjs: List[np.ndarray] = [adj.copy()]
    if scheme in ("rand", "undir"):
        for _ in range(n_layers - 1):
            layer_adjs.append(_rand_layer(adj, rho, rng, oriented=(scheme == "rand")))
    elif scheme == "pi_min":
        # Build sequentially; bias sampling against accumulated edge usage.
        usage = np.zeros((n, n), dtype=np.float64)
        # Seed usage with the minimal-path layer's load.
        nh0, reach0, _ = _layer_tables(adj, seed, max_len)
        usage += _edge_usage(nh0, reach0, max_hops=max_len)
        for li in range(n_layers - 1):
            u_sym = usage + usage.T
            if u_sym.max() > 0:
                norm = u_sym / u_sym.max()
            else:
                norm = u_sym
            pi = rng.permutation(n)
            iu, ju = np.nonzero(np.triu(adj, 1))
            # Edge keep-probability shrinks with historical usage but keeps
            # expected density ~= rho.
            raw = 1.0 - 0.75 * norm[iu, ju]
            prob = raw * (rho * len(iu) / max(raw.sum(), 1e-9))
            keep = rng.random(len(iu)) < np.clip(prob, 0.0, 1.0)
            la = np.zeros((n, n), dtype=bool)
            u, v = iu[keep], ju[keep]
            fwd = pi[u] < pi[v]
            uu = np.where(fwd, u, v)
            vv = np.where(fwd, v, u)
            la[uu, vv] = True
            layer_adjs.append(la)
            nh_i, reach_i, _ = _layer_tables(la, seed + 100 + li, max_len)
            usage += _edge_usage(nh_i, reach_i, max_hops=max_len)
    elif scheme == "spain":
        for li in range(n_layers - 1):
            root = int(rng.integers(n))
            tree = _bfs_tree(adj, root, rng)
            layer_adjs.append(tree)
    elif scheme == "past":
        for li in range(n_layers - 1):
            layer_adjs.append(adj.copy())  # re-randomised tie-breaks below
    elif scheme == "ksp":
        for li in range(n_layers - 1):
            layer_adjs.append(adj.copy())  # perturbed weights below
    else:
        raise ValueError(f"unknown scheme {scheme!r}")

    nhs, reaches, plens = [], [], []
    for i, la in enumerate(layer_adjs):
        if scheme == "ksp" and i > 0:
            nh, reach, plen = _ksp_tables(adj, seed + 17 * i, max_len, rng)
        else:
            nh, reach, plen = _layer_tables(la, seed + 17 * i, max_len)
        nhs.append(nh)
        reaches.append(reach)
        plens.append(plen)

    return LayeredRouting(
        topo=topo, scheme=scheme, rho=rho,
        nh=np.stack(nhs), reach=np.stack(reaches),
        pathlen=np.stack(plens), layer_adj=np.stack(layer_adjs),
    )


def _bfs_tree(adj: np.ndarray, root: int, rng: np.random.Generator) -> np.ndarray:
    """Random-order BFS spanning tree (undirected layer)."""
    n = adj.shape[0]
    tree = np.zeros((n, n), dtype=bool)
    seen = np.zeros(n, dtype=bool)
    seen[root] = True
    frontier = [root]
    while frontier:
        nxt: List[int] = []
        order = rng.permutation(len(frontier))
        for fi in order:
            v = frontier[fi]
            nbrs = np.nonzero(adj[v] & ~seen)[0]
            rng.shuffle(nbrs)
            for u in nbrs:
                if not seen[u]:
                    seen[u] = True
                    tree[v, u] = tree[u, v] = True
                    nxt.append(int(u))
        frontier = nxt
    return tree


def _ksp_tables(adj: np.ndarray, seed: int, max_len: int,
                rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """k-shortest-paths-style layer: randomly perturbed edge weights spread
    traffic over *near-minimal* paths.  Weighted shortest paths via repeated
    (min, +) relaxation (Bellman-Ford on the weight matrix)."""
    n = adj.shape[0]
    w = np.where(adj, 1.0 + 0.25 * rng.random((n, n)), np.inf)
    w = np.minimum(w, w.T)
    np.fill_diagonal(w, 0.0)
    dist = w.copy()
    for _ in range(max_len):
        # (min,+) product, chunked to bound memory.
        new = dist.copy()
        for s0 in range(0, n, 128):
            s1 = min(n, s0 + 128)
            new[s0:s1] = np.minimum(
                new[s0:s1], (dist[s0:s1, :, None] + w[None, :, :]).min(axis=1))
        if np.allclose(new, dist):
            break
        dist = new
    hop = np.asarray(paths_mod.shortest_path_lengths(jnp.asarray(adj), max_l=max_len))
    reach = hop <= max_len
    # next hop: neighbor minimising w[s,u] + dist[u,t], random tie-break.
    nh = np.full((n, n), -1, dtype=np.int32)
    for s in range(n):
        cost = w[s][:, None] + dist  # (u, t)
        cost[~adj[s]] = np.inf
        best = cost.argmin(axis=0).astype(np.int32)
        nh[s] = np.where(np.isfinite(cost.min(axis=0)), best, -1)
    idx = np.arange(n)
    nh[idx, idx] = idx
    plen = np.where(reach, hop, _UNREACH).astype(np.int16)
    return nh, reach, plen


def layer_disjoint_paths(lr: LayeredRouting, s: int, t: int,
                         max_hops: int = 16) -> int:
    """How many pairwise edge-disjoint (s->t) paths do the layers realise?

    Greedy: walk each usable layer's path, keep it if it shares no
    (undirected) edge with already-kept paths.  This is the quantity behind
    the paper's "nine layers suffice for three disjoint paths" (Fig 12).
    """
    kept_edges = set()
    count = 0
    for i in range(lr.n_layers):
        if not lr.reach[i, s, t]:
            continue
        path = paths_mod.walk_paths(lr.nh[i], np.array([s]), np.array([t]),
                                    max_hops)[0]
        edges = set()
        ok = True
        reached = False
        prev = int(path[0])
        for v in path[1:]:
            v = int(v)
            if prev == t:
                reached = True
                break
            if v < 0:
                ok = False
                break
            e = (min(prev, v), max(prev, v))
            if e in kept_edges or e in edges:
                ok = False
                break
            edges.add(e)
            prev = v
        if prev == t:
            reached = True
        if ok and reached and edges:
            kept_edges |= edges
            count += 1
    return count
