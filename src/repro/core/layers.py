"""FatPaths layered routing (paper §5.2–§5.4).

A *layer* is a subset of links with its own shortest-path forwarding
function sigma_i.  Layer 0 always contains every link (minimal paths);
layers 1..n-1 are rho-sparsified and oriented into DAGs by random vertex
permutations (Listing 1), so their "shortest paths" are non-minimal paths
of the full network — the "fat" path diversity.

Construction schemes (§5.3):
  * ``rand``    — Listing 1 verbatim: keep directed edge (u, v) with
                  pi(u) < pi(v) and probability rho.
  * ``pi_min``  — overlap-minimising variant (§5.3.2): edge inclusion
                  probability is biased *against* edges already heavily used
                  by the shortest paths of previously built layers.
  * ``undir``   — ablation: sparsify without DAG orientation (layer graphs
                  stay undirected; forwarding remains loop-free because it
                  follows intra-layer shortest paths).
  * ``spain``   — SPAIN adaptation: each layer is a BFS spanning tree from a
                  random root (tree paths, resilience-style multipathing).
  * ``past``    — PAST adaptation: per-layer re-randomised shortest-path
                  trees on the full graph (one address-tree per layer).
  * ``ksp``     — k-shortest-paths adaptation: per-layer randomly perturbed
                  edge weights spread traffic over near-minimal paths.

Forwarding is destination-based: ``nh[i, s, t]`` = next hop at router s for
a packet tagged layer i, destination t.  Unreachable (layer, s, t) entries
are -1; the load balancer (transport sim) only assigns flowlets to layers
whose reach mask is set, and falls back to layer 0 otherwise (§C.3).

Table construction is BATCHED: whatever the scheme, every layer's APSP +
forwarding tables come out of ONE jitted device program built on the
semiring engine (:mod:`repro.core.paths`, :mod:`repro.kernels.semiring`).
The host only samples layer adjacencies (cheap, O(E) per layer) — and for
``pi_min``/``ksp`` even that runs on device, because their sampling is
coupled to previously built tables (usage bias) or to perturbed-weight
(min, +) distances.  Tie-breaks use per-stack PRNG keys; the choice among
equal-cost next hops is uniform, distribution-identical to the historical
host-side ``rng.random`` scoring.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import paths as paths_mod
from .topology import Topology

__all__ = ["LayeredRouting", "LoopCheckReport", "build_layers",
           "layer_disjoint_paths", "layer_disjoint_paths_batch"]

_UNREACH = 10_000


@dataclasses.dataclass(frozen=True)
class LoopCheckReport:
    """Outcome of :meth:`LayeredRouting.validate_loop_free`.

    Truthy iff every checked entry delivered.  ``witnesses`` holds the
    offending ``(layer, src, dst)`` triples (capped), each tagged in
    ``kinds`` as ``"hole"`` (walk fell off the table) or ``"loop"``
    (walk never reached dst within the hop budget).
    """

    ok: bool
    n_checked: int
    exhaustive: bool
    witnesses: Tuple[Tuple[int, int, int], ...] = ()
    kinds: Tuple[str, ...] = ()

    def __bool__(self) -> bool:
        return self.ok

    def describe(self) -> str:
        if self.ok:
            mode = "exhaustive" if self.exhaustive else "sampled"
            return f"loop-free ({self.n_checked} entries, {mode})"
        shown = ", ".join(f"{k}@(l={li},s={s},t={t})" for (li, s, t), k
                          in zip(self.witnesses, self.kinds))
        return (f"{len(self.witnesses)} bad forwarding entr"
                f"{'y' if len(self.witnesses) == 1 else 'ies'} "
                f"of {self.n_checked} checked: {shown}")


@dataclasses.dataclass
class LayeredRouting:
    """Stacked forwarding state for n layers over one topology."""

    topo: Topology
    scheme: str
    rho: float
    nh: np.ndarray          # (L, N, N) int32 next hop, -1 unreachable
    reach: np.ndarray       # (L, N, N) bool
    pathlen: np.ndarray     # (L, N, N) int16 intra-layer shortest-path length
    layer_adj: np.ndarray   # (L, N, N) bool directed layer adjacency
    build_stats: Optional[Dict[str, float]] = None  # wall-time split
    # Per-directed-link death step for mid-run failures ((N, N) int32,
    # INT32_MAX = never dies); None = pristine fabric.  Set by the
    # fault-injection engine (repro.core.failures.link_down_schedule).
    link_down_step: Optional[np.ndarray] = None
    # Mid-run churn schedule: per-directed-link sorted (down, up) step
    # intervals ((N, N, K, 2) int32, INT32_MAX = never; see
    # repro.core.failures.churn_schedule).  Capacity restores at up;
    # flowlets may re-pick the link only at up + churn_conv steps
    # (control-plane re-convergence delay).  None = no churn.
    link_churn: Optional[np.ndarray] = None
    churn_conv: int = 0
    # Compressed per-router (dst-block, next-hop set) tables — attached
    # when the stack was built with representation="compressed" (the
    # blocked engine's default).  Exactly reconstructs ``nh``; the
    # transport walk and the batched disjoint-path walk prefer it.
    compressed: Optional[paths_mod.CompressedTables] = None

    @property
    def n_layers(self) -> int:
        return int(self.nh.shape[0])

    def usable_layers(self, s: int, t: int) -> np.ndarray:
        return np.nonzero(self.reach[:, s, t])[0]

    def validate_loop_free(self, n_samples: int = 200, seed: int = 0,
                           max_hops: int = 64, raise_on_fail: bool = True,
                           max_witnesses: int = 16) -> LoopCheckReport:
        """Walk the tables for (layer, s, t) entries; every reachable
        entry must hit t within max_hops (shortest-path forwarding =>
        loop-free).  All samples walk in ONE batched table walk.

        When ``n_samples`` covers the whole ``L * N * (N - 1)`` entry
        space the check enumerates EVERY entry instead of sampling with
        replacement (sampling could silently miss entries while
        appearing thorough).  Returns a :class:`LoopCheckReport` naming
        the offending ``(layer, src, dst)`` witnesses (capped at
        ``max_witnesses``); with ``raise_on_fail`` (the default) a bad
        table raises ``AssertionError`` carrying the same witnesses.
        """
        L, N, _ = self.nh.shape
        total = L * N * (N - 1)
        exhaustive = n_samples >= total
        if exhaustive:
            li, s, t = np.nonzero(~np.eye(N, dtype=bool)[None]
                                  & np.ones((L, N, N), dtype=bool))
        else:
            rng = np.random.default_rng(seed)
            li = rng.integers(L, size=n_samples)
            s = rng.integers(N, size=n_samples)
            t = (s + 1 + rng.integers(N - 1, size=n_samples)) % N  # t != s
        keep = self.reach[li, s, t]
        li, s, t = li[keep], s[keep], t[keep]
        if len(li) == 0:
            return LoopCheckReport(ok=True, n_checked=0,
                                   exhaustive=exhaustive)
        seqs = paths_mod.walk_paths_layers(self.nh, li, s, t, max_hops)
        holes = (seqs < 0).any(axis=1)
        stuck = ~holes & (seqs[:, -1] != t)
        bad = holes | stuck
        witnesses = []
        kinds = []
        for i in np.nonzero(bad)[0][:max_witnesses]:
            witnesses.append((int(li[i]), int(s[i]), int(t[i])))
            kinds.append("hole" if holes[i] else "loop")
        report = LoopCheckReport(ok=not bad.any(), n_checked=int(len(li)),
                                 exhaustive=exhaustive,
                                 witnesses=tuple(witnesses),
                                 kinds=tuple(kinds))
        if raise_on_fail:
            assert report.ok, report.describe()
        return report


def _rand_layer(adj: np.ndarray, rho: float, rng: np.random.Generator,
                oriented: bool = True) -> np.ndarray:
    """One Listing-1 layer: directed DAG (or undirected if not oriented)."""
    n = adj.shape[0]
    pi = rng.permutation(n)
    iu, ju = np.nonzero(np.triu(adj, 1))
    keep = rng.random(len(iu)) < rho
    out = np.zeros((n, n), dtype=bool)
    u, v = iu[keep], ju[keep]
    if oriented:
        fwd = pi[u] < pi[v]
        uu = np.where(fwd, u, v)
        vv = np.where(fwd, v, u)
        out[uu, vv] = True
    else:
        out[u, v] = True
        out[v, u] = True
    return out


# -----------------------------------------------------------------------------
# Single-program builders for the schemes whose sampling depends on
# previously built tables (pi_min) or on weighted semiring distances (ksp).
# -----------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_layers", "max_l", "engine"))
def _pi_min_program(adj, nbr, iu, ju, key, n_layers, rho, max_l,
                    engine="dense"):
    """The whole §5.3.2 build as one device program: a scan over layers
    that samples each DAG biased against accumulated edge usage, builds
    its tables, and folds the counting-semiring usage fixpoint back into
    the next layer's sampling."""
    n = adj.shape[0]
    e = iu.shape[0]
    k0, krest = jax.random.split(key)
    nh0, reach0, dist0 = paths_mod._layer_tables_core(adj[None], nbr, k0,
                                                      max_l, engine)
    usage0 = paths_mod._edge_usage_core(nh0[0], reach0[0], max_l)

    def step(usage, k):
        k_pi, k_keep, k_fw = jax.random.split(k, 3)
        u_sym = usage + usage.T
        mx = u_sym.max()
        norm = jnp.where(mx > 0, u_sym / jnp.maximum(mx, 1e-30), 0.0)
        pi = jax.random.permutation(k_pi, n)
        # Edge keep-probability shrinks with historical usage but keeps
        # expected density ~= rho.
        raw = 1.0 - 0.75 * norm[iu, ju]
        prob = raw * (rho * e / jnp.maximum(raw.sum(), 1e-9))
        keep = jax.random.uniform(k_keep, (e,)) < jnp.clip(prob, 0.0, 1.0)
        fwd = pi[iu] < pi[ju]
        uu = jnp.where(fwd, iu, ju)
        vv = jnp.where(fwd, ju, iu)
        la = jnp.zeros((n, n), dtype=bool).at[uu, vv].set(keep)
        nh, reach, dist = paths_mod._layer_tables_core(la[None], nbr, k_fw,
                                                       max_l, engine)
        usage = usage + paths_mod._edge_usage_core(nh[0], reach[0], max_l)
        return usage, (la, nh[0], reach[0], dist[0])

    if n_layers > 1:
        keys = jax.random.split(krest, n_layers - 1)
        _, (las, nhs, reaches, dists) = jax.lax.scan(step, usage0, keys)
        la_all = jnp.concatenate([adj[None], las])
        nh_all = jnp.concatenate([nh0, nhs])
        reach_all = jnp.concatenate([reach0, reaches])
        dist_all = jnp.concatenate([dist0, dists])
    else:
        la_all, nh_all, reach_all, dist_all = adj[None], nh0, reach0, dist0
    return la_all, nh_all, reach_all, dist_all


@functools.partial(jax.jit, static_argnames=("n_layers", "max_l", "engine"))
def _ksp_program(adj, nbr, key, n_layers, max_l, engine="dense"):
    """k-shortest-paths-style layers in one program: per-layer perturbed
    edge weights, (min, +) semiring all-pairs distances, and next hops
    minimising ``w[s, u] + D[u, t]`` over neighbors u."""
    n = adj.shape[0]
    idx = jnp.arange(n)
    k0, kw = jax.random.split(key)
    nh0, reach0, dist0 = paths_mod._layer_tables_core(adj[None], nbr, k0,
                                                      max_l, engine)
    hop = dist0[0]
    kk = n_layers - 1
    u01 = jax.random.uniform(kw, (kk, n, n))
    w = jnp.where(adj[None], 1.0 + 0.25 * u01, jnp.inf)
    w = jnp.minimum(w, jnp.transpose(w, (0, 2, 1)))
    w = w.at[:, idx, idx].set(0.0)
    d = paths_mod._minplus_apsp_core(w, max_l)

    has_edge = jnp.take_along_axis(adj, nbr, axis=1)          # (N, D)
    rows = idx[:, None]

    def one_layer(args):
        w_l, d_l = args
        w_nbr = jnp.take_along_axis(w_l, nbr, axis=1)         # (N, D)
        cost = jnp.where(has_edge[:, :, None],
                         w_nbr[:, :, None] + d_l[nbr], jnp.inf)
        j = jnp.argmin(cost, axis=1)                          # (N, N)
        best = nbr[rows, j].astype(jnp.int32)
        nh = jnp.where(jnp.isfinite(cost.min(axis=1)), best, -1)
        return nh.at[idx, idx].set(idx)

    def one_layer_blocked(args):
        # Destination-chunked twin of one_layer: the (N, D, N) cost cube
        # becomes (N, D, _CHUNK) slabs; per-column argmin is identical.
        w_l, d_l = args
        ch = paths_mod._CHUNK
        nc = -(-n // ch)
        npad = nc * ch
        w_nbr = jnp.take_along_axis(w_l, nbr, axis=1)         # (N, D)
        d_p = jnp.full((n, npad), jnp.inf).at[:, :n].set(d_l)
        d_cs = jnp.moveaxis(d_p.reshape(n, nc, ch), 1, 0)     # (nc, N, C)

        def one_chunk(d_c):
            cost = jnp.where(has_edge[:, :, None],
                             w_nbr[:, :, None] + d_c[nbr], jnp.inf)
            j = jnp.argmin(cost, axis=1)                      # (N, C)
            best = nbr[rows, j].astype(jnp.int32)
            return jnp.where(jnp.isfinite(cost.min(axis=1)), best, -1)

        out = jax.lax.map(one_chunk, d_cs)                    # (nc, N, C)
        nh = jnp.moveaxis(out, 0, 1).reshape(n, npad)[:, :n]
        return nh.at[idx, idx].set(idx)

    layer_fn = one_layer_blocked if engine == "blocked" else one_layer
    nh_extra = jax.lax.map(layer_fn, (w, d))
    nh_all = jnp.concatenate([nh0, nh_extra])
    reach_all = jnp.broadcast_to((hop <= max_l)[None], (n_layers, n, n))
    dist_all = jnp.broadcast_to(hop[None], (n_layers, n, n))
    la_all = jnp.broadcast_to(adj[None], (n_layers, n, n))
    return la_all, nh_all, reach_all, dist_all


def build_layers(topo: Topology, n_layers: int, rho: float,
                 scheme: str = "rand", seed: int = 0,
                 max_len: Optional[int] = None,
                 engine: Optional[str] = None,
                 representation: Optional[str] = None) -> LayeredRouting:
    """Construct the FatPaths layer stack (layer 0 = all links, minimal).

    All L layers' tables come from ONE batched device program; there is
    no per-layer host loop for table construction.  ``build_stats`` on
    the result records the host (adjacency sampling) vs device (semiring
    table construction) wall-time split.

    ``engine`` overrides the ``REPRO_PATH_ENGINE`` resolution (``dense``
    below 512 routers, ``blocked`` — frontier APSP + chunked forwarding
    — above; both bit-identical).  ``representation`` picks the table
    form: ``"compressed"`` attaches :class:`~repro.core.paths
    .CompressedTables` to the result (the default whenever the engine
    resolves blocked), ``"dense"`` keeps plain arrays only.
    """
    adj = np.asarray(topo.adj, dtype=bool)
    n = adj.shape[0]
    eng = paths_mod.path_engine(n, engine)
    if representation in (None, "", "auto"):
        rep = "compressed" if eng == "blocked" else "dense"
    elif representation in ("dense", "compressed"):
        rep = representation
    else:
        raise ValueError(f"unknown representation {representation!r}")
    if max_len is None:
        # Allow "almost minimal" detours: nominal diameter + slack.
        max_len = max(6, topo.diameter_nominal + 4)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    nbr = jnp.asarray(paths_mod.neighbor_table(adj))
    adj_j = jnp.asarray(adj)

    t0 = time.perf_counter()
    if scheme == "pi_min":
        iu, ju = np.nonzero(np.triu(adj, 1))
        t_dev = time.perf_counter()
        la, nh, reach, dist = _pi_min_program(
            adj_j, nbr, jnp.asarray(iu), jnp.asarray(ju), key, n_layers,
            float(rho), max_len, eng)
    elif scheme == "ksp":
        t_dev = time.perf_counter()
        la, nh, reach, dist = _ksp_program(adj_j, nbr, key, n_layers,
                                           max_len, eng)
    else:
        layer_adjs: List[np.ndarray] = [adj.copy()]
        if scheme in ("rand", "undir"):
            for _ in range(n_layers - 1):
                layer_adjs.append(
                    _rand_layer(adj, rho, rng, oriented=(scheme == "rand")))
        elif scheme == "spain":
            for _ in range(n_layers - 1):
                root = int(rng.integers(n))
                layer_adjs.append(_bfs_tree(adj, root, rng))
        elif scheme == "past":
            for _ in range(n_layers - 1):
                layer_adjs.append(adj.copy())  # re-randomised tie-breaks
        else:
            raise ValueError(f"unknown scheme {scheme!r}")
        la = jnp.asarray(np.stack(layer_adjs))
        t_dev = time.perf_counter()
        nh, reach, dist = paths_mod._layer_tables_program(la, nbr, key,
                                                          max_len, eng)
    jax.block_until_ready(nh)
    t1 = time.perf_counter()

    reach_np = np.asarray(reach)
    pathlen = np.where(reach_np, np.asarray(dist), _UNREACH).astype(np.int16)
    nh_np = np.asarray(nh)
    compressed = None
    if rep == "compressed":
        compressed = paths_mod.CompressedTables.from_dense(nh_np)
    t2 = time.perf_counter()
    return LayeredRouting(
        topo=topo, scheme=scheme, rho=rho,
        nh=nh_np, reach=reach_np,
        pathlen=pathlen, layer_adj=np.asarray(la),
        build_stats={"total_s": t2 - t0, "device_s": t1 - t_dev,
                     "host_s": t_dev - t0, "compress_s": t2 - t1},
        compressed=compressed,
    )


def _bfs_tree(adj: np.ndarray, root: int, rng: np.random.Generator) -> np.ndarray:
    """Random-order BFS spanning tree (undirected layer)."""
    n = adj.shape[0]
    tree = np.zeros((n, n), dtype=bool)
    seen = np.zeros(n, dtype=bool)
    seen[root] = True
    frontier = [root]
    while frontier:
        nxt: List[int] = []
        order = rng.permutation(len(frontier))
        for fi in order:
            v = frontier[fi]
            nbrs = np.nonzero(adj[v] & ~seen)[0]
            rng.shuffle(nbrs)
            for u in nbrs:
                if not seen[u]:
                    seen[u] = True
                    tree[v, u] = tree[u, v] = True
                    nxt.append(int(u))
        frontier = nxt
    return tree


def _greedy_disjoint(paths: np.ndarray, reach_lt: np.ndarray, t: int) -> int:
    """Greedy edge-disjoint count over one (L, max_hops+1) path batch."""
    kept_edges = set()
    count = 0
    for i in range(paths.shape[0]):
        if not reach_lt[i]:
            continue
        path = paths[i]
        edges = set()
        ok = True
        reached = False
        prev = int(path[0])
        for v in path[1:]:
            v = int(v)
            if prev == t:
                reached = True
                break
            if v < 0:
                ok = False
                break
            e = (min(prev, v), max(prev, v))
            if e in kept_edges or e in edges:
                ok = False
                break
            edges.add(e)
            prev = v
        if prev == t:
            reached = True
        if ok and reached and edges:
            kept_edges |= edges
            count += 1
    return count


def layer_disjoint_paths_batch(lr: LayeredRouting, s: np.ndarray,
                               t: np.ndarray, max_hops: int = 16
                               ) -> np.ndarray:
    """:func:`layer_disjoint_paths` for many (s, t) pairs: ALL
    (pair, layer) table walks happen in one batched call; only the cheap
    greedy edge-disjointness filter stays per pair.  When the routing
    carries compressed tables the walk gathers off them directly — the
    per-hop working set is O(pairs * L), never a dense (L, N, N) slice,
    which is what keeps this usable at paper scale."""
    s = np.asarray(s, dtype=np.int32)
    t = np.asarray(t, dtype=np.int32)
    n_pairs = len(s)
    L = lr.n_layers
    li = np.tile(np.arange(L, dtype=np.int32), n_pairs)
    ss = np.repeat(s, L)
    tt = np.repeat(t, L)
    tables = lr.compressed if lr.compressed is not None else lr.nh
    walks = paths_mod.walk_paths_layers(tables, li, ss, tt, max_hops)
    walks = walks.reshape(n_pairs, L, max_hops + 1)
    out = np.zeros(n_pairs, dtype=np.int64)
    for p in range(n_pairs):
        out[p] = _greedy_disjoint(walks[p], lr.reach[:, s[p], t[p]],
                                  int(t[p]))
    return out


def layer_disjoint_paths(lr: LayeredRouting, s: int, t: int,
                         max_hops: int = 16) -> int:
    """How many pairwise edge-disjoint (s->t) paths do the layers realise?

    Greedy: walk each usable layer's path, keep it if it shares no
    (undirected) edge with already-kept paths.  This is the quantity behind
    the paper's "nine layers suffice for three disjoint paths" (Fig 12).
    """
    return int(layer_disjoint_paths_batch(lr, np.array([s]), np.array([t]),
                                          max_hops)[0])
