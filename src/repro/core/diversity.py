"""Path-diversity metrics (paper §4.2, Appendix B).

Three measures:

* **CDP** — count of disjoint paths at length ``l`` between router *sets*
  A, B: the smallest number of edge removals after which no path of length
  <= l connects A to B (§4.2.1).  Exact length-bounded min-cut is NP-hard in
  general; like the paper we compute it with a Ford–Fulkerson-style greedy:
  repeatedly find a shortest path (BFS) of length <= l and remove its edges.
  The count of peeled paths lower-bounds the cut; for the unbounded case it
  is cross-checked against true edge connectivity in tests.

* **Cheung et al. finite-field rank method** (Appendix B.3) — all-pairs
  length-limited edge connectivity via linear propagation over GF(p):
  ``c_st = rank(P_s (sum_{i<l} K^i) Q_t)``.  The E x E modular matmul is the
  computational hot spot; on TPU it maps to ``repro.kernels.gfmm``.  Here it
  runs as float64 BLAS with p^2 * E < 2^53 so products stay exact.

* **PI** — path interference ``I^l_{ac,bd} = c_l(a,b) + c_l(c,d)
  - c_l({a,c},{b,d})`` (§4.2.2), and **TNL** ``k' N_r / l_avg`` (§4.2.3).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import paths as paths_mod
from .topology import Topology

__all__ = [
    "cdp_peel",
    "cdp_pairs_sampled",
    "path_interference",
    "pi_samples",
    "total_network_load",
    "GFConnectivity",
    "DiversityReport",
    "diversity_report",
]

# Prime with E * p^2 < 2^53 for E <= 4096 (float64-exact modular matmul).
GF_PRIME = 1_048_573


# -----------------------------------------------------------------------------
# Greedy length-limited edge-disjoint path peeling (Ford–Fulkerson variant).
# -----------------------------------------------------------------------------
def _bfs_path(nbr: List[np.ndarray], alive: np.ndarray, src: Sequence[int],
              dst_mask: np.ndarray, max_len: int) -> Optional[List[int]]:
    """Shortest path (<= max_len edges) from any vertex in ``src`` to the dst
    set using only edges with ``alive[eid]``; returns vertex list or None.

    nbr[v] is an (deg, 2) array of (neighbor, edge_id) rows.
    """
    n = len(nbr)
    parent = np.full(n, -2, dtype=np.int64)  # -2 unvisited, -1 root
    parent_edge = np.full(n, -1, dtype=np.int64)
    depth = np.zeros(n, dtype=np.int64)
    dq = deque()
    for s in src:
        if parent[s] == -2:
            parent[s] = -1
            dq.append(s)
            if dst_mask[s]:
                return [int(s)]
    while dq:
        v = dq.popleft()
        if depth[v] >= max_len:
            continue
        for u, eid in nbr[v]:
            if parent[u] != -2 or not alive[eid]:
                continue
            parent[u] = v
            parent_edge[u] = eid
            depth[u] = depth[v] + 1
            if dst_mask[u]:
                out = [int(u)]
                w = u
                while parent[w] != -1:
                    w = parent[w]
                    out.append(int(w))
                return out[::-1]
            dq.append(u)
    return None


def _neighbor_lists(adj: np.ndarray) -> Tuple[List[np.ndarray], int]:
    """Undirected edge ids; each undirected edge has one id used by both dirs."""
    iu, ju = np.nonzero(np.triu(adj, 1))
    n_edges = len(iu)
    n = adj.shape[0]
    lists: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
    for eid, (u, v) in enumerate(zip(iu, ju)):
        lists[u].append((v, eid))
        lists[v].append((u, eid))
    nbr = [np.array(l, dtype=np.int64).reshape(-1, 2) for l in lists]
    return nbr, n_edges


def cdp_peel(adj: np.ndarray, A: Iterable[int], B: Iterable[int], l: int,
             return_paths: bool = False):
    """Greedy count of edge-disjoint paths of length <= l from set A to set B.

    Peels shortest paths first (the paper's pruning heuristic); each peeled
    path removes its (undirected) edges.  Edges internal to A or B still
    count as capacity, matching the h^l(A) ∩ B = ∅ condition.
    """
    A = list(dict.fromkeys(int(a) for a in A))
    B = set(int(b) for b in B)
    if set(A) & B:
        raise ValueError("A and B must be disjoint")
    nbr, n_edges = _neighbor_lists(adj)
    alive = np.ones(n_edges, dtype=bool)
    dst_mask = np.zeros(adj.shape[0], dtype=bool)
    for b in B:
        dst_mask[b] = True
    found: List[List[int]] = []
    while True:
        p = _bfs_path(nbr, alive, A, dst_mask, l)
        if p is None:
            break
        # remove path edges
        for u, v in zip(p[:-1], p[1:]):
            for w, eid in nbr[u]:
                if w == v:
                    alive[eid] = False
                    break
        found.append(p)
    if return_paths:
        return len(found), found
    return len(found)


def cdp_pairs_sampled(topo: Topology, l: int, n_samples: int = 200,
                      seed: int = 0) -> np.ndarray:
    """CDP for uniformly sampled router pairs; radix-invariant use is
    ``result / k'`` (paper Table 4 reports CDP as a fraction of k')."""
    rng = np.random.default_rng(seed)
    n = topo.n_routers
    out = np.zeros(n_samples, dtype=np.int64)
    for i in range(n_samples):
        s, t = rng.choice(n, size=2, replace=False)
        out[i] = cdp_peel(topo.adj, [s], [t], l)
    return out


# -----------------------------------------------------------------------------
# Path interference (§4.2.2).
# -----------------------------------------------------------------------------
def path_interference(adj: np.ndarray, a: int, b: int, c: int, d: int,
                      l: int) -> int:
    """I^l_{ac,bd} = c_l(a,b) + c_l(c,d) - c_l({a,c},{b,d})."""
    cab = cdp_peel(adj, [a], [b], l)
    ccd = cdp_peel(adj, [c], [d], l)
    cboth = cdp_peel(adj, [a, c], [b, d], l)
    return int(cab + ccd - cboth)


def pi_samples(topo: Topology, l: int, n_samples: int = 100,
               seed: int = 0) -> np.ndarray:
    """Sample PI for random disjoint 4-tuples (a,b),(c,d)."""
    rng = np.random.default_rng(seed)
    n = topo.n_routers
    out = np.zeros(n_samples, dtype=np.int64)
    for i in range(n_samples):
        a, b, c, d = rng.choice(n, size=4, replace=False)
        out[i] = path_interference(topo.adj, a, b, c, d, l)
    return out


def total_network_load(topo: Topology, l_avg: Optional[float] = None) -> float:
    """TNL = k' N_r / l — max flows sustainable without congestion (§4.2.3)."""
    if l_avg is None:
        l_avg = paths_mod.average_path_length(topo.adj)
    kprime = topo.adj.sum() / topo.n_routers
    return float(kprime * topo.n_routers / max(l_avg, 1e-9))


# -----------------------------------------------------------------------------
# Cheung-style GF(p) rank method (Appendix B.3).
# -----------------------------------------------------------------------------
@dataclasses.dataclass
class GFConnectivity:
    """Precomputed length-limited edge-connectivity oracle.

    Builds M_l = sum_{i=0}^{l-1} K^i over GF(p) where K is the E_dir x E_dir
    edge-incidence propagation matrix with random coefficients; then
    ``query(s, t)`` returns rank(P_s M_l Q_t) over GF(p), which sandwiches
    the count of edge-disjoint length-<=l paths (see module docstring).
    """

    edges: np.ndarray          # (E_dir, 2) directed edges
    M: np.ndarray              # (E_dir, E_dir) float64 (values in [0, p))
    out_edges: List[np.ndarray]
    in_edges: List[np.ndarray]
    p: int
    max_len: int

    @staticmethod
    def build(adj: np.ndarray, max_len: int, p: int = GF_PRIME,
              seed: int = 0) -> "GFConnectivity":
        adj = np.asarray(adj, dtype=bool)
        n = adj.shape[0]
        u, v = np.nonzero(adj)
        edges = np.stack([u, v], axis=1).astype(np.int64)
        e = len(edges)
        if e > 4096:
            raise ValueError(
                f"E_dir={e} too large for float64-exact GF({p}) matmul; "
                "use sampled cdp_peel instead")
        rng = np.random.default_rng(seed)
        # K[(i,k),(k,j)] = random coefficient (edge-chain propagation).
        head = edges[:, 1]
        tail = edges[:, 0]
        K = np.zeros((e, e), dtype=np.float64)
        # connect edge a -> edge b when head(a) == tail(b); forbid immediate
        # u->v->u backtracking to keep walks closer to paths (heuristic that
        # does not change the rank bound: removing walks can only lower rank,
        # and disjoint simple paths never backtrack).
        match = head[:, None] == tail[None, :]
        back = (edges[:, 0][:, None] == edges[:, 1][None, :]) & match
        match &= ~back
        K[match] = rng.integers(1, p, size=int(match.sum())).astype(np.float64)
        # M = sum_{i=0}^{l-1} K^i computed as Horner: M_1 = I;
        # M_{j+1} = M_j K + I  ->  after l-1 steps M = sum_{i<l} K^i.
        M = np.eye(e, dtype=np.float64)
        for _ in range(max_len - 1):
            M = (M @ K) % p
            M[np.arange(e), np.arange(e)] = (M[np.arange(e), np.arange(e)] + 1) % p
        out_edges = [np.nonzero(tail == s)[0] for s in range(n)]
        in_edges = [np.nonzero(head == t)[0] for t in range(n)]
        return GFConnectivity(edges, M, out_edges, in_edges, p, max_len)

    def query(self, s: int, t: int) -> int:
        sub = self.M[np.ix_(self.out_edges[s], self.in_edges[t])]
        return _rank_gf(sub % self.p, self.p)

    def query_pairs(self, pairs: Sequence[Tuple[int, int]]) -> np.ndarray:
        return np.array([self.query(s, t) for s, t in pairs], dtype=np.int64)


def _rank_gf(m: np.ndarray, p: int) -> int:
    """Rank of a small matrix over GF(p) by Gaussian elimination (float64
    storage, exact because all values < p and p^2 * ncols < 2^53)."""
    m = m.astype(np.int64) % p
    rows, cols = m.shape
    rank = 0
    r = 0
    for c in range(cols):
        piv = None
        for rr in range(r, rows):
            if m[rr, c] % p != 0:
                piv = rr
                break
        if piv is None:
            continue
        m[[r, piv]] = m[[piv, r]]
        inv = pow(int(m[r, c]), p - 2, p)
        m[r] = (m[r] * inv) % p
        for rr in range(rows):
            if rr != r and m[rr, c] != 0:
                m[rr] = (m[rr] - m[rr, c] * m[r]) % p
        r += 1
        rank += 1
        if r == rows:
            break
    return rank


# -----------------------------------------------------------------------------
# Aggregate report (Table 4 analogue).
# -----------------------------------------------------------------------------
@dataclasses.dataclass
class DiversityReport:
    name: str
    diameter: int
    avg_path_len: float
    kprime: int
    n_routers: int
    n_endpoints: int
    frac_single_minimal: float   # fraction of pairs with exactly 1 shortest path
    cdp_mean_frac: float         # mean CDP / k' at d'
    cdp_tail_frac: float         # 1% tail CDP / k'
    pi_mean_frac: float          # mean PI / k'
    pi_tail_frac: float          # 99.9% (here 99%) tail PI / k'
    d_prime: int
    tnl: float


def diversity_report(topo: Topology, n_cdp: int = 150, n_pi: int = 80,
                     seed: int = 0, d_prime: Optional[int] = None) -> DiversityReport:
    """Compute the Table-4 row for a topology.

    d' is chosen (as in the paper) as the smallest length for which the
    sampled CDP tail reaches >= 3 disjoint paths.
    """
    dist, counts = paths_mod.min_path_stats(topo.adj)
    n = topo.n_routers
    off = ~np.eye(n, dtype=bool)
    reachable = dist[off] < 10_000
    single = (counts[off] == 1) & reachable
    frac_single = float(single.sum()) / max(1, reachable.sum())
    diam = int(dist[off][reachable].max())
    apl = float(dist[off][reachable].mean())
    kprime = topo.network_radix

    if d_prime is None:
        d_prime = diam
        for cand in range(diam, diam + 4):
            vals = cdp_pairs_sampled(topo, cand, n_samples=min(60, n_cdp), seed=seed)
            if np.quantile(vals, 0.001) >= 3 or vals.min() >= 3:
                d_prime = cand
                break
            d_prime = cand

    cdp = cdp_pairs_sampled(topo, d_prime, n_samples=n_cdp, seed=seed)
    pi = pi_samples(topo, d_prime, n_samples=n_pi, seed=seed + 1)
    return DiversityReport(
        name=topo.name,
        diameter=diam,
        avg_path_len=apl,
        kprime=kprime,
        n_routers=n,
        n_endpoints=topo.n_endpoints,
        frac_single_minimal=frac_single,
        cdp_mean_frac=float(cdp.mean()) / kprime,
        cdp_tail_frac=float(np.quantile(cdp, 0.01)) / kprime,
        pi_mean_frac=float(pi.mean()) / kprime,
        pi_tail_frac=float(np.quantile(pi, 0.99)) / kprime,
        d_prime=d_prime,
        tnl=total_network_load(topo, apl),
    )
