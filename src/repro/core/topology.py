"""Topology generators for the networks studied in the FatPaths paper.

Every generator returns a :class:`Topology` holding a symmetric boolean
adjacency matrix over routers, the per-router endpoint concentration, and
bookkeeping (name, structural parameters, nominal diameter).

Implemented (paper §2.2 / Appendix A):
  * Slim Fly (MMS construction, diameter 2), prime ``q`` only — all paper
    instances reproduced here use prime q (19, 29); see DESIGN.md §7.
  * Dragonfly ("balanced", a = 2p = 2h, g = a·h + 1), diameter 3.
  * Jellyfish (random regular graph), flexible.
  * Xpander (single ℓ-lift of a complete graph), semi-flexible.
  * HyperX / Hamming graph (regular, L ∈ {2, 3}); L=2 is a Flattened
    Butterfly.
  * Three-stage fat tree (Clos, D = 4) with k/2 endpoints per edge router.
  * Complete graph (clique) and star (single crossbar) baselines.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

__all__ = [
    "Topology",
    "slim_fly",
    "dragonfly",
    "jellyfish",
    "xpander",
    "hyperx",
    "fat_tree",
    "two_layer_fat_tree",
    "cost_matched_ft2",
    "clique",
    "star",
    "equivalent_jellyfish",
    "by_name",
    "TOPOLOGY_FAMILIES",
]


@dataclasses.dataclass(frozen=True)
class Topology:
    """An interconnection network: routers + full-duplex inter-router links.

    Attributes:
      name: human-readable identifier, e.g. ``"SF(q=19)"``.
      family: short family tag (``sf``, ``df``, ``jf``, ``xp``, ``hx``,
        ``ft``, ``clique``, ``star``).
      adj: (N_r, N_r) symmetric bool adjacency, zero diagonal.
      concentration: (N_r,) int endpoints attached to each router.
      diameter_nominal: the topology's designed diameter (paper Table 5);
        the *measured* diameter is available via ``repro.core.paths``.
      params: structural input parameters.
    """

    name: str
    family: str
    adj: np.ndarray
    concentration: np.ndarray
    diameter_nominal: int
    params: Dict[str, int]

    # ---- derived quantities -------------------------------------------------
    @property
    def n_routers(self) -> int:
        return int(self.adj.shape[0])

    @property
    def n_endpoints(self) -> int:
        return int(self.concentration.sum())

    @property
    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1).astype(np.int64)

    @property
    def network_radix(self) -> int:
        """k' — max channels from a router to other routers."""
        return int(self.degrees.max())

    @property
    def router_radix(self) -> int:
        """k = k' + p (max over routers)."""
        return int((self.degrees + self.concentration).max())

    @property
    def n_links(self) -> int:
        """Number of undirected inter-router cables."""
        return int(self.adj.sum()) // 2

    @property
    def n_cables(self) -> int:
        """All cables including endpoint links (paper Fig 10 accounting)."""
        return self.n_links + self.n_endpoints

    @property
    def edge_density(self) -> float:
        """(#cables)/(#endpoints), the paper's cost proxy (Fig 10)."""
        return self.n_cables / max(1, self.n_endpoints)

    # ---- edge indexing helpers ---------------------------------------------
    def directed_edges(self) -> np.ndarray:
        """(E_dir, 2) int32 array of directed edges (u, v), lexicographic."""
        u, v = np.nonzero(self.adj)
        return np.stack([u, v], axis=1).astype(np.int32)

    def edge_index_matrix(self) -> np.ndarray:
        """(N_r, N_r) int32: directed edge id for (u, v), -1 if no edge."""
        e = self.directed_edges()
        m = np.full((self.n_routers, self.n_routers), -1, dtype=np.int32)
        m[e[:, 0], e[:, 1]] = np.arange(len(e), dtype=np.int32)
        return m

    def validate(self) -> None:
        a = self.adj
        assert a.ndim == 2 and a.shape[0] == a.shape[1], "square"
        assert a.dtype == np.bool_, "bool adjacency"
        assert not a.diagonal().any(), "no self loops"
        assert (a == a.T).all(), "undirected"
        assert (self.concentration >= 0).all()


def _finish(name, family, adj, conc, d, params) -> Topology:
    adj = np.asarray(adj, dtype=np.bool_)
    np.fill_diagonal(adj, False)
    adj = adj | adj.T
    conc = np.asarray(conc, dtype=np.int64)
    t = Topology(name, family, adj, conc, d, dict(params))
    t.validate()
    return t


# -----------------------------------------------------------------------------
# Slim Fly (MMS graphs) — Besta & Hoefler SC'14, diameter 2.
# -----------------------------------------------------------------------------
def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    for f in range(2, int(math.isqrt(n)) + 1):
        if n % f == 0:
            return False
    return True


def _primitive_root(q: int) -> int:
    """Smallest primitive root modulo prime q."""
    phi = q - 1
    factors = set()
    m = phi
    f = 2
    while f * f <= m:
        while m % f == 0:
            factors.add(f)
            m //= f
        f += 1
    if m > 1:
        factors.add(m)
    for g in range(2, q):
        if all(pow(g, phi // p, q) != 1 for p in factors):
            return g
    raise ValueError(f"no primitive root for {q}")


def slim_fly(q: int, concentration: Optional[int] = None) -> Topology:
    """MMS Slim Fly over GF(q), prime q with q = 4w + delta, delta in {-1,0,1}.

    Routers: two classes of q^2 each — (0, x, y) and (1, m, c) with
    x, y, m, c in GF(q).  Edges:
      (0,x,y) ~ (0,x,y')  iff  y - y' in X   (quadratic-residue-like set)
      (1,m,c) ~ (1,m,c')  iff  c - c' in X'
      (0,x,y) ~ (1,m,c)   iff  y = m*x + c
    Network radix k' = (3q - delta) / 2.  Default p = ceil(k'/2).
    """
    if not _is_prime(q):
        raise ValueError(f"slim_fly requires prime q, got {q}")
    delta = 1 if q % 4 == 1 else -1  # prime q > 2 is odd: q = 4w ± 1
    xi = _primitive_root(q)
    # Generator sets, verified to yield (3q-delta)/2-regular diameter-2 MMS
    # graphs for all primes 5..43 (see tests/test_topology.py):
    #   q = 4w+1:  X  = even powers of xi (the quadratic residues),
    #   q = 4w-1:  X  = {+-xi^(2i) : 0 <= i < w}   (w symmetric pairs),
    #   both:      X' = xi * X.
    if delta == 1:
        X = sorted({pow(xi, 2 * i, q) for i in range((q - 1) // 2)})
    else:
        w = (q + 1) // 4
        base = {pow(xi, 2 * i, q) for i in range(w)}
        X = sorted(base | {(q - b) % q for b in base})
    Xp = sorted({(xi * b) % q for b in X})
    X = np.array(X, dtype=np.int64)
    Xp = np.array(Xp, dtype=np.int64)

    nr = 2 * q * q
    adj = np.zeros((nr, nr), dtype=np.bool_)

    rng_q = np.arange(q)
    # Intra-"column" edges: y - y' in X (class 0), c - c' in X' (class 1).
    diff = (rng_q[:, None] - rng_q[None, :]) % q
    in_X = np.isin(diff, X)
    in_Xp = np.isin(diff, Xp)
    for x in range(q):
        b0 = x * q
        adj[b0 : b0 + q, b0 : b0 + q] |= in_X
        b1 = q * q + x * q
        adj[b1 : b1 + q, b1 : b1 + q] |= in_Xp
    # Bipartite edges: (0, x, y) ~ (1, m, c) iff y = m*x + c (vectorised).
    xg, mg, cg = np.meshgrid(rng_q, rng_q, rng_q, indexing="ij")
    yg = (mg * xg + cg) % q
    rows = (xg * q + yg).ravel()
    cols = (q * q + mg * q + cg).ravel()
    adj[rows, cols] = True

    kprime = (3 * q - delta) // 2
    p = concentration if concentration is not None else (kprime + 1) // 2
    conc = np.full(nr, p, dtype=np.int64)
    return _finish(
        f"SF(q={q})", "sf", adj, conc, 2, {"q": q, "kprime": kprime, "p": p}
    )


# -----------------------------------------------------------------------------
# Dragonfly, "balanced": a = 2p = 2h, g = a*h + 1.
# -----------------------------------------------------------------------------
def dragonfly(p: int) -> Topology:
    """Balanced maximum-capacity Dragonfly parameterised by concentration p.

    a = 2p routers per group, h = p global links per router,
    g = a*h + 1 groups, one global link between every group pair.
    k' = (a - 1) + h = 3p - 1, diameter 3.
    """
    a, h = 2 * p, p
    g = a * h + 1
    nr = a * g
    adj = np.zeros((nr, nr), dtype=np.bool_)

    # Intra-group complete graphs.
    for gi in range(g):
        s = gi * a
        adj[s : s + a, s : s + a] = True
    # Global links: group gi's global port j (j in [0, a*h)) connects to group
    # ((gi + j + 1) mod g); the router is j // h, its h-slot is j % h.
    # The standard "consecutive" arrangement pairs port j of group gi with
    # the matching port of the peer group.
    for gi in range(g):
        for j in range(a * h):
            gj = (gi + j + 1) % g
            if gj == gi:
                continue
            # Peer group's port index pointing back to gi:
            jj = (gi - gj - 1) % g
            ri = gi * a + j // h
            rj = gj * a + jj // h
            adj[ri, rj] = True
            adj[rj, ri] = True

    conc = np.full(nr, p, dtype=np.int64)
    return _finish(
        f"DF(p={p})", "df", adj, conc, 3,
        {"p": p, "a": a, "h": h, "g": g, "kprime": 3 * p - 1},
    )


# -----------------------------------------------------------------------------
# Jellyfish: random regular graph.
# -----------------------------------------------------------------------------
def jellyfish(n_routers: int, kprime: int, concentration: int, seed: int = 0) -> Topology:
    """Random k'-regular graph (pairing model with retries)."""
    if n_routers * kprime % 2 != 0:
        raise ValueError("n_routers * kprime must be even")
    rng = np.random.default_rng(seed)
    for attempt in range(200):
        stubs = np.repeat(np.arange(n_routers), kprime)
        rng.shuffle(stubs)
        u, v = stubs[0::2], stubs[1::2]
        ok = u != v
        adj = np.zeros((n_routers, n_routers), dtype=np.bool_)
        # reject multi-edges by checking before set
        dup = adj[u[ok], v[ok]]
        if (~ok).sum() == 0:
            adj[u, v] = True
            adj[v, u] = True
            if (adj.sum(axis=1) == kprime).all() and not dup.any():
                # also require connectivity
                if _connected(adj):
                    conc = np.full(n_routers, concentration, dtype=np.int64)
                    return _finish(
                        f"JF(Nr={n_routers},k'={kprime})", "jf", adj, conc, 3,
                        {"kprime": kprime, "p": concentration, "seed": seed + attempt},
                    )
        seed += 1
        rng = np.random.default_rng(seed * 7919 + attempt)
    # Fall back to networkx's configuration-model-free generator.
    import networkx as nx

    g = nx.random_regular_graph(kprime, n_routers, seed=seed)
    adj = nx.to_numpy_array(g, dtype=bool)
    conc = np.full(n_routers, concentration, dtype=np.int64)
    return _finish(
        f"JF(Nr={n_routers},k'={kprime})", "jf", adj, conc, 3,
        {"kprime": kprime, "p": concentration, "seed": seed},
    )


def _connected(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    frontier = np.zeros(n, dtype=bool)
    frontier[0] = True
    seen[0] = True
    while frontier.any():
        nxt = adj[frontier].any(axis=0) & ~seen
        seen |= nxt
        frontier = nxt
    return bool(seen.all())


# -----------------------------------------------------------------------------
# Xpander: single ℓ-lift of K_{k'+1}.
# -----------------------------------------------------------------------------
def xpander(kprime: int, lift: Optional[int] = None, concentration: Optional[int] = None,
            seed: int = 0) -> Topology:
    """ℓ-lift of the complete graph K_{k'+1} (paper A.4, ℓ = k' default).

    N_r = ℓ (k'+1); each base edge (s, t) of K_{k'+1} is replaced by a random
    perfect matching between the ℓ copies of s and the ℓ copies of t.
    """
    l = lift if lift is not None else kprime
    base_n = kprime + 1
    nr = l * base_n
    rng = np.random.default_rng(seed)
    adj = np.zeros((nr, nr), dtype=np.bool_)
    for s in range(base_n):
        for t in range(s + 1, base_n):
            pi = rng.permutation(l)
            si = s * l + np.arange(l)
            ti = t * l + pi
            adj[si, ti] = True
            adj[ti, si] = True
    p = concentration if concentration is not None else (kprime + 1) // 2
    conc = np.full(nr, p, dtype=np.int64)
    return _finish(
        f"XP(k'={kprime},l={l})", "xp", adj, conc, 3,
        {"kprime": kprime, "lift": l, "p": p, "seed": seed},
    )


# -----------------------------------------------------------------------------
# HyperX / Hamming graph: S^L vertices, clique along each dimension.
# -----------------------------------------------------------------------------
def hyperx(L: int, S: int, concentration: Optional[int] = None) -> Topology:
    """Regular HyperX (L, S, K=1). L=2 = Flattened Butterfly. k' = L(S-1)."""
    nr = S ** L
    idx = np.arange(nr)
    coords = np.stack([(idx // (S ** d)) % S for d in range(L)], axis=1)
    adj = np.zeros((nr, nr), dtype=np.bool_)
    # Vertices differing in exactly one coordinate are adjacent.
    diff = (coords[:, None, :] != coords[None, :, :]).sum(axis=2)
    adj = diff == 1
    kprime = L * (S - 1)
    p = concentration if concentration is not None else max(1, int(round(kprime / L)))
    conc = np.full(nr, p, dtype=np.int64)
    return _finish(
        f"HX(L={L},S={S})", "hx", adj, conc, L,
        {"L": L, "S": S, "kprime": kprime, "p": p},
    )


# -----------------------------------------------------------------------------
# Three-stage fat tree (Clos), D = 4 router hops between distant endpoints.
# -----------------------------------------------------------------------------
def fat_tree(k: int, oversubscription: int = 1) -> Topology:
    """Three-layer fat tree from radix-k routers (paper A.6).

    k pods; per pod k/2 edge + k/2 aggregation routers; (k/2)^2 core routers.
    Only edge routers host endpoints: p = (k/2) * oversubscription.
    ``oversubscription=2`` gives the paper's cost-matched 2x fat tree.
    """
    if k % 2 != 0:
        raise ValueError("fat_tree requires even k")
    half = k // 2
    n_edge = k * half
    n_agg = k * half
    n_core = half * half
    nr = n_edge + n_agg + n_core

    def edge_id(pod, i):
        return pod * half + i

    def agg_id(pod, i):
        return n_edge + pod * half + i

    def core_id(i, j):
        return n_edge + n_agg + i * half + j

    adj = np.zeros((nr, nr), dtype=np.bool_)
    for pod in range(k):
        for e in range(half):
            for a in range(half):
                adj[edge_id(pod, e), agg_id(pod, a)] = True
    # Aggregation router (pod, a) connects to core routers (a, j) for all j.
    for pod in range(k):
        for a in range(half):
            for j in range(half):
                adj[agg_id(pod, a), core_id(a, j)] = True
    adj |= adj.T

    conc = np.zeros(nr, dtype=np.int64)
    conc[:n_edge] = half * oversubscription
    return _finish(
        f"FT3(k={k}{',2x' if oversubscription == 2 else ''})", "ft", adj, conc, 4,
        {"k": k, "oversub": oversubscription, "p": half * oversubscription},
    )


def two_layer_fat_tree(leaves: int, spines: int,
                       concentration: int) -> Topology:
    """Two-layer (leaf-spine) fat tree, the arXiv:1301.6179 construction.

    Every leaf connects to every spine (one cable each); endpoints attach
    only to leaves.  Diameter 2, full bisection when ``spines >=
    concentration``.  Cables per endpoint is ``1 + spines/concentration``,
    which is what :func:`cost_matched_ft2` tunes to equalise link cost
    against a target low-diameter topology.  Spines are modelled as
    logical crossbars (a physical build would decompose a radix-``leaves``
    spine into a sub-tree; that is invisible at the routing level).
    """
    if leaves < 1 or spines < 1 or concentration < 1:
        raise ValueError("two_layer_fat_tree needs positive L, S, p")
    nr = leaves + spines
    adj = np.zeros((nr, nr), dtype=np.bool_)
    adj[:leaves, leaves:] = True
    adj |= adj.T
    conc = np.zeros(nr, dtype=np.int64)
    conc[:leaves] = concentration
    return _finish(
        f"FT2(L={leaves},S={spines},p={concentration})", "ft2", adj, conc, 2,
        {"leaves": leaves, "spines": spines, "p": concentration},
    )


def cost_matched_ft2(target: Topology) -> Topology:
    """The two-layer fat tree whose endpoint count and cables-per-endpoint
    (``edge_density``) match ``target``'s — the paper's cost-equalised
    baseline pairing (§2.2.3 methodology applied to the 1301.6179 FT2).

    Per-leaf concentration is set to the target's network radix, spines
    to ``round(p * (density - 1))`` (density = 1 + S/p for an FT2), and
    the leaf count to whatever reproduces the endpoint total.
    """
    p = max(1, target.network_radix)
    spines = max(1, int(round(p * (target.edge_density - 1.0))))
    leaves = max(2, int(round(target.n_endpoints / p)))
    ft2 = two_layer_fat_tree(leaves, spines, p)
    return dataclasses.replace(ft2, name=f"{target.name}-FT2")


# -----------------------------------------------------------------------------
# Corner cases: clique and star.
# -----------------------------------------------------------------------------
def clique(kprime: int, concentration: Optional[int] = None) -> Topology:
    nr = kprime + 1
    adj = ~np.eye(nr, dtype=np.bool_)
    p = concentration if concentration is not None else kprime
    conc = np.full(nr, p, dtype=np.int64)
    return _finish(f"K{nr}", "clique", adj, conc, 1, {"kprime": kprime, "p": p})


def star(n_endpoints: int) -> Topology:
    """Single crossbar with all endpoints attached (TCP validation baseline)."""
    adj = np.zeros((1, 1), dtype=np.bool_)
    conc = np.array([n_endpoints], dtype=np.int64)
    return _finish(f"Star({n_endpoints})", "star", adj, conc, 0,
                   {"p": n_endpoints})


# -----------------------------------------------------------------------------
# Equivalent Jellyfish + registry.
# -----------------------------------------------------------------------------
def equivalent_jellyfish(topo: Topology, seed: int = 0) -> Topology:
    """The X-JF with identical N_r, k', p (paper §2.2.3)."""
    kprime = int(round(topo.adj.sum() / topo.n_routers))
    p = int(round(topo.n_endpoints / topo.n_routers))
    if topo.n_routers * kprime % 2 != 0:
        kprime -= 1
    jf = jellyfish(topo.n_routers, kprime, p, seed=seed)
    return dataclasses.replace(jf, name=f"{topo.name}-JF")


TOPOLOGY_FAMILIES = {
    "sf": slim_fly,
    "df": dragonfly,
    "jf": jellyfish,
    "xp": xpander,
    "hx": hyperx,
    "ft": fat_tree,
    "ft2": two_layer_fat_tree,
    "clique": clique,
    "star": star,
}


def by_name(spec: str, **kw) -> Topology:
    """Build a topology from a compact spec like ``sf:19``, ``df:6``,
    ``hx:2x16``, ``ft:8``, ``ft2:861x42x43``, ``jf:128x12x6``, ``xp:16``."""
    fam, _, arg = spec.partition(":")
    if fam == "sf":
        return slim_fly(int(arg), **kw)
    if fam == "df":
        return dragonfly(int(arg), **kw)
    if fam == "hx":
        L, S = arg.split("x")
        return hyperx(int(L), int(S), **kw)
    if fam == "ft":
        return fat_tree(int(arg), **kw)
    if fam == "ft2":
        L, S, p = (int(x) for x in arg.split("x"))
        return two_layer_fat_tree(L, S, p, **kw)
    if fam == "jf":
        nr, kp, p = (int(x) for x in arg.split("x"))
        return jellyfish(nr, kp, p, **kw)
    if fam == "xp":
        return xpander(int(arg), **kw)
    if fam == "clique":
        return clique(int(arg), **kw)
    if fam == "star":
        return star(int(arg), **kw)
    raise ValueError(f"unknown topology spec {spec!r}")
